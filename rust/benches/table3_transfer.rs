//! Table 3: feature-matrix transfer time vs (client executors × server
//! workers).
//!
//! Paper: 2,251,569×10,000 f64 over Cray Aries; transfer fastest when
//! executor and worker counts match, slowest with 2 executors. Here the
//! matrix scales to rows×1024 f64 over localhost TCP, sweeping executors
//! {1,2,4,8} × workers {2,3,4}; the diagonal-minimum shape is the target.
//! Reported numbers are the mean of `--runs` (default 3) like the paper.

mod bench_common;

use alchemist::cli::Args;
use alchemist::client::AlchemistContext;
use alchemist::coordinator::AlchemistServer;
use alchemist::metrics::{Stats, Table};
use alchemist::sparklite::IndexedRowMatrix;
use alchemist::util::fmt;
use alchemist::workloads::TimitSpec;
use bench_common::{bench_config, is_quick};

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let mut cfg = bench_config(&args)?;
    // transfer only; engine never runs
    cfg.apply("engine", "native")?;
    let quick = is_quick(&args);
    let rows = args.get_usize("rows", if quick { 4096 } else { 16_384 })?;
    let cols = args.get_usize("cols", 1024)?;
    let default_execs: &[usize] = if quick { &[2, 4] } else { &[1, 2, 4, 8] };
    let default_workers: &[usize] = if quick { &[2] } else { &[2, 3, 4] };
    let executors_list = args.get_usize_list("executors", default_execs)?;
    let workers_list = args.get_usize_list("workers", default_workers)?;
    let runs = args.get_usize("runs", 3)?;

    // dense feature matrix (contents irrelevant to transfer cost; use the
    // TIMIT generator so data creation time is also reportable, like the
    // paper's "data set creation times" column)
    let t0 = std::time::Instant::now();
    let spec = TimitSpec {
        train_rows: rows,
        test_rows: 1,
        raw_features: cols,
        classes: 2,
        ..TimitSpec::default()
    };
    let data = spec.generate();
    let creation_secs = t0.elapsed().as_secs_f64();
    println!(
        "data set: {rows} x {cols} f64 ({}), created in {creation_secs:.2}s",
        fmt::bytes((rows * cols * 8) as u64)
    );

    let mut table = Table::new(
        "Table 3 (scaled): feature-matrix transfer times (s), mean of runs",
        &["executors \\ workers", "w=2", "w=3", "w=4"],
    );

    for &execs in &executors_list {
        let mut cells = vec![format!("{execs}")];
        for &workers in &[2usize, 3, 4] {
            if !workers_list.contains(&workers) {
                cells.push("-".into());
                continue;
            }
            let server = AlchemistServer::start(cfg.clone(), workers)?;
            let mut stats = Stats::new();
            let mut gbps = Stats::new();
            for run in 0..runs {
                let mut ac =
                    AlchemistContext::connect(&server.control_addr, &cfg, execs)?;
                let irm = IndexedRowMatrix::from_local(&data.x_train, execs.max(workers) * 2);
                let (al, s) = ac.send_matrix(&format!("X{run}"), &irm)?;
                stats.push(s.secs);
                gbps.push(s.throughput_gbps());
                ac.free(&al)?;
                ac.stop();
            }
            cells.push(format!("{:.3} ({:.2} GB/s)", stats.mean(), gbps.mean()));
            server.shutdown();
        }
        table.row(&cells);
    }

    table.print();
    println!(
        "paper shape: more executors help until they exceed workers; minimum near \
         executors == workers"
    );
    Ok(())
}
