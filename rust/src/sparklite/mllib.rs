//! MLlib-analog baselines: Spark-style CG and truncated SVD.
//!
//! Same mathematics as `crate::linalg` (so accuracy comparisons are
//! apples-to-apples) but executed the way Spark MLlib executes them:
//!
//! * each Gram-operator application is a BSP stage over the row RDD plus a
//!   driver-side aggregation (two overhead charges per iteration);
//! * per-partition compute is *row-oriented and unblocked* — rows are
//!   separate `Vec<f64>`s, exactly like `IndexedRowMatrix`, so there is no
//!   cache blocking (this is the honest part of the Spark penalty, on top
//!   of the modeled scheduler/task overheads);
//! * all small state lives on the driver.

use crate::distmat::LocalMatrix;
use crate::linalg::cg::CgOptions;
use crate::linalg::lanczos::SvdOptions;
use crate::linalg::rff::RffMap;
use crate::util::prng::Rng;

use super::matrix::{IndexedRow, IndexedRowMatrix};
use super::rdd::Rdd;
use super::scheduler::SparkEngine;

/// Per-partition Gram partial: Σ_i xᵢ ⊗ (xᵢ·V), row-at-a-time.
fn gram_partial(rows: &[IndexedRow], v: &LocalMatrix) -> LocalMatrix {
    let d = v.rows();
    let c = v.cols();
    let mut out = LocalMatrix::zeros(d, c);
    let mut xv = vec![0.0; c];
    for row in rows {
        let x = &row.vector;
        // xv = xᵀ·V  (c-wide accumulators, row-major V walk)
        xv.iter_mut().for_each(|t| *t = 0.0);
        for (k, &xk) in x.iter().enumerate() {
            if xk != 0.0 {
                let vrow = v.row(k);
                for j in 0..c {
                    xv[j] += xk * vrow[j];
                }
            }
        }
        // out += x ⊗ xv
        for (k, &xk) in x.iter().enumerate() {
            if xk != 0.0 {
                let orow = out.row_mut(k);
                for j in 0..c {
                    orow[j] += xk * xv[j];
                }
            }
        }
    }
    out
}

/// One distributed application of `(XᵀX + reg·I)·V` as a stage + driver
/// merge.
fn gram_stage(
    engine: &mut SparkEngine,
    x: &IndexedRowMatrix,
    v: &LocalMatrix,
    reg: f64,
    name: &str,
) -> LocalMatrix {
    let bytes = v.rows() * v.cols() * 8;
    let mut q = engine
        .run_stage_reduce(
            name,
            x.rdd.partitions(),
            |_, part| gram_partial(part, v),
            |mut a, b| {
                a.axpy(1.0, &b);
                a
            },
            bytes,
        )
        .unwrap_or_else(|| LocalMatrix::zeros(v.rows(), v.cols()));
    q.axpy(reg, v);
    q
}

#[derive(Debug)]
pub struct SparkCgResult {
    pub w: LocalMatrix,
    pub iters: usize,
    pub residuals: Vec<f64>,
    /// Wall seconds per iteration (includes injected overhead sleeps).
    pub iter_secs: Vec<f64>,
    /// Simulated cluster seconds per iteration.
    pub iter_sim_secs: Vec<f64>,
}

/// Spark-style block CG on the normal equations (the paper's hand-written
/// Spark CG of §4.1 — MLlib has no CG, exactly as the paper notes).
pub fn cg_solve(
    engine: &mut SparkEngine,
    x: &IndexedRowMatrix,
    y: &IndexedRowMatrix,
    opts: &CgOptions,
) -> crate::Result<SparkCgResult> {
    anyhow::ensure!(x.rows == y.rows, "X/Y row mismatch");
    // cluster memory budget: X must be cacheable (Table 1's boundary)
    anyhow::ensure!(
        x.size_bytes() + y.size_bytes() <= engine.memory_budget_bytes,
        "insufficient cluster memory to cache {} of training data \
         (budget {}); Spark job fails",
        crate::util::fmt::bytes((x.size_bytes() + y.size_bytes()) as u64),
        crate::util::fmt::bytes(engine.memory_budget_bytes as u64),
    );
    let d = x.cols;
    let c = y.cols;
    let reg = x.rows as f64 * opts.lambda;

    // b = XᵀY: zip X and Y rows by partition (co-partitioned by
    // construction), one stage
    anyhow::ensure!(
        x.num_partitions() == y.num_partitions(),
        "X and Y must be co-partitioned"
    );
    let pairs: Vec<(usize, usize)> =
        (0..x.num_partitions()).map(|i| (i, i)).collect();
    let b = engine
        .run_stage_reduce(
            "cg:Xt*Y",
            &pairs,
            |_, &(px, py)| {
                let xr = &x.rdd.partitions()[px];
                let yr = &y.rdd.partitions()[py];
                let mut out = LocalMatrix::zeros(d, c);
                for (rx, ry) in xr.iter().zip(yr) {
                    debug_assert_eq!(rx.index, ry.index);
                    for (k, &xk) in rx.vector.iter().enumerate() {
                        if xk != 0.0 {
                            let orow = out.row_mut(k);
                            for j in 0..c {
                                orow[j] += xk * ry.vector[j];
                            }
                        }
                    }
                }
                out
            },
            |mut a, b| {
                a.axpy(1.0, &b);
                a
            },
            d * c * 8,
        )
        .unwrap();

    let mut w = LocalMatrix::zeros(d, c);
    let mut r = b.clone();
    let mut p = r.clone();
    let rs0 = r.col_dots(&r);
    let mut rs_old = rs0.clone();

    let mut residuals = Vec::new();
    let mut iter_secs = Vec::new();
    let mut iter_sim_secs = Vec::new();
    let mut iters = 0;

    for it in 0..opts.max_iters {
        let t0 = std::time::Instant::now();
        let sim0 = engine.sim_elapsed_secs();

        let q = gram_stage(engine, x, &p, reg, "cg:gram");

        let pq = p.col_dots(&q);
        let alpha: Vec<f64> = rs_old
            .iter()
            .zip(&pq)
            .map(|(&rs, &pq)| if pq.abs() > 0.0 { rs / pq } else { 0.0 })
            .collect();
        // driver-side state update (D×C, unblocked)
        for i in 0..d {
            let wr = w.row_mut(i);
            let pr = p.row(i);
            for j in 0..c {
                wr[j] += alpha[j] * pr[j];
            }
            let rr = r.row_mut(i);
            let qr = q.row(i);
            for j in 0..c {
                rr[j] -= alpha[j] * qr[j];
            }
        }

        let rs_new = r.col_dots(&r);
        let rel = rs_new
            .iter()
            .zip(&rs0)
            .map(|(&n, &z)| if z > 0.0 { (n / z).sqrt() } else { 0.0 })
            .fold(0.0f64, f64::max);
        residuals.push(rel);
        iter_secs.push(t0.elapsed().as_secs_f64());
        iter_sim_secs.push(engine.sim_elapsed_secs() - sim0);
        iters = it + 1;
        if rel < opts.tol {
            break;
        }
        let beta: Vec<f64> = rs_new
            .iter()
            .zip(&rs_old)
            .map(|(&n, &o)| if o > 0.0 { n / o } else { 0.0 })
            .collect();
        for i in 0..d {
            let pr = p.row_mut(i);
            let rr = r.row(i);
            for j in 0..c {
                pr[j] = rr[j] + beta[j] * pr[j];
            }
        }
        rs_old = rs_new;
    }

    Ok(SparkCgResult { w, iters, residuals, iter_secs, iter_sim_secs })
}

/// Spark-side random-feature expansion (one stage over the rows). The
/// expanded matrix must fit the cluster memory budget — this is where the
/// paper's ">10k features" Spark runs die (Table 1).
pub fn rff_expand(
    engine: &mut SparkEngine,
    x: &IndexedRowMatrix,
    map: &RffMap,
) -> crate::Result<IndexedRowMatrix> {
    anyhow::ensure!(x.cols == map.input_dim(), "rff input dim mismatch");
    let expanded_bytes = x.rows * map.output_dim() * 8;
    anyhow::ensure!(
        expanded_bytes <= engine.memory_budget_bytes,
        "expanded feature matrix ({}) exceeds cluster memory budget ({}); \
         Spark job fails",
        crate::util::fmt::bytes(expanded_bytes as u64),
        crate::util::fmt::bytes(engine.memory_budget_bytes as u64),
    );
    let parts = engine.run_stage("rff:expand", x.rdd.partitions(), |_, part| {
        part.iter()
            .map(|row| {
                let d = map.output_dim();
                let mut z = vec![0.0; d];
                for (k, &xk) in row.vector.iter().enumerate() {
                    if xk != 0.0 {
                        let orow = map.omega.row(k);
                        for j in 0..d {
                            z[j] += xk * orow[j];
                        }
                    }
                }
                for (j, zj) in z.iter_mut().enumerate() {
                    *zj = map.scale * (*zj + map.bias[j]).cos();
                }
                IndexedRow { index: row.index, vector: z }
            })
            .collect::<Vec<_>>()
    });
    Ok(IndexedRowMatrix {
        rdd: Rdd::from_partitions(parts),
        rows: x.rows,
        cols: map.output_dim(),
    })
}

#[derive(Debug)]
pub struct SparkSvdResult {
    pub sigma: Vec<f64>,
    pub v: LocalMatrix,
    pub u: IndexedRowMatrix,
    pub steps: usize,
}

/// Spark-style truncated SVD: Lanczos on the Gram operator with one stage
/// per matvec (MLlib's `computeSVD` drives ARPACK exactly this way: the
/// distributed multiply is an aggregate over the row RDD per Arnoldi
/// step — that per-iteration stage cost is the whole story of Table 5).
pub fn truncated_svd(
    engine: &mut SparkEngine,
    a: &IndexedRowMatrix,
    opts: &SvdOptions,
) -> crate::Result<SparkSvdResult> {
    let k_dim = a.cols;
    anyhow::ensure!(opts.rank >= 1 && opts.rank <= k_dim, "bad rank");
    anyhow::ensure!(
        a.size_bytes() <= engine.memory_budget_bytes,
        "matrix ({}) exceeds cluster memory budget ({})",
        crate::util::fmt::bytes(a.size_bytes() as u64),
        crate::util::fmt::bytes(engine.memory_budget_bytes as u64),
    );
    let m = if opts.steps == 0 {
        (2 * opts.rank + 24).min(k_dim)
    } else {
        opts.steps.min(k_dim)
    };

    let mut rng = Rng::new(opts.seed);
    let mut v0 = rng.normals(k_dim);
    let n0 = v0.iter().map(|x| x * x).sum::<f64>().sqrt();
    v0.iter_mut().for_each(|x| *x /= n0);

    let mut basis: Vec<Vec<f64>> = vec![v0];
    let mut alphas = Vec::new();
    let mut betas: Vec<f64> = Vec::new();

    for j in 0..m {
        let vj = LocalMatrix::from_data(k_dim, 1, basis[j].clone());
        let w_mat = gram_stage(engine, a, &vj, 0.0, "svd:gram");
        let mut w = w_mat.into_data();

        let alpha: f64 = w.iter().zip(&basis[j]).map(|(a, b)| a * b).sum();
        alphas.push(alpha);
        for (wi, vi) in w.iter_mut().zip(&basis[j]) {
            *wi -= alpha * vi;
        }
        if j > 0 {
            for (wi, vi) in w.iter_mut().zip(&basis[j - 1]) {
                *wi -= betas[j - 1] * vi;
            }
        }
        for _ in 0..2 {
            for q in &basis {
                let c: f64 = w.iter().zip(q).map(|(a, b)| a * b).sum();
                for (wi, qi) in w.iter_mut().zip(q) {
                    *wi -= c * qi;
                }
            }
        }
        let beta = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        if j + 1 == m {
            break;
        }
        if beta < 1e-12 {
            let mut fresh = rng.normals(k_dim);
            for q in &basis {
                let c: f64 = fresh.iter().zip(q).map(|(a, b)| a * b).sum();
                for (fi, qi) in fresh.iter_mut().zip(q) {
                    *fi -= c * qi;
                }
            }
            let n = fresh.iter().map(|x| x * x).sum::<f64>().sqrt();
            fresh.iter_mut().for_each(|x| *x /= n);
            betas.push(0.0);
            basis.push(fresh);
            continue;
        }
        betas.push(beta);
        w.iter_mut().for_each(|x| *x /= beta);
        basis.push(w);
    }

    let steps = alphas.len();
    let (theta, y) = crate::linalg::tridiag::tql2(&alphas, &betas[..steps - 1])?;
    let k = opts.rank.min(steps);
    let mut sigma = Vec::with_capacity(k);
    let mut v = LocalMatrix::zeros(k_dim, k);
    for kk in 0..k {
        let idx = steps - 1 - kk;
        sigma.push(theta[idx].max(0.0).sqrt());
        for (j, q) in basis.iter().take(steps).enumerate() {
            let c = y[idx][j];
            for i in 0..k_dim {
                let cur = v.get(i, kk);
                v.set(i, kk, cur + c * q[i]);
            }
        }
    }

    // U = A·V·Σ⁻¹ as one more stage over the rows
    let sig = sigma.clone();
    let vref = &v;
    let u_parts = engine.run_stage("svd:U", a.rdd.partitions(), |_, part| {
        part.iter()
            .map(|row| {
                let mut u = vec![0.0; k];
                for (kd, &xk) in row.vector.iter().enumerate() {
                    if xk != 0.0 {
                        let vrow = vref.row(kd);
                        for kk in 0..k {
                            u[kk] += xk * vrow[kk];
                        }
                    }
                }
                for (kk, s) in sig.iter().enumerate() {
                    if *s > 1e-300 {
                        u[kk] /= s;
                    }
                }
                IndexedRow { index: row.index, vector: u }
            })
            .collect::<Vec<_>>()
    });

    Ok(SparkSvdResult {
        sigma,
        v,
        u: IndexedRowMatrix {
            rdd: Rdd::from_partitions(u_parts),
            rows: a.rows,
            cols: k,
        },
        steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn quiet_engine() -> SparkEngine {
        let mut cfg = Config::default();
        cfg.overhead.scheduler_delay_s = 0.0;
        cfg.overhead.task_launch_s = 0.0;
        let mut e = SparkEngine::new(2, &cfg);
        e.inject_real_delays = false;
        e
    }

    #[test]
    fn spark_cg_matches_mpi_cg() {
        let mut rng = Rng::new(21);
        let n = 40;
        let x = LocalMatrix::from_fn(n, 10, |_, _| rng.normal());
        let y = LocalMatrix::from_fn(n, 3, |_, _| rng.normal());
        let opts = CgOptions { lambda: 1e-3, tol: 1e-12, max_iters: 200 };

        let mut engine = quiet_engine();
        let xs = IndexedRowMatrix::from_local(&x, 3);
        let ys = IndexedRowMatrix::from_local(&y, 3);
        let spark = cg_solve(&mut engine, &xs, &ys, &opts).unwrap();

        // oracle: the linalg (MPI-side) solver on one rank
        let comms = crate::collectives::LocalComm::group(1, None);
        let mut ne = crate::compute::NativeEngine::new();
        let mpi = crate::linalg::cg_solve(&comms[0], &mut ne, &x, &y, n, &opts).unwrap();
        assert!(
            spark.w.max_abs_diff(&mpi.w) < 1e-8,
            "diff {}",
            spark.w.max_abs_diff(&mpi.w)
        );
        assert!(spark.residuals.last().unwrap() < &1e-10);
    }

    #[test]
    fn spark_svd_matches_mpi_svd() {
        let mut rng = Rng::new(22);
        let a = LocalMatrix::from_fn(50, 16, |i, j| {
            // decaying structure so the spectrum is well separated
            ((i + 1) as f64).recip() * rng.normal() + if i % 16 == j { 3.0 } else { 0.0 }
        });
        let opts = SvdOptions { rank: 3, steps: 0, seed: 5 };

        let mut engine = quiet_engine();
        let ai = IndexedRowMatrix::from_local(&a, 4);
        let spark = truncated_svd(&mut engine, &ai, &opts).unwrap();

        let comms = crate::collectives::LocalComm::group(1, None);
        let mut ne = crate::compute::NativeEngine::new();
        let mpi = crate::linalg::truncated_svd(&comms[0], &mut ne, &a, &opts).unwrap();
        for (s, m) in spark.sigma.iter().zip(&mpi.sigma) {
            assert!((s - m).abs() < 1e-8 * (1.0 + m), "{s} vs {m}");
        }
    }

    #[test]
    fn memory_budget_enforced_like_table1() {
        let mut cfg = Config::default();
        cfg.spark_driver_max_bytes = 1024; // tiny budget
        let mut engine = SparkEngine::new(2, &cfg);
        engine.inject_real_delays = false;
        let x = LocalMatrix::zeros(64, 8);
        let xs = IndexedRowMatrix::from_local(&x, 2);
        let ys = IndexedRowMatrix::from_local(&LocalMatrix::zeros(64, 2), 2);
        let err = cg_solve(&mut engine, &xs, &ys, &CgOptions::default()).unwrap_err();
        assert!(err.to_string().contains("memory"), "{err}");

        let map = RffMap::generate(8, 512, 1.0, 3);
        let err = rff_expand(&mut engine, &xs, &map).unwrap_err();
        assert!(err.to_string().contains("memory"), "{err}");
    }

    #[test]
    fn rff_expand_matches_engine_expansion() {
        let mut rng = Rng::new(23);
        let x = LocalMatrix::from_fn(12, 6, |_, _| rng.normal());
        let map = RffMap::generate(6, 32, 0.7, 9);
        let mut engine = quiet_engine();
        let xs = IndexedRowMatrix::from_local(&x, 3);
        let z = rff_expand(&mut engine, &xs, &map).unwrap();
        let want = map.expand(&mut crate::compute::NativeEngine::new(), &x).unwrap();
        assert!(z.to_local().unwrap().max_abs_diff(&want) < 1e-12);
    }
}
