//! Truncated SVD via Lanczos on the Gram operator — the ARPACK-style
//! routine behind paper §4.2 (footnote 3: both MLlib and the MPI
//! implementation compute eigenvalues of the Gram matrix).
//!
//! For a row-distributed A (n×K), run Lanczos with full
//! reorthogonalization on `G = AᵀA` (K×K, applied matrix-free through the
//! engine's fused `gram_matvec` + one allreduce), solve the projected
//! tridiagonal problem with [`super::tridiag::tql2`], extract the top-k
//! Ritz pairs, and recover the left singular vectors `U = A·V·Σ⁻¹`
//! locally (U inherits A's row distribution).

use super::blas1::{axpy, dot, norm, normalize};
use crate::collectives::{allreduce_sum, Communicator};
use crate::compute::Engine;
use crate::distmat::LocalMatrix;
use crate::tasks::TaskScope;
use crate::util::prng::Rng;

#[derive(Debug, Clone)]
pub struct SvdOptions {
    /// Number of singular triplets to return.
    pub rank: usize,
    /// Lanczos steps (0 = auto: `min(K, 2·rank + 24)`).
    pub steps: usize,
    /// Seed for the (replicated) start vector.
    pub seed: u64,
}

impl Default for SvdOptions {
    fn default() -> Self {
        SvdOptions { rank: 20, steps: 0, seed: 0x53D5 }
    }
}

#[derive(Debug)]
pub struct SvdResult {
    /// Top singular values, descending (length `rank`).
    pub sigma: Vec<f64>,
    /// Right singular vectors, K×rank (replicated).
    pub v: LocalMatrix,
    /// This rank's rows of the left singular vectors, local_rows×rank.
    pub u_local: LocalMatrix,
    /// Lanczos steps actually taken.
    pub steps: usize,
}

const TAG: u64 = 0x5644_0000;

/// SPMD truncated SVD of the row-distributed matrix whose local block is
/// `a_local` (all ranks must pass the same `opts`). Runs under a detached
/// [`TaskScope`] — never cancelled, progress unobserved.
pub fn truncated_svd(
    comm: &dyn Communicator,
    engine: &mut dyn Engine,
    a_local: &LocalMatrix,
    opts: &SvdOptions,
) -> crate::Result<SvdResult> {
    truncated_svd_scoped(comm, engine, a_local, opts, &TaskScope::detached())
}

/// [`truncated_svd`] under an explicit [`TaskScope`]: each Lanczos step
/// reports `(step, β_j)` (the off-diagonal norm stands in for a residual)
/// and cancellation is decided *collectively* at the step boundary — the
/// locally-observed token is allreduced so every rank bails together (see
/// `linalg::cg` for why a unilateral bail would deadlock the group).
pub fn truncated_svd_scoped(
    comm: &dyn Communicator,
    engine: &mut dyn Engine,
    a_local: &LocalMatrix,
    opts: &SvdOptions,
    scope: &TaskScope,
) -> crate::Result<SvdResult> {
    let k_dim = a_local.cols();
    anyhow::ensure!(opts.rank >= 1, "rank must be >= 1");
    anyhow::ensure!(
        opts.rank <= k_dim,
        "rank {} exceeds column count {k_dim}",
        opts.rank
    );
    let m = if opts.steps == 0 {
        (2 * opts.rank + 24).min(k_dim)
    } else {
        opts.steps.min(k_dim)
    };

    // Replicated deterministic start vector: all ranks generate the same.
    let mut rng = Rng::new(opts.seed);
    let mut v0: Vec<f64> = rng.normals(k_dim);
    normalize(&mut v0);

    // Lanczos with full reorthogonalization (K is small — ≤ a few
    // thousand — so keeping the basis replicated is what the paper's
    // implementation does too).
    let mut basis: Vec<Vec<f64>> = vec![v0];
    let mut alphas: Vec<f64> = Vec::new();
    let mut betas: Vec<f64> = Vec::new();
    // A is static across all Lanczos steps: device-backed engines keep the
    // panels resident (§Perf)
    let a_key = crate::compute::fresh_operand_key();

    for j in 0..m {
        // collective cancellation check at the step boundary (steps are
        // synchronized by the Gram allreduce below, so all ranks reach
        // this together and agree); free for detached scopes
        scope.collective_check_cancelled(comm, TAG + 8 + (j as u64 % 64) * 256)?;

        // w = G·vj (matrix-free, reg = 0); one clone to column-matrix
        // form — `basis[j]` itself stays borrowed for the α/β updates
        let vj_mat = LocalMatrix::from_data(k_dim, 1, basis[j].clone());
        let mut w = engine.gram_matvec_keyed(a_key, a_local, &vj_mat, 0.0)?;
        allreduce_sum(comm, TAG + (j as u64 % 64) * 256, w.data_mut())?;
        let mut w = w.into_data();

        let alpha = dot(&w, &basis[j]);
        alphas.push(alpha);
        // w -= alpha·vj + beta·v_{j-1}
        axpy(&mut w, -alpha, &basis[j]);
        if j > 0 {
            axpy(&mut w, -betas[j - 1], &basis[j - 1]);
        }
        // full reorthogonalization (twice is enough)
        for _ in 0..2 {
            for q in &basis {
                let c = dot(&w, q);
                axpy(&mut w, -c, q);
            }
        }
        let beta = norm(&w);
        scope.report((j + 1) as u64, beta);
        if j + 1 == m {
            break;
        }
        if beta < 1e-12 {
            // invariant subspace found: restart orthogonal to the basis
            // (deterministic across ranks)
            let mut fresh = rng.normals(k_dim);
            for q in &basis {
                let c = dot(&fresh, q);
                axpy(&mut fresh, -c, q);
            }
            normalize(&mut fresh);
            betas.push(0.0);
            basis.push(fresh);
            continue;
        }
        betas.push(beta);
        for x in &mut w {
            *x /= beta;
        }
        basis.push(w);
    }

    let steps = alphas.len();
    let (theta, y) = super::tridiag::tql2(&alphas, &betas[..steps - 1])?;

    // top-k Ritz pairs (tql2 returns ascending)
    let k = opts.rank.min(steps);
    let mut sigma = Vec::with_capacity(k);
    let mut v = LocalMatrix::zeros(k_dim, k);
    // contiguous column scratch: accumulate V_kk = Σ_j y[idx][j]·basis[j]
    // with vectorizable axpys, then one strided write into the k_dim×k
    // output (the per-element get/set walk defeated vectorization)
    let mut col = vec![0.0f64; k_dim];
    for kk in 0..k {
        let idx = steps - 1 - kk;
        let lam = theta[idx].max(0.0);
        sigma.push(lam.sqrt());
        col.fill(0.0);
        for (j, q) in basis.iter().take(steps).enumerate() {
            axpy(&mut col, y[idx][j], q);
        }
        for (i, x) in col.iter().enumerate() {
            v.set(i, kk, *x);
        }
    }

    // U = A · V · Σ⁻¹ (row-distributed like A)
    let mut u_local = LocalMatrix::zeros(a_local.rows(), k);
    engine.gemm(crate::compute::GemmVariant::NN, &mut u_local, a_local, &v)?;
    for i in 0..u_local.rows() {
        let row = u_local.row_mut(i);
        for (kk, s) in sigma.iter().enumerate() {
            if *s > 1e-300 {
                row[kk] /= s;
            }
        }
    }

    Ok(SvdResult { sigma, v, u_local, steps })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::LocalComm;
    use crate::compute::NativeEngine;
    use crate::distmat::RowBlockLayout;

    /// Deterministic matrix with a known, well-separated spectrum:
    /// A = U·diag(σ)·Vᵀ built from Householder-orthogonalized random bases.
    fn matrix_with_spectrum(n: usize, k_dim: usize, sigmas: &[f64], seed: u64) -> LocalMatrix {
        let mut rng = Rng::new(seed);
        // crude orthogonalization of random tall matrices
        let mut u = LocalMatrix::from_fn(n, sigmas.len(), |_, _| rng.normal());
        gram_schmidt(&mut u);
        let mut v = LocalMatrix::from_fn(k_dim, sigmas.len(), |_, _| rng.normal());
        gram_schmidt(&mut v);
        let mut a = LocalMatrix::zeros(n, k_dim);
        // a += U diag(s) Vᵀ
        let mut us = u.clone();
        for i in 0..n {
            let row = us.row_mut(i);
            for (j, s) in sigmas.iter().enumerate() {
                row[j] *= s;
            }
        }
        a.gemm_nt(&us, &v);
        a
    }

    fn gram_schmidt(m: &mut LocalMatrix) {
        let (rows, cols) = (m.rows(), m.cols());
        for j in 0..cols {
            for prev in 0..j {
                let mut c = 0.0;
                for i in 0..rows {
                    c += m.get(i, j) * m.get(i, prev);
                }
                for i in 0..rows {
                    let v = m.get(i, j) - c * m.get(i, prev);
                    m.set(i, j, v);
                }
            }
            let mut nrm = 0.0;
            for i in 0..rows {
                nrm += m.get(i, j) * m.get(i, j);
            }
            let nrm = nrm.sqrt();
            for i in 0..rows {
                let v = m.get(i, j) / nrm;
                m.set(i, j, v);
            }
        }
    }

    #[test]
    fn recovers_known_spectrum_single_rank() {
        let sigmas = [10.0, 7.0, 4.0, 2.0, 1.0];
        let a = matrix_with_spectrum(60, 30, &sigmas, 5);
        let comms = LocalComm::group(1, None);
        let mut engine = NativeEngine::new();
        let res = truncated_svd(
            &comms[0],
            &mut engine,
            &a,
            &SvdOptions { rank: 3, steps: 0, seed: 1 },
        )
        .unwrap();
        for (got, want) in res.sigma.iter().zip(&sigmas[..3]) {
            assert!((got - want).abs() < 1e-6, "sigma {got} vs {want}");
        }
        // residual check: ‖A v − σ u‖ small
        let mut av = LocalMatrix::zeros(60, 3);
        av.gemm_nn(&a, &res.v);
        for kk in 0..3 {
            for i in 0..60 {
                let want = res.sigma[kk] * res.u_local.get(i, kk);
                assert!((av.get(i, kk) - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn distributed_matches_serial() {
        let sigmas = [9.0, 6.0, 3.0, 1.5];
        let n = 64;
        let a = matrix_with_spectrum(n, 24, &sigmas, 6);
        let opts = SvdOptions { rank: 2, steps: 0, seed: 2 };

        let serial = {
            let comms = LocalComm::group(1, None);
            truncated_svd(&comms[0], &mut NativeEngine::new(), &a, &opts).unwrap()
        };

        for workers in [2usize, 3] {
            let layout = RowBlockLayout::even(n, 24, workers);
            let comms = LocalComm::group(workers, None);
            let mut handles = Vec::new();
            for comm in comms {
                let (ra, rb) = layout.ranges[comm.rank()];
                let local = a.slice_rows(ra, rb);
                let opts = opts.clone();
                handles.push(std::thread::spawn(move || {
                    truncated_svd(&comm, &mut NativeEngine::new(), &local, &opts).unwrap()
                }));
            }
            let results: Vec<SvdResult> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            for res in &results {
                for (g, w) in res.sigma.iter().zip(&serial.sigma) {
                    assert!((g - w).abs() < 1e-8, "workers={workers}");
                }
                // replicated V identical across ranks (up to bit equality,
                // since every rank does the same arithmetic)
                assert_eq!(res.v, results[0].v);
            }
        }
    }

    #[test]
    fn rank_validation() {
        let a = LocalMatrix::zeros(4, 3);
        let comms = LocalComm::group(1, None);
        let mut e = NativeEngine::new();
        assert!(truncated_svd(&comms[0], &mut e, &a, &SvdOptions { rank: 9, steps: 0, seed: 0 }).is_err());
        assert!(truncated_svd(&comms[0], &mut e, &a, &SvdOptions { rank: 0, steps: 0, seed: 0 }).is_err());
    }
}
