//! Simulated cluster time.
//!
//! The paper's scaling results (Tables 2–4, Figure 3) need more cores than
//! this box has (one). Workers here execute *really* — all numerics are
//! computed — but sequentially time-sliced, so wallclock cannot show
//! "doubling workers halves compute". The SimClock reconstructs cluster
//! elapsed time from per-worker busy time the way a discrete-event
//! simulator would:
//!
//! * a parallel region advances the clock by `max` over worker busy
//!   seconds (the BSP barrier semantics both Spark and MPI share);
//! * communication advances it by the modeled interconnect cost
//!   ([`crate::config::SimNetConfig`]);
//! * serial sections (driver work, injected scheduler delays) add up
//!   directly.
//!
//! Every bench prints wallclock next to simulated time; only the scaling
//! *shape* is claimed from the simulated column (DESIGN.md §2).

/// Accumulates simulated elapsed seconds.
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    elapsed: f64,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// A BSP parallel region: all lanes start together, the region ends at
    /// the slowest lane (barrier).
    pub fn advance_parallel(&mut self, lane_busy_secs: &[f64]) {
        let max = lane_busy_secs.iter().copied().fold(0.0, f64::max);
        self.elapsed += max;
    }

    /// A parallel region where `tasks` units of `secs_each` work are
    /// spread over `lanes` lanes (Spark task waves): ceil(tasks/lanes)
    /// waves of the per-task cost.
    pub fn advance_task_waves(&mut self, tasks: usize, lanes: usize, secs_each: f64) {
        if tasks == 0 || lanes == 0 {
            return;
        }
        let waves = tasks.div_ceil(lanes);
        self.elapsed += waves as f64 * secs_each;
    }

    /// Serial driver-side work.
    pub fn advance_serial(&mut self, secs: f64) {
        self.elapsed += secs;
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed
    }

    /// Merge another clock's elapsed time (sequential composition).
    pub fn extend(&mut self, other: &SimClock) {
        self.elapsed += other.elapsed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_takes_max() {
        let mut c = SimClock::new();
        c.advance_parallel(&[1.0, 3.0, 2.0]);
        assert_eq!(c.elapsed_secs(), 3.0);
        c.advance_serial(0.5);
        assert_eq!(c.elapsed_secs(), 3.5);
    }

    #[test]
    fn task_waves_ceiling() {
        let mut c = SimClock::new();
        c.advance_task_waves(10, 4, 1.0); // 3 waves
        assert_eq!(c.elapsed_secs(), 3.0);
        c.advance_task_waves(0, 4, 1.0);
        c.advance_task_waves(4, 0, 1.0);
        assert_eq!(c.elapsed_secs(), 3.0);
    }

    #[test]
    fn doubling_lanes_halves_balanced_work() {
        let mut a = SimClock::new();
        let mut b = SimClock::new();
        a.advance_task_waves(16, 2, 1.0);
        b.advance_task_waves(16, 4, 1.0);
        assert_eq!(a.elapsed_secs(), 2.0 * b.elapsed_secs());
    }
}
