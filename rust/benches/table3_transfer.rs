//! Table 3: feature-matrix transfer time vs (client executors × server
//! workers), both directions.
//!
//! Paper: 2,251,569×10,000 f64 over Cray Aries; transfer fastest when
//! executor and worker counts match, slowest with 2 executors. Here the
//! matrix scales to rows×1024 f64 over localhost TCP, sweeping executors
//! {1,2,4,8} × workers {2,3,4}; the diagonal-minimum shape is the target.
//! Reported numbers are the mean of `--runs` (default 3) like the paper.
//!
//! Beyond the paper's push-only table, this bench measures the pull leg
//! (v3 streaming protocol) and can emit a machine-readable baseline with
//! `--json PATH` — `BENCH_transfer.json` in the repo root is the
//! committed reference every data-plane PR is compared against (CI runs
//! the `--quick` size and uploads the artifact). The artifact also
//! carries `fabric_cells` (protocol v8: local-mailbox vs tcp-loopback
//! collectives) and `sched_cells` (protocol v9: no-op task round-trip
//! latency serially vs concurrent tag lanes vs concurrent tenants).

mod bench_common;

use alchemist::cli::Args;
use alchemist::client::AlchemistContext;
use alchemist::collectives::{
    algorithms::infallible, loopback_group, Communicator, FabricOptions,
    LocalComm, TAG_WINDOW,
};
use alchemist::coordinator::AlchemistServer;
use alchemist::metrics::{Stats, Table};
use alchemist::protocol::Params;
use alchemist::sparklite::IndexedRowMatrix;
use alchemist::util::fmt;
use alchemist::workloads::TimitSpec;
use bench_common::{bench_config, is_quick};

/// One measured (executors, workers) cell.
struct Cell {
    executors: usize,
    workers: usize,
    push_secs: f64,
    push_gbps: f64,
    pull_secs: f64,
    pull_gbps: f64,
}

/// One measured rank-fabric collective cell (protocol v8,
/// `docs/fabric.md`): the same algorithm over in-process mailboxes
/// (`local`) vs a tcp-loopback mesh (`tcp`).
struct FabricCell {
    fabric: &'static str,
    op: &'static str,
    elems: usize,
    ranks: usize,
    secs_per_op: f64,
    /// Logical vector bytes per op / secs — a normalization shared by
    /// both fabrics, so ratios between them are meaningful.
    gbps: f64,
}

/// One measured scheduler cell (protocol v9, `docs/scheduler.md`):
/// submit→Done round-trip cost of a no-op task, streamed serially vs
/// under concurrent lanes / concurrent tenants.
struct SchedCell {
    /// `serial` (1 tenant, 1 lane), `lanes2` (1 tenant, 2 tasks in
    /// flight on one group), `tenants2` (2 tenants on disjoint groups).
    case: &'static str,
    tenants: usize,
    lanes: usize,
    /// no-op tasks per tenant stream.
    tasks: usize,
    /// slowest tenant's wall-clock / its task count — per-stream latency.
    secs_per_task: f64,
    /// aggregate completions / slowest tenant's wall-clock — higher is
    /// better, so the baseline checker's throughput diff applies as-is.
    tasks_per_sec: f64,
}

/// Time `reps` back-to-back collectives on every rank; returns the
/// slowest rank's wall-clock seconds per op (barrier-fenced, so setup
/// skew is excluded).
fn time_collective<C>(comms: Vec<C>, op: &'static str, elems: usize, reps: usize) -> f64
where
    C: Communicator + 'static,
{
    let mut handles = Vec::new();
    for c in comms {
        handles.push(std::thread::spawn(move || {
            let mut buf = vec![0.0f64; elems];
            infallible::barrier(&c);
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                match op {
                    "allreduce" => infallible::allreduce_sum(&c, TAG_WINDOW, &mut buf),
                    "broadcast" => infallible::broadcast(&c, 2 * TAG_WINDOW, 0, &mut buf),
                    other => unreachable!("unknown fabric op {other}"),
                }
            }
            infallible::barrier(&c);
            t0.elapsed().as_secs_f64()
        }));
    }
    let slowest = handles
        .into_iter()
        .map(|h| h.join().expect("fabric bench rank panicked"))
        .fold(0.0f64, f64::max);
    slowest / reps as f64
}

/// The fabric comparison: eager-sized (latency) and rendezvous-sized
/// (bandwidth) vectors through both transports at a fixed group size.
fn bench_fabric(cfg: &alchemist::config::Config, quick: bool) -> Vec<FabricCell> {
    let ranks = 4;
    let opts = FabricOptions {
        eager_bytes: cfg.fabric.eager_bytes,
        buf_bytes: cfg.fabric.buf_bytes,
        ..FabricOptions::default()
    };
    // 2 KiB vectors stay eager (and, at 4 ranks, recursive doubling);
    // 8 MiB vectors take the gathered-writev rendezvous path (and ring)
    let cases: &[(usize, usize)] = if quick {
        &[(256, 50), (1 << 20, 3)]
    } else {
        &[(256, 200), (1 << 20, 5)]
    };
    let mut cells = Vec::new();
    for &(elems, reps) in cases {
        for op in ["allreduce", "broadcast"] {
            for fabric in ["local", "tcp"] {
                let secs = match fabric {
                    "local" => {
                        time_collective(LocalComm::group(ranks, None), op, elems, reps)
                    }
                    _ => {
                        let comms = loopback_group(ranks, &opts)
                            .expect("forming loopback mesh");
                        time_collective(comms, op, elems, reps)
                    }
                };
                cells.push(FabricCell {
                    fabric,
                    op,
                    elems,
                    ranks,
                    secs_per_op: secs,
                    gbps: (elems * 8) as f64 / secs / 1e9,
                });
            }
        }
    }
    cells
}

/// Stream `tasks` no-op tasks through one session, keeping up to
/// `lanes` in flight; returns the stream's wall-clock seconds.
fn drive_tasks(
    addr: &str,
    cfg: &alchemist::config::Config,
    want_workers: usize,
    lanes: usize,
    tasks: usize,
) -> alchemist::Result<f64> {
    let mut ac = AlchemistContext::connect_with_workers(addr, cfg, 1, want_workers)?;
    ac.register_library("elemental", "builtin:elemental")?;
    let t0 = std::time::Instant::now();
    let mut done = 0usize;
    while done < tasks {
        let burst = lanes.min(tasks - done);
        let mut ids = Vec::with_capacity(burst);
        for _ in 0..burst {
            let params = Params::new().with_i64("millis", 0);
            ids.push(ac.submit("elemental", "sleep", params)?.task_id);
        }
        for id in ids {
            ac.task(id).wait()?;
        }
        done += burst;
    }
    let secs = t0.elapsed().as_secs_f64();
    ac.stop();
    Ok(secs)
}

/// The scheduler comparison (protocol v9): the same no-op task stream
/// serially, with two tag lanes on one group, and from two tenants on
/// disjoint groups. Measures pure scheduler round-trip cost — admission,
/// dispatch, lane setup/retire — since the routine itself does nothing.
fn bench_sched(
    cfg: &alchemist::config::Config,
    quick: bool,
) -> alchemist::Result<Vec<SchedCell>> {
    let workers = 2;
    let tasks = if quick { 16 } else { 64 };
    let mut cells = Vec::new();
    let cases: &[(&'static str, usize, usize)] =
        &[("serial", 1, 1), ("lanes2", 1, 2), ("tenants2", 2, 1)];
    for &(case, tenants, lanes) in cases {
        let mut c = cfg.clone();
        c.apply("scheduler.tasks_per_group", &lanes.to_string())?;
        let server = AlchemistServer::start(c.clone(), workers)?;
        let secs = if tenants == 1 {
            drive_tasks(&server.control_addr, &c, workers, lanes, tasks)?
        } else {
            // one worker per tenant so both sessions admit concurrently;
            // the slowest stream is the honest aggregate clock
            let handles: Vec<_> = (0..tenants)
                .map(|_| {
                    let addr = server.control_addr.clone();
                    let c = c.clone();
                    std::thread::spawn(move || drive_tasks(&addr, &c, 1, lanes, tasks))
                })
                .collect();
            let mut worst = 0.0f64;
            for h in handles {
                worst = worst.max(h.join().expect("sched bench tenant panicked")?);
            }
            worst
        };
        server.shutdown();
        cells.push(SchedCell {
            case,
            tenants,
            lanes,
            tasks,
            secs_per_task: secs / tasks as f64,
            tasks_per_sec: (tasks * tenants) as f64 / secs,
        });
    }
    Ok(cells)
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".to_string()
    }
}

#[allow(clippy::too_many_arguments)]
fn write_json(
    path: &str,
    rows: usize,
    cols: usize,
    runs: usize,
    quick: bool,
    cfg: &alchemist::config::Config,
    cells: &[Cell],
    fabric_cells: &[FabricCell],
    sched_cells: &[SchedCell],
) -> alchemist::Result<()> {
    let mut body = String::new();
    body.push_str("{\n");
    body.push_str("  \"bench\": \"table3_transfer\",\n");
    body.push_str(&format!(
        "  \"protocol_version\": {},\n",
        alchemist::protocol::PROTOCOL_VERSION
    ));
    body.push_str("  \"units\": {\"secs\": \"mean wallclock seconds\", \"gbps\": \"GB/s, 1e9 bytes\"},\n");
    body.push_str(&format!(
        "  \"config\": {{\"rows\": {rows}, \"cols\": {cols}, \"runs\": {runs}, \
         \"quick\": {quick}, \"rows_per_frame\": {}, \"buf_bytes\": {}, \
         \"pull_stripe_rows\": {}, \"pull_window\": {}}},\n",
        cfg.transfer.rows_per_frame,
        cfg.transfer.buf_bytes,
        cfg.transfer.pull_stripe_rows,
        cfg.transfer.pull_window,
    ));
    body.push_str("  \"cells\": [\n");
    for (i, c) in cells.iter().enumerate() {
        body.push_str(&format!(
            "    {{\"executors\": {}, \"workers\": {}, \"push_secs\": {}, \
             \"push_gbps\": {}, \"pull_secs\": {}, \"pull_gbps\": {}}}{}\n",
            c.executors,
            c.workers,
            json_num(c.push_secs),
            json_num(c.push_gbps),
            json_num(c.pull_secs),
            json_num(c.pull_gbps),
            if i + 1 == cells.len() { "" } else { "," },
        ));
    }
    body.push_str("  ],\n");
    body.push_str("  \"fabric_cells\": [\n");
    for (i, c) in fabric_cells.iter().enumerate() {
        body.push_str(&format!(
            "    {{\"fabric\": \"{}\", \"op\": \"{}\", \"elems\": {}, \
             \"ranks\": {}, \"secs_per_op\": {}, \"gbps\": {}}}{}\n",
            c.fabric,
            c.op,
            c.elems,
            c.ranks,
            json_num(c.secs_per_op),
            json_num(c.gbps),
            if i + 1 == fabric_cells.len() { "" } else { "," },
        ));
    }
    body.push_str("  ],\n");
    body.push_str("  \"sched_cells\": [\n");
    for (i, c) in sched_cells.iter().enumerate() {
        body.push_str(&format!(
            "    {{\"case\": \"{}\", \"tenants\": {}, \"lanes\": {}, \
             \"tasks\": {}, \"secs_per_task\": {}, \"tasks_per_sec\": {}}}{}\n",
            c.case,
            c.tenants,
            c.lanes,
            c.tasks,
            json_num(c.secs_per_task),
            json_num(c.tasks_per_sec),
            if i + 1 == sched_cells.len() { "" } else { "," },
        ));
    }
    body.push_str("  ]\n}\n");
    std::fs::write(path, body)?;
    println!("wrote {path}");
    Ok(())
}

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let mut cfg = bench_config(&args)?;
    // transfer only; engine never runs
    cfg.apply("engine", "native")?;
    let quick = is_quick(&args);
    let rows = args.get_usize("rows", if quick { 4096 } else { 16_384 })?;
    let cols = args.get_usize("cols", 1024)?;
    let default_execs: &[usize] = if quick { &[2, 4] } else { &[1, 2, 4, 8] };
    let default_workers: &[usize] = if quick { &[2] } else { &[2, 3, 4] };
    let executors_list = args.get_usize_list("executors", default_execs)?;
    let workers_list = args.get_usize_list("workers", default_workers)?;
    let runs = args.get_usize("runs", 3)?;

    // dense feature matrix (contents irrelevant to transfer cost; use the
    // TIMIT generator so data creation time is also reportable, like the
    // paper's "data set creation times" column)
    let t0 = std::time::Instant::now();
    let spec = TimitSpec {
        train_rows: rows,
        test_rows: 1,
        raw_features: cols,
        classes: 2,
        ..TimitSpec::default()
    };
    let data = spec.generate();
    let creation_secs = t0.elapsed().as_secs_f64();
    println!(
        "data set: {rows} x {cols} f64 ({}), created in {creation_secs:.2}s",
        fmt::bytes((rows * cols * 8) as u64)
    );

    let mut table = Table::new(
        "Table 3 (scaled): transfer times (s), push | pull, mean of runs",
        &["executors \\ workers", "w=2", "w=3", "w=4"],
    );
    let mut cells: Vec<Cell> = Vec::new();

    for &execs in &executors_list {
        let mut row_cells = vec![format!("{execs}")];
        for &workers in &[2usize, 3, 4] {
            if !workers_list.contains(&workers) {
                row_cells.push("-".into());
                continue;
            }
            let server = AlchemistServer::start(cfg.clone(), workers)?;
            let mut push_secs = Stats::new();
            let mut push_gbps = Stats::new();
            let mut pull_secs = Stats::new();
            let mut pull_gbps = Stats::new();
            for run in 0..runs {
                let mut ac =
                    AlchemistContext::connect(&server.control_addr, &cfg, execs)?;
                let irm = IndexedRowMatrix::from_local(&data.x_train, execs.max(workers) * 2);
                let (al, s) = ac.send_matrix(&format!("X{run}"), &irm)?;
                push_secs.push(s.secs);
                push_gbps.push(s.throughput_gbps());
                let (back, p) = ac.to_indexed_row_matrix(&al, execs.max(1))?;
                anyhow::ensure!(
                    back.rows == rows && back.cols == cols,
                    "pull returned {}x{}, expected {rows}x{cols}",
                    back.rows,
                    back.cols
                );
                pull_secs.push(p.secs);
                pull_gbps.push(p.throughput_gbps());
                ac.free(&al)?;
                ac.stop();
            }
            row_cells.push(format!(
                "{:.3} ({:.2} GB/s) | {:.3} ({:.2} GB/s)",
                push_secs.mean(),
                push_gbps.mean(),
                pull_secs.mean(),
                pull_gbps.mean()
            ));
            cells.push(Cell {
                executors: execs,
                workers,
                push_secs: push_secs.mean(),
                push_gbps: push_gbps.mean(),
                pull_secs: pull_secs.mean(),
                pull_gbps: pull_gbps.mean(),
            });
            server.shutdown();
        }
        table.row(&row_cells);
    }

    table.print();
    println!(
        "paper shape: more executors help until they exceed workers; minimum near \
         executors == workers"
    );

    // rank-fabric collectives (protocol v8): local mailboxes vs a
    // tcp-loopback mesh, eager- and rendezvous-sized vectors
    let fabric_cells = bench_fabric(&cfg, quick);
    let mut ftable = Table::new(
        "Rank fabric: collective per-op time (local vs tcp-loopback, 4 ranks)",
        &["op", "elems", "local", "tcp", "tcp/local"],
    );
    for pair in fabric_cells.chunks(2) {
        let [l, t] = pair else { continue };
        ftable.row(&[
            l.op.to_string(),
            format!("{}", l.elems),
            format!("{:.1} us ({:.2} GB/s)", l.secs_per_op * 1e6, l.gbps),
            format!("{:.1} us ({:.2} GB/s)", t.secs_per_op * 1e6, t.gbps),
            format!("{:.2}x", t.gbps / l.gbps),
        ]);
    }
    ftable.print();

    // scheduler round-trip cost (protocol v9): no-op tasks serially vs
    // two tag lanes vs two tenants
    let sched_cells = bench_sched(&cfg, quick)?;
    let mut stable = Table::new(
        "Scheduler: no-op task round-trip (serial vs lanes vs tenants)",
        &["case", "tenants", "lanes", "per task", "tasks/s"],
    );
    for c in &sched_cells {
        stable.row(&[
            c.case.to_string(),
            format!("{}", c.tenants),
            format!("{}", c.lanes),
            format!("{:.2} ms", c.secs_per_task * 1e3),
            format!("{:.0}", c.tasks_per_sec),
        ]);
    }
    stable.print();

    if let Some(path) = args.get("json") {
        write_json(path, rows, cols, runs, quick, &cfg, &cells, &fabric_cells, &sched_cells)?;
    }
    Ok(())
}
