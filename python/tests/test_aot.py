"""AOT path: every spec lowers to parseable f64 HLO text, deterministically,
and the manifest round-trips the information the rust runtime needs."""

import os
import re

import pytest

from compile import aot


@pytest.fixture(scope="module")
def quick_specs():
    return aot.default_specs(quick=True)


def test_spec_names_unique():
    specs = aot.default_specs(quick=False)
    names = [s.name for s in specs]
    assert len(names) == len(set(names))


def test_full_set_covers_both_engines_and_tile_sizes():
    specs = aot.default_specs(quick=False)
    engines = {s.engine for s in specs}
    assert engines == {"pallas", "xla"}
    gemm_dims = {s.dims[0] for s in specs if s.op.startswith("gemm")}
    assert {128, 256, 512} <= gemm_dims


def test_lower_emits_f64_hlo(quick_specs):
    spec = next(s for s in quick_specs if s.op == "gemm_nn")
    text = aot.lower_spec(spec)
    assert "HloModule" in text
    assert "f64" in text
    # The paper's data is double precision end to end: no f32 leaks.
    assert not re.search(r"\bf32\b", text)


def test_lowering_is_deterministic(quick_specs):
    spec = next(s for s in quick_specs if s.op == "cg_update")
    assert aot.lower_spec(spec) == aot.lower_spec(spec)


def test_manifest_line_parses_back(quick_specs):
    for spec in quick_specs:
        kv = dict(tok.split("=", 1) for tok in spec.manifest_line().split())
        assert kv["name"] == spec.name
        assert kv["op"] == spec.op
        assert kv["engine"] == spec.engine
        assert kv["dtype"] == "f64"
        ins = kv["inputs"].split(";")
        assert len(ins) == len(spec.in_shapes)
        for s, txt in zip(spec.in_shapes, ins):
            assert tuple(int(d) for d in txt.split("x")) == s


def test_main_writes_artifacts(tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path), "--quick",
                   "--only", "xla_gemm_nn_256x256x256"])
    assert rc == 0
    files = os.listdir(tmp_path)
    assert "manifest.txt" in files
    assert "xla_gemm_nn_256x256x256.hlo.txt" in files
    manifest = (tmp_path / "manifest.txt").read_text()
    assert "xla_gemm_nn_256x256x256" in manifest
