//! Table 1: which feature-matrix sizes each system can run.
//!
//! Paper: Spark could not run CG beyond 10,000 random features; Alchemist
//! ran 10k–60k. The boundary is cluster memory for the cached expanded
//! RDD. Here the sweep is D ∈ {1024..6144} with the sparklite memory
//! budget scaled so the boundary lands mid-sweep; Alchemist expands
//! server-side and is bounded only by server RAM.

mod bench_common;

use alchemist::cli::Args;
use alchemist::client::AlchemistContext;
use alchemist::coordinator::AlchemistServer;
use alchemist::linalg::CgOptions;
use alchemist::metrics::Table;
use alchemist::protocol::Params;
use alchemist::sparklite::{mllib, IndexedRowMatrix, SparkEngine};
use alchemist::util::fmt;
use alchemist::workloads::TimitSpec;
use bench_common::{bench_config, is_quick, require_artifacts};

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let mut cfg = bench_config(&args)?;
    if !require_artifacts(&cfg) {
        return Ok(());
    }
    let quick = is_quick(&args);
    let rows = args.get_usize("rows", if quick { 1024 } else { 4096 })?;
    // budget calibrated so the Spark boundary falls inside the sweep,
    // like the paper's 10k-of-60k boundary
    cfg.spark_driver_max_bytes =
        args.get_usize("spark-budget", rows * 2560 * 8)?;
    let default_dims: &[usize] = if quick {
        &[1024, 4096]
    } else {
        &[1024, 2048, 3072, 4096, 5120, 6144]
    };
    let dims = args.get_usize_list("dims", default_dims)?;
    let workers = args.get_usize("workers", 3)?;

    let spec = TimitSpec { train_rows: rows, test_rows: 1, ..TimitSpec::default() };
    let data = spec.generate();
    let x = IndexedRowMatrix::from_local(&data.x_train, workers * 2);
    let y = IndexedRowMatrix::from_local(&data.y_train, workers * 2);
    let opts = CgOptions { lambda: 1e-5, tol: 0.0, max_iters: 2 };

    let mut table = Table::new(
        &format!(
            "Table 1 (scaled): feature-matrix capability, {} rows, spark budget {}",
            rows,
            fmt::bytes(cfg.spark_driver_max_bytes as u64)
        ),
        &["features D", "expanded size", "Spark", "Alchemist"],
    );

    let server = AlchemistServer::start(cfg.clone(), workers)?;
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, workers)?;
    ac.register_library("skylark", "builtin:skylark")?;
    let (al_x, _) = ac.send_matrix("X", &x)?;
    let (al_y, _) = ac.send_matrix("Y", &y)?;

    for &d in &dims {
        // Spark: expansion must fit the cluster memory budget
        let spark_ok = {
            let mut engine = SparkEngine::new(workers, &cfg);
            engine.inject_real_delays = false; // capability check only
            let map =
                alchemist::linalg::RffMap::generate(spec.raw_features, d, 0.06, 1);
            mllib::rff_expand(&mut engine, &x, &map)
                .and_then(|z| mllib::cg_solve(&mut engine, &z, &y, &opts))
                .is_ok()
        };
        // Alchemist: expand + 2 CG iterations server-side
        let alch_ok = ac
            .run_task(
                "skylark",
                "cg_solve",
                Params::new()
                    .with_matrix("X", al_x.id)
                    .with_matrix("Y", al_y.id)
                    .with_f64("lambda", 1e-5)
                    .with_f64("tol", 0.0)
                    .with_i64("max_iters", 2)
                    .with_i64("rff_d", d as i64)
                    .with_f64("rff_gamma", 0.06)
                    .with_i64("rff_seed", 1),
            )
            .is_ok();
        table.row(&[
            d.to_string(),
            fmt::bytes((rows * d * 8) as u64),
            if spark_ok { "Yes" } else { "No" }.into(),
            if alch_ok { "Yes" } else { "No" }.into(),
        ]);
    }

    ac.shutdown_server()?;
    server.shutdown_on_request();
    table.print();
    println!("paper: Spark capped at 10,000 features; Alchemist ran 10k-60k");
    Ok(())
}
