//! In-process communicator: ranks are threads, messages are mailboxes.
//!
//! Used by the coordinator's worker group (the paper runs Alchemist's MPI
//! ranks inside one allocation; we run them inside one process). A
//! [`crate::config::SimNetConfig`] cost model charges each *received*
//! message with modeled interconnect time so the SimClock can reconstruct
//! what the same traffic would cost across nodes.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Condvar, Mutex};

use crate::config::SimNetConfig;

use super::Communicator;

type Key = (usize, u64); // (sender, tag)

#[derive(Default)]
struct Mailbox {
    // FIFO per (sender, tag)
    queues: Mutex<HashMap<Key, std::collections::VecDeque<Vec<f64>>>>,
    signal: Condvar,
}

struct Shared {
    boxes: Vec<Mailbox>,
    barrier: Barrier,
    simnet: Option<SimNetConfig>,
}

/// One rank's endpoint into the shared in-proc fabric.
pub struct LocalComm {
    rank: usize,
    size: usize,
    /// This endpoint's rank in the server's full worker pool (== `rank`
    /// for groups built with [`LocalComm::group`]).
    global_rank: usize,
    shared: Arc<Shared>,
    /// Modeled comm nanoseconds charged to this rank.
    sim_ns: Arc<AtomicU64>,
}

impl LocalComm {
    /// Create endpoints for a `size`-rank group.
    pub fn group(size: usize, simnet: Option<SimNetConfig>) -> Vec<LocalComm> {
        assert!(size > 0);
        let ranks: Vec<usize> = (0..size).collect();
        Self::subgroup(&ranks, simnet)
    }

    /// Create endpoints for an independent communicator over an arbitrary
    /// subset of global worker ranks (session-scoped worker groups).
    /// Endpoint `i` gets group-local rank `i` and remembers
    /// `global_ranks[i]`. The fabric (mailboxes, barrier) is fresh, so
    /// collectives on disjoint subgroups never contend with each other.
    pub fn subgroup(
        global_ranks: &[usize],
        simnet: Option<SimNetConfig>,
    ) -> Vec<LocalComm> {
        let size = global_ranks.len();
        assert!(size > 0, "subgroup must have at least one rank");
        {
            let mut sorted = global_ranks.to_vec();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), size, "subgroup ranks must be distinct");
        }
        let shared = Arc::new(Shared {
            boxes: (0..size).map(|_| Mailbox::default()).collect(),
            barrier: Barrier::new(size),
            simnet,
        });
        global_ranks
            .iter()
            .enumerate()
            .map(|(rank, &global_rank)| LocalComm {
                rank,
                size,
                global_rank,
                shared: shared.clone(),
                sim_ns: Arc::new(AtomicU64::new(0)),
            })
            .collect()
    }

    /// Rank in the server's full worker pool (group-local ranks are what
    /// [`Communicator::rank`] returns).
    pub fn global_rank(&self) -> usize {
        self.global_rank
    }

    fn charge(&self, bytes: usize) {
        if let Some(net) = &self.shared.simnet {
            let secs = net.transfer_secs(bytes);
            self.sim_ns
                .fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
        }
    }
}

impl Communicator for LocalComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send(&self, to: usize, tag: u64, data: Vec<f64>) {
        debug_assert!(to < self.size);
        let mbox = &self.shared.boxes[to];
        let mut queues = mbox.queues.lock().unwrap();
        queues.entry((self.rank, tag)).or_default().push_back(data);
        mbox.signal.notify_all();
    }

    fn recv(&self, from: usize, tag: u64) -> Vec<f64> {
        let mbox = &self.shared.boxes[self.rank];
        let mut queues = mbox.queues.lock().unwrap();
        loop {
            if let Some(q) = queues.get_mut(&(from, tag)) {
                if let Some(data) = q.pop_front() {
                    drop(queues);
                    self.charge(data.len() * 8);
                    return data;
                }
            }
            queues = mbox.signal.wait(queues).unwrap();
        }
    }

    fn barrier(&self) {
        self.shared.barrier.wait();
    }

    fn sim_comm_secs(&self) -> f64 {
        self.sim_ns.load(Ordering::Relaxed) as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spawn_ranks<F>(n: usize, f: F)
    where
        F: Fn(LocalComm) + Send + Sync + Clone + 'static,
    {
        let comms = LocalComm::group(n, None);
        let mut handles = Vec::new();
        for c in comms {
            let f = f.clone();
            handles.push(std::thread::spawn(move || f(c)));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn point_to_point_fifo_per_tag() {
        spawn_ranks(2, |c| {
            if c.rank() == 0 {
                c.send(1, 5, vec![1.0]);
                c.send(1, 5, vec![2.0]);
                c.send(1, 9, vec![3.0]);
            } else {
                // tag 9 can be read before tag 5's backlog
                assert_eq!(c.recv(0, 9), vec![3.0]);
                assert_eq!(c.recv(0, 5), vec![1.0]);
                assert_eq!(c.recv(0, 5), vec![2.0]);
            }
        });
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        COUNT.store(0, Ordering::SeqCst);
        spawn_ranks(4, |c| {
            COUNT.fetch_add(1, Ordering::SeqCst);
            c.barrier();
            // after the barrier every rank must observe all 4 arrivals
            assert_eq!(COUNT.load(Ordering::SeqCst), 4);
        });
    }

    #[test]
    fn subgroup_is_local_ranked_and_independent() {
        // two disjoint subgroups of a 5-rank pool run collectives
        // concurrently without seeing each other's traffic or barriers
        let ga = [1usize, 4];
        let gb = [0usize, 2, 3];
        let ca = LocalComm::subgroup(&ga, None);
        let cb = LocalComm::subgroup(&gb, None);
        for (i, c) in ca.iter().enumerate() {
            assert_eq!(c.rank(), i);
            assert_eq!(c.size(), 2);
            assert_eq!(c.global_rank(), ga[i]);
        }
        let mut handles = Vec::new();
        for c in ca.into_iter().chain(cb.into_iter()) {
            handles.push(std::thread::spawn(move || {
                // ring exchange within the group, then a group barrier:
                // would deadlock if the fabrics were shared
                let next = (c.rank() + 1) % c.size();
                let prev = (c.rank() + c.size() - 1) % c.size();
                c.send(next, 7, vec![c.global_rank() as f64]);
                let got = c.recv(prev, 7);
                assert_eq!(got.len(), 1);
                c.barrier();
                got[0]
            }));
        }
        let vals: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let mut sorted = vals;
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(sorted, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "distinct")]
    fn subgroup_rejects_duplicate_ranks() {
        let _ = LocalComm::subgroup(&[1, 1], None);
    }

    #[test]
    fn sim_cost_charged_on_receive() {
        let comms = LocalComm::group(
            2,
            Some(crate::config::SimNetConfig { latency_s: 1e-6, bytes_per_s: 1e9 }),
        );
        let [c0, c1]: [LocalComm; 2] = comms.try_into().map_err(|_| ()).unwrap();
        let t = std::thread::spawn(move || {
            c0.send(1, 0, vec![0.0; 1000]);
            c0.sim_comm_secs()
        });
        let _ = c1.recv(0, 0);
        let sender_cost = t.join().unwrap();
        assert_eq!(sender_cost, 0.0);
        // 8000 bytes at 1 GB/s + 1 µs = 9 µs
        assert!((c1.sim_comm_secs() - 9e-6).abs() < 1e-7, "{}", c1.sim_comm_secs());
    }
}
