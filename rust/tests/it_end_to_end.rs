//! End-to-end integration: the paper's two experiments, miniaturized, on
//! the full production stack — TCP client, Alchemist server, XLA engine on
//! the workers (requires `make artifacts`; skips loudly otherwise).

use alchemist::client::AlchemistContext;
use alchemist::config::{Config, EngineKind};
use alchemist::coordinator::AlchemistServer;
use alchemist::distmat::LocalMatrix;
use alchemist::protocol::Params;
use alchemist::sparklite::IndexedRowMatrix;
use alchemist::workloads::{timit, OceanSpec, TimitSpec};

fn xla_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.engine = EngineKind::Xla;
    cfg
}

macro_rules! require_artifacts {
    ($cfg:expr) => {
        if !$cfg.resolved_artifacts_dir().join("manifest.txt").exists() {
            eprintln!("SKIP: artifacts missing; run `make artifacts`");
            return;
        }
    };
}

#[test]
fn speech_cg_offload_end_to_end() {
    let cfg = xla_cfg();
    require_artifacts!(cfg);
    // miniature TIMIT: raw features in, RFF expansion + CG server-side
    let spec = TimitSpec {
        train_rows: 512,
        test_rows: 128,
        raw_features: 40,
        classes: 8,
        noise: 0.4,
        seed: 99,
    };
    let data = spec.generate();

    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 2).unwrap();
    ac.register_library("skylark", "builtin:skylark").unwrap();

    let (al_x, _) = ac
        .send_matrix("X", &IndexedRowMatrix::from_local(&data.x_train, 4))
        .unwrap();
    let (al_y, _) = ac
        .send_matrix("Y", &IndexedRowMatrix::from_local(&data.y_train, 4))
        .unwrap();

    let rff_d = 512usize;
    let res = ac
        .run_task(
            "skylark",
            "cg_solve",
            Params::new()
                .with_matrix("X", al_x.id)
                .with_matrix("Y", al_y.id)
                .with_f64("lambda", 1e-4)
                .with_f64("tol", 1e-8)
                .with_i64("max_iters", 200)
                .with_i64("rff_d", rff_d as i64)
                .with_f64("rff_gamma", 0.1)
                .with_i64("rff_seed", 1234),
        )
        .unwrap();
    assert!(res.timing("expand") > 0.0, "expansion happened server-side");
    let al_w = res.output("W").unwrap().clone();
    assert_eq!((al_w.rows, al_w.cols), (rff_d, 8));

    let (w, _) = ac.to_indexed_row_matrix(&al_w, 1).unwrap();
    let w = w.to_local().unwrap();

    // client-side evaluation: expand test features with the same map
    let map = alchemist::linalg::RffMap::generate(40, rff_d, 0.1, 1234);
    let mut ne = alchemist::compute::NativeEngine::new();
    let z_test = map.expand(&mut ne, &data.x_test).unwrap();
    let mut scores = LocalMatrix::zeros(z_test.rows(), 8);
    scores.gemm_nn(&z_test, &w);
    let acc = timit::accuracy(&scores, &data.labels_test);
    assert!(acc > 0.5, "test accuracy {acc} must beat 1/8 chance soundly");

    ac.stop();
    server.shutdown();
}

#[test]
fn ocean_svd_offload_end_to_end() {
    let cfg = xla_cfg();
    require_artifacts!(cfg);
    let spec = OceanSpec {
        cells: 1024,
        times: 192,
        modes: 8,
        sigma0: 60.0,
        decay: 0.7,
        noise: 0.02,
        seed: 42,
    };
    let dir = std::env::temp_dir().join("alchemist-it-e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ocean.bin");
    spec.write_file(&path).unwrap();

    let server = AlchemistServer::start(cfg.clone(), 3).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 2).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();

    // use-case 3 of Table 5: Alchemist loads the file directly
    let load = ac
        .run_task(
            "elemental",
            "load_hdf5",
            Params::new().with_str("path", path.to_str().unwrap()),
        )
        .unwrap();
    let al_a = load.output("A").unwrap().clone();
    assert_eq!((al_a.rows, al_a.cols), (1024, 192));
    assert!(load.timing("load") > 0.0);

    let svd = ac
        .run_task(
            "elemental",
            "truncated_svd",
            Params::new().with_matrix("A", al_a.id).with_i64("rank", 8),
        )
        .unwrap();
    let sigma = match svd.scalars.get("sigma") {
        Some(alchemist::protocol::Value::F64s(v)) => v.clone(),
        other => panic!("sigma missing: {other:?}"),
    };
    assert_eq!(sigma.len(), 8);

    // results back to the client (the S ⇐ A leg)
    let al_u = svd.output("U").unwrap().clone();
    let al_v = svd.output("V").unwrap().clone();
    let (u, _) = ac.to_indexed_row_matrix(&al_u, 2).unwrap();
    let (v, _) = ac.to_indexed_row_matrix(&al_v, 1).unwrap();
    let u = u.to_local().unwrap();
    let v = v.to_local().unwrap();

    // certify: ‖A·v_k − σ_k·u_k‖ small relative to σ_k, and energy capture
    let a = alchemist::hdf5sim::read_matrix(&path).unwrap();
    let mut av = LocalMatrix::zeros(1024, 8);
    av.gemm_nn(&a, &v);
    for k in 0..8 {
        let mut res = 0.0f64;
        for i in 0..1024 {
            res += (av.get(i, k) - sigma[k] * u.get(i, k)).powi(2);
        }
        let rel = res.sqrt() / sigma[k].max(1e-300);
        assert!(rel < 1e-6, "triplet {k} residual {rel}");
    }
    let energy: f64 = sigma.iter().map(|s| s * s).sum();
    assert!(energy / a.fro_sq() > 0.95, "rank-8 energy capture");

    // spark baseline agrees on the spectrum (numerics identical)
    let mut cfg_q = Config::default();
    cfg_q.overhead.scheduler_delay_s = 0.0;
    cfg_q.overhead.task_launch_s = 0.0;
    let mut spark = alchemist::sparklite::SparkEngine::new(2, &cfg_q);
    spark.inject_real_delays = false;
    let sres = alchemist::sparklite::mllib::truncated_svd(
        &mut spark,
        &IndexedRowMatrix::from_local(&a, 4),
        &alchemist::linalg::SvdOptions { rank: 8, steps: 0, seed: 0x53D5 },
    )
    .unwrap();
    for (a_s, b_s) in sigma.iter().zip(&sres.sigma) {
        assert!((a_s - b_s).abs() < 1e-6 * (1.0 + b_s), "{a_s} vs {b_s}");
    }

    ac.shutdown_server().unwrap();
    server.shutdown_on_request();
}
