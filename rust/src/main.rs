//! `alchemist` — the leader binary.
//!
//! Subcommands:
//!
//! * `serve --workers N [--port P] [--engine E]` — run an Alchemist
//!   server until a client sends Shutdown (or ^C). With
//!   `--set fabric.mode=tcp` the worker ranks are spawned as `worker`
//!   subprocesses instead of threads (protocol v8, `docs/fabric.md`).
//! * `worker --connect ADDR --rank-id N` — one process-separated worker
//!   rank; normally spawned by a tcp-mode `serve`, not by hand. Exits
//!   when the coordinator shuts down or drops the connection.
//! * `info` — print config, artifact manifest summary, and library list.
//! * `gen-ocean --out FILE [--cells N --times T]` — write a synthetic
//!   ocean field to an hdf5sim file (used by the Table 5 / Fig 3 drivers).
//!
//! See `examples/` for end-to-end drivers and `rust/benches/` for the
//! paper's tables and figures.

use alchemist::cli::Args;
use alchemist::config::Config;
use alchemist::coordinator::AlchemistServer;
use alchemist::workloads::OceanSpec;

fn apply_overrides(cfg: &mut Config, args: &Args) -> alchemist::Result<()> {
    if let Some(engine) = args.get("engine") {
        cfg.apply("engine", engine)?;
    }
    if let Some(dir) = args.get("artifacts-dir") {
        cfg.apply("artifacts_dir", dir)?;
    }
    if let Some(pairs) = args.get("set") {
        for pair in pairs.split(',') {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--set expects k=v, got {pair:?}"))?;
            cfg.apply(k.trim(), v.trim())?;
        }
    }
    Ok(())
}

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let mut cfg = match args.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?,
        None => Config::default(),
    };
    apply_overrides(&mut cfg, &args)?;

    match args.subcommand(&["serve", "worker", "info", "gen-ocean"])? {
        "serve" => {
            let workers = args.get_usize("workers", 3)?;
            let handle = AlchemistServer::start(cfg, workers)?;
            println!("control address: {}", handle.control_addr);
            for (r, a) in handle.worker_addrs.iter().enumerate() {
                println!("worker {r} data address: {a}");
            }
            println!("serving until a client sends Shutdown ...");
            // Park until the server stops itself (client-initiated).
            // The handle's threads own the sockets; joining them blocks
            // this thread exactly as long as the server lives.
            handle.shutdown_on_request();
        }
        "worker" => {
            let connect = args.get("connect").ok_or_else(|| {
                anyhow::anyhow!("--connect COORDINATOR_ADDR required")
            })?;
            let rank = args.get_usize("rank-id", usize::MAX)?;
            anyhow::ensure!(rank != usize::MAX, "--rank-id N required");
            alchemist::coordinator::remote::run_worker(connect, rank, cfg)?;
        }
        "info" => {
            println!("engine: {}", cfg.engine.as_str());
            println!("artifacts: {:?}", cfg.resolved_artifacts_dir());
            match alchemist::runtime::Manifest::load(
                &cfg.resolved_artifacts_dir().join("manifest.txt"),
            ) {
                Ok(m) => {
                    println!("{} artifacts:", m.entries().len());
                    for e in m.entries() {
                        println!(
                            "  {} ({} {} dims {:?})",
                            e.name, e.engine, e.op, e.dims
                        );
                    }
                }
                Err(e) => println!("no manifest: {e:#} (run `make artifacts`)"),
            }
            println!("builtin libraries: skylark, elemental");
        }
        "gen-ocean" => {
            let out = args
                .get("out")
                .ok_or_else(|| anyhow::anyhow!("--out FILE required"))?;
            let spec = OceanSpec {
                cells: args.get_usize("cells", OceanSpec::default().cells)?,
                times: args.get_usize("times", OceanSpec::default().times)?,
                ..OceanSpec::default()
            };
            let bytes = spec.write_file(std::path::Path::new(out))?;
            println!(
                "wrote {} ({} x {}) to {out}",
                alchemist::util::fmt::bytes(bytes),
                spec.cells,
                spec.times
            );
        }
        _ => unreachable!(),
    }
    Ok(())
}
