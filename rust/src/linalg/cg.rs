//! Distributed block conjugate gradient on the regularized normal
//! equations — the libSkylark routine of paper §4.1.
//!
//! Solves `(XᵀX + nλI)·W = XᵀY` for the ridge-regression weights `W`
//! (D×C, one column per class). X (n×D) and Y (n×C) are row-distributed;
//! W and the CG state are replicated, so the only communication per
//! iteration is one allreduce of the Gram-operator partial sums — exactly
//! the communication profile that makes this loop cheap under MPI and
//! ruinously expensive under Spark's per-stage overheads (Table 2).
//!
//! Each column runs its own scalar CG recurrence (shared matvec): `alpha`
//! and `beta` are per-column, applied by the engine's fused `cg_update`.

use crate::collectives::{allreduce_sum, Communicator};
use crate::compute::Engine;
use crate::distmat::LocalMatrix;
use crate::tasks::TaskScope;

#[derive(Debug, Clone)]
pub struct CgOptions {
    /// Ridge regularizer λ (the paper uses 1e-5).
    pub lambda: f64,
    /// Stop when every column's relative residual falls below this.
    pub tol: f64,
    pub max_iters: usize,
}

impl Default for CgOptions {
    fn default() -> Self {
        CgOptions { lambda: 1e-5, tol: 1e-8, max_iters: 500 }
    }
}

#[derive(Debug)]
pub struct CgResult {
    /// D×C solution (replicated; identical on every rank).
    pub w: LocalMatrix,
    pub iters: usize,
    /// Max-over-columns relative residual after each iteration.
    pub residuals: Vec<f64>,
    /// Wall seconds per iteration (this rank).
    pub iter_secs: Vec<f64>,
}

/// Tag window base for CG's collectives.
const TAG: u64 = 0x4347_0000;

/// SPMD block-CG. `x_local`/`y_local` are this rank's rows of X and Y;
/// `n_global` is the total row count (for the nλ scaling). Runs under a
/// detached [`TaskScope`] — never cancelled, progress unobserved.
pub fn cg_solve(
    comm: &dyn Communicator,
    engine: &mut dyn Engine,
    x_local: &LocalMatrix,
    y_local: &LocalMatrix,
    n_global: usize,
    opts: &CgOptions,
) -> crate::Result<CgResult> {
    cg_solve_scoped(comm, engine, x_local, y_local, n_global, opts, &TaskScope::detached())
}

/// [`cg_solve`] under an explicit [`TaskScope`]: each iteration reports
/// `(iteration, max relative residual)` and the ranks *collectively*
/// decide cancellation — the locally-observed token is allreduced at the
/// iteration boundary so either every rank bails together or none does
/// (a unilateral bail would strand peers inside the Gram allreduce).
/// Cancellation is observed within one iteration.
pub fn cg_solve_scoped(
    comm: &dyn Communicator,
    engine: &mut dyn Engine,
    x_local: &LocalMatrix,
    y_local: &LocalMatrix,
    n_global: usize,
    opts: &CgOptions,
    scope: &TaskScope,
) -> crate::Result<CgResult> {
    let d = x_local.cols();
    let c = y_local.cols();
    anyhow::ensure!(
        x_local.rows() == y_local.rows(),
        "X and Y row counts differ on rank {}",
        comm.rank()
    );
    let reg = n_global as f64 * opts.lambda;
    // reg·V must enter the operator exactly once across ranks: rank 0
    // carries it, the allreduce distributes it.
    let reg_local = if comm.rank() == 0 { reg } else { 0.0 };

    // operand key: X is static across the whole solve, so device-backed
    // engines keep its panels resident (§Perf)
    let x_key = crate::compute::fresh_operand_key();

    // b = XᵀY (allreduced partial products)
    let mut b = LocalMatrix::zeros(d, c);
    engine.gemm(crate::compute::GemmVariant::TN, &mut b, x_local, y_local)?;
    allreduce_sum(comm, TAG, b.data_mut())?;

    let mut w = LocalMatrix::zeros(d, c);
    let mut r = b.clone(); // r = b - A·0
    let mut p = r.clone();
    let rs0: Vec<f64> = r.col_dots(&r);
    let mut rs_old = rs0.clone();

    let mut residuals = Vec::new();
    let mut iter_secs = Vec::new();
    let mut iters = 0;

    for it in 0..opts.max_iters {
        let t0 = std::time::Instant::now();

        // collective cancellation check at the iteration boundary (the
        // Gram allreduce below keeps ranks in lockstep, so all reach
        // this together and agree); free for detached scopes, so plain
        // `cg_solve` callers pay no extra collective per iteration
        scope.collective_check_cancelled(
            comm,
            TAG + (1 + 2 * (it % 64) as u64) * crate::collectives::TAG_WINDOW,
        )?;

        // q = (XᵀX + nλI)·p — the hot path
        let mut q = engine.gram_matvec_keyed(x_key, x_local, &p, reg_local)?;
        allreduce_sum(
            comm,
            TAG + (2 + 2 * (it % 64) as u64) * crate::collectives::TAG_WINDOW,
            q.data_mut(),
        )?;

        let pq = p.col_dots(&q);
        let alpha: Vec<f64> = rs_old
            .iter()
            .zip(&pq)
            .map(|(&rs, &pq)| if pq.abs() > 0.0 { rs / pq } else { 0.0 })
            .collect();

        engine.cg_update(&mut w, &mut r, &p, &q, &alpha)?;

        let rs_new = r.col_dots(&r);
        let rel = rs_new
            .iter()
            .zip(&rs0)
            .map(|(&n, &z)| if z > 0.0 { (n / z).sqrt() } else { 0.0 })
            .fold(0.0f64, f64::max);
        residuals.push(rel);
        iter_secs.push(t0.elapsed().as_secs_f64());
        iters = it + 1;
        scope.report(iters as u64, rel);

        if rel < opts.tol {
            break;
        }

        let beta: Vec<f64> = rs_new
            .iter()
            .zip(&rs_old)
            .map(|(&n, &o)| if o > 0.0 { n / o } else { 0.0 })
            .collect();
        // p = r + beta ⊙ p
        for i in 0..d {
            let pr = p.row_mut(i);
            let rr = r.row(i);
            for j in 0..c {
                pr[j] = rr[j] + beta[j] * pr[j];
            }
        }
        rs_old = rs_new;
    }

    Ok(CgResult { w, iters, residuals, iter_secs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::LocalComm;
    use crate::compute::NativeEngine;
    use crate::distmat::RowBlockLayout;
    use crate::util::prng::Rng;

    /// Serial reference: dense solve of (XᵀX + nλI) W = XᵀY via Cholesky.
    fn ridge_ref(x: &LocalMatrix, y: &LocalMatrix, lambda: f64) -> LocalMatrix {
        let d = x.cols();
        let mut g = LocalMatrix::identity(d);
        g.scale(x.rows() as f64 * lambda);
        g.gemm_tn(x, x);
        let mut b = LocalMatrix::zeros(d, y.cols());
        b.gemm_tn(x, y);
        let r = crate::linalg::dense::cholesky_upper(&g).unwrap();
        // solve RᵀR W = B: forward then back substitution, column-wise
        let bt = b.transpose();
        let z = crate::linalg::dense::solve_right_upper(&bt, &r).unwrap(); // z·R = bᵀ → z = bᵀR⁻¹ = (R⁻ᵀ b)ᵀ
        // now solve wᵀ Rᵀ = z  ⇔  R w = zᵀ: use right-solve against Rᵀ
        // easier: w = R⁻¹ zᵀ via back substitution on columns
        let n = d;
        let zt = z.transpose();
        let mut w = LocalMatrix::zeros(n, y.cols());
        for col in 0..y.cols() {
            for i in (0..n).rev() {
                let mut s = zt.get(i, col);
                for k in i + 1..n {
                    s -= r.get(i, k) * w.get(k, col);
                }
                w.set(i, col, s / r.get(i, i));
            }
        }
        w
    }

    fn run_cg_on(workers: usize, n: usize, d: usize, c: usize, lambda: f64) {
        let mut rng = Rng::new(42);
        let x = LocalMatrix::from_fn(n, d, |_, _| rng.normal());
        let y = LocalMatrix::from_fn(n, c, |_, _| rng.normal());
        let want = ridge_ref(&x, &y, lambda);

        let layout = RowBlockLayout::even(n, d, workers);
        let comms = LocalComm::group(workers, None);
        let mut handles = Vec::new();
        for comm in comms {
            let (a, b) = layout.ranges[comm.rank()];
            let xl = x.slice_rows(a, b);
            let yl = y.slice_rows(a, b);
            handles.push(std::thread::spawn(move || {
                let mut engine = NativeEngine::new();
                cg_solve(
                    &comm,
                    &mut engine,
                    &xl,
                    &yl,
                    n,
                    &CgOptions { lambda, tol: 1e-12, max_iters: 400 },
                )
                .unwrap()
            }));
        }
        let results: Vec<CgResult> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for res in &results {
            assert!(
                res.w.max_abs_diff(&want) < 1e-6,
                "workers={workers}: diff {}",
                res.w.max_abs_diff(&want)
            );
            // replicated state: all ranks identical
            assert_eq!(res.w, results[0].w);
            // residuals decrease overall
            assert!(res.residuals.last().unwrap() < &1e-10);
        }
    }

    #[test]
    fn matches_dense_solve_single_rank() {
        run_cg_on(1, 40, 12, 3, 1e-3);
    }

    #[test]
    fn matches_dense_solve_multi_rank() {
        run_cg_on(3, 46, 10, 4, 1e-3);
        run_cg_on(4, 32, 8, 1, 1e-2);
    }

    #[test]
    fn cancel_is_observed_within_an_iteration_and_progress_reported() {
        use crate::tasks::{CancelToken, RankProgress, TaskScope, CANCELLED_MSG};
        use std::sync::Arc;

        // a solve that cannot converge (tol = 0) with a huge iteration
        // budget: only cancellation ends it
        let workers = 2usize;
        let n = 32;
        let mut rng = Rng::new(9);
        let x = LocalMatrix::from_fn(n, 8, |_, _| rng.normal());
        let y = LocalMatrix::from_fn(n, 2, |_, _| rng.normal());
        let layout = RowBlockLayout::even(n, 8, workers);
        let comms = LocalComm::group(workers, None);

        let token = Arc::new(CancelToken::new());
        let slots: Vec<Arc<RankProgress>> =
            (0..workers).map(|_| Arc::new(RankProgress::new())).collect();
        let mut handles = Vec::new();
        for comm in comms {
            let rank = comm.rank();
            let (a, b) = layout.ranges[rank];
            let xl = x.slice_rows(a, b);
            let yl = y.slice_rows(a, b);
            let scope = TaskScope::new(token.clone(), slots[rank].clone());
            handles.push(std::thread::spawn(move || {
                let mut engine = NativeEngine::new();
                cg_solve_scoped(
                    &comm,
                    &mut engine,
                    &xl,
                    &yl,
                    n,
                    &CgOptions { lambda: 1e-3, tol: 0.0, max_iters: 50_000_000 },
                    &scope,
                )
            }));
        }
        // let some iterations happen, then pull the plug
        while slots.iter().any(|s| s.iters() < 3) {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        token.cancel();
        for h in handles {
            let err = h.join().unwrap().unwrap_err();
            // every rank bailed with the cancellation marker (nobody hung
            // in a collective waiting for a departed peer)
            assert!(err.to_string().contains(CANCELLED_MSG), "{err}");
        }
        for s in &slots {
            assert!(s.iters() >= 3, "progress was reported before cancel");
            assert!(s.residual() >= 0.0, "residual was reported");
        }
    }

    #[test]
    fn zero_rhs_converges_immediately() {
        let comms = LocalComm::group(1, None);
        let x = LocalMatrix::from_fn(10, 4, |i, j| (i + j) as f64 * 0.1);
        let y = LocalMatrix::zeros(10, 2);
        let mut engine = NativeEngine::new();
        let res = cg_solve(&comms[0], &mut engine, &x, &y, 10, &CgOptions::default()).unwrap();
        assert!(res.w.fro_norm() < 1e-12);
        assert_eq!(res.iters, 1);
    }
}
