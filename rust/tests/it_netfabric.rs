//! Integration: the protocol-v8 network rank fabric (`docs/fabric.md`).
//!
//! Three layers, from transport up:
//!
//! * loopback [`TcpComm`] groups produce **bit-identical** results to
//!   [`LocalComm`] for every collective algorithm, on both sides of the
//!   recursive-doubling/ring switch and on both the eager and the
//!   gathered-`writev` rendezvous wire paths;
//! * a 4-process `fabric.mode = tcp` server (each rank its own spawned
//!   `alchemist worker` OS process) runs CG and truncated SVD end to end
//!   and matches the thread-pool local mode bit for bit;
//! * killing one worker process mid-solve fails the task promptly with
//!   the dead rank as the root cause — no hang, peers unwind as
//!   collateral through the mesh poison.

use std::time::{Duration, Instant};

use alchemist::client::AlchemistContext;
use alchemist::collectives::algorithms::{self, ALLREDUCE_DOUBLING_MAX_ELEMS};
use alchemist::collectives::{
    loopback_group, Communicator, FabricOptions, LocalComm, TAG_WINDOW,
};
use alchemist::config::{Config, EngineKind, FabricMode};
use alchemist::coordinator::AlchemistServer;
use alchemist::distmat::LocalMatrix;
use alchemist::protocol::{Params, TaskState, Value};
use alchemist::sparklite::IndexedRowMatrix;
use alchemist::util::prng::Rng;

fn native_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.engine = EngineKind::Native;
    cfg
}

/// Local mode config, switched onto the process fabric. The worker
/// executable must be named explicitly: inside an integration test
/// `current_exe()` is the test runner, not `alchemist`.
fn tcp_cfg() -> Config {
    let mut cfg = native_cfg();
    cfg.fabric.mode = FabricMode::Tcp;
    cfg.fabric.worker_exe = env!("CARGO_BIN_EXE_alchemist").into();
    cfg
}

fn random_matrix(seed: u64, rows: usize, cols: usize) -> LocalMatrix {
    let mut rng = Rng::new(seed);
    LocalMatrix::from_fn(rows, cols, |_, _| rng.normal())
}

/// Run `f` on every rank of `comms` (one thread per rank) and return the
/// per-rank results.
fn run_ranks<C, T, F>(comms: Vec<C>, f: F) -> Vec<T>
where
    C: Communicator + 'static,
    T: Send + 'static,
    F: Fn(&dyn Communicator) -> T + Send + Sync + Clone + 'static,
{
    let mut handles = Vec::new();
    for c in comms {
        let f = f.clone();
        handles.push(std::thread::spawn(move || f(&c)));
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Deterministic per-rank input, a pure function of (rank, index) so the
/// local and tcp runs feed every algorithm the exact same bits.
fn rank_input(rank: usize, n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i * 31 + rank * 977) % 1009) as f64 * 0.5 - 99.0).collect()
}

/// The full collective suite, once per vector size, each invocation in
/// its own TAG_WINDOW. Returns every rank-visible result in order.
fn collective_suite(c: &dyn Communicator, sizes: &[usize]) -> Vec<Vec<f64>> {
    let mut out = Vec::new();
    let mut win = 0u64;
    for &n in sizes {
        let mine = rank_input(c.rank(), n);

        win += 1;
        let mut buf = mine.clone();
        algorithms::allreduce_sum(c, win * TAG_WINDOW, &mut buf).unwrap();
        out.push(buf);

        win += 1;
        let mut b = if c.rank() == 0 { mine.clone() } else { Vec::new() };
        algorithms::broadcast(c, win * TAG_WINDOW, 0, &mut b).unwrap();
        out.push(b);

        // reduce_sum consumes non-root buffers (contents unspecified
        // after the call), so only root's result is comparable
        win += 1;
        let mut r = mine.clone();
        algorithms::reduce_sum(c, win * TAG_WINDOW, 0, &mut r).unwrap();
        out.push(if c.rank() == 0 { r } else { Vec::new() });

        win += 1;
        let g = algorithms::gather(c, win * TAG_WINDOW, 0, mine.clone()).unwrap();
        out.push(g.map(|parts| parts.concat()).unwrap_or_default());

        win += 1;
        let parts = (c.rank() == 0)
            .then(|| (0..c.size()).map(|r| rank_input(r, n)).collect());
        out.push(algorithms::scatter(c, win * TAG_WINDOW, 0, parts).unwrap());

        win += 1;
        let ag = algorithms::allgather(c, win * TAG_WINDOW, mine).unwrap();
        out.push(ag.concat());

        c.barrier().unwrap();
    }
    out
}

/// Collectives over a loopback TCP mesh must be *bit-identical* to the
/// in-process mailboxes: the wire moves raw f64 little-endian bytes and
/// the algorithms (and so the reduction order) are shared.
fn assert_loopback_matches_local(opts: FabricOptions, sizes: &'static [usize]) {
    for p in [1usize, 2, 3, 4] {
        let local = run_ranks(LocalComm::group(p, None), move |c| {
            collective_suite(c, sizes)
        });
        let tcp = run_ranks(loopback_group(p, &opts).unwrap(), move |c| {
            collective_suite(c, sizes)
        });
        for (rank, (l, t)) in local.iter().zip(&tcp).enumerate() {
            assert_eq!(l, t, "p={p} rank={rank}");
        }
    }
}

#[test]
fn loopback_eager_path_bit_identical_to_local() {
    // default threshold (4 KiB): every size below stays on the eager
    // (buffered) wire path
    assert_loopback_matches_local(FabricOptions::default(), &[1, 3, 7, 65]);
}

#[test]
fn loopback_rendezvous_path_bit_identical_to_local() {
    // 64-byte eager cutoff: everything from 8 elements up takes the
    // gathered-writev rendezvous leg, including both sides of the
    // allreduce doubling/ring switch
    let opts = FabricOptions { eager_bytes: 64, ..FabricOptions::default() };
    assert_loopback_matches_local(
        opts,
        &[1, 8, 129, ALLREDUCE_DOUBLING_MAX_ELEMS, ALLREDUCE_DOUBLING_MAX_ELEMS + 1],
    );
}

/// The paper's Figure 2 loop on a 4-process fabric, checked bit-for-bit
/// against the same session in thread-pool local mode: CG solve and
/// truncated SVD produce the same group shape, the same reduction order,
/// and therefore the exact same doubles either way.
#[test]
fn four_process_cg_and_svd_match_local_mode_bit_for_bit() {
    let x = random_matrix(11, 120, 24);
    let y = random_matrix(12, 120, 3);
    let a = random_matrix(13, 96, 10);

    // (W, iters, sigma, U) for one server mode
    let run = |cfg: Config| -> (LocalMatrix, i64, Vec<f64>, LocalMatrix) {
        let server = AlchemistServer::start(cfg.clone(), 4).unwrap();
        let mut ac =
            AlchemistContext::connect(&server.control_addr, &cfg, 4).unwrap();
        assert_eq!(ac.num_workers(), 4);
        ac.register_library("skylark", "builtin:skylark").unwrap();
        ac.register_library("elemental", "builtin:elemental").unwrap();

        let (al_x, _) =
            ac.send_matrix("X", &IndexedRowMatrix::from_local(&x, 7)).unwrap();
        let (al_y, _) =
            ac.send_matrix("Y", &IndexedRowMatrix::from_local(&y, 7)).unwrap();
        let res = ac
            .run_task(
                "skylark",
                "cg_solve",
                Params::new()
                    .with_matrix("X", al_x.id)
                    .with_matrix("Y", al_y.id)
                    .with_f64("lambda", 1e-3)
                    .with_f64("tol", 1e-10)
                    .with_i64("max_iters", 200),
            )
            .unwrap();
        let iters = res.scalars.i64("iters").unwrap();
        let (w, _) =
            ac.to_indexed_row_matrix(res.output("W").unwrap(), 5).unwrap();

        let (al_a, _) =
            ac.send_matrix("A", &IndexedRowMatrix::from_local(&a, 9)).unwrap();
        let svd = ac
            .run_task(
                "elemental",
                "truncated_svd",
                Params::new()
                    .with_matrix("A", al_a.id)
                    .with_i64("rank", 4)
                    .with_i64("seed", 7),
            )
            .unwrap();
        let sigma = match svd.scalars.get("sigma") {
            Some(Value::F64s(v)) => v.clone(),
            other => panic!("sigma missing: {other:?}"),
        };
        let (u, _) =
            ac.to_indexed_row_matrix(svd.output("U").unwrap(), 11).unwrap();

        ac.stop();
        server.shutdown();
        (w.to_local().unwrap(), iters, sigma, u.to_local().unwrap())
    };

    let (w_l, iters_l, sigma_l, u_l) = run(native_cfg());
    let (w_t, iters_t, sigma_t, u_t) = run(tcp_cfg());

    assert_eq!(iters_l, iters_t);
    assert!(iters_l > 1, "CG should iterate, took {iters_l}");
    assert_eq!(w_l.max_abs_diff(&w_t), 0.0, "CG W differs across fabrics");
    assert_eq!(sigma_l, sigma_t, "SVD spectrum differs across fabrics");
    assert_eq!(u_l.max_abs_diff(&u_t), 0.0, "SVD U differs across fabrics");
    // and the numbers are not degenerate
    assert!(sigma_l.iter().all(|s| *s > 0.0));
}

/// Kill one worker *process* mid-solve: its work socket drops (the
/// coordinator fails the rank's pending request) and its mesh links drop
/// (peers poison the group with `RankFailed`), so the task fails within
/// the deadline, naming the dead rank as the root cause — the peers'
/// PeerFailed unwinding is collateral, never the headline.
#[test]
fn killed_worker_process_fails_task_root_cause_first() {
    let cfg = tcp_cfg();
    let server = AlchemistServer::start(cfg.clone(), 4).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 4).unwrap();
    ac.register_library("skylark", "builtin:skylark").unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();

    // server-side problem, unconvergeable (tol 0) and capped far beyond
    // test time: one allreduce per CG iteration until we pull the plug
    let x = ac
        .run_task(
            "elemental",
            "rand_matrix",
            Params::new().with_i64("rows", 512).with_i64("cols", 128).with_i64("seed", 1),
        )
        .unwrap();
    let y = ac
        .run_task(
            "elemental",
            "rand_matrix",
            Params::new().with_i64("rows", 512).with_i64("cols", 4).with_i64("seed", 2),
        )
        .unwrap();
    let task_id = ac
        .submit(
            "skylark",
            "cg_solve",
            Params::new()
                .with_matrix("X", x.outputs[0].id)
                .with_matrix("Y", y.outputs[0].id)
                .with_f64("tol", 0.0)
                .with_i64("max_iters", 500_000_000),
        )
        .unwrap()
        .task_id;

    let t0 = Instant::now();
    loop {
        assert!(t0.elapsed() < Duration::from_secs(30), "task never started");
        if matches!(ac.task(task_id).status().unwrap(), TaskState::Running { .. }) {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    // let the solve get into its iteration loop before pulling the plug
    std::thread::sleep(Duration::from_millis(300));

    let t_kill = Instant::now();
    assert!(server.kill_worker(2), "worker 2 should be live to kill");
    let err = ac.task(task_id).wait().unwrap_err();
    assert!(
        t_kill.elapsed() < Duration::from_secs(20),
        "failure took {:?} — peers hung instead of unwinding",
        t_kill.elapsed()
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("rank 2"), "dead rank not the root cause: {msg}");
    assert!(msg.contains("connection lost"), "cause not named: {msg}");

    // teardown with a dead pool member must not hang either
    ac.stop();
    server.shutdown();
}
