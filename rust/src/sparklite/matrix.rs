//! `IndexedRowMatrix` — the row-RDD matrix the paper's ACI ships to
//! Alchemist (§3.1.2: "Alchemist currently sends and receives data using
//! Spark's IndexedRowMatrix RDD data structure").

use crate::distmat::LocalMatrix;

use super::rdd::Rdd;

/// One matrix row with its global index (rows may arrive out of order).
#[derive(Debug, Clone, PartialEq)]
pub struct IndexedRow {
    pub index: u64,
    pub vector: Vec<f64>,
}

/// A dense matrix as an RDD of indexed rows.
#[derive(Debug, Clone)]
pub struct IndexedRowMatrix {
    pub rdd: Rdd<IndexedRow>,
    pub rows: usize,
    pub cols: usize,
}

impl IndexedRowMatrix {
    /// Partition a local matrix into `num_partitions` row chunks.
    pub fn from_local(m: &LocalMatrix, num_partitions: usize) -> Self {
        let items: Vec<IndexedRow> = (0..m.rows())
            .map(|i| IndexedRow { index: i as u64, vector: m.row(i).to_vec() })
            .collect();
        IndexedRowMatrix {
            rdd: Rdd::parallelize(items, num_partitions),
            rows: m.rows(),
            cols: m.cols(),
        }
    }

    /// Materialize as a dense local matrix (driver-side collect).
    pub fn to_local(&self) -> crate::Result<LocalMatrix> {
        let mut out = LocalMatrix::zeros(self.rows, self.cols);
        let mut seen = vec![false; self.rows];
        for part in self.rdd.partitions() {
            for row in part {
                let i = row.index as usize;
                anyhow::ensure!(i < self.rows, "row index {i} out of bounds");
                anyhow::ensure!(!seen[i], "duplicate row {i}");
                anyhow::ensure!(
                    row.vector.len() == self.cols,
                    "row {i} has {} cols, want {}",
                    row.vector.len(),
                    self.cols
                );
                out.row_mut(i).copy_from_slice(&row.vector);
                seen[i] = true;
            }
        }
        anyhow::ensure!(seen.iter().all(|&s| s), "missing rows in matrix");
        Ok(out)
    }

    pub fn num_partitions(&self) -> usize {
        self.rdd.num_partitions()
    }

    /// Total payload bytes (memory-budget checks and transfer sizing).
    pub fn size_bytes(&self) -> usize {
        self.rows * self.cols * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn local_roundtrip() {
        let mut rng = Rng::new(8);
        let m = LocalMatrix::from_fn(13, 4, |_, _| rng.normal());
        let irm = IndexedRowMatrix::from_local(&m, 3);
        assert_eq!(irm.num_partitions(), 3);
        assert_eq!(irm.size_bytes(), 13 * 4 * 8);
        assert_eq!(irm.to_local().unwrap(), m);
    }

    #[test]
    fn detects_missing_and_duplicate_rows() {
        let m = LocalMatrix::zeros(3, 2);
        let mut irm = IndexedRowMatrix::from_local(&m, 1);
        // drop a row
        let mut parts = irm.rdd.clone().into_partitions();
        parts[0].pop();
        irm.rdd = Rdd::from_partitions(parts);
        assert!(irm.to_local().is_err());
        // duplicate a row
        let m = LocalMatrix::zeros(3, 2);
        let mut irm = IndexedRowMatrix::from_local(&m, 1);
        let mut parts = irm.rdd.clone().into_partitions();
        let dup = parts[0][0].clone();
        parts[0][2] = dup;
        irm.rdd = Rdd::from_partitions(parts);
        assert!(irm.to_local().is_err());
    }
}
