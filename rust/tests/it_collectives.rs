//! Property tests: collective algorithms equal their serial semantics for
//! arbitrary group sizes, lengths, and roots.

use alchemist::collectives::{
    allgather, allreduce_sum, broadcast, gather, reduce_sum, scatter, Communicator,
    LocalComm, TAG_WINDOW,
};
use alchemist::testkit::props;

/// Run `f` on every rank; collect per-rank results sorted by rank.
fn run_group<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(&LocalComm) -> T + Send + Sync + Clone + 'static,
{
    let comms = LocalComm::group(n, None);
    let mut handles = Vec::new();
    for c in comms {
        let f = f.clone();
        handles.push(std::thread::spawn(move || (c.rank(), f(&c))));
    }
    let mut out: Vec<(usize, T)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    out.sort_by_key(|(r, _)| *r);
    out.into_iter().map(|(_, t)| t).collect()
}

#[test]
fn allreduce_equals_serial_sum() {
    props(40, |g| {
        let p = g.usize_in(1, 6);
        let n = g.usize_in(0, 200);
        let inputs: Vec<Vec<f64>> = (0..p).map(|_| g.vec_normal(n)).collect();
        let want: Vec<f64> = (0..n)
            .map(|i| inputs.iter().map(|v| v[i]).sum())
            .collect();
        let inputs2 = inputs.clone();
        let results = run_group(p, move |c| {
            let mut buf = inputs2[c.rank()].clone();
            allreduce_sum(c, 7 * TAG_WINDOW, &mut buf).unwrap();
            buf
        });
        for got in results {
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()));
            }
        }
    });
}

#[test]
fn broadcast_from_random_root() {
    props(40, |g| {
        let p = g.usize_in(1, 7);
        let root = g.usize_in(0, p - 1);
        let n = g.usize_in(0, 64);
        let payload = g.vec_normal(n);
        let payload2 = payload.clone();
        let results = run_group(p, move |c| {
            let mut buf = if c.rank() == root { payload2.clone() } else { vec![] };
            broadcast(c, 9 * TAG_WINDOW, root, &mut buf).unwrap();
            buf
        });
        for got in results {
            assert_eq!(got, payload);
        }
    });
}

#[test]
fn reduce_then_scatter_then_allgather_chain() {
    props(25, |g| {
        let p = g.usize_in(1, 5);
        let n = g.usize_in(1, 32);
        let inputs: Vec<Vec<f64>> = (0..p).map(|_| g.vec_normal(n)).collect();
        let want_sum: Vec<f64> = (0..n)
            .map(|i| inputs.iter().map(|v| v[i]).sum())
            .collect();
        let inputs2 = inputs.clone();
        let results = run_group(p, move |c| {
            // reduce to root 0
            let mut buf = inputs2[c.rank()].clone();
            reduce_sum(c, 11 * TAG_WINDOW, 0, &mut buf).unwrap();
            // root scatters equal shares back (pad to p*n for evenness)
            let parts = if c.rank() == 0 {
                Some(vec![buf.clone(); c.size()])
            } else {
                None
            };
            let share = scatter(c, 12 * TAG_WINDOW, 0, parts).unwrap();
            // everyone allgathers their share
            let all = allgather(c, 13 * TAG_WINDOW, share).unwrap();
            (c.rank(), all)
        });
        for (_, all) in results {
            assert_eq!(all.len(), p);
            for part in all {
                for (a, b) in part.iter().zip(&want_sum) {
                    assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()));
                }
            }
        }
    });
}

#[test]
fn gather_preserves_rank_payloads() {
    props(30, |g| {
        let p = g.usize_in(1, 6);
        let sizes: Vec<usize> = (0..p).map(|_| g.usize_in(0, 20)).collect();
        let sizes2 = sizes.clone();
        let results = run_group(p, move |c| {
            let mine = vec![c.rank() as f64; sizes2[c.rank()]];
            gather(c, 15 * TAG_WINDOW, 0, mine).unwrap()
        });
        let root_view = results[0].as_ref().expect("root gathers");
        for (r, part) in root_view.iter().enumerate() {
            assert_eq!(part, &vec![r as f64; sizes[r]]);
        }
        for other in &results[1..] {
            assert!(other.is_none());
        }
    });
}

#[test]
fn concurrent_collectives_with_distinct_tags() {
    // two interleaved allreduces on different tag windows must not mix
    let results = run_group(4, |c| {
        let mut a = vec![c.rank() as f64; 16];
        let mut b = vec![(c.rank() * 10) as f64; 16];
        // interleave manually: start both, alternating chunks
        allreduce_sum(c, TAG_WINDOW, &mut a).unwrap();
        allreduce_sum(c, 2 * TAG_WINDOW, &mut b).unwrap();
        (a[0], b[0])
    });
    for (a, b) in results {
        assert_eq!(a, 6.0); // 0+1+2+3
        assert_eq!(b, 60.0);
    }
}

// ---------------------------------------------------------------------------
// Fault injection (protocol v5): a rank that dies before or inside a
// collective must release its peers with `CommError::PeerFailed` within
// the deadline — never strand them — and a disjoint group's fabric must
// be completely unaffected.

use std::sync::Barrier;
use std::time::{Duration, Instant};

use alchemist::collectives::{CommError, PoisonCause};

/// How long a released peer may take to observe the poison. The wakeup is
/// a condvar notification (microseconds); the bound is generous for noisy
/// CI runners while still catching a genuine strand (which would hang
/// until the harness timeout).
const RELEASE_DEADLINE: Duration = Duration::from_secs(5);

/// Run one fault-injection scenario on a 3-rank group: rank `dead` never
/// contributes; the survivors run `collective` and must each unwind with
/// `PeerFailed { rank: dead }` within the deadline. With `die_first` the
/// poison lands before the survivors enter the collective; otherwise they
/// are already blocked inside it when the poison lands.
fn one_rank_dies<F>(dead: usize, die_first: bool, collective: F)
where
    F: Fn(&LocalComm) -> Result<(), CommError> + Send + Sync + Clone + 'static,
{
    let comms = LocalComm::group(3, None);
    // entry gate: all 3 ranks participate so the ordering is real
    let gate = std::sync::Arc::new(Barrier::new(3));
    let mut handles = Vec::new();
    for c in comms {
        let gate = gate.clone();
        let collective = collective.clone();
        handles.push(std::thread::spawn(move || {
            if c.rank() == dead {
                if die_first {
                    // poison, THEN let the peers proceed into the
                    // collective: they must fail on entry
                    c.poison(PoisonCause::RankFailed(dead));
                    gate.wait();
                } else {
                    // let the peers enter and block, then poison: they
                    // must be woken out of the collective
                    gate.wait();
                    std::thread::sleep(Duration::from_millis(50));
                    c.poison(PoisonCause::RankFailed(dead));
                }
                return None;
            }
            gate.wait();
            let t0 = Instant::now();
            let err = collective(&c).expect_err("peer must not complete");
            Some((err, t0.elapsed()))
        }));
    }
    for outcome in handles.into_iter().map(|h| h.join().unwrap()).flatten() {
        let (err, elapsed) = outcome;
        assert_eq!(err, CommError::PeerFailed { rank: dead });
        assert!(
            elapsed < RELEASE_DEADLINE,
            "peer released after {elapsed:?} — not within the deadline"
        );
    }
}

#[test]
fn rank_death_releases_peers_from_barrier() {
    for die_first in [true, false] {
        one_rank_dies(1, die_first, |c| c.barrier());
    }
}

#[test]
fn rank_death_releases_peers_from_broadcast() {
    for die_first in [true, false] {
        // root 1 is the dead rank: both survivors block in recv
        one_rank_dies(1, die_first, |c| {
            let mut buf = Vec::new();
            broadcast(c, 300 * TAG_WINDOW, 1, &mut buf)
        });
    }
}

#[test]
fn rank_death_releases_peers_from_allreduce() {
    for die_first in [true, false] {
        one_rank_dies(2, die_first, |c| {
            let mut buf = vec![c.rank() as f64; 64];
            allreduce_sum(c, 400 * TAG_WINDOW, &mut buf)
        });
    }
}

#[test]
fn rank_death_in_subgroup_leaves_disjoint_group_unaffected() {
    // two disjoint subgroups of a 5-rank pool: group A loses a rank
    // mid-allreduce, group B keeps collecting correct sums throughout
    let ga = LocalComm::subgroup(&[0, 2, 4], None);
    let gb = LocalComm::subgroup(&[1, 3], None);

    let mut handles = Vec::new();
    for c in ga {
        handles.push(std::thread::spawn(move || {
            if c.rank() == 1 {
                std::thread::sleep(Duration::from_millis(30));
                c.poison(PoisonCause::RankFailed(1));
                return true;
            }
            let mut buf = vec![1.0; 32];
            allreduce_sum(&c, 500 * TAG_WINDOW, &mut buf).unwrap_err()
                == CommError::PeerFailed { rank: 1 }
        }));
    }
    let mut b_handles = Vec::new();
    for c in gb {
        b_handles.push(std::thread::spawn(move || {
            // keep collecting while group A dies; every round must
            // succeed with the right sum
            for round in 0..200u64 {
                let mut buf = vec![c.rank() as f64 + 1.0; 8];
                allreduce_sum(&c, (600 + round) * TAG_WINDOW, &mut buf).unwrap();
                assert_eq!(buf, vec![3.0; 8]);
                c.barrier().unwrap();
            }
        }));
    }
    for h in handles {
        assert!(h.join().unwrap(), "group A peer saw the wrong error");
    }
    for h in b_handles {
        h.join().unwrap();
    }
}

#[test]
fn poisoned_fabric_recovers_after_reset() {
    // the coordinator reuses one fabric across tasks: after a failure +
    // reset, collectives must work again and stale traffic must be gone
    let comms = LocalComm::group(2, None);
    comms[0].send(1, 7 * TAG_WINDOW, vec![99.0]); // undelivered by the "failed task"
    comms[1].poison(PoisonCause::RankFailed(1));
    assert!(comms[0].recv(1, 7 * TAG_WINDOW).is_err());
    comms[0].reset();
    let mut handles = Vec::new();
    for c in comms {
        handles.push(std::thread::spawn(move || {
            let mut buf = vec![c.rank() as f64; 4];
            allreduce_sum(&c, 7 * TAG_WINDOW, &mut buf).unwrap();
            buf
        }));
    }
    for h in handles {
        assert_eq!(h.join().unwrap(), vec![1.0; 4]);
    }
}
