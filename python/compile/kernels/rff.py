"""L1: fused random-Fourier-features finalize kernel.

The speech-classification experiment (paper §4.1) expands the 440-feature
TIMIT matrix to D random features *inside Alchemist* (Rahimi–Recht random
kitchen sinks): ``Z = sqrt(2/D) * cos(X @ Omega + b)``. The projection
``X @ Omega`` runs through the GEMM kernel; this kernel fuses the
elementwise tail — bias broadcast, cosine, scaling — in a single pass over
the accumulated tile so the projection never makes a second trip through
HBM on a real TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _pick_block


def _rff_kernel(acc_ref, bias_ref, scale_ref, o_ref):
    # scale arrives as a [1, 1] block in SMEM-style layout; bias as a [1, bn]
    # row broadcast down the tile.
    o_ref[...] = scale_ref[0, 0] * jnp.cos(acc_ref[...] + bias_ref[...])


def make_rff_finalize(m: int, n: int, *, dtype=jnp.float64, block: int = 128,
                      interpret: bool = True):
    """Build ``fn(acc [m,n], bias [1,n], scale [1,1]) -> scale*cos(acc+bias)``."""
    bm = _pick_block(m, block)
    bn = _pick_block(n, block)
    grid = (m // bm, n // bn)

    call = pl.pallas_call(
        _rff_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), dtype),
        interpret=interpret,
    )

    def rff_finalize(acc, bias, scale):
        assert acc.shape == (m, n)
        assert bias.shape == (1, n)
        assert scale.shape == (1, 1)
        return call(acc, bias, scale)

    return rff_finalize
