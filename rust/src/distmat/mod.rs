//! The Elemental stand-in (DESIGN.md §2): dense distributed matrices.
//!
//! Alchemist stores incoming RDD rows in Elemental `DistMatrix`es; the
//! paper's workloads only ever use dense, double-precision, row-partitioned
//! matrices (`IndexedRowMatrix` on the Spark side), so the layout here is
//! 1-D row-block: worker `r` owns the contiguous global row range
//! `layout.ranges[r]`.

pub mod dense;
pub mod layout;

pub use dense::LocalMatrix;
pub use layout::RowBlockLayout;

/// One worker's shard of a distributed matrix: the global layout plus the
/// locally-owned row block. Cross-worker operations (Gram products, norms,
/// redistribution) live in `linalg`/`coordinator` and use the collectives.
#[derive(Debug, Clone)]
pub struct DistShard {
    pub layout: RowBlockLayout,
    pub rank: usize,
    /// The rows `layout.ranges[rank]`, dense row-major.
    pub local: LocalMatrix,
}

impl DistShard {
    pub fn new(layout: RowBlockLayout, rank: usize, local: LocalMatrix) -> Self {
        let (a, b) = layout.ranges[rank];
        assert_eq!(local.rows(), b - a, "local block height mismatch");
        assert_eq!(local.cols(), layout.cols, "local block width mismatch");
        DistShard { layout, rank, local }
    }

    /// Allocate an all-zeros shard for this rank.
    pub fn zeros(layout: RowBlockLayout, rank: usize) -> Self {
        let (a, b) = layout.ranges[rank];
        let local = LocalMatrix::zeros(b - a, layout.cols);
        DistShard { layout, rank, local }
    }

    /// Global row range `[start, end)` owned by this shard.
    pub fn row_range(&self) -> (usize, usize) {
        self.layout.ranges[self.rank]
    }

    /// Squared Frobenius norm of the local block (allreduce for global).
    pub fn local_fro_sq(&self) -> f64 {
        self.local.fro_sq()
    }

    /// Replicate the local block column-wise `times` (Figure 3's data-set
    /// construction: the 2.2 TB ocean matrix replicated to 4.4/8.8/17.6 TB).
    pub fn replicate_cols(&self, times: usize) -> DistShard {
        let local = self.local.tile_cols(times);
        let mut layout = self.layout.clone();
        layout.cols *= times;
        DistShard { layout, rank: self.rank, local }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_shape_checked() {
        let layout = RowBlockLayout::even(10, 3, 2);
        let shard = DistShard::zeros(layout.clone(), 0);
        assert_eq!(shard.row_range(), (0, 5));
        assert_eq!(shard.local.rows(), 5);
        let shard1 = DistShard::zeros(layout, 1);
        assert_eq!(shard1.row_range(), (5, 10));
    }

    #[test]
    #[should_panic(expected = "height mismatch")]
    fn mismatched_block_rejected() {
        let layout = RowBlockLayout::even(10, 3, 2);
        let _ = DistShard::new(layout, 0, LocalMatrix::zeros(4, 3));
    }

    #[test]
    fn replicate_cols_grows_layout() {
        let layout = RowBlockLayout::even(4, 2, 2);
        let mut shard = DistShard::zeros(layout, 0);
        shard.local.set(0, 1, 7.0);
        let rep = shard.replicate_cols(3);
        assert_eq!(rep.layout.cols, 6);
        assert_eq!(rep.local.get(0, 1), 7.0);
        assert_eq!(rep.local.get(0, 3), 7.0);
        assert_eq!(rep.local.get(0, 5), 7.0);
    }
}
