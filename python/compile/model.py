"""L2: the JAX compute graphs the Alchemist workers execute.

These are the per-worker SPMD panels of the paper's two MPI routines —
libSkylark's block-CG on the regularized normal equations and the
ARPACK-style Lanczos truncated SVD — plus the random-feature expansion.
Each function composes the L1 Pallas kernels (``engine="pallas"``) or their
pure-jnp oracles (``engine="xla"``, lowered to native XLA dot/cos for the
engine ablation). ``aot.py`` lowers every exported (function, shape,
engine) to HLO text once at build time; the rust runtime threads worker
data through the resulting executables and the collectives layer does the
cross-worker allreduces. Python never runs at serve time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import cg_update as _cg
from .kernels import matmul as _mm
from .kernels import ref as _ref
from .kernels import rff as _rff


def _check_engine(engine: str) -> None:
    if engine not in ("pallas", "xla"):
        raise ValueError(f"unknown engine {engine!r}")


def make_gemm(m, n, k, *, variant="nn", engine="pallas", block=128,
              dtype=jnp.float64):
    """``(c, a, b) -> c + op(a)·op(b)`` — the composable tile primitive."""
    _check_engine(engine)
    if engine == "pallas":
        return _mm.make_gemm(m, n, k, variant=variant, block=block, dtype=dtype)
    return getattr(_ref, f"gemm_{variant}")


def make_gram_matvec(m, k, c, *, engine="pallas", block=128,
                     dtype=jnp.float64):
    """``(a [m,k], v [k,c], reg [1,1]) -> aᵀ(a·v) + reg·v``.

    One worker's panel of the Gram operator behind both CG (reg = nλ) and
    the Lanczos SVD (reg = 0); partial results are allreduced in rust. The
    two GEMMs lower into one HLO module so XLA schedules the intermediate
    ``a·v`` panel without a round-trip through the coordinator.
    """
    _check_engine(engine)
    if engine == "xla":
        return _ref.gram_matvec
    nn = _mm.make_gemm(m, c, k, variant="nn", block=block, dtype=dtype)
    tn = _mm.make_gemm(k, c, m, variant="tn", block=block, dtype=dtype)

    def gram_matvec(a, v, reg):
        av = nn(jnp.zeros((m, c), dtype), a, v)
        return tn(reg * v, a, av)

    return gram_matvec


def make_rff_expand(m, k0, d, *, engine="pallas", block=128,
                    dtype=jnp.float64):
    """``(x [m,k0], omega [k0,d], bias [1,d], scale [1,1]) -> z [m,d]``.

    Rahimi–Recht random-feature panel: project then fused cos-finalize.
    The paper expands TIMIT's 440 raw features to 10k–60k random features
    *inside* Alchemist (cheaper than shipping the expanded TBs over TCP);
    the rust skylark library runs this per row-panel.
    """
    _check_engine(engine)
    if engine == "xla":
        def rff_expand_ref(x, omega, bias, scale):
            return _ref.rff_finalize(x @ omega, bias, scale)
        return rff_expand_ref
    nn = _mm.make_gemm(m, d, k0, variant="nn", block=block, dtype=dtype)
    fin = _rff.make_rff_finalize(m, d, block=block, dtype=dtype)

    def rff_expand(x, omega, bias, scale):
        acc = nn(jnp.zeros((m, d), dtype), x, omega)
        return fin(acc, bias, scale)

    return rff_expand


def make_cg_update(m, n, *, engine="pallas", block=128, dtype=jnp.float64):
    """``(x, r, p, q, alpha [1,n]) -> (x + alpha·p, r - alpha·q)``."""
    _check_engine(engine)
    if engine == "xla":
        return _ref.cg_update
    return _cg.make_cg_update(m, n, block=block, dtype=dtype)
