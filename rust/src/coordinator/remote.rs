//! Process-separated worker ranks (protocol v8).
//!
//! With `fabric.mode = tcp` the server's ranks are separate OS processes
//! (`alchemist worker --connect <coordinator>`) instead of threads. This
//! module holds both halves of that split:
//!
//! * the **coordinator side** — [`RemoteWorker`] (one multiplexed work
//!   socket per worker process, requests routed by `req_id`, replies
//!   arriving out of order), [`RankHandle`] (a rank that is either an
//!   in-process thread or a remote process), and [`SessionFabric`] (what
//!   the dispatcher resets/poisons between tasks, regardless of
//!   transport);
//! * the **worker side** — [`run_worker`], a worker process's main loop:
//!   its own [`MatrixStore`], data-plane listener, mesh acceptor, and the
//!   same task command loop an in-process rank runs
//!   ([`super::worker::worker_main`]).
//!
//! The coordinator stays control-plane only: collective traffic flows
//! rank↔rank through each session's `TcpComm` mesh
//! (`collectives::netcomm`, brokered here via [`WorkMsg::MeshForm`]) and
//! ingest/fetch traffic flows client↔worker through each process's data
//! listener — exactly the paper's driver/worker split, with the MPI
//! communicator replaced by the TCP mesh (see `docs/fabric.md`).
//!
//! Failure mapping: a worker process dying drops both its work socket
//! (the reader thread fails every pending request with a "connection
//! lost" error) and its mesh links (peers poison their group with
//! [`PoisonCause::RankFailed`]), so the dispatcher's root-cause-first
//! aggregation reports `PeerFailed {{ rank }}` on every surviving rank
//! instead of hanging.

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::Context;

use crate::collectives::{
    CommError, Communicator, LocalComm, MeshAcceptor, PoisonCause, TcpComm,
    LANE_ALL,
};
use crate::config::Config;
use crate::distmat::RowBlockLayout;
use crate::metrics::StorageMetrics;
use crate::net::{Framed, Server};
use crate::protocol::fabric::{
    WireOutput, WorkMsg, FAIL_KIND_CANCELLED, FAIL_KIND_PEER_FAILED,
    FAIL_KIND_PLAIN, FAIL_KIND_TIMEOUT,
};
use crate::protocol::PROTOCOL_VERSION;
use crate::tasks::{CancelToken, RankProgress, TaskScope};

use super::registry;
use super::store::MatrixStore;
use super::worker::{
    handle_data_conn, worker_main, OutputMeta, TaskReply, WorkerCmd,
    WorkerShared,
};

// -- coordinator side -------------------------------------------------------

/// Outstanding request on a worker process's work socket, keyed by
/// `req_id`. The reader thread routes each reply to its waiter; a dead
/// socket fails them all.
enum Pending {
    Task(mpsc::Sender<crate::Result<TaskReply>>),
    Ack(mpsc::Sender<crate::Result<(u64, String)>>),
}

/// The coordinator's handle to one worker *process*: the attach-time
/// metadata plus the multiplexed work socket. Requests carry a fresh
/// `req_id`; replies may arrive in any order (a long task runs while
/// store and mesh operations are serviced) and are routed back by the
/// reader thread.
pub struct RemoteWorker {
    /// Global rank in the server's worker pool.
    pub rank: usize,
    /// `host:port` of the process's data-plane listener (row push/pull).
    pub data_addr: String,
    /// `host:port` of the process's mesh listener (peer links form here).
    pub mesh_addr: String,
    writer: Mutex<Framed<TcpStream, TcpStream>>,
    pending: Mutex<HashMap<u64, Pending>>,
    next_req: AtomicU64,
    dead: AtomicBool,
}

impl RemoteWorker {
    /// Coordinator side of the attach handshake on a freshly accepted
    /// work socket: read the worker's `Attach` (version-checked, bounded
    /// by `attach_timeout`), ack it, and start the reply-reader thread.
    pub fn attach(
        stream: TcpStream,
        buf_bytes: usize,
        attach_timeout: Duration,
    ) -> crate::Result<Arc<RemoteWorker>> {
        // the timeout applies to the socket, so it bounds the handshake
        // read through either clone; cleared once the worker is attached
        stream
            .set_read_timeout(Some(attach_timeout))
            .context("setting attach timeout")?;
        let wstream = stream.try_clone().context("cloning work socket")?;
        let mut writer = Framed::tcp(wstream, buf_bytes)?;
        let mut reader = Framed::new(
            stream.try_clone().context("cloning work socket")?,
            std::io::sink(),
        );
        let (rank, data_addr, mesh_addr) =
            match WorkMsg::decode(&reader.recv().context("reading Attach")?)? {
                WorkMsg::Attach { version, rank, data_addr, mesh_addr } => {
                    anyhow::ensure!(
                        version == PROTOCOL_VERSION,
                        "worker process speaks protocol {version}, \
                         coordinator speaks {PROTOCOL_VERSION}"
                    );
                    (rank as usize, data_addr, mesh_addr)
                }
                other => anyhow::bail!("expected Attach, got {other:?}"),
            };
        stream.set_read_timeout(None).context("clearing attach timeout")?;
        writer.send_flush(&WorkMsg::AttachAck { rank: rank as u32 }.encode())?;
        let worker = Arc::new(RemoteWorker {
            rank,
            data_addr,
            mesh_addr,
            writer: Mutex::new(writer),
            pending: Mutex::new(HashMap::new()),
            next_req: AtomicU64::new(1),
            dead: AtomicBool::new(false),
        });
        {
            let worker = Arc::clone(&worker);
            std::thread::Builder::new()
                .name(format!("work-recv-{rank}"))
                .spawn(move || worker.reader_loop(reader))
                .context("spawning work-socket reader")?;
        }
        Ok(worker)
    }

    /// Whether the work socket has dropped (the process died or was
    /// killed). Requests against a dead worker fail immediately.
    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::Acquire)
    }

    /// Send one message (fire-and-forget path; requests go through
    /// [`run_task`](Self::run_task) / [`request_ack`](Self::request_ack)).
    /// A send failure marks the worker dead and fails all pending
    /// requests — the socket is gone either way.
    pub fn send(&self, msg: &WorkMsg) -> crate::Result<()> {
        if self.is_dead() {
            anyhow::bail!("worker process {} is down", self.rank);
        }
        let res = self.writer.lock().unwrap().send_flush(&msg.encode());
        if res.is_err() {
            self.mark_dead();
        }
        res
    }

    /// Dispatch a task; the returned channel yields the rank's reply (or
    /// the connection-lost error if the process dies mid-task). Mirrors
    /// the in-process `WorkerCmd::RunTask` reply channel so the
    /// dispatcher's gather loop is transport-agnostic.
    #[allow(clippy::too_many_arguments)]
    pub fn run_task(
        &self,
        session_id: u64,
        task_id: u64,
        lib: &str,
        routine: &str,
        params: crate::protocol::Params,
        out_base: u64,
        out_span: u64,
        engine_threads: usize,
        lane: u64,
    ) -> crate::Result<mpsc::Receiver<crate::Result<TaskReply>>> {
        let req_id = self.next_req.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        self.pending.lock().unwrap().insert(req_id, Pending::Task(tx));
        let msg = WorkMsg::RunTask {
            req_id,
            session_id,
            task_id,
            lib: lib.to_string(),
            routine: routine.to_string(),
            params,
            out_base,
            out_span,
            engine_threads: engine_threads as u32,
            lane,
        };
        match self.send(&msg) {
            Ok(()) => Ok(rx),
            Err(e) => {
                self.pending.lock().unwrap().remove(&req_id);
                Err(e)
            }
        }
    }

    /// Issue one acked request without waiting (pipelining: the mesh
    /// brokering and group-wide resets send to every rank before awaiting
    /// any ack). The channel yields `(value, message)` from the worker's
    /// `Ack`, or an error.
    pub fn start_ack(
        &self,
        build: impl FnOnce(u64) -> WorkMsg,
    ) -> crate::Result<mpsc::Receiver<crate::Result<(u64, String)>>> {
        let req_id = self.next_req.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        self.pending.lock().unwrap().insert(req_id, Pending::Ack(tx));
        match self.send(&build(req_id)) {
            Ok(()) => Ok(rx),
            Err(e) => {
                self.pending.lock().unwrap().remove(&req_id);
                Err(e)
            }
        }
    }

    /// Blocking acked request: send, wait for the routed reply.
    pub fn request_ack(
        &self,
        build: impl FnOnce(u64) -> WorkMsg,
    ) -> crate::Result<(u64, String)> {
        let rx = self.start_ack(build)?;
        Self::await_ack(self.rank, rx)
    }

    /// Resolve a [`start_ack`](Self::start_ack) channel (maps a dropped
    /// channel — impossible outside a coordinator bug — to the same
    /// connection-lost error as a dead socket).
    pub fn await_ack(
        rank: usize,
        rx: mpsc::Receiver<crate::Result<(u64, String)>>,
    ) -> crate::Result<(u64, String)> {
        rx.recv().unwrap_or_else(|_| {
            Err(anyhow::anyhow!("worker process {rank}: connection lost"))
        })
    }

    fn take(&self, req_id: u64) -> Option<Pending> {
        self.pending.lock().unwrap().remove(&req_id)
    }

    fn reader_loop(self: Arc<Self>, mut reader: Framed<TcpStream, std::io::Sink>) {
        loop {
            // EOF / corrupt frame: the process is gone
            let Ok(buf) = reader.recv() else { break };
            let Ok(msg) = WorkMsg::decode(&buf) else { break };
            match msg {
                WorkMsg::TaskDone { req_id, outputs, scalars, timings } => {
                    if let Some(Pending::Task(tx)) = self.take(req_id) {
                        let outputs =
                            outputs.into_iter().map(meta_from_wire).collect();
                        let _ = tx.send(Ok(TaskReply { outputs, scalars, timings }));
                    }
                }
                WorkMsg::TaskFailed { req_id, kind, rank, tag, message } => {
                    if let Some(Pending::Task(tx)) = self.take(req_id) {
                        let _ = tx.send(Err(rebuild_failure(kind, rank, tag, &message)));
                    }
                }
                WorkMsg::Ack { req_id, ok, value, message } => {
                    if let Some(Pending::Ack(tx)) = self.take(req_id) {
                        let _ = tx.send(if ok {
                            Ok((value, message))
                        } else {
                            Err(anyhow::anyhow!(
                                "worker process {}: {message}",
                                self.rank
                            ))
                        });
                    }
                }
                other => log::warn!(
                    "unexpected message from worker process {}: {other:?}",
                    self.rank
                ),
            }
        }
        self.mark_dead();
    }

    /// First death wins: fail every outstanding request with the same
    /// connection-lost error a fresh request against a dead worker gets.
    fn mark_dead(&self) {
        if self.dead.swap(true, Ordering::AcqRel) {
            return;
        }
        log::warn!("worker process {}: connection lost", self.rank);
        let drained: Vec<Pending> = {
            let mut pending = self.pending.lock().unwrap();
            pending.drain().map(|(_, p)| p).collect()
        };
        for p in drained {
            let err = || anyhow::anyhow!("worker process {}: connection lost", self.rank);
            match p {
                Pending::Task(tx) => {
                    let _ = tx.send(Err(err()));
                }
                Pending::Ack(tx) => {
                    let _ = tx.send(Err(err()));
                }
            }
        }
    }
}

/// Rebuild a remote rank's failure so the dispatcher's aggregation sees
/// the exact `CommError` classification (root-cause vs collateral) the
/// worker observed. Plain failures keep their formatted message.
fn rebuild_failure(kind: u8, rank: u64, tag: u64, message: &str) -> anyhow::Error {
    match kind {
        FAIL_KIND_PEER_FAILED => {
            anyhow::Error::new(CommError::PeerFailed { rank: rank as usize })
        }
        FAIL_KIND_CANCELLED => anyhow::Error::new(CommError::Cancelled),
        FAIL_KIND_TIMEOUT => {
            anyhow::Error::new(CommError::Timeout { from: rank as usize, tag })
        }
        _ => anyhow::anyhow!("{message}"),
    }
}

/// The inverse of [`rebuild_failure`], applied on the worker side.
fn classify_failure(req_id: u64, e: &anyhow::Error) -> WorkMsg {
    let (kind, rank, tag) = match e.downcast_ref::<CommError>() {
        Some(CommError::PeerFailed { rank }) => {
            (FAIL_KIND_PEER_FAILED, *rank as u64, 0)
        }
        Some(CommError::Cancelled) => (FAIL_KIND_CANCELLED, 0, 0),
        Some(CommError::Timeout { from, tag }) => {
            (FAIL_KIND_TIMEOUT, *from as u64, *tag)
        }
        None => (FAIL_KIND_PLAIN, 0, 0),
    };
    WorkMsg::TaskFailed { req_id, kind, rank, tag, message: format!("{e:#}") }
}

fn meta_from_wire(o: WireOutput) -> OutputMeta {
    let layout = RowBlockLayout {
        rows: o.rows as usize,
        cols: o.cols as usize,
        ranges: o.ranges.iter().map(|&(a, b)| (a as usize, b as usize)).collect(),
    };
    OutputMeta { id: o.id, name: o.name, rows: o.rows, cols: o.cols, layout }
}

fn wire_from_meta(m: &OutputMeta) -> WireOutput {
    WireOutput {
        id: m.id,
        name: m.name.clone(),
        rows: m.rows,
        cols: m.cols,
        ranges: m
            .layout
            .ranges
            .iter()
            .map(|&(a, b)| (a as u64, b as u64))
            .collect(),
    }
}

/// Encode the full group layout for the store-management messages.
pub fn wire_ranges(layout: &RowBlockLayout) -> Vec<(u64, u64)> {
    layout.ranges.iter().map(|&(a, b)| (a as u64, b as u64)).collect()
}

fn layout_from_wire(rows: u64, cols: u64, ranges: &[(u64, u64)]) -> RowBlockLayout {
    RowBlockLayout {
        rows: rows as usize,
        cols: cols as usize,
        ranges: ranges.iter().map(|&(a, b)| (a as usize, b as usize)).collect(),
    }
}

/// One rank of the server's pool: an in-process worker thread or a
/// separate worker process. The driver holds one per global rank and
/// matches on the variant only where the transports genuinely differ
/// (store access vs store RPC). Clonable (cheap handle copies) so the
/// recovery path can work on a group's ranks without holding the pool
/// lock.
#[derive(Clone)]
pub enum RankHandle {
    Local {
        shared: Arc<WorkerShared>,
        sender: mpsc::Sender<WorkerCmd>,
    },
    Remote(Arc<RemoteWorker>),
}

impl RankHandle {
    /// `host:port` of this rank's data-plane listener.
    pub fn data_addr(&self) -> String {
        match self {
            RankHandle::Local { shared, .. } => {
                shared.data_addr.lock().unwrap().clone()
            }
            RankHandle::Remote(w) => w.data_addr.clone(),
        }
    }

    /// The in-process state, when this rank lives in the server process.
    /// Introspection helpers (block counts, storage metrics) aggregate
    /// local ranks only — a worker process owns its own store.
    pub fn local(&self) -> Option<&Arc<WorkerShared>> {
        match self {
            RankHandle::Local { shared, .. } => Some(shared),
            RankHandle::Remote(_) => None,
        }
    }

    pub fn remote(&self) -> Option<&Arc<RemoteWorker>> {
        match self {
            RankHandle::Local { .. } => None,
            RankHandle::Remote(w) => Some(w),
        }
    }
}

/// A session's group communicator as the driver manages it. The local
/// variant IS the fabric (shared state, direct calls); the remote variant
/// holds the control handles through which the per-process `TcpComm`
/// endpoints are reset/poisoned. Clone is cheap (Arcs) — the driver
/// snapshots the fabric out of the session's group lock so rank
/// replacement (protocol v10) can swap it without blocking readers on
/// in-flight I/O.
#[derive(Clone)]
pub enum SessionFabric {
    Local(Arc<LocalComm>),
    Remote { session_id: u64, ranks: Vec<Arc<RemoteWorker>> },
}

impl SessionFabric {
    /// Reset the group's communicator between tasks (epoch bump: drops
    /// stragglers, clears poison). Remote resets are pipelined — all
    /// ranks are told before any ack is awaited — and a dead rank's
    /// missing ack is logged, not fatal: the next task on that group
    /// fails through the mesh poison anyway.
    pub fn reset(&self) {
        match self {
            SessionFabric::Local(f) => f.reset(),
            SessionFabric::Remote { session_id, ranks } => {
                let sid = *session_id;
                let waits: Vec<_> = ranks
                    .iter()
                    .map(|w| {
                        w.start_ack(|req_id| WorkMsg::MeshReset {
                            req_id,
                            session_id: sid,
                        })
                    })
                    .collect();
                for (w, wait) in ranks.iter().zip(waits) {
                    let res = wait.and_then(|rx| RemoteWorker::await_ack(w.rank, rx));
                    if let Err(e) = res {
                        log::warn!(
                            "mesh reset on worker process {}: {e:#}",
                            w.rank
                        );
                    }
                }
            }
        }
    }

    /// Poison the whole group (every lane). Remote poison is
    /// fire-and-forget per rank (a wedged worker's ack would never come);
    /// each process's `TcpComm` also re-broadcasts the cause over its own
    /// mesh links.
    pub fn poison(&self, cause: PoisonCause) {
        match self {
            SessionFabric::Local(f) => f.poison(cause),
            SessionFabric::Remote { session_id, ranks } => {
                let (kind, rank) = wire_cause(cause);
                for w in ranks {
                    let _ = w.send(&WorkMsg::MeshPoison {
                        session_id: *session_id,
                        kind,
                        rank,
                        lane: LANE_ALL,
                    });
                }
            }
        }
    }

    /// Poison one task's tag lane only (protocol v9): ranks blocked in
    /// that task's collectives unwind, sibling tasks on other lanes keep
    /// running. Same fire-and-forget transport as [`SessionFabric::poison`].
    pub fn poison_lane(&self, lane: u64, cause: PoisonCause) {
        match self {
            SessionFabric::Local(f) => f.poison_lane(lane, cause),
            SessionFabric::Remote { session_id, ranks } => {
                let (kind, rank) = wire_cause(cause);
                for w in ranks {
                    let _ = w.send(&WorkMsg::MeshPoison {
                        session_id: *session_id,
                        kind,
                        rank,
                        lane,
                    });
                }
            }
        }
    }

    /// Retire a finished task's tag lane: drop its queued stragglers and
    /// clear any lane-scoped poison, so the lane's window is inert for
    /// the rest of the session (lanes are never reused). Lane 0 — the
    /// untasked tag space — is never retired.
    pub fn retire_lane(&self, lane: u64) {
        if lane == 0 {
            return;
        }
        match self {
            SessionFabric::Local(f) => f.retire_lane(lane),
            SessionFabric::Remote { session_id, ranks } => {
                for w in ranks {
                    let _ = w.send(&WorkMsg::MeshRetire {
                        session_id: *session_id,
                        lane,
                    });
                }
            }
        }
    }

    /// Forward a cooperative cancel to process-separated ranks. The local
    /// path is a no-op: in-process ranks share the task's cancel token
    /// directly through their `TaskScope`.
    pub fn propagate_cancel(&self, task_id: u64) {
        if let SessionFabric::Remote { session_id, ranks } = self {
            for w in ranks {
                let _ = w.send(&WorkMsg::CancelTask {
                    session_id: *session_id,
                    task_id,
                });
            }
        }
    }
}

/// [`PoisonCause`] as the `MeshPoison` wire pair (kind, rank).
fn wire_cause(cause: PoisonCause) -> (u8, u64) {
    match cause {
        PoisonCause::RankFailed(r) => (0u8, r as u64),
        PoisonCause::HardCancel => (1u8, 0),
    }
}

// -- worker side ------------------------------------------------------------

/// Main loop of `alchemist worker --connect <coordinator> --rank-id <n>`:
/// one process-separated rank of the server's pool.
///
/// Owns a [`MatrixStore`], a data-plane listener (same
/// [`handle_data_conn`] the in-process ranks run), a [`MeshAcceptor`] for
/// peer links, and one task thread running the unmodified
/// [`worker_main`] command loop. The work socket to the coordinator
/// carries everything else: task dispatch (replies forwarded off the
/// control loop so cancels keep flowing mid-task), mesh brokering, and
/// store management. Exits when the coordinator says [`WorkMsg::Shutdown`]
/// — or drops the socket, so an orphaned worker can never outlive its
/// server.
pub fn run_worker(coordinator: &str, rank: usize, cfg: Config) -> crate::Result<()> {
    let shared = Arc::new(WorkerShared {
        rank,
        store: MatrixStore::with_storage(
            rank,
            &cfg.storage,
            Arc::new(StorageMetrics::new()),
        ),
        data_addr: Mutex::new(String::new()),
        sessions: Mutex::new(HashMap::new()),
    });

    // data-plane listener (row push/pull from executors); advertised
    // under `fabric.advertise_addr` when set, so clients on other hosts
    // get a reachable pull address (v10)
    let data_listener = Server::bind_advertised(0, &cfg.fabric.advertise_addr)?;
    let data_addr = data_listener.addr().to_string();
    *shared.data_addr.lock().unwrap() = data_addr.clone();
    {
        let shared = Arc::clone(&shared);
        let cfg = cfg.clone();
        std::thread::Builder::new()
            .name(format!("data-serve-{rank}"))
            .spawn(move || {
                let shared2 = Arc::clone(&shared);
                let _ = data_listener.serve(move |stream| {
                    handle_data_conn(&shared2, stream, &cfg);
                });
            })
            .context("spawning data listener")?;
    }

    // mesh listener: peer ranks connect here at group formation (the
    // advertised host replaces the hard-coded loopback for multi-host
    // meshes)
    let acceptor = MeshAcceptor::bind_advertised(&cfg.fabric.advertise_addr)?;

    // work socket + attach handshake
    let stream = TcpStream::connect(coordinator)
        .with_context(|| format!("connecting to coordinator at {coordinator}"))?;
    let mut writer = Framed::tcp(
        stream.try_clone().context("cloning work socket")?,
        cfg.transfer.buf_bytes,
    )?;
    let mut reader = Framed::new(stream, std::io::sink());
    writer.send_flush(
        &WorkMsg::Attach {
            version: PROTOCOL_VERSION,
            rank: rank as u32,
            data_addr,
            mesh_addr: acceptor.addr().to_string(),
        }
        .encode(),
    )?;
    match WorkMsg::decode(&reader.recv().context("awaiting AttachAck")?)? {
        WorkMsg::AttachAck { rank: acked } => anyhow::ensure!(
            acked as usize == rank,
            "coordinator acked rank {acked}, expected {rank}"
        ),
        other => anyhow::bail!("expected AttachAck, got {other:?}"),
    }
    let writer = Arc::new(Mutex::new(writer));

    // one task thread: the same command loop an in-process rank runs (no
    // shared compute pool across processes — the engine builds a private
    // one, clamped per task by `engine_threads`)
    let (cmd_tx, cmd_rx) = mpsc::channel();
    let task_thread = {
        let shared = Arc::clone(&shared);
        let cfg = cfg.clone();
        std::thread::Builder::new()
            .name(format!("worker-{rank}"))
            .spawn(move || worker_main(shared, cfg, cmd_rx, None))
            .context("spawning task thread")?
    };

    // cancel tokens of running tasks, for CancelTask routing
    let running: Arc<Mutex<HashMap<(u64, u64), Arc<CancelToken>>>> =
        Arc::new(Mutex::new(HashMap::new()));

    log::info!("worker process {rank} attached to coordinator {coordinator}");
    let fabric_opts = cfg.fabric.options();
    loop {
        let buf = match reader.recv() {
            Ok(b) => b,
            Err(_) => {
                // coordinator gone: never outlive the server
                log::warn!("worker process {rank}: coordinator connection lost");
                break;
            }
        };
        match WorkMsg::decode(&buf)? {
            WorkMsg::RunTask {
                req_id,
                session_id,
                task_id,
                lib,
                routine,
                params,
                out_base,
                out_span,
                engine_threads,
                lane,
            } => {
                let library = match registry::builtin(&lib) {
                    Ok(l) => l,
                    Err(e) => {
                        post(&writer, &classify_failure(req_id, &e));
                        continue;
                    }
                };
                let cancel = Arc::new(CancelToken::new());
                let scope = TaskScope::new(
                    Arc::clone(&cancel),
                    Arc::new(RankProgress::new()),
                )
                .with_lane(lane);
                running.lock().unwrap().insert((session_id, task_id), cancel);
                let (reply_tx, reply_rx) = mpsc::channel();
                let sent = cmd_tx.send(WorkerCmd::RunTask {
                    session_id,
                    lib: library,
                    routine,
                    params,
                    out_base,
                    out_span,
                    engine_threads: engine_threads as usize,
                    scope,
                    reply: reply_tx,
                });
                if sent.is_err() {
                    running.lock().unwrap().remove(&(session_id, task_id));
                    post(
                        &writer,
                        &classify_failure(
                            req_id,
                            &anyhow::anyhow!("worker task thread died"),
                        ),
                    );
                    continue;
                }
                // forward the reply off the control loop: the task runs
                // for a while and cancels/mesh ops must keep flowing
                let writer = Arc::clone(&writer);
                let running = Arc::clone(&running);
                std::thread::spawn(move || {
                    let result = reply_rx.recv().unwrap_or_else(|_| {
                        Err(anyhow::anyhow!("worker task thread died"))
                    });
                    running.lock().unwrap().remove(&(session_id, task_id));
                    let msg = match result {
                        Ok(reply) => WorkMsg::TaskDone {
                            req_id,
                            outputs: reply
                                .outputs
                                .iter()
                                .map(wire_from_meta)
                                .collect(),
                            scalars: reply.scalars,
                            timings: reply.timings,
                        },
                        Err(e) => classify_failure(req_id, &e),
                    };
                    post(&writer, &msg);
                });
            }
            WorkMsg::CancelTask { session_id, task_id } => {
                if let Some(tok) =
                    running.lock().unwrap().get(&(session_id, task_id))
                {
                    tok.cancel();
                }
            }
            WorkMsg::MeshForm { req_id, session_id, group_rank, peers } => {
                // formation runs inline: every rank receives its MeshForm
                // before the coordinator awaits any ack, so the group's
                // processes form concurrently with each other
                let reply = match TcpComm::form(
                    &acceptor,
                    session_id,
                    group_rank as usize,
                    &peers,
                    &fabric_opts,
                ) {
                    Ok(comm) => {
                        shared
                            .sessions
                            .lock()
                            .unwrap()
                            .insert(session_id, Arc::new(comm));
                        ack_ok(req_id, 0)
                    }
                    Err(e) => ack_err(req_id, &e),
                };
                post(&writer, &reply);
            }
            WorkMsg::MeshReset { req_id, session_id } => {
                let reply = match shared.sessions.lock().unwrap().get(&session_id)
                {
                    Some(f) => {
                        f.reset();
                        ack_ok(req_id, 0)
                    }
                    None => ack_err(
                        req_id,
                        &anyhow::anyhow!("session {session_id} holds no group here"),
                    ),
                };
                post(&writer, &reply);
            }
            WorkMsg::MeshPoison { session_id, kind, rank: failed, lane } => {
                let cause = if kind == 1 {
                    PoisonCause::HardCancel
                } else {
                    PoisonCause::RankFailed(failed as usize)
                };
                if let Some(f) = shared.sessions.lock().unwrap().get(&session_id) {
                    if lane == LANE_ALL {
                        f.poison(cause);
                    } else {
                        f.poison_lane(lane, cause);
                    }
                }
            }
            WorkMsg::MeshRetire { session_id, lane } => {
                if let Some(f) = shared.sessions.lock().unwrap().get(&session_id) {
                    f.retire_lane(lane);
                }
            }
            WorkMsg::SessionClose { req_id, session_id } => {
                // dropping the fabric closes its mesh links in order
                // (Close frames first, so peers do not mistake the EOFs
                // for a rank failure)
                let fabric = shared.sessions.lock().unwrap().remove(&session_id);
                drop(fabric);
                let freed = shared.store.free_session(session_id);
                post(&writer, &ack_ok(req_id, freed as u64));
            }
            WorkMsg::StoreAlloc {
                req_id,
                session_id,
                id,
                name,
                rows,
                cols,
                ranges,
                slot,
            } => {
                let layout = layout_from_wire(rows, cols, &ranges);
                let reply = match shared.store.alloc(
                    id,
                    &name,
                    layout,
                    slot as usize,
                    session_id,
                ) {
                    Ok(()) => ack_ok(req_id, 0),
                    Err(e) => ack_err(req_id, &e),
                };
                post(&writer, &reply);
            }
            WorkMsg::StoreSeal { req_id, id } => {
                let reply = match shared.store.seal(id) {
                    Ok(rows) => ack_ok(req_id, rows),
                    Err(e) => ack_err(req_id, &e),
                };
                post(&writer, &reply);
            }
            WorkMsg::StoreFree { id } => {
                shared.store.free(id);
            }
            WorkMsg::StoreLoad {
                req_id,
                session_id,
                id,
                name,
                path,
                rows,
                cols,
                ranges,
                slot,
            } => {
                let layout = layout_from_wire(rows, cols, &ranges);
                let reply = match load_one(
                    &shared,
                    session_id,
                    id,
                    &name,
                    std::path::Path::new(&path),
                    layout,
                    slot as usize,
                ) {
                    Ok(()) => ack_ok(req_id, 0),
                    Err(e) => ack_err(req_id, &e),
                };
                post(&writer, &reply);
            }
            WorkMsg::StoreRestore {
                req_id,
                session_id,
                id,
                name,
                path,
                rows,
                cols,
                ranges,
                slot,
            } => {
                let layout = layout_from_wire(rows, cols, &ranges);
                let reply = match restore_one(
                    &shared,
                    session_id,
                    id,
                    &name,
                    std::path::Path::new(&path),
                    layout,
                    slot as usize,
                ) {
                    Ok(local_rows) => ack_ok(req_id, local_rows),
                    Err(e) => ack_err(req_id, &e),
                };
                post(&writer, &reply);
            }
            WorkMsg::StoreStats { req_id } => {
                // (blocks << 32) | spill_segments, each saturated at u32
                // — the coordinator-side leak accounting for ranks whose
                // store lives in another process
                let blocks = (shared.store.len() as u64).min(u32::MAX as u64);
                let segs =
                    (shared.store.spill_segments() as u64).min(u32::MAX as u64);
                post(&writer, &ack_ok(req_id, (blocks << 32) | segs));
            }
            WorkMsg::Shutdown => break,
            other => {
                log::warn!("worker process {rank}: unexpected {other:?}");
            }
        }
    }

    // drain: in-flight task first, then exit
    let _ = cmd_tx.send(WorkerCmd::Shutdown);
    let _ = task_thread.join();
    log::info!("worker process {rank} exiting");
    Ok(())
}

/// This rank's half of a `LoadMatrix`: mmap the `hdf5sim` file when the
/// host supports in-place mapping, else a buffered read of just this
/// rank's row range (same fallback order as the in-process
/// [`super::worker::load_group`]).
fn load_one(
    shared: &WorkerShared,
    session_id: u64,
    id: u64,
    name: &str,
    path: &std::path::Path,
    layout: RowBlockLayout,
    slot: usize,
) -> crate::Result<()> {
    match crate::hdf5sim::MappedMatrix::open(path) {
        Ok(map) => shared.store.insert_mapped(
            id,
            name,
            layout,
            Arc::new(map),
            slot,
            session_id,
        ),
        Err(e) => {
            log::info!("mmap ingest unavailable for {path:?} ({e}); buffered load");
            let (lo, hi) = layout.ranges[slot];
            let local = crate::hdf5sim::read_rows(path, lo, hi)?;
            shared.store.insert(id, name, layout, local, slot, session_id)
        }
    }
}

/// Replay a dead rank's shard onto this (spare) rank from its
/// task-boundary checkpoint: the file holds ONLY the slot's local rows
/// (`local_rows × cols`), written by the dead rank at its last seal or
/// insert. The block lands born-sealed — and `insert` immediately
/// re-checkpoints it under this store's own `checkpoint_dir`, so a
/// second failure can replay again. Returns the restored local row
/// count (the coordinator cross-checks it against the layout).
fn restore_one(
    shared: &WorkerShared,
    session_id: u64,
    id: u64,
    name: &str,
    path: &std::path::Path,
    layout: RowBlockLayout,
    slot: usize,
) -> crate::Result<u64> {
    anyhow::ensure!(
        slot < layout.ranges.len(),
        "restore slot {slot} outside layout of {} ranges",
        layout.ranges.len()
    );
    let (lo, hi) = layout.ranges[slot];
    let local = crate::hdf5sim::read_rows(path, 0, hi - lo).map_err(|e| {
        anyhow::anyhow!("reading checkpoint {path:?} for matrix {id}: {e:#}")
    })?;
    let rows = local.rows() as u64;
    shared.store.insert(id, name, layout, local, slot, session_id)?;
    Ok(rows)
}

fn ack_ok(req_id: u64, value: u64) -> WorkMsg {
    WorkMsg::Ack { req_id, ok: true, value, message: String::new() }
}

fn ack_err(req_id: u64, e: &anyhow::Error) -> WorkMsg {
    WorkMsg::Ack { req_id, ok: false, value: 0, message: format!("{e:#}") }
}

fn post(writer: &Mutex<Framed<TcpStream, TcpStream>>, msg: &WorkMsg) {
    if let Err(e) = writer.lock().unwrap().send_flush(&msg.encode()) {
        log::warn!("work-socket send failed: {e:#}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failure_kinds_roundtrip_through_the_wire_classification() {
        let cases: Vec<anyhow::Error> = vec![
            anyhow::Error::new(CommError::PeerFailed { rank: 2 }),
            anyhow::Error::new(CommError::Cancelled),
            anyhow::Error::new(CommError::Timeout { from: 1, tag: 0x4347_0000 }),
            anyhow::anyhow!("routine cg_solve panicked: boom"),
        ];
        for e in cases {
            let WorkMsg::TaskFailed { kind, rank, tag, message, .. } =
                classify_failure(7, &e)
            else {
                panic!("classify_failure must produce TaskFailed");
            };
            let rebuilt = rebuild_failure(kind, rank, tag, &message);
            match e.downcast_ref::<CommError>() {
                Some(orig) => {
                    assert_eq!(rebuilt.downcast_ref::<CommError>(), Some(orig));
                }
                None => {
                    assert!(rebuilt.downcast_ref::<CommError>().is_none());
                    assert_eq!(rebuilt.to_string(), format!("{e:#}"));
                }
            }
        }
    }

    #[test]
    fn wire_output_preserves_layout() {
        let meta = OutputMeta {
            id: 42,
            name: "W".into(),
            rows: 10,
            cols: 3,
            layout: RowBlockLayout {
                rows: 10,
                cols: 3,
                ranges: vec![(0, 5), (5, 10)],
            },
        };
        let wire = wire_from_meta(&meta);
        let back = meta_from_wire(wire);
        assert_eq!(back.id, 42);
        assert_eq!(back.layout.rows, 10);
        assert_eq!(back.layout.cols, 3);
        assert_eq!(back.layout.ranges, vec![(0, 5), (5, 10)]);
    }
}
