//! Table 4: Alchemist CG cost vs number of random features (fixed
//! workers).
//!
//! Paper: 30 nodes, D ∈ {10k…60k}; per-iteration cost grows linearly in D
//! and the (fixed) 169.6 s transfer is amortized as D grows. Here D ∈
//! {1024…3072} on 3 workers; the linearity of the per-iteration cost and
//! the shrinking transfer share are the targets.

mod bench_common;

use alchemist::cli::Args;
use alchemist::client::AlchemistContext;
use alchemist::coordinator::AlchemistServer;
use alchemist::metrics::{Stats, Table};
use alchemist::protocol::{Params, Value};
use alchemist::sparklite::IndexedRowMatrix;
use alchemist::workloads::TimitSpec;
use bench_common::{bench_config, is_quick, require_artifacts, PAPER_CG_ITERS};

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let cfg = bench_config(&args)?;
    if !require_artifacts(&cfg) {
        return Ok(());
    }
    let quick = is_quick(&args);
    let rows = args.get_usize("rows", if quick { 2048 } else { 4096 })?;
    let workers = args.get_usize("workers", 3)?;
    let default_dims: &[usize] = if quick { &[1024] } else { &[1024, 2048, 3072] };
    let dims = args.get_usize_list("dims", default_dims)?;
    let iters = args.get_usize("iters", if quick { 4 } else { 8 })?;

    let spec = TimitSpec { train_rows: rows, test_rows: 1, ..TimitSpec::default() };
    let data = spec.generate();

    let server = AlchemistServer::start(cfg.clone(), workers)?;
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, workers)?;
    ac.register_library("skylark", "builtin:skylark")?;

    let irm_x = IndexedRowMatrix::from_local(&data.x_train, workers * 2);
    let irm_y = IndexedRowMatrix::from_local(&data.y_train, workers * 2);
    let t0 = std::time::Instant::now();
    let (al_x, sx) = ac.send_matrix("X", &irm_x)?;
    let (al_y, _) = ac.send_matrix("Y", &irm_y)?;
    let transfer_secs = t0.elapsed().as_secs_f64();
    println!(
        "raw feature matrix sent once: {:.3}s ({:.2} GB/s) — amortized across all D",
        transfer_secs,
        sx.throughput_gbps()
    );

    let total_hdr = format!("total {PAPER_CG_ITERS} iters (s)");
    let mut table = Table::new(
        &format!("Table 4 (scaled): Alchemist CG vs feature count, {workers} workers"),
        &[
            "features D", "iter (ms, mean±sd)", "iter sim (ms)", &total_hdr,
            "transfer share",
        ],
    );

    for &d in &dims {
        let res = ac.run_task(
            "skylark",
            "cg_solve",
            Params::new()
                .with_matrix("X", al_x.id)
                .with_matrix("Y", al_y.id)
                .with_f64("lambda", 1e-5)
                .with_f64("tol", 0.0)
                .with_i64("max_iters", iters as i64)
                .with_i64("rff_d", d as i64)
                .with_f64("rff_gamma", 0.06)
                .with_i64("rff_seed", 1),
        )?;
        let n_iters = res.scalars.i64("iters")? as usize;
        let iter_secs = match res.scalars.get("iter_secs") {
            Some(Value::F64s(v)) => v.clone(),
            _ => vec![],
        };
        let per: Stats = iter_secs.iter().map(|s| s * 1e3).collect();
        let sim_per_ms = res.timing("sim_secs") / n_iters.max(1) as f64 * 1e3;
        let total = per.mean() / 1e3 * PAPER_CG_ITERS as f64;
        table.row(&[
            d.to_string(),
            per.mean_pm_std(1),
            format!("{sim_per_ms:.1}"),
            format!("{total:.0}"),
            format!("{:.2}%", transfer_secs / (transfer_secs + total) * 100.0),
        ]);
    }

    ac.shutdown_server()?;
    server.shutdown_on_request();
    table.print();
    println!(
        "paper: per-iteration cost linear in D (1.49s at 10k -> 8.79s at 60k); \
         transfer share shrinks as D grows"
    );
    Ok(())
}
