//! Synthetic ocean-temperature field (paper §4.2) — the CFSR substitute.
//!
//! The real data: global ocean temperature on a 0.5° grid at 40 depths,
//! six-hourly, Jan 1979 – mid 1984; as a matrix, one row per grid cell and
//! one column per time step (6,177,583 × 8,096, 400 GB). Climate fields
//! have strong low-rank structure (seasonal harmonics + trends + spatially
//! coherent modes) over spatially-correlated noise — that structure is
//! exactly why rank-20 truncated SVD is the paper's workload. The
//! generator builds `A = Σ_r σ_r·u_r·v_r(t) + ε` with smooth spatial modes
//! u_r, seasonal/trend temporal modes v_r, and a geometrically decaying
//! σ spectrum, so the truncated SVD has a meaningful, testable target.

use crate::distmat::LocalMatrix;
use crate::util::prng::Rng;

#[derive(Debug, Clone)]
pub struct OceanSpec {
    /// Grid cells (paper: 6,177,583).
    pub cells: usize,
    /// Time steps (paper: 8,096 for the 400 GB subset).
    pub times: usize,
    /// Number of structured modes.
    pub modes: usize,
    /// Leading singular value scale.
    pub sigma0: f64,
    /// Geometric spectrum decay per mode.
    pub decay: f64,
    /// White-noise floor.
    pub noise: f64,
    pub seed: u64,
}

impl Default for OceanSpec {
    fn default() -> Self {
        // ~1/512 of the 400 GB subset; bench configs scale further
        OceanSpec {
            cells: 16_384,
            times: 2_048,
            modes: 24,
            sigma0: 100.0,
            decay: 0.80,
            noise: 0.05,
            seed: 0x0CEA_0000,
        }
    }
}

impl OceanSpec {
    /// σ_r = sigma0 · decay^r for the structured modes.
    pub fn spectrum(&self) -> Vec<f64> {
        (0..self.modes)
            .map(|r| self.sigma0 * self.decay.powi(r as i32))
            .collect()
    }

    /// Generate rows `[row_start, row_end)` of the field — workers call
    /// this with their shard ranges, so the 17.6 TB-analog cases never
    /// materialize the full matrix in one place.
    pub fn generate_rows(&self, row_start: usize, row_end: usize) -> LocalMatrix {
        assert!(row_end <= self.cells && row_start <= row_end);
        let sigmas = self.spectrum();
        // temporal modes: seasonal harmonics with phase + slow trend
        let base = Rng::new(self.seed);
        let mut temporal = LocalMatrix::zeros(self.modes, self.times);
        for r in 0..self.modes {
            let mut mrng = base.derive(1_000 + r as u64);
            let freq = 1.0 + mrng.below(8) as f64; // cycles per "year"
            let phase = mrng.uniform_in(0.0, std::f64::consts::TAU);
            let trend = mrng.normal() * 0.1;
            let row = temporal.row_mut(r);
            let inv_norm = (2.0 / self.times as f64).sqrt();
            for (t, v) in row.iter_mut().enumerate() {
                let tt = t as f64 / self.times as f64;
                *v = inv_norm
                    * ((std::f64::consts::TAU * freq * tt + phase).sin()
                        + trend * (tt - 0.5));
            }
        }

        let mut out = LocalMatrix::zeros(row_end - row_start, self.times);
        for gi in row_start..row_end {
            // spatial weight of each mode at this cell: smooth in the cell
            // index (a 1-D stand-in for latitude bands) + per-cell jitter
            let mut cell_rng = base.derive(gi as u64);
            let li = gi - row_start;
            let pos = gi as f64 / self.cells as f64;
            let row = out.row_mut(li);
            for (r, sigma) in sigmas.iter().enumerate() {
                let spatial = ((r + 1) as f64 * std::f64::consts::PI * pos).sin()
                    * (2.0 / self.cells as f64).sqrt()
                    + 0.1 * cell_rng.normal() / (self.cells as f64).sqrt();
                let weight = sigma * spatial;
                let trow = temporal.row(r);
                for (t, v) in row.iter_mut().enumerate() {
                    *v += weight * trow[t];
                }
            }
            for v in row.iter_mut() {
                *v += self.noise * cell_rng.normal();
            }
        }
        out
    }

    /// Generate the full matrix (small configs only).
    pub fn generate(&self) -> LocalMatrix {
        self.generate_rows(0, self.cells)
    }

    /// Write the field to an `hdf5sim` file in row chunks (bounded
    /// memory — a dataset many times RAM streams through an ~8 MB
    /// window), returning total bytes.
    pub fn write_file(&self, path: &std::path::Path) -> crate::Result<u64> {
        let chunk_rows = ((8usize << 20) / (self.times * 8).max(1)).max(1);
        let mut w = crate::hdf5sim::Writer::create(path, self.cells, self.times)?;
        let mut r = 0;
        while r < self.cells {
            let e = (r + chunk_rows).min(self.cells);
            w.append(&self.generate_rows(r, e))?;
            r = e;
        }
        w.finish()?;
        Ok((self.cells * self.times * 8) as u64)
    }

    /// Total bytes of the field's payload.
    pub fn bytes(&self) -> u64 {
        (self.cells as u64) * (self.times as u64) * 8
    }
}

/// What one [`ocean_svd_outofcore`] run measured and proved.
#[derive(Debug)]
pub struct OutOfCoreReport {
    /// Top singular values, descending.
    pub sigma: Vec<f64>,
    /// Wall seconds for the direct `LoadMatrix` ingest.
    pub load_secs: f64,
    /// Server-side SVD compute seconds.
    pub svd_secs: f64,
    /// Payload bytes that crossed the CLIENT connection during the load
    /// — the direct-ingest guarantee is that this is zero.
    pub client_bytes_loaded: usize,
    /// Dataset payload size.
    pub dataset_bytes: u64,
    /// Per-session per-rank heap budget the run was held to.
    pub budget_bytes: u64,
    /// Merged storage-plane counters; `storage.cycled()` proves blocks
    /// went to the spill file AND were read back during the run.
    pub storage: crate::metrics::StorageSnapshot,
    /// Rows of U pulled back to the client.
    pub u_rows: usize,
}

/// The out-of-core proof run (paper's terabyte claim, scaled): truncated
/// SVD of an ocean field several times the per-rank storage budget.
///
/// The dataset is loaded via direct ingest — each worker maps its shard
/// of the `hdf5sim` file, so the payload is budget-exempt (page cache)
/// and zero bytes cross the client link. The SVD streams `panel_rows`
/// rows at a time through the block handle, and the N×k left factor it
/// produces exceeds the budget, so writing and pulling it back cycles
/// blocks through the spill file — the returned report's counters prove
/// it. Callers assert `dataset_bytes >= 4 * budget_bytes`-style ratios
/// and compare `sigma` against an in-memory run.
pub fn ocean_svd_outofcore(
    spec: &OceanSpec,
    path: &std::path::Path,
    budget_bytes: u64,
    workers: usize,
    opts: &crate::linalg::SvdOptions,
    panel_rows: usize,
) -> crate::Result<OutOfCoreReport> {
    use crate::client::AlchemistContext;
    use crate::coordinator::AlchemistServer;
    use crate::protocol::{Params, Value};

    anyhow::ensure!(
        budget_bytes > 0,
        "a zero budget is unlimited — nothing out-of-core to prove"
    );
    anyhow::ensure!(panel_rows > 0, "panel_rows must be > 0 to stream");
    if !path.exists() {
        spec.write_file(path)?;
    }
    let mut cfg = crate::config::Config::default();
    cfg.storage.budget_bytes = budget_bytes;
    let server = AlchemistServer::start(cfg.clone(), workers)?;

    let run = (|| -> crate::Result<OutOfCoreReport> {
        let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, workers)?;
        ac.register_library("elemental", "builtin:elemental")?;

        let (al_a, load) = ac.load_matrix("A", path.to_str().unwrap())?;
        let res = ac.run_task(
            "elemental",
            "truncated_svd",
            Params::new()
                .with_matrix("A", al_a.id)
                .with_i64("rank", opts.rank as i64)
                .with_i64("steps", opts.steps as i64)
                .with_i64("seed", opts.seed as i64)
                .with_i64("panel_rows", panel_rows as i64),
        )?;
        let svd_secs = res.timing("compute");
        let sigma = match res.scalars.get("sigma") {
            Some(Value::F64s(v)) => v.clone(),
            _ => anyhow::bail!("svd returned no sigma"),
        };
        // pull U back through the data plane: it spilled at insert time
        // (N×k exceeds the budget), so this read is what pages/streams
        // the blocks back from disk
        let (u, _) = ac.to_indexed_row_matrix(res.output("U")?, 1)?;
        let storage = server.storage_metrics();
        ac.stop();
        Ok(OutOfCoreReport {
            sigma,
            load_secs: load.secs,
            svd_secs,
            client_bytes_loaded: load.bytes,
            dataset_bytes: spec.bytes(),
            budget_bytes,
            storage,
            u_rows: u.rows,
        })
    })();
    server.shutdown();
    run
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> OceanSpec {
        OceanSpec {
            cells: 256,
            times: 96,
            modes: 6,
            sigma0: 50.0,
            decay: 0.6,
            noise: 0.01,
            seed: 11,
        }
    }

    #[test]
    fn sharded_generation_matches_full() {
        let spec = small_spec();
        let full = spec.generate();
        let top = spec.generate_rows(0, 100);
        let bottom = spec.generate_rows(100, 256);
        assert_eq!(full.slice_rows(0, 100), top);
        assert_eq!(full.slice_rows(100, 256), bottom);
    }

    #[test]
    fn truncated_svd_captures_most_energy() {
        let spec = small_spec();
        let a = spec.generate();
        let comms = crate::collectives::LocalComm::group(1, None);
        let mut e = crate::compute::NativeEngine::new();
        let res = crate::linalg::truncated_svd(
            &comms[0],
            &mut e,
            &a,
            &crate::linalg::SvdOptions { rank: 6, steps: 40, seed: 2 },
        )
        .unwrap();
        let energy: f64 = res.sigma.iter().map(|s| s * s).sum();
        let total = a.fro_sq();
        assert!(
            energy / total > 0.95,
            "rank-6 captures {:.3} of energy",
            energy / total
        );
        // spectrum decays
        for w in res.sigma.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }
}
