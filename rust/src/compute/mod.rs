//! Worker compute engines (DESIGN.md ablation #1).
//!
//! Everything numeric the Alchemist workers do funnels through the
//! [`Engine`] trait: composable GEMM, the fused Gram-operator matvec, the
//! random-feature expansion, and the fused CG state update. Three
//! implementations:
//!
//! * [`NativeEngine`] — packed-panel pure-rust kernels
//!   ([`crate::distmat::dense`]) parallelized over an intra-rank
//!   [`ThreadPool`] (`engine.threads`), the floor the ablation bench
//!   compares against;
//! * [`XlaEngine`] with `engine = "xla"` — AOT artifacts lowered from the
//!   pure-jnp L2 graphs (XLA's own `dot`);
//! * [`XlaEngine`] with `engine = "pallas"` — the same graphs lowered
//!   through the Pallas kernels (`interpret=True`);
//! * [`DispatchEngine`] with `engine = "auto"` — the adaptive plane: a
//!   calibrated cost model picks native vs XLA per call ([`dispatch`]).
//!
//! Engines are constructed *inside* each worker thread
//! ([`build_engine`] / [`build_engine_with_pool`]) — the runtime's
//! executable caches are deliberately not shared across ranks, which
//! conveniently mirrors per-rank MPI library contexts. Since PR 6 the
//! native engine can ride a client handle of the server's shared
//! work-stealing [`ThreadPool`] instead of private threads.

pub mod dispatch;
pub mod native;
pub mod pool;
pub mod tiled;

pub use dispatch::DispatchEngine;
pub use native::NativeEngine;
pub use pool::ThreadPool;
pub use tiled::XlaEngine;

use std::sync::Arc;

use crate::config::{Config, EngineKind};
use crate::distmat::LocalMatrix;
use crate::tasks::CancelToken;

/// GEMM storage variants (`c += op(a)·op(b)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmVariant {
    /// a: m×k, b: k×n
    NN,
    /// a stored k×m (transposed use), b: k×n
    TN,
    /// a: m×k, b stored n×k
    NT,
}

impl GemmVariant {
    pub fn op_name(self) -> &'static str {
        match self {
            GemmVariant::NN => "gemm_nn",
            GemmVariant::TN => "gemm_tn",
            GemmVariant::NT => "gemm_nt",
        }
    }

    /// (m, n, k) given the two operand shapes.
    pub fn problem_dims(self, a: &LocalMatrix, b: &LocalMatrix) -> (usize, usize, usize) {
        match self {
            GemmVariant::NN => (a.rows(), b.cols(), a.cols()),
            GemmVariant::TN => (a.cols(), b.cols(), a.rows()),
            GemmVariant::NT => (a.rows(), b.rows(), a.cols()),
        }
    }
}

/// The worker-side compute interface. `&mut self` because the XLA engines
/// keep executable caches and perf counters.
pub trait Engine {
    fn kind(&self) -> EngineKind;

    /// `c += op(a)·op(b)`.
    fn gemm(
        &mut self,
        variant: GemmVariant,
        c: &mut LocalMatrix,
        a: &LocalMatrix,
        b: &LocalMatrix,
    ) -> crate::Result<()>;

    /// `aᵀ(a·v) + reg·v` for a row-panel `a` (the CG/Lanczos hot path).
    fn gram_matvec(
        &mut self,
        a: &LocalMatrix,
        v: &LocalMatrix,
        reg: f64,
    ) -> crate::Result<LocalMatrix>;

    /// Like [`gram_matvec`](Engine::gram_matvec) but with a caller-chosen
    /// operand key: the same `key` promises the same `a` contents, letting
    /// device-backed engines keep the panel resident across iterations
    /// (§Perf — the dominant win for iterative solvers). Obtain keys from
    /// [`fresh_operand_key`]; default implementations ignore the key.
    fn gram_matvec_keyed(
        &mut self,
        _key: u64,
        a: &LocalMatrix,
        v: &LocalMatrix,
        reg: f64,
    ) -> crate::Result<LocalMatrix> {
        self.gram_matvec(a, v, reg)
    }

    /// Random-feature panel: `scale · cos(x·omega + bias)`.
    fn rff_expand(
        &mut self,
        x: &LocalMatrix,
        omega: &LocalMatrix,
        bias: &[f64],
        scale: f64,
    ) -> crate::Result<LocalMatrix>;

    /// Fused pair-AXPY: `x += alpha⊙p; r -= alpha⊙q` (alpha per column).
    fn cg_update(
        &mut self,
        x: &mut LocalMatrix,
        r: &mut LocalMatrix,
        p: &LocalMatrix,
        q: &LocalMatrix,
        alpha: &[f64],
    ) -> crate::Result<()>;

    /// (calls, seconds) spent in PJRT execute, for perf accounting.
    fn exec_stats(&self) -> (u64, f64) {
        (0, 0.0)
    }

    /// Set the intra-rank parallelism for subsequent ops. The scheduler
    /// clamps the value at session admission so `granted_workers ×
    /// threads ≤ available cores` (see `docs/compute.md`); results must
    /// be bit-identical for any thread count (the SPMD determinism
    /// contract). Engines without an internal pool ignore it.
    fn set_threads(&mut self, _threads: usize) {}

    /// Install (or clear, with `None`) a cancellation token that the
    /// engine polls at MC-panel boundaries inside its kernels. A
    /// cancelled token makes subsequent ops fail fast with
    /// [`crate::tasks::CANCELLED_MSG`], so even a routine that never
    /// polls its [`crate::tasks::TaskScope`] terminates within one panel
    /// of a hard cancel. Engines without cancellable kernels ignore it.
    fn set_cancel(&mut self, _token: Option<Arc<CancelToken>>) {}
}

/// Process-unique operand key for [`Engine::gram_matvec_keyed`]: a new key
/// per solver invocation guarantees no stale-cache aliasing even after
/// matrices are freed and reallocated.
pub fn fresh_operand_key() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Build the engine selected by `cfg.engine`. Must be called on the thread
/// that will use it.
pub fn build_engine(cfg: &Config) -> crate::Result<Box<dyn Engine>> {
    build_engine_with_pool(cfg, None)
}

/// Like [`build_engine`], but engines with an intra-rank pool (`native`,
/// and the native half of `auto`) run on `pool` — normally a per-rank
/// client handle of the server's shared work-stealing pool — instead of
/// spawning private threads. `None` falls back to a private pool.
pub fn build_engine_with_pool(
    cfg: &Config,
    pool: Option<ThreadPool>,
) -> crate::Result<Box<dyn Engine>> {
    let native = |pool: Option<ThreadPool>| match pool {
        Some(p) => NativeEngine::from_pool(p),
        None => NativeEngine::new(),
    };
    Ok(match cfg.engine {
        EngineKind::Native => Box::new(native(pool)),
        EngineKind::Xla => Box::new(XlaEngine::new(cfg, "xla")?),
        EngineKind::Pallas => Box::new(XlaEngine::new(cfg, "pallas")?),
        EngineKind::Auto => Box::new(DispatchEngine::new(cfg, native(pool))),
    })
}
