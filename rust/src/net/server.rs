//! Accept-loop helper: bind, spawn one handler thread per connection,
//! join on shutdown.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Context;

/// A listening socket with a graceful-ish shutdown flag. Handler panics
/// are contained to their connection thread.
pub struct Server {
    listener: TcpListener,
    addr: String,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind `127.0.0.1:port` (`port = 0` for ephemeral).
    pub fn bind(port: u16) -> crate::Result<Self> {
        Self::bind_advertised(port, "")
    }

    /// Bind with an advertised host (v10, `fabric.advertise_addr`):
    /// empty = the loopback default; non-empty binds all interfaces and
    /// reports `advertise:port` from [`Server::addr`], so clients on
    /// other hosts can be handed a reachable address.
    pub fn bind_advertised(port: u16, advertise: &str) -> crate::Result<Self> {
        let host = if advertise.is_empty() { "127.0.0.1" } else { "0.0.0.0" };
        let listener = TcpListener::bind((host, port))
            .with_context(|| format!("binding port {port}"))?;
        let local = listener.local_addr()?;
        let addr = if advertise.is_empty() {
            local.to_string()
        } else {
            format!("{advertise}:{}", local.port())
        };
        Ok(Server { listener, addr, stop: Arc::new(AtomicBool::new(false)) })
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// A clonable flag that makes [`serve`] return after the next
    /// connection is handled (pair with a wake-up connect).
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Run the accept loop on the current thread, spawning one detached
    /// thread per connection. Returns when the stop flag is set.
    ///
    /// Handler threads are deliberately *not* joined: a connection held
    /// open by a slow (or dead) client must not stall server shutdown —
    /// handlers exit on their own when the peer socket closes.
    pub fn serve<F>(&self, handler: F) -> crate::Result<()>
    where
        F: Fn(TcpStream) + Send + Sync + 'static,
    {
        let handler = Arc::new(handler);
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = conn.context("accept")?;
            let h = handler.clone();
            std::thread::spawn(move || h(stream));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    #[test]
    fn serves_multiple_connections_then_stops() {
        let server = Server::bind(0).unwrap();
        let addr = server.addr().to_string();
        let stop = server.stop_flag();
        let t = std::thread::spawn(move || {
            server
                .serve(|mut s| {
                    let mut b = [0u8; 1];
                    let _ = s.read_exact(&mut b);
                    let _ = s.write_all(&[b[0] + 1]);
                })
                .unwrap();
        });
        for i in 0..3u8 {
            let mut c = TcpStream::connect(&addr).unwrap();
            c.write_all(&[i]).unwrap();
            let mut b = [0u8; 1];
            c.read_exact(&mut b).unwrap();
            assert_eq!(b[0], i + 1);
        }
        stop.store(true, std::sync::atomic::Ordering::SeqCst);
        let _ = TcpStream::connect(&addr).unwrap(); // wake the accept loop
        t.join().unwrap();
    }
}
