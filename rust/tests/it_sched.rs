//! Integration: the protocol-v9 serving-grade scheduler
//! (`docs/scheduler.md`).
//!
//! Four scheduler properties, each pinned end to end over the client API:
//!
//! * **admission priority**: a queued interactive handshake is admitted
//!   before an earlier-queued batch one, and an admission timeout names
//!   the class, grant position, and queue depth;
//! * **fair share**: with equal classes, the tenant holding fewer active
//!   sessions is granted capacity first even if it queued later;
//! * **concurrent tasks per group**: with `scheduler.tasks_per_group`
//!   raised, a solve and an SVD run on the SAME worker group at once —
//!   each on its own communicator tag lane — and produce bit-identical
//!   results to serial execution, under both `fabric.mode = local` and
//!   tcp loopback worker processes;
//! * **lane-scoped cancellation**: hard-cancelling one of two concurrent
//!   tasks poisons only its own tag lane — the sibling task survives to
//!   `Done` (pre-v9 the group-wide poison would have failed it too);
//! * **metrics stream**: a `SubscribeMetrics` connection pushes JSON-line
//!   snapshots carrying a gauge for every running task.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use alchemist::client::AlchemistContext;
use alchemist::config::{Config, EngineKind, FabricMode};
use alchemist::coordinator::AlchemistServer;
use alchemist::protocol::{Params, TaskState, Value};
use alchemist::sparklite::IndexedRowMatrix;

fn native_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.engine = EngineKind::Native;
    cfg
}

/// Local-mode config switched onto the process fabric (the worker
/// executable must be named explicitly: inside an integration test
/// `current_exe()` is the test runner, not `alchemist`).
fn tcp_cfg() -> Config {
    let mut cfg = native_cfg();
    cfg.fabric.mode = FabricMode::Tcp;
    cfg.fabric.worker_exe = env!("CARGO_BIN_EXE_alchemist").into();
    cfg
}

/// Poll until `f` returns true or the timeout fires (sleep-based tests
/// stay robust on slow CI runners).
fn eventually(timeout: Duration, what: &str, mut f: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !f() {
        assert!(t0.elapsed() < timeout, "timed out waiting for: {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Connect on a thread, report the label the moment admission succeeds,
/// then end the session (releasing its worker for the next grant).
fn admit_async(
    addr: String,
    cfg: Config,
    priority: u32,
    name: &'static str,
    tx: mpsc::Sender<&'static str>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let ac =
            AlchemistContext::connect_named(&addr, &cfg, 1, 1, priority, name)
                .unwrap();
        tx.send(name).unwrap();
        ac.stop();
    })
}

#[test]
fn interactive_class_preempts_earlier_batch_handshake() {
    let mut cfg = native_cfg();
    // aging off: this test pins pure class ordering
    cfg.apply("scheduler.age_secs", "0").unwrap();
    let server = AlchemistServer::start(cfg.clone(), 1).unwrap();
    let addr = server.control_addr.clone();

    // a normal-class session holds the only worker
    let holder =
        AlchemistContext::connect_named(&addr, &cfg, 1, 1, 1, "holder").unwrap();

    // batch queues FIRST, interactive second
    let (tx, rx) = mpsc::channel();
    let t_batch = admit_async(addr.clone(), cfg.clone(), 0, "batch", tx.clone());
    eventually(Duration::from_secs(10), "batch handshake to queue", || {
        server.sched_metrics().admission_depth[0] == 1
    });
    let t_inter = admit_async(addr.clone(), cfg.clone(), 2, "interactive", tx);
    eventually(Duration::from_secs(10), "interactive handshake to queue", || {
        server.sched_metrics().admission_depth[2] == 1
    });

    // the worker frees up: the LATER, higher-class handshake wins it
    holder.stop();
    assert_eq!(rx.recv_timeout(Duration::from_secs(20)).unwrap(), "interactive");
    // ...and batch is not starved once capacity returns
    assert_eq!(rx.recv_timeout(Duration::from_secs(20)).unwrap(), "batch");
    t_batch.join().unwrap();
    t_inter.join().unwrap();

    let m = server.sched_metrics();
    assert_eq!(m.admission_depth, [0; 4]);
    assert_eq!(m.sessions_admitted, 3);
    server.shutdown();
}

#[test]
fn admission_timeout_reports_class_and_grant_position() {
    let mut cfg = native_cfg();
    cfg.apply("scheduler.age_secs", "0").unwrap();
    cfg.apply("scheduler.queue_timeout_s", "0.3").unwrap();
    let server = AlchemistServer::start(cfg.clone(), 1).unwrap();
    let addr = server.control_addr.clone();

    let holder =
        AlchemistContext::connect_named(&addr, &cfg, 1, 1, 1, "holder").unwrap();
    let err = AlchemistContext::connect_named(&addr, &cfg, 1, 1, 0, "late")
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("admission timed out"), "{msg}");
    assert!(msg.contains("class batch"), "{msg}");
    assert!(msg.contains("grant position 1 of 1 queued"), "{msg}");

    assert_eq!(server.sched_metrics().sessions_rejected, 1);
    holder.stop();
    server.shutdown();
}

#[test]
fn fair_share_grants_idle_tenant_before_loaded_one() {
    let mut cfg = native_cfg();
    cfg.apply("scheduler.age_secs", "0").unwrap();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let addr = server.control_addr.clone();

    // tenant alpha holds BOTH workers across two sessions
    let a1 =
        AlchemistContext::connect_named(&addr, &cfg, 1, 1, 1, "alpha").unwrap();
    let a2 =
        AlchemistContext::connect_named(&addr, &cfg, 1, 1, 1, "alpha").unwrap();

    // alpha queues a third session FIRST, beta queues second — same class
    let (tx, rx) = mpsc::channel();
    let t_a3 = admit_async(addr.clone(), cfg.clone(), 1, "alpha3", tx.clone());
    eventually(Duration::from_secs(10), "alpha3 to queue", || {
        server.sched_metrics().admission_depth[1] == 1
    });
    let t_b = admit_async(addr.clone(), cfg.clone(), 1, "beta", tx);
    eventually(Duration::from_secs(10), "beta to queue", || {
        server.sched_metrics().admission_depth[1] == 2
    });

    // one worker frees: beta (0 active sessions) outranks alpha (1 still
    // active) despite queueing later — weighted fair share, not FIFO
    a2.stop();
    assert_eq!(rx.recv_timeout(Duration::from_secs(20)).unwrap(), "beta");
    a1.stop();
    assert_eq!(rx.recv_timeout(Duration::from_secs(20)).unwrap(), "alpha3");
    t_a3.join().unwrap();
    t_b.join().unwrap();
    server.shutdown();
}

/// Run the paper loop once: CG solve, truncated SVD, and a pull of A.
/// `concurrent = true` submits the solve and the SVD together (so they
/// run on two tag lanes of one group) and pulls A while both are in
/// flight; `false` runs everything serially. The returned bits must not
/// depend on which way it ran.
fn solve_svd_pull(
    cfg: &Config,
    concurrent: bool,
) -> (Vec<f64>, i64, Vec<f64>, Vec<f64>, Vec<f64>) {
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, cfg, 2).unwrap();
    ac.register_library("skylark", "builtin:skylark").unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();

    let gen = |ac: &mut AlchemistContext, rows: i64, cols: i64, seed: i64| {
        ac.run_task(
            "elemental",
            "rand_matrix",
            Params::new().with_i64("rows", rows).with_i64("cols", cols).with_i64("seed", seed),
        )
        .unwrap()
        .outputs[0]
            .clone()
    };
    let x = gen(&mut ac, 192, 48, 1);
    let y = gen(&mut ac, 192, 3, 2);
    let a = gen(&mut ac, 128, 12, 3);

    let cg_params = Params::new()
        .with_matrix("X", x.id)
        .with_matrix("Y", y.id)
        .with_f64("lambda", 1e-3)
        .with_f64("tol", 1e-10)
        .with_i64("max_iters", 200);
    let svd_params =
        Params::new().with_matrix("A", a.id).with_i64("rank", 4).with_i64("seed", 7);

    let (cg_res, svd_res, a_back) = if concurrent {
        let cg_id = ac.submit("skylark", "cg_solve", cg_params).unwrap().task_id;
        let svd_id =
            ac.submit("elemental", "truncated_svd", svd_params).unwrap().task_id;
        // the pull overlaps whatever is still solving: it rides the data
        // sockets, not a task lane, so it needs no third lane
        let (a_back, _) = ac.to_indexed_row_matrix(&a, 1).unwrap();
        let cg_res = ac.task(cg_id).wait().unwrap();
        let svd_res = ac.task(svd_id).wait().unwrap();
        (cg_res, svd_res, a_back)
    } else {
        let cg_res = ac.run_task("skylark", "cg_solve", cg_params).unwrap();
        let svd_res =
            ac.run_task("elemental", "truncated_svd", svd_params).unwrap();
        let (a_back, _) = ac.to_indexed_row_matrix(&a, 1).unwrap();
        (cg_res, svd_res, a_back)
    };

    let iters = cg_res.scalars.i64("iters").unwrap();
    let (w, _) = ac.to_indexed_row_matrix(cg_res.output("W").unwrap(), 1).unwrap();
    let sigma = match svd_res.scalars.get("sigma") {
        Some(Value::F64s(v)) => v.clone(),
        other => panic!("sigma missing: {other:?}"),
    };
    let (u, _) = ac.to_indexed_row_matrix(svd_res.output("U").unwrap(), 1).unwrap();

    let flat = |m: IndexedRowMatrix| m.to_local().unwrap().data().to_vec();
    ac.stop();
    server.shutdown();
    (flat(w), iters, sigma, flat(u), flat(a_back))
}

fn assert_concurrent_matches_serial(mut cfg: Config) {
    let serial = solve_svd_pull(&cfg, false);
    cfg.apply("scheduler.tasks_per_group", "2").unwrap();
    let overlapped = solve_svd_pull(&cfg, true);
    assert!(serial.1 > 1, "CG should iterate, took {}", serial.1);
    assert_eq!(serial.1, overlapped.1, "CG iteration count differs");
    assert_eq!(serial.0, overlapped.0, "CG W differs under concurrency");
    assert_eq!(serial.2, overlapped.2, "SVD spectrum differs under concurrency");
    assert_eq!(serial.3, overlapped.3, "SVD U differs under concurrency");
    assert_eq!(serial.4, overlapped.4, "pulled A differs under concurrency");
}

#[test]
fn concurrent_solve_and_svd_bit_identical_to_serial_local_mode() {
    assert_concurrent_matches_serial(native_cfg());
}

#[test]
fn concurrent_solve_and_svd_bit_identical_to_serial_tcp_mode() {
    assert_concurrent_matches_serial(tcp_cfg());
}

/// Two tasks on one group, then a hard cancel of one: only the
/// cancelled task's tag lane is poisoned, so the sibling runs to `Done`.
/// Pre-v9 the cancel poisoned the whole group fabric and the sibling
/// died as collateral.
fn lane_scoped_hard_cancel(cfg: &Config) {
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, cfg, 1).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();

    // `spin` never observes its cooperative token — only a (lane) poison
    // can end it early; the sibling `sleep` outlives the whole cancel
    let victim = ac
        .submit("elemental", "spin", Params::new().with_i64("millis", 30_000))
        .unwrap()
        .task_id;
    let sibling = ac
        .submit("elemental", "sleep", Params::new().with_i64("millis", 8_000))
        .unwrap()
        .task_id;
    eventually(Duration::from_secs(10), "both tasks running concurrently", || {
        server.session_queue_depths().first().is_some_and(|d| d.running == 2)
    });

    let t_cancel = Instant::now();
    ac.task(victim).cancel_hard(200).unwrap();
    let err = ac.task(victim).wait().unwrap_err();
    assert!(err.to_string().contains("cancelled"), "{err}");
    assert!(
        t_cancel.elapsed() < Duration::from_secs(10),
        "hard cancel took {:?}",
        t_cancel.elapsed()
    );

    // the sibling was untouched by the poison: never Failed, and it
    // finishes normally on its own lane
    let st = ac.task(sibling).status().unwrap();
    assert!(
        matches!(st, TaskState::Running { .. } | TaskState::Done { .. }),
        "sibling collateral-damaged by the cancel: {st:?}"
    );
    let st = ac.task(sibling).wait_timeout(20_000).unwrap();
    assert!(matches!(st, TaskState::Done { .. }), "{st:?}");

    // group still healthy afterwards
    let res = ac
        .run_task("elemental", "sleep", Params::new().with_i64("millis", 10))
        .unwrap();
    assert_eq!(res.scalars.i64("ranks").unwrap(), 2);

    let m = server.sched_metrics();
    assert_eq!(m.tasks_cancelled, 1);
    assert_eq!(m.tasks_failed, 0, "the sibling must not fail as collateral");
    ac.stop();
    server.shutdown();
}

#[test]
fn hard_cancel_poisons_only_its_lane_local_mode() {
    let mut cfg = native_cfg();
    cfg.apply("scheduler.tasks_per_group", "2").unwrap();
    lane_scoped_hard_cancel(&cfg);
}

#[test]
fn hard_cancel_poisons_only_its_lane_tcp_mode() {
    let mut cfg = tcp_cfg();
    cfg.apply("scheduler.tasks_per_group", "2").unwrap();
    lane_scoped_hard_cancel(&cfg);
}

#[test]
fn metrics_stream_pushes_gauges_for_every_running_task() {
    let mut cfg = native_cfg();
    cfg.apply("scheduler.tasks_per_group", "2").unwrap();
    let server = AlchemistServer::start(cfg.clone(), 1).unwrap();
    let addr = server.control_addr.clone();
    let mut ac = AlchemistContext::connect(&addr, &cfg, 1).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();

    // subscribe on its own connection, fast cadence
    let mut stream = AlchemistContext::subscribe_metrics(&addr, &cfg, 50).unwrap();

    let t1 = ac
        .submit("elemental", "sleep", Params::new().with_i64("millis", 4_000))
        .unwrap()
        .task_id;
    let t2 = ac
        .submit("elemental", "spin", Params::new().with_i64("millis", 4_000))
        .unwrap()
        .task_id;

    // within the tasks' lifetime the push stream must deliver a snapshot
    // gauging BOTH running tasks, with monotonic sequence numbers and
    // one JSON object per line
    let mut last_seq = None;
    let mut saw_both = false;
    for _ in 0..200 {
        let u = stream.next().expect("stream ended early").unwrap();
        if let Some(prev) = last_seq {
            assert!(u.seq > prev, "seq went {prev} -> {}", u.seq);
        }
        last_seq = Some(u.seq);
        assert!(!u.json.contains('\n'), "snapshot not a single JSON line");
        assert!(u.json.contains("\"admission_depth\":{\"batch\":"), "{}", u.json);
        if u.json.contains("\"routine\":\"elemental.sleep\"")
            && u.json.contains("\"routine\":\"elemental.spin\"")
            && u.json.contains("\"running\":2")
        {
            // two tasks, two distinct lanes
            assert!(u.json.contains("\"lane\":"), "{}", u.json);
            saw_both = true;
            break;
        }
    }
    assert!(saw_both, "stream never gauged both running tasks");

    assert!(matches!(
        ac.task(t1).wait_timeout(20_000).unwrap(),
        TaskState::Done { .. }
    ));
    assert!(matches!(
        ac.task(t2).wait_timeout(20_000).unwrap(),
        TaskState::Done { .. }
    ));
    drop(stream); // unsubscribes: the server drops the push thread
    ac.stop();
    server.shutdown();
}
