//! Length-prefixed frame transport over any `Read + Write` pair.
//!
//! Frame = `u32` little-endian length + payload. The writer is buffered
//! (`Config.transfer.buf_bytes` sized) so row-batch frames coalesce into
//! large socket writes — this buffer is one of the transfer-path knobs the
//! ablation bench sweeps.
//!
//! Two frame paths exist:
//!
//! * the **owned** path ([`send_data`](Framed::send_data) /
//!   [`recv_data`](Framed::recv_data)) encodes through a `Writer` Vec and
//!   decodes into fresh allocations — fine for control traffic;
//! * the **single-copy** path ([`send_data_ref`](Framed::send_data_ref) /
//!   [`recv_data_view`](Framed::recv_data_view)) writes header + payload
//!   straight into the socket buffer and decodes payloads as slices into
//!   a reusable receive buffer, so steady-state row transfer performs no
//!   per-frame heap allocation (tracked by
//!   [`recv_buf_grows`](Framed::recv_buf_grows)). Payloads at or above
//!   [`VECTORED_MIN_BYTES`] skip the write buffer entirely: the length
//!   prefix, header, and payload go to the socket in one gathered
//!   `writev`, so big row batches reach the kernel with **zero**
//!   user-space copies of the f64s on the send side.

use std::io::{BufReader, BufWriter, IoSlice, Read, Write};
use std::net::TcpStream;

use anyhow::Context;

use crate::protocol::{ControlMsg, DataMsg, DataMsgRef, DataMsgView};

/// Maximum accepted frame (guards against corrupt length prefixes).
/// Public so frame producers (e.g. the worker's pull streams) can size
/// their payloads to fit under it.
pub const MAX_FRAME: u32 = 1 << 30;

/// Frames between retained-capacity checks on the receive buffer (see
/// [`Framed::recv_ref`]): long enough that one check window spans a whole
/// steady-state burst, short enough that an idle control link lets a
/// transient large frame's memory go promptly.
const SHRINK_CHECK_FRAMES: u32 = 64;

/// Never shrink the receive buffer below this (control frames churn
/// around this size; shrinking further would just re-grow).
const MIN_RETAINED_BYTES: usize = 4 << 10;

/// Payloads at or above this bypass the write buffer via a gathered
/// `writev` ([`Framed::send_data_ref`]). Below it, copying into the
/// buffer is cheaper than a dedicated syscall; at or above it, the
/// buffer copy is pure overhead — the payload alone already justifies
/// its own socket write.
pub const VECTORED_MIN_BYTES: usize = 4 << 10;

/// Write every byte of `bufs` through `write_vectored`, walking the
/// cursor across partial writes by hand (`IoSlice::advance_slices` needs
/// a newer compiler than this crate's floor).
fn write_all_vectored<W: Write>(w: &mut W, bufs: &[&[u8]]) -> crate::Result<()> {
    let mut idx = 0; // first not-fully-written buf
    let mut off = 0; // bytes of bufs[idx] already written
    while idx < bufs.len() {
        if off == bufs[idx].len() {
            idx += 1;
            off = 0;
            continue;
        }
        let slices: Vec<IoSlice<'_>> = std::iter::once(IoSlice::new(&bufs[idx][off..]))
            .chain(bufs[idx + 1..].iter().map(|b| IoSlice::new(b)))
            .collect();
        let mut n = match w.write_vectored(&slices) {
            Ok(0) => anyhow::bail!("socket closed mid-frame (wrote 0 bytes)"),
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        };
        while idx < bufs.len() && n >= bufs[idx].len() - off {
            n -= bufs[idx].len() - off;
            idx += 1;
            off = 0;
        }
        off += n;
    }
    Ok(())
}

pub struct Framed<R: Read, W: Write> {
    r: BufReader<R>,
    w: BufWriter<W>,
    /// Reusable frame receive buffer: payloads decode in place, so the
    /// buffer reaches the largest frame size and stops allocating.
    rbuf: Vec<u8>,
    /// Times `rbuf` had to grow — flat in steady state (the data plane's
    /// zero-allocation invariant; asserted by tests).
    rbuf_grows: u64,
    /// Largest frame seen in the current shrink-check window: the
    /// capacity worth retaining. One transient large frame must not pin
    /// peak-frame memory for the life of a long-lived connection.
    rbuf_high: usize,
    /// Frames received since the last retained-capacity check.
    rbuf_frames: u32,
}

impl Framed<TcpStream, TcpStream> {
    /// Wrap a TCP stream (clones the fd for the read half) with the given
    /// write-buffer size.
    pub fn tcp(stream: TcpStream, buf_bytes: usize) -> crate::Result<Self> {
        stream.set_nodelay(true).context("set_nodelay")?;
        let rd = stream.try_clone().context("clone tcp stream")?;
        Ok(Framed {
            r: BufReader::with_capacity(buf_bytes.max(8 << 10), rd),
            w: BufWriter::with_capacity(buf_bytes.max(8 << 10), stream),
            rbuf: Vec::new(),
            rbuf_grows: 0,
            rbuf_high: 0,
            rbuf_frames: 0,
        })
    }

    /// Connect to `addr` and wrap.
    pub fn connect(addr: &str, buf_bytes: usize) -> crate::Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to {addr}"))?;
        Self::tcp(stream, buf_bytes)
    }
}

impl<R: Read, W: Write> Framed<R, W> {
    /// Wrap an arbitrary read/write pair (tests use in-memory pipes).
    pub fn new(r: R, w: W) -> Self {
        Framed {
            r: BufReader::new(r),
            w: BufWriter::new(w),
            rbuf: Vec::new(),
            rbuf_grows: 0,
            rbuf_high: 0,
            rbuf_frames: 0,
        }
    }

    /// Queue one frame (stays in the write buffer until [`flush`] or the
    /// buffer fills).
    pub fn send(&mut self, payload: &[u8]) -> crate::Result<()> {
        let len = u32::try_from(payload.len()).context("frame too large")?;
        anyhow::ensure!(len <= MAX_FRAME, "frame of {len} bytes exceeds cap");
        self.w.write_all(&len.to_le_bytes())?;
        self.w.write_all(payload)?;
        Ok(())
    }

    pub fn flush(&mut self) -> crate::Result<()> {
        self.w.flush()?;
        Ok(())
    }

    /// Queue and flush.
    pub fn send_flush(&mut self, payload: &[u8]) -> crate::Result<()> {
        self.send(payload)?;
        self.flush()
    }

    /// Block until one frame arrives; the returned slice points into the
    /// reusable receive buffer and is valid until the next `recv_*` call.
    pub fn recv_ref(&mut self) -> crate::Result<&[u8]> {
        let mut len_buf = [0u8; 4];
        self.r.read_exact(&mut len_buf).context("reading frame length")?;
        let len = u32::from_le_bytes(len_buf);
        anyhow::ensure!(len <= MAX_FRAME, "incoming frame of {len} bytes exceeds cap");
        let len = len as usize;
        // bound the retained capacity: if a whole check window of frames
        // stayed far below what the buffer once grew to, release the
        // excess (a 1 GiB outlier must not be pinned per link forever).
        // Runs before `resize` so no borrow of the payload is live; the
        // target includes the incoming frame, so this never forces an
        // immediate re-grow (and never counts as one).
        self.rbuf_high = self.rbuf_high.max(len);
        self.rbuf_frames += 1;
        if self.rbuf_frames >= SHRINK_CHECK_FRAMES {
            let keep = self.rbuf_high.max(MIN_RETAINED_BYTES);
            if self.rbuf.capacity() > keep.saturating_mul(4) {
                self.rbuf.clear();
                self.rbuf.shrink_to(keep);
            }
            self.rbuf_high = len;
            self.rbuf_frames = 0;
        }
        if self.rbuf.capacity() < len {
            self.rbuf_grows += 1;
        }
        if self.rbuf.len() < len {
            // grow-only: `len` stays pinned at the high-water mark (the
            // shrink above is what lowers it), so the zero-fill covers
            // just the newly exposed region once — a plain `resize(len)`
            // would memset the whole frame every time a frame follows a
            // smaller one (e.g. RowsData after a 9-byte PullDone trailer)
            self.rbuf.resize(len, 0);
        }
        self.r.read_exact(&mut self.rbuf[..len]).context("reading frame payload")?;
        Ok(&self.rbuf[..len])
    }

    /// Block until one frame arrives, copied into a fresh Vec (control
    /// path; the transfer hot path uses [`recv_ref`](Self::recv_ref) /
    /// [`recv_data_view`](Self::recv_data_view)).
    pub fn recv(&mut self) -> crate::Result<Vec<u8>> {
        Ok(self.recv_ref()?.to_vec())
    }

    /// Times the receive buffer has grown since this link opened. Steady
    /// state (frames of a stable size) keeps this flat — the data plane's
    /// no-per-frame-allocation invariant.
    pub fn recv_buf_grows(&self) -> u64 {
        self.rbuf_grows
    }

    /// Currently retained receive-buffer capacity in bytes. Tracks the
    /// recent peak frame size rather than the all-time peak — see the
    /// shrink logic in [`recv_ref`](Self::recv_ref).
    pub fn recv_buf_capacity(&self) -> usize {
        self.rbuf.capacity()
    }

    // -- typed convenience wrappers --

    pub fn send_ctrl(&mut self, msg: &ControlMsg) -> crate::Result<()> {
        self.send_flush(&msg.encode())
    }

    pub fn recv_ctrl(&mut self) -> crate::Result<ControlMsg> {
        Ok(ControlMsg::decode(&self.recv()?)?)
    }

    /// Control request/response in one call; unwraps server-side `Error`
    /// replies into `Err`.
    pub fn call(&mut self, msg: &ControlMsg) -> crate::Result<ControlMsg> {
        self.send_ctrl(msg)?;
        match self.recv_ctrl()? {
            ControlMsg::Error { message } => anyhow::bail!("server error: {message}"),
            reply => Ok(reply),
        }
    }

    /// Queue a data message WITHOUT flushing (row streams batch many).
    pub fn send_data(&mut self, msg: &DataMsg) -> crate::Result<()> {
        self.send(&msg.encode())
    }

    pub fn send_data_flush(&mut self, msg: &DataMsg) -> crate::Result<()> {
        self.send_data(msg)?;
        self.flush()
    }

    pub fn recv_data(&mut self) -> crate::Result<DataMsg> {
        Ok(DataMsg::decode(&self.recv()?)?)
    }

    /// Queue a borrowed-payload data frame WITHOUT flushing: length
    /// prefix + fixed header + the payload's raw little-endian bytes go
    /// straight into the socket buffer — no intermediate encode Vec, so
    /// the f64s are copied at most once on this side. Payloads of
    /// [`VECTORED_MIN_BYTES`] or more skip even that copy: pending
    /// buffered bytes are flushed (frame order is preserved) and the
    /// whole frame goes out as one gathered `writev` of three slices.
    pub fn send_data_ref(&mut self, msg: &DataMsgRef) -> crate::Result<()> {
        let len = msg.frame_len();
        anyhow::ensure!(
            len <= MAX_FRAME as usize,
            "frame of {len} bytes exceeds cap"
        );
        let header = msg.encode_header()?;
        let data = msg.payload();
        #[cfg(target_endian = "little")]
        {
            let payload = crate::protocol::wire::f64s_as_le_bytes(data);
            if payload.len() >= VECTORED_MIN_BYTES {
                self.w.flush()?;
                let prefix = (len as u32).to_le_bytes();
                return write_all_vectored(
                    self.w.get_mut(),
                    &[&prefix, &header, payload],
                );
            }
            self.w.write_all(&(len as u32).to_le_bytes())?;
            self.w.write_all(&header)?;
            self.w.write_all(payload)?;
        }
        #[cfg(target_endian = "big")]
        {
            // byte-swapping host: element-wise conversion needs a copy
            // anyway, so the buffered path is always the right one
            self.w.write_all(&(len as u32).to_le_bytes())?;
            self.w.write_all(&header)?;
            for x in data {
                self.w.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Receive one data frame decoded in place: payload-carrying messages
    /// borrow their bytes from the reusable receive buffer (valid until
    /// the next `recv_*` call); everything else decodes owned.
    pub fn recv_data_view(&mut self) -> crate::Result<DataMsgView<'_>> {
        let buf = self.recv_ref()?;
        Ok(DataMsgView::decode(buf)?)
    }

    /// Queue one frame whose payload is `header` followed by `payload`,
    /// without building an intermediate encode Vec. Payloads of
    /// `vectored_min` bytes or more skip the write buffer entirely
    /// (flush, then one gathered `writev` of prefix + header + payload —
    /// zero user-space copies of the payload); smaller ones are copied
    /// once into the write buffer so they coalesce with neighbors. The
    /// fabric's eager/rendezvous split rides this switch
    /// (`collectives::netcomm`, `fabric.eager_bytes`).
    pub fn send_gathered(
        &mut self,
        header: &[u8],
        payload: &[u8],
        vectored_min: usize,
    ) -> crate::Result<()> {
        let len = header.len() + payload.len();
        anyhow::ensure!(
            len <= MAX_FRAME as usize,
            "frame of {len} bytes exceeds cap"
        );
        let prefix = (len as u32).to_le_bytes();
        if payload.len() >= vectored_min {
            self.w.flush()?;
            return write_all_vectored(self.w.get_mut(), &[&prefix, header, payload]);
        }
        self.w.write_all(&prefix)?;
        self.w.write_all(header)?;
        self.w.write_all(payload)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn frames_roundtrip_over_tcp() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut f = Framed::tcp(s, 1 << 16).unwrap();
            loop {
                match f.recv_ctrl().unwrap() {
                    ControlMsg::Shutdown => {
                        f.send_ctrl(&ControlMsg::Bye).unwrap();
                        break;
                    }
                    ControlMsg::Handshake { client_name, version, .. } => {
                        assert_eq!(client_name, "t");
                        f.send_ctrl(&ControlMsg::HandshakeAck {
                            session_id: 1,
                            version,
                            granted_workers: 0,
                            worker_addrs: vec![],
                            rows_per_frame: 64,
                            buf_bytes: 1 << 16,
                            session_token: 7,
                        })
                        .unwrap();
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
        });

        let mut c = Framed::connect(&addr.to_string(), 1 << 16).unwrap();
        let reply = c
            .call(&ControlMsg::Handshake {
                client_name: "t".into(),
                version: 1,
                request_workers: 0,
                rows_per_frame: 0,
                buf_bytes: 0,
                priority: crate::protocol::DEFAULT_PRIORITY,
            })
            .unwrap();
        assert!(matches!(reply, ControlMsg::HandshakeAck { session_id: 1, .. }));
        let bye = c.call(&ControlMsg::Shutdown).unwrap();
        assert_eq!(bye, ControlMsg::Bye);
        server.join().unwrap();
    }

    #[test]
    fn error_reply_becomes_err() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut f = Framed::tcp(s, 4096).unwrap();
            let _ = f.recv_ctrl().unwrap();
            f.send_ctrl(&ControlMsg::Error { message: "nope".into() }).unwrap();
        });
        let mut c = Framed::connect(&addr.to_string(), 4096).unwrap();
        let err = c.call(&ControlMsg::ListMatrices).unwrap_err();
        assert!(err.to_string().contains("nope"));
        server.join().unwrap();
    }

    #[test]
    fn borrowed_frames_roundtrip_and_reuse_recv_buffer() {
        use crate::protocol::DataMsgRef;

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let frames = 50usize;
        let ncols = 16usize;
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut f = Framed::tcp(s, 1 << 16).unwrap();
            let mut row = vec![0f64; ncols];
            for i in 0..frames {
                match f.recv_data_view().unwrap() {
                    crate::protocol::DataMsgView::PushRows {
                        matrix_id,
                        start_row,
                        nrows,
                        ncols: nc,
                        payload,
                    } => {
                        assert_eq!(matrix_id, 7);
                        assert_eq!(start_row, i as u64);
                        assert_eq!((nrows, nc), (1, ncols as u32));
                        crate::protocol::copy_le_f64s(payload, &mut row);
                        assert_eq!(row[0], i as f64);
                        assert_eq!(row[ncols - 1], i as f64 + 0.5);
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
            // identical frame sizes: the receive buffer grew once at most
            assert!(
                f.recv_buf_grows() <= 1,
                "recv buffer grew {} times for {frames} equal frames",
                f.recv_buf_grows()
            );
        });

        let mut c = Framed::connect(&addr.to_string(), 1 << 16).unwrap();
        let mut data = vec![0f64; ncols];
        for i in 0..frames {
            data[0] = i as f64;
            data[ncols - 1] = i as f64 + 0.5;
            c.send_data_ref(&DataMsgRef::PushRows {
                matrix_id: 7,
                start_row: i as u64,
                nrows: 1,
                ncols: ncols as u32,
                data: &data,
            })
            .unwrap();
        }
        c.flush().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn vectored_large_frames_interleave_with_buffered_small_ones() {
        use crate::protocol::DataMsgRef;

        // alternating payloads straddling VECTORED_MIN_BYTES: the small
        // ones take the buffered path, the big ones flush-then-writev —
        // frame order and content must survive the mixed paths
        let big_cols = VECTORED_MIN_BYTES / 8 + 13; // comfortably above
        let small_cols = 4usize;
        let rounds = 20usize;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut f = Framed::tcp(s, 1 << 16).unwrap();
            for i in 0..2 * rounds {
                let want_cols = if i % 2 == 0 { small_cols } else { big_cols };
                match f.recv_data_view().unwrap() {
                    crate::protocol::DataMsgView::PushRows {
                        start_row,
                        nrows,
                        ncols,
                        payload,
                        ..
                    } => {
                        assert_eq!(start_row, i as u64, "frames out of order");
                        assert_eq!((nrows, ncols as usize), (1, want_cols));
                        let mut row = vec![0f64; want_cols];
                        crate::protocol::copy_le_f64s(payload, &mut row);
                        assert_eq!(row[0], i as f64);
                        assert_eq!(row[want_cols - 1], i as f64 + 0.25);
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
        });

        let mut c = Framed::connect(&addr.to_string(), 1 << 16).unwrap();
        for i in 0..2 * rounds {
            let cols = if i % 2 == 0 { small_cols } else { big_cols };
            let mut data = vec![0f64; cols];
            data[0] = i as f64;
            data[cols - 1] = i as f64 + 0.25;
            c.send_data_ref(&DataMsgRef::PushRows {
                matrix_id: 9,
                start_row: i as u64,
                nrows: 1,
                ncols: cols as u32,
                data: &data,
            })
            .unwrap();
        }
        c.flush().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn write_all_vectored_survives_partial_writes() {
        // a writer that accepts at most 7 bytes per call forces the
        // cursor walk across every slice boundary
        struct Dribble(Vec<u8>);
        impl Write for Dribble {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                let n = buf.len().min(7);
                self.0.extend_from_slice(&buf[..n]);
                Ok(n)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let bufs: [&[u8]; 4] = [b"ab", b"", b"cdefghijk", b"lmnop"];
        let mut w = Dribble(Vec::new());
        write_all_vectored(&mut w, &bufs).unwrap();
        assert_eq!(w.0, b"abcdefghijklmnop");
    }

    #[test]
    fn gathered_send_frames_identically_on_both_paths() {
        // the same (header, payload) pair must produce byte-identical
        // frames whether it rides the write buffer or the writev path
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let header = [9u8, 8, 7];
        let payload: Vec<u8> = (0..64u8).collect();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut f = Framed::tcp(s, 4096).unwrap();
            let mut want = vec![9u8, 8, 7];
            want.extend(0..64u8);
            assert_eq!(f.recv_ref().unwrap(), &want[..]); // buffered path
            assert_eq!(f.recv_ref().unwrap(), &want[..]); // writev path
        });
        let mut c = Framed::connect(&addr.to_string(), 4096).unwrap();
        c.send_gathered(&header, &payload, usize::MAX).unwrap();
        c.send_gathered(&header, &payload, 1).unwrap();
        c.flush().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn recv_buffer_releases_transient_large_frame() {
        // one big frame followed by a long run of small ones: the
        // retained capacity must come back down instead of pinning the
        // peak for the life of the connection
        let big = 1usize << 20;
        let mut wire = Vec::new();
        let mut push = |payload: &[u8]| {
            wire.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            wire.extend_from_slice(payload);
        };
        let outlier = vec![7u8; big];
        push(&outlier);
        let small = [1u8, 2, 3];
        for _ in 0..2 * SHRINK_CHECK_FRAMES {
            push(&small);
        }
        let mut f = Framed::new(std::io::Cursor::new(wire), std::io::sink());
        assert_eq!(f.recv_ref().unwrap().len(), big);
        assert!(f.recv_buf_capacity() >= big);
        for _ in 0..2 * SHRINK_CHECK_FRAMES {
            assert_eq!(f.recv_ref().unwrap(), &small);
        }
        assert!(
            f.recv_buf_capacity() < big,
            "capacity still {} after {} small frames",
            f.recv_buf_capacity(),
            2 * SHRINK_CHECK_FRAMES
        );
        // the shrink target always covers the current frame size, so
        // shrinking never forces a re-grow: only the big frame allocated
        assert_eq!(f.recv_buf_grows(), 1);
    }

    #[test]
    fn oversized_incoming_frame_rejected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            use std::io::Write;
            let (mut s, _) = listener.accept().unwrap();
            // a corrupt length prefix far beyond MAX_FRAME
            s.write_all(&u32::MAX.to_le_bytes()).unwrap();
            s.flush().unwrap();
        });
        let mut c = Framed::connect(&addr.to_string(), 4096).unwrap();
        let err = c.recv().unwrap_err();
        assert!(err.to_string().contains("exceeds cap"), "{err}");
        server.join().unwrap();
    }

    #[test]
    fn large_data_frame() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let n = 100_000;
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut f = Framed::tcp(s, 1 << 20).unwrap();
            match f.recv_data().unwrap() {
                DataMsg::PushRows { nrows, ncols, data, .. } => {
                    assert_eq!(nrows as usize * ncols as usize, data.len());
                    assert_eq!(data.len(), n);
                    assert_eq!(data[n - 1], (n - 1) as f64);
                }
                other => panic!("unexpected {other:?}"),
            }
        });
        let mut c = Framed::connect(&addr.to_string(), 1 << 20).unwrap();
        let data: Vec<f64> = (0..n).map(|i| i as f64).collect();
        c.send_data_flush(&DataMsg::PushRows {
            matrix_id: 1,
            start_row: 0,
            nrows: (n / 10) as u32,
            ncols: 10,
            data,
        })
        .unwrap();
        server.join().unwrap();
    }
}
