//! The BSP stage scheduler with the calibrated overhead model.
//!
//! A stage = one task per partition + a synchronization barrier. Tasks
//! execute for real (all numerics are computed); the Spark-specific costs
//! the paper attributes the gap to are charged explicitly, in two ledgers:
//!
//! * **wallclock** — `scheduler_delay_s` is *slept* once per stage and
//!   `task_launch_s` per task wave, so end-to-end wallclock shows the
//!   paper's shape directly;
//! * **simulated cluster time** — per-task durations (with deterministic
//!   straggler jitter) are packed into `executors`-wide waves and the
//!   [`SimClock`] advances by the sum of wave maxima, which is what the
//!   same stage would cost on a real cluster with that many executors.
//!
//! Calibration (defaults in [`crate::config::OverheadConfig`]): Table 2
//! reports Spark per-iteration costs of 40–75 s against 1.2–2.5 s for
//! Alchemist at 20–40 nodes; Gittens et al. 2016 decompose the difference
//! into scheduler delay, task start, and (de)serialization. Scaled by the
//! ~1/50 problem-size ratio used throughout this repro, that yields
//! scheduler_delay ≈ 0.4 s/stage and task_launch ≈ 20 ms/task. The
//! overhead-sensitivity ablation sweeps these ×{0.25, 1, 4}.

use std::time::Instant;

use crate::config::OverheadConfig;
use crate::metrics::SimClock;
use crate::util::prng::Rng;

/// Measured + modeled costs of the stages run so far.
#[derive(Debug, Clone, Default)]
pub struct StageStats {
    pub stages: usize,
    pub tasks: usize,
    /// Real seconds spent computing task bodies.
    pub compute_secs: f64,
    /// Real seconds of injected overhead (slept).
    pub overhead_secs: f64,
}

/// Runs stages over partitioned data, charging overheads.
pub struct SparkEngine {
    pub executors: usize,
    overhead: OverheadConfig,
    /// Cluster/driver memory budget (bytes) for cached data; exceeding it
    /// fails the job like the paper's >10k-feature Spark runs (Table 1).
    pub memory_budget_bytes: usize,
    sim: SimClock,
    stats: StageStats,
    jitter: Rng,
    /// Skip the real sleeps (unit tests); sim accounting still applies.
    pub inject_real_delays: bool,
}

impl SparkEngine {
    pub fn new(executors: usize, cfg: &crate::config::Config) -> Self {
        SparkEngine {
            executors: executors.max(1),
            overhead: cfg.overhead.clone(),
            memory_budget_bytes: cfg.spark_driver_max_bytes,
            sim: SimClock::new(),
            stats: StageStats::default(),
            jitter: Rng::new(cfg.seed ^ 0x5A5A),
            inject_real_delays: true,
        }
    }

    pub fn sim_elapsed_secs(&self) -> f64 {
        self.sim.elapsed_secs()
    }

    pub fn stats(&self) -> &StageStats {
        &self.stats
    }

    fn sleep(&mut self, secs: f64) {
        self.stats.overhead_secs += secs;
        if self.inject_real_delays && secs > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(secs));
        }
    }

    /// Run one BSP stage: `task(partition_index, partition) -> output`,
    /// one task per input partition. Returns the per-partition outputs.
    pub fn run_stage<T, U>(
        &mut self,
        name: &str,
        inputs: &[T],
        mut task: impl FnMut(usize, &T) -> U,
    ) -> Vec<U> {
        let ntasks = inputs.len();
        // stage submission: driver schedules, executors wake up
        self.sleep(self.overhead.scheduler_delay_s);
        self.sim.advance_serial(self.overhead.scheduler_delay_s);

        let mut outputs = Vec::with_capacity(ntasks);
        let mut durations = Vec::with_capacity(ntasks);
        let mut result_bytes = 0usize;
        for (i, input) in inputs.iter().enumerate() {
            let t0 = Instant::now();
            let out = task(i, input);
            let secs = t0.elapsed().as_secs_f64();
            self.stats.compute_secs += secs;
            result_bytes += std::mem::size_of::<U>();
            // deterministic straggler jitter on the modeled duration
            let jit = (1.0 + self.overhead.straggler_cv * self.jitter.normal()).max(0.2);
            durations.push(secs * jit + self.overhead.task_launch_s);
            outputs.push(out);
        }
        // wallclock: task launches serialize per wave on the real box
        let waves = ntasks.div_ceil(self.executors);
        self.sleep(waves as f64 * self.overhead.task_launch_s);
        // result serialization back to the driver
        let serde_secs = result_bytes as f64 / self.overhead.serde_bytes_per_s;
        self.sleep(serde_secs);
        self.sim.advance_serial(serde_secs);

        // simulated cluster time: pack tasks into executor-wide waves
        durations.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let mut sim_stage = 0.0;
        for wave in durations.chunks(self.executors) {
            sim_stage += wave[0]; // descending sort: first = max of wave
        }
        self.sim.advance_parallel(&[sim_stage]);

        self.stats.stages += 1;
        self.stats.tasks += ntasks;
        log::debug!(
            "sparklite stage {name}: {ntasks} tasks, sim {:.3}s",
            sim_stage
        );
        outputs
    }

    /// A shuffle-like aggregation stage: task outputs are combined
    /// pairwise on the driver (`reduce`), charging serde per byte moved.
    pub fn run_stage_reduce<T, U>(
        &mut self,
        name: &str,
        inputs: &[T],
        task: impl FnMut(usize, &T) -> U,
        reduce: impl Fn(U, U) -> U,
        bytes_per_output: usize,
    ) -> Option<U> {
        let outputs = self.run_stage(name, inputs, task);
        let n = outputs.len();
        // driver-side merge pays deserialization of every task result
        let serde = (n * bytes_per_output) as f64 / self.overhead.serde_bytes_per_s;
        self.sleep(serde);
        self.sim.advance_serial(serde);
        outputs.into_iter().reduce(reduce)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn quiet_engine(executors: usize) -> SparkEngine {
        let mut cfg = Config::default();
        cfg.overhead.scheduler_delay_s = 0.0;
        cfg.overhead.task_launch_s = 0.01;
        let mut e = SparkEngine::new(executors, &cfg);
        e.inject_real_delays = false;
        e
    }

    #[test]
    fn stage_computes_all_tasks() {
        let mut e = quiet_engine(2);
        let parts = vec![vec![1, 2], vec![3], vec![4, 5, 6]];
        let sums = e.run_stage("sum", &parts, |_, p| p.iter().sum::<i32>());
        assert_eq!(sums, vec![3, 3, 15]);
        assert_eq!(e.stats().stages, 1);
        assert_eq!(e.stats().tasks, 3);
    }

    #[test]
    fn sim_time_decreases_with_executors() {
        // identical work, more executors => fewer waves => less sim time
        let run = |execs: usize| {
            let mut e = quiet_engine(execs);
            let parts: Vec<u64> = (0..8).collect();
            e.run_stage("spin", &parts, |_, _| {
                // non-trivial real work so durations are non-zero
                let mut acc = 0u64;
                for i in 0..200_000u64 {
                    acc = acc.wrapping_add(i * i);
                }
                acc
            });
            e.sim_elapsed_secs()
        };
        let t2 = run(2);
        let t8 = run(8);
        assert!(t8 < t2, "sim time should shrink with executors: {t2} vs {t8}");
    }

    #[test]
    fn reduce_combines() {
        let mut e = quiet_engine(4);
        let parts = vec![vec![1.0, 2.0], vec![3.0]];
        let total = e
            .run_stage_reduce(
                "agg",
                &parts,
                |_, p: &Vec<f64>| p.iter().sum::<f64>(),
                |a, b| a + b,
                8,
            )
            .unwrap();
        assert_eq!(total, 6.0);
    }

    #[test]
    fn overhead_ledger_accumulates() {
        let mut cfg = Config::default();
        cfg.overhead.scheduler_delay_s = 0.5;
        cfg.overhead.task_launch_s = 0.125;
        let mut e = SparkEngine::new(2, &cfg);
        e.inject_real_delays = false;
        let parts = vec![(), (), (), ()];
        e.run_stage("s", &parts, |_, _| ());
        // 0.5 scheduler + 2 waves * 0.125 launch (+ negligible serde)
        assert!(
            (e.stats().overhead_secs - 0.75).abs() < 1e-3,
            "{}",
            e.stats().overhead_secs
        );
        // sim time includes scheduler delay plus per-task launch waves
        assert!(e.sim_elapsed_secs() >= 0.5 + 2.0 * 0.125 - 1e-6);
    }
}
