//! Mini property-testing runner (proptest is not in the offline vendor
//! set). Deterministic, seed-addressable, with failure reporting that
//! names the seed so a case can be replayed:
//!
//! ```no_run
//! use alchemist::testkit::{props, Gen};
//! props(100, |g| {
//!     let n = g.usize_in(1, 50);
//!     let xs = g.vec_f64(n, -1.0, 1.0);
//!     assert!(xs.len() == n);
//! });
//! ```

use crate::util::prng::Rng;

/// Synthesize an artifact set under `dir` so tests can exercise the XLA
/// engines without `make artifacts`: only `manifest.txt` is written — the
/// PJRT stand-in derives each computation from the manifest entry's op +
/// shapes ([`crate::runtime::pjrtsim`]), never from the HLO payloads.
///
/// Exports, for both the `xla` and `pallas` families:
/// * `gemm_{nn,tn,nt}` at `tile`³;
/// * `gram_matvec` at `(panel_rows, panel_k, panel_c)`;
/// * `rff_expand` at `(panel_rows, panel_k, panel_k)` (Ω padded square);
/// * `cg_update` at `(panel_rows, panel_c)`.
pub fn write_sim_artifacts(
    dir: &std::path::Path,
    tile: usize,
    panel_rows: usize,
    panel_k: usize,
    panel_c: usize,
) -> crate::Result<()> {
    use std::fmt::Write as _;
    let mut text = String::from("# synthesized by testkit::write_sim_artifacts\n");
    let (t, pm, pk, pc) = (tile, panel_rows, panel_k, panel_c);
    for family in ["xla", "pallas"] {
        for op in ["gemm_nn", "gemm_tn", "gemm_nt"] {
            writeln!(
                text,
                "name={family}_{op}_{t}x{t}x{t} op={op} engine={family} \
                 dtype=f64 dims={t},{t},{t} inputs={t}x{t};{t}x{t};{t}x{t} \
                 outputs={t}x{t} sha=sim"
            )
            .expect("write to String");
        }
        writeln!(
            text,
            "name={family}_gram_matvec_{pm}x{pk}x{pc} op=gram_matvec \
             engine={family} dtype=f64 dims={pm},{pk},{pc} \
             inputs={pm}x{pk};{pk}x{pc};1x1 outputs={pk}x{pc} sha=sim"
        )
        .expect("write to String");
        writeln!(
            text,
            "name={family}_rff_expand_{pm}x{pk}x{pk} op=rff_expand \
             engine={family} dtype=f64 dims={pm},{pk},{pk} \
             inputs={pm}x{pk};{pk}x{pk};1x{pk};1x1 outputs={pm}x{pk} sha=sim"
        )
        .expect("write to String");
        writeln!(
            text,
            "name={family}_cg_update_{pm}x{pc} op=cg_update engine={family} \
             dtype=f64 dims={pm},{pc} \
             inputs={pm}x{pc};{pm}x{pc};{pm}x{pc};{pm}x{pc};1x{pc} \
             outputs={pm}x{pc};{pm}x{pc} sha=sim"
        )
        .expect("write to String");
    }
    std::fs::create_dir_all(dir)
        .map_err(|e| anyhow::anyhow!("creating {dir:?}: {e}"))?;
    std::fs::write(dir.join("manifest.txt"), text)
        .map_err(|e| anyhow::anyhow!("writing manifest to {dir:?}: {e}"))?;
    Ok(())
}

/// Generator handed to each property case.
pub struct Gen {
    rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        assert!(hi_inclusive >= lo);
        lo + self.rng.below(hi_inclusive - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// ASCII identifier-ish string.
    pub fn ident(&mut self, max_len: usize) -> String {
        let n = self.usize_in(1, max_len.max(1));
        (0..n)
            .map(|_| (b'a' + self.rng.below(26) as u8) as char)
            .collect()
    }
}

/// Run `cases` property cases with the default seed.
pub fn props(cases: usize, f: impl FnMut(&mut Gen)) {
    props_seeded(0xA1C4_E5D1, cases, f)
}

/// Run `cases` property cases; each case gets an independent stream so a
/// failure report's `(seed, case)` pair replays exactly.
pub fn props_seeded(seed: u64, cases: usize, mut f: impl FnMut(&mut Gen)) {
    let env_seed = std::env::var("ALCHEMIST_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(seed);
    let base = Rng::new(env_seed);
    for case in 0..cases {
        let mut g = Gen { rng: base.derive(case as u64), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut g)
        }));
        if let Err(payload) = result {
            eprintln!(
                "property failed at seed={env_seed:#x} case={case} \
                 (replay: ALCHEMIST_PROP_SEED={env_seed})"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Chaos-soak driver (`tests/it_chaos.rs`, `docs/recovery.md`): seeded
/// round *plans* composing every fault mode the task plane knows —
/// routine failure, uncooperative spin + hard cancel, cooperative
/// cancel, client drop (with or without `Reattach`), and worker-process
/// kill — under concurrent tenant load. The plan is pure data generated
/// from a [`Gen`] stream so a failing round replays exactly from its
/// `(seed, case)` pair; the test binary owns execution. A round log can
/// be captured by pointing `ALCHEMIST_CHAOS_LOG` at a file (CI uploads
/// it as the failure artifact).
pub mod chaos {
    use super::Gen;

    /// One client-visible operation in a tenant's script. Every variant
    /// must terminate within the harness timeout whatever else the round
    /// injects — that is the zero-hang property the soak pins.
    #[derive(Debug, Clone, PartialEq)]
    pub enum TenantOp {
        /// `fail_on{rank: 0}`: a deterministic routine failure (the
        /// process stays alive, so this must *not* trigger replacement).
        FailOneRank,
        /// `spin` ignores the cooperative token; only `cancel_hard`'s
        /// group poison can end it early.
        SpinHardCancel,
        /// `sleep` + cooperative cancel.
        SleepCancel,
        /// `rand_matrix` → `truncated_svd`, collecting the outputs.
        SvdCollect,
        /// Drop the control socket with a task in flight; when the round
        /// lingers and `reattach` is set, resume by token and keep going.
        /// Always a tenant's last scripted op (the drop ends the script
        /// unless the reattach succeeds).
        DropClient { reattach: bool },
    }

    /// A full seeded round: server shape + two concurrent tenant scripts
    /// + an optional worker-process kill injected mid-round.
    #[derive(Debug, Clone)]
    pub struct RoundPlan {
        /// `fabric.mode = tcp` (process ranks, killable, spare pool)
        /// instead of the in-process local pool.
        pub tcp: bool,
        /// `scheduler.session_linger_s` for the round (0 = eager close).
        pub linger_s: f64,
        /// Kilobyte-scale `storage.budget_bytes` so spill segments are
        /// in play and the leak assertion has teeth.
        pub tight_budget: bool,
        /// Global rank to `kill_worker` ~150ms into the round (tcp only).
        pub kill_rank: Option<usize>,
        /// One op script per concurrent tenant.
        pub tenants: Vec<Vec<TenantOp>>,
    }

    /// Generate one round from the seeded stream. `allow_tcp` gates the
    /// process-fabric rounds (they need a worker executable).
    pub fn plan_round(g: &mut Gen, allow_tcp: bool) -> RoundPlan {
        let tcp = allow_tcp && g.bool();
        let linger_s = if g.bool() { 0.4 } else { 0.0 };
        let tight_budget = g.bool();
        let kill_rank = (tcp && g.bool()).then(|| g.usize_in(0, 1));
        let tenants = (0..2)
            .map(|_| {
                let n = g.usize_in(1, 2);
                (0..n)
                    .map(|i| {
                        // the drop ends a script, so only the last slot
                        // may be a DropClient
                        if i + 1 == n && g.usize_in(0, 3) == 0 {
                            TenantOp::DropClient {
                                reattach: linger_s > 0.0 && g.bool(),
                            }
                        } else {
                            match g.usize_in(0, 3) {
                                0 => TenantOp::FailOneRank,
                                1 => TenantOp::SpinHardCancel,
                                2 => TenantOp::SleepCancel,
                                _ => TenantOp::SvdCollect,
                            }
                        }
                    })
                    .collect()
            })
            .collect();
        RoundPlan { tcp, linger_s, tight_budget, kill_rank, tenants }
    }

    impl RoundPlan {
        /// One-line description for the round log: enough to reconstruct
        /// the round by eye without replaying the seed.
        pub fn describe(&self) -> String {
            format!(
                "mode={} linger={:.1}s tight_budget={} kill={:?} tenants={:?}",
                if self.tcp { "tcp" } else { "local" },
                self.linger_s,
                self.tight_budget,
                self.kill_rank,
                self.tenants,
            )
        }
    }

    /// Append-only round log, enabled by `ALCHEMIST_CHAOS_LOG=<path>`.
    /// Each round is recorded *before* it runs, so a hang or crash
    /// leaves the guilty plan on disk for the CI artifact.
    pub struct ChaosLog {
        path: Option<std::path::PathBuf>,
    }

    impl ChaosLog {
        pub fn from_env() -> Self {
            Self {
                path: std::env::var("ALCHEMIST_CHAOS_LOG")
                    .ok()
                    .filter(|p| !p.is_empty())
                    .map(std::path::PathBuf::from),
            }
        }

        /// Best-effort append (logging must never fail a round).
        pub fn record(&self, line: &str) {
            use std::io::Write as _;
            let Some(path) = &self.path else { return };
            if let Ok(mut f) = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
            {
                let _ = writeln!(f, "{line}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_in_range() {
        props(200, |g| {
            let n = g.usize_in(3, 9);
            assert!((3..=9).contains(&n));
            let x = g.f64_in(-2.0, 5.0);
            assert!((-2.0..5.0).contains(&x));
            let v = g.vec_f64(n, 0.0, 1.0);
            assert_eq!(v.len(), n);
            let s = g.ident(8);
            assert!(!s.is_empty() && s.len() <= 8);
            let pick = *g.choose(&[1, 2, 3]);
            assert!([1, 2, 3].contains(&pick));
        });
    }

    #[test]
    fn cases_are_independent_streams() {
        let mut first = Vec::new();
        props(5, |g| {
            // same call pattern in every case must still differ across cases
            first.push(g.u64());
        });
        let unique: std::collections::HashSet<_> = first.iter().collect();
        assert_eq!(unique.len(), first.len());
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        props(10, |g| {
            assert!(g.case < 5, "deliberate failure");
        });
    }

    #[test]
    fn chaos_plans_replay_deterministically() {
        let plan_stream = |seed: u64| {
            let base = Rng::new(seed);
            (0..8)
                .map(|case| {
                    let mut g = Gen { rng: base.derive(case), case: case as usize };
                    chaos::plan_round(&mut g, true).describe()
                })
                .collect::<Vec<_>>()
        };
        // same seed → identical plans (a logged round replays exactly);
        // different seed → the stream actually varies
        assert_eq!(plan_stream(7), plan_stream(7));
        assert_ne!(plan_stream(7), plan_stream(8));

        // invariants the executor relies on: two tenants, drops only in
        // the final slot, kills only under tcp, reattach only with linger
        props(200, |g| {
            let p = chaos::plan_round(g, g.bool());
            assert_eq!(p.tenants.len(), 2);
            assert!(p.kill_rank.is_none() || p.tcp);
            for ops in &p.tenants {
                assert!(!ops.is_empty() && ops.len() <= 2);
                for (i, op) in ops.iter().enumerate() {
                    if let chaos::TenantOp::DropClient { reattach } = op {
                        assert_eq!(i + 1, ops.len(), "drop must end the script");
                        assert!(!reattach || p.linger_s > 0.0);
                    }
                }
            }
        });
    }
}
