"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, block sizes, and dtypes; this is the core
correctness signal for everything the rust workers execute.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cg_update, matmul, ref, rff

DIMS = st.sampled_from([16, 32, 48, 64, 96, 128, 192, 256])
BLOCKS = st.sampled_from([16, 32, 64, 128])
DTYPES = st.sampled_from([jnp.float32, jnp.float64])


def _rng(seed):
    return np.random.default_rng(seed)


def _tol(dtype):
    return 1e-3 if dtype == jnp.float32 else 1e-9


@settings(max_examples=25, deadline=None)
@given(m=DIMS, n=DIMS, k=DIMS, block=BLOCKS, dtype=DTYPES,
       variant=st.sampled_from(["nn", "tn", "nt"]), seed=st.integers(0, 2**31))
def test_gemm_matches_ref(m, n, k, block, dtype, variant, seed):
    rng = _rng(seed)
    c = rng.normal(size=(m, n)).astype(dtype)
    a_shape = (k, m) if variant == "tn" else (m, k)
    b_shape = (n, k) if variant == "nt" else (k, n)
    a = rng.normal(size=a_shape).astype(dtype)
    b = rng.normal(size=b_shape).astype(dtype)
    got = matmul.make_gemm(m, n, k, variant=variant, block=block,
                           dtype=dtype)(c, a, b)
    want = getattr(ref, f"gemm_{variant}")(c, a, b)
    assert got.dtype == want.dtype == dtype
    np.testing.assert_allclose(got, want, rtol=_tol(dtype) * k,
                               atol=_tol(dtype) * k)


@settings(max_examples=20, deadline=None)
@given(m=DIMS, n=DIMS, block=BLOCKS, dtype=DTYPES, seed=st.integers(0, 2**31))
def test_rff_finalize_matches_ref(m, n, block, dtype, seed):
    rng = _rng(seed)
    acc = rng.normal(size=(m, n)).astype(dtype)
    bias = rng.normal(size=(1, n)).astype(dtype)
    scale = np.array([[rng.normal()]]).astype(dtype)
    got = rff.make_rff_finalize(m, n, block=block, dtype=dtype)(acc, bias, scale)
    want = ref.rff_finalize(acc, bias, scale)
    np.testing.assert_allclose(got, want, rtol=_tol(dtype), atol=_tol(dtype))


@settings(max_examples=20, deadline=None)
@given(m=DIMS, n=DIMS, block=BLOCKS, dtype=DTYPES, seed=st.integers(0, 2**31))
def test_cg_update_matches_ref(m, n, block, dtype, seed):
    rng = _rng(seed)
    x, r, p, q = (rng.normal(size=(m, n)).astype(dtype) for _ in range(4))
    alpha = rng.normal(size=(1, n)).astype(dtype)
    gx, gr = cg_update.make_cg_update(m, n, block=block, dtype=dtype)(
        x, r, p, q, alpha)
    wx, wr = ref.cg_update(x, r, p, q, alpha)
    np.testing.assert_allclose(gx, wx, rtol=_tol(dtype), atol=_tol(dtype))
    np.testing.assert_allclose(gr, wr, rtol=_tol(dtype), atol=_tol(dtype))


def test_gemm_rejects_bad_variant():
    with pytest.raises(ValueError):
        matmul.make_gemm(8, 8, 8, variant="tt")


def test_gemm_block_larger_than_dim_falls_back():
    # block > dim must still tile exactly (picks a divisor).
    rng = _rng(0)
    c = rng.normal(size=(8, 8))
    a = rng.normal(size=(8, 8))
    b = rng.normal(size=(8, 8))
    got = matmul.make_gemm(8, 8, 8, block=999)(c, a, b)
    np.testing.assert_allclose(got, ref.gemm_nn(c, a, b), rtol=1e-12)


def test_gemm_non_power_of_two_dims():
    rng = _rng(3)
    m, n, k = 24, 36, 60  # awkward divisors
    c = rng.normal(size=(m, n))
    a = rng.normal(size=(m, k))
    b = rng.normal(size=(k, n))
    got = matmul.make_gemm(m, n, k, block=16)(c, a, b)
    np.testing.assert_allclose(got, ref.gemm_nn(c, a, b), rtol=1e-10)


def test_gemm_accumulates_into_c_not_overwrite():
    rng = _rng(4)
    c = rng.normal(size=(16, 16))
    a = np.zeros((16, 16))
    b = np.zeros((16, 16))
    got = matmul.make_gemm(16, 16, 16)(c, a, b)
    np.testing.assert_allclose(got, c, rtol=1e-14)
