//! The MPI stand-in (DESIGN.md §2).
//!
//! Alchemist's workers are MPI ranks; this module gives the rust workers
//! the same programming model: a [`Communicator`] with point-to-point
//! send/recv plus the collective algorithms the numerics need (barrier,
//! binomial-tree broadcast/reduce, ring allreduce, gather/scatter/
//! allgather). The collectives are implemented *over* send/recv — the real
//! algorithms, not shared-memory shortcuts — so their communication volume
//! is faithful and the SimClock can charge modeled interconnect time per
//! message (the box has one core; see `metrics::simclock`).
//!
//! Groups come in two flavors: [`LocalComm::group`] builds the full pool,
//! and [`LocalComm::subgroup`] builds an independent communicator over an
//! arbitrary rank subset — the substrate for session-scoped worker groups
//! (disjoint sessions collect over disjoint fabrics, so they never
//! serialize on each other).

pub mod algorithms;
pub mod local;

pub use algorithms::{
    allgather, allreduce_sum, broadcast, gather, reduce_sum, scatter,
};
pub use local::LocalComm;

/// Point-to-point message transport between ranks of one worker group.
///
/// Messages are `Vec<f64>` (every payload in this system is double
/// precision) addressed by `(peer, tag)`; tags keep concurrent collectives
/// from interleaving. Implementations must deliver messages from the same
/// (sender, tag) in order.
pub trait Communicator: Send {
    fn rank(&self) -> usize;
    fn size(&self) -> usize;
    /// Non-blocking buffered send.
    fn send(&self, to: usize, tag: u64, data: Vec<f64>);
    /// Blocking receive.
    fn recv(&self, from: usize, tag: u64) -> Vec<f64>;
    /// Block until every rank arrives.
    fn barrier(&self);
    /// Modeled communication seconds charged to this rank so far (for
    /// simulated-cluster-time accounting); implementations without a cost
    /// model return 0.
    fn sim_comm_secs(&self) -> f64 {
        0.0
    }
}

/// Tag-space layout so nested collectives never collide: each collective
/// invocation passes a distinct `base` tag and algorithms offset within
/// a 2^16 window.
pub const TAG_WINDOW: u64 = 1 << 16;
