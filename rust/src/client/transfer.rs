//! Executor-side row transfer: push partitions to workers / pull row
//! ranges back, over per-executor TCP sockets (paper §3.2 "Direct
//! Transfer").
//!
//! Each executor thread owns one socket per worker it talks to. Rows are
//! batched `rows_per_frame` at a time into `PushRows` frames (contiguous
//! runs only — a run breaks whenever the destination worker or row
//! continuity changes); the whole stream is acknowledged once per worker
//! by `PushDone`.

use std::time::Instant;

use crate::config::TransferConfig;
use crate::net::Framed;
use crate::protocol::DataMsg;
use crate::sparklite::IndexedRow;

use super::almatrix::AlMatrix;

/// Measured cost of one distributed transfer.
#[derive(Debug, Clone, Default)]
pub struct TransferStats {
    pub bytes: usize,
    pub secs: f64,
    pub frames: usize,
    pub executors: usize,
}

impl TransferStats {
    pub fn throughput_gbps(&self) -> f64 {
        if self.secs > 0.0 {
            self.bytes as f64 / self.secs / 1e9
        } else {
            0.0
        }
    }

    /// Fold another transfer's stats into this one: volumes add, wallclock
    /// takes the max (executors run concurrently), and so does the
    /// executor count — merging a per-thread share (executors = 0) into a
    /// whole-transfer record must not erase the transfer's parallelism.
    pub fn merge(&mut self, other: &TransferStats) {
        self.bytes += other.bytes;
        self.frames += other.frames;
        self.secs = self.secs.max(other.secs);
        self.executors = self.executors.max(other.executors);
    }
}

#[cfg(test)]
mod tests {
    use super::TransferStats;

    #[test]
    fn merge_keeps_executors_and_concurrent_semantics() {
        let mut total = TransferStats { executors: 4, ..Default::default() };
        let a = TransferStats { bytes: 100, secs: 0.5, frames: 2, executors: 0 };
        let b = TransferStats { bytes: 300, secs: 0.2, frames: 1, executors: 0 };
        total.merge(&a);
        total.merge(&b);
        assert_eq!(total.bytes, 400);
        assert_eq!(total.frames, 3);
        assert_eq!(total.secs, 0.5); // slowest concurrent executor
        assert_eq!(total.executors, 4); // not clobbered by per-thread shares

        // merging two whole-transfer records (e.g. push + pull legs)
        let mut push = TransferStats { bytes: 8, secs: 1.0, frames: 1, executors: 2 };
        let pull = TransferStats { bytes: 8, secs: 2.0, frames: 1, executors: 3 };
        push.merge(&pull);
        assert_eq!(push.executors, 3);
    }
}

/// One executor's sockets to the workers it talks to (lazily opened).
struct ExecutorLinks<'a> {
    worker_addrs: &'a [String],
    cfg: &'a TransferConfig,
    links: Vec<Option<Framed<std::net::TcpStream, std::net::TcpStream>>>,
    session_id: u64,
    executor_id: u32,
}

impl<'a> ExecutorLinks<'a> {
    fn new(
        worker_addrs: &'a [String],
        cfg: &'a TransferConfig,
        session_id: u64,
        executor_id: u32,
    ) -> Self {
        ExecutorLinks {
            worker_addrs,
            cfg,
            links: (0..worker_addrs.len()).map(|_| None).collect(),
            session_id,
            executor_id,
        }
    }

    fn link(
        &mut self,
        rank: usize,
    ) -> crate::Result<&mut Framed<std::net::TcpStream, std::net::TcpStream>> {
        if self.links[rank].is_none() {
            let mut f =
                Framed::connect(&self.worker_addrs[rank], self.cfg.buf_bytes)?;
            f.send_data_flush(&DataMsg::DataHandshake {
                session_id: self.session_id,
                executor_id: self.executor_id,
            })?;
            match f.recv_data()? {
                DataMsg::DataHandshakeAck { worker_rank } => {
                    anyhow::ensure!(
                        worker_rank as usize == rank,
                        "connected to worker {worker_rank}, expected {rank}"
                    );
                }
                other => anyhow::bail!("bad data handshake reply: {other:?}"),
            }
            self.links[rank] = Some(f);
        }
        Ok(self.links[rank].as_mut().unwrap())
    }
}

/// Push one executor's share of rows. `rows` need not be sorted; batching
/// exploits contiguity when present.
fn push_rows_one_executor(
    matrix: &AlMatrix,
    rows: &[&IndexedRow],
    links: &mut ExecutorLinks,
    rows_per_frame: usize,
) -> crate::Result<TransferStats> {
    let t0 = Instant::now();
    let ncols = matrix.cols;
    let mut stats = TransferStats::default();
    let mut touched = vec![false; matrix.row_ranges.len()];

    // current run being accumulated
    let mut run_start: u64 = 0;
    let mut run_owner: usize = usize::MAX;
    let mut run_data: Vec<f64> = Vec::new();
    let mut run_rows: u32 = 0;

    let flush = |owner: usize,
                     start: u64,
                     nrows: u32,
                     data: &mut Vec<f64>,
                     stats: &mut TransferStats,
                     links: &mut ExecutorLinks|
     -> crate::Result<()> {
        if nrows == 0 {
            return Ok(());
        }
        let msg = DataMsg::PushRows {
            matrix_id: matrix.id,
            start_row: start,
            nrows,
            ncols: ncols as u32,
            data: std::mem::take(data),
        };
        stats.bytes += nrows as usize * ncols * 8;
        stats.frames += 1;
        links.link(owner)?.send_data(&msg)?;
        Ok(())
    };

    for row in rows {
        anyhow::ensure!(
            row.vector.len() == ncols,
            "row {} has {} cols, matrix has {ncols}",
            row.index,
            row.vector.len()
        );
        let owner = matrix.owner_of(row.index as usize);
        touched[owner] = true;
        let contiguous = run_rows > 0
            && owner == run_owner
            && row.index == run_start + run_rows as u64
            && (run_rows as usize) < rows_per_frame;
        if !contiguous {
            flush(run_owner, run_start, run_rows, &mut run_data, &mut stats, links)?;
            run_start = row.index;
            run_owner = owner;
            run_rows = 0;
        }
        run_data.extend_from_slice(&row.vector);
        run_rows += 1;
    }
    flush(run_owner, run_start, run_rows, &mut run_data, &mut stats, links)?;

    // end-of-stream ack per touched worker
    for (rank, used) in touched.iter().enumerate() {
        if *used {
            let link = links.link(rank)?;
            link.send_data_flush(&DataMsg::PushDone { matrix_id: matrix.id })?;
            match link.recv_data()? {
                DataMsg::PushDoneAck { .. } => {}
                DataMsg::DataError { message } => anyhow::bail!("push failed: {message}"),
                other => anyhow::bail!("bad push ack: {other:?}"),
            }
        }
    }
    stats.secs = t0.elapsed().as_secs_f64();
    Ok(stats)
}

/// Push all partitions with `executors` concurrent sender threads
/// (partition list split evenly). Returns merged stats (secs = slowest
/// executor, the paper's transfer-time definition).
pub fn push_matrix(
    matrix: &AlMatrix,
    partitions: &[Vec<IndexedRow>],
    worker_addrs: &[String],
    cfg: &TransferConfig,
    session_id: u64,
    executors: usize,
) -> crate::Result<TransferStats> {
    let executors = executors.max(1);
    let assignment = crate::util::even_ranges(partitions.len(), executors);
    let t0 = Instant::now();
    let mut merged = TransferStats { executors, ..Default::default() };
    std::thread::scope(|scope| -> crate::Result<()> {
        let mut handles = Vec::new();
        for (eid, &(a, b)) in assignment.iter().enumerate() {
            let parts = &partitions[a..b];
            handles.push(scope.spawn(move || -> crate::Result<TransferStats> {
                if parts.is_empty() {
                    return Ok(TransferStats::default());
                }
                let mut links =
                    ExecutorLinks::new(worker_addrs, cfg, session_id, eid as u32);
                let rows: Vec<&IndexedRow> = parts.iter().flatten().collect();
                let stats = push_rows_one_executor(
                    matrix,
                    &rows,
                    &mut links,
                    cfg.rows_per_frame.max(1),
                )?;
                // polite close
                for link in links.links.iter_mut().flatten() {
                    let _ = link.send_data_flush(&DataMsg::DataBye);
                }
                Ok(stats)
            }));
        }
        for h in handles {
            let stats = h.join().map_err(|_| anyhow::anyhow!("executor thread panicked"))??;
            merged.merge(&stats);
        }
        Ok(())
    })?;
    merged.secs = t0.elapsed().as_secs_f64();
    Ok(merged)
}

/// Pull the whole matrix back with `executors` concurrent threads; each
/// covers an even share of the global rows, chunked by `rows_per_frame`.
/// Returns the rows (unordered) plus stats.
pub fn pull_matrix(
    matrix: &AlMatrix,
    worker_addrs: &[String],
    cfg: &TransferConfig,
    session_id: u64,
    executors: usize,
) -> crate::Result<(Vec<IndexedRow>, TransferStats)> {
    let executors = executors.max(1);
    let shares = crate::util::even_ranges(matrix.rows, executors);
    let t0 = Instant::now();
    let mut all_rows: Vec<IndexedRow> = Vec::with_capacity(matrix.rows);
    let mut merged = TransferStats { executors, ..Default::default() };
    std::thread::scope(|scope| -> crate::Result<()> {
        let mut handles = Vec::new();
        for (eid, &(lo, hi)) in shares.iter().enumerate() {
            handles.push(scope.spawn(move || -> crate::Result<(Vec<IndexedRow>, TransferStats)> {
                let mut links =
                    ExecutorLinks::new(worker_addrs, cfg, session_id, eid as u32);
                let mut rows = Vec::with_capacity(hi - lo);
                let mut stats = TransferStats::default();
                let te = Instant::now();
                let mut i = lo;
                while i < hi {
                    let owner = matrix.owner_of(i);
                    let (_, owner_end) = matrix.row_ranges[owner];
                    let chunk_end =
                        (i + cfg.rows_per_frame.max(1)).min(hi).min(owner_end);
                    let n = chunk_end - i;
                    let link = links.link(owner)?;
                    link.send_data_flush(&DataMsg::PullRows {
                        matrix_id: matrix.id,
                        start_row: i as u64,
                        nrows: n as u32,
                    })?;
                    match link.recv_data()? {
                        DataMsg::RowsData { start_row, nrows, ncols, data, .. } => {
                            anyhow::ensure!(
                                start_row as usize == i && nrows as usize == n,
                                "pull reply mismatch"
                            );
                            let ncols = ncols as usize;
                            stats.bytes += data.len() * 8;
                            stats.frames += 1;
                            for (k, chunk) in data.chunks_exact(ncols).enumerate() {
                                rows.push(IndexedRow {
                                    index: (i + k) as u64,
                                    vector: chunk.to_vec(),
                                });
                            }
                        }
                        DataMsg::DataError { message } => anyhow::bail!("pull failed: {message}"),
                        other => anyhow::bail!("bad pull reply: {other:?}"),
                    }
                    i = chunk_end;
                }
                for link in links.links.iter_mut().flatten() {
                    let _ = link.send_data_flush(&DataMsg::DataBye);
                }
                stats.secs = te.elapsed().as_secs_f64();
                Ok((rows, stats))
            }));
        }
        for h in handles {
            let (rows, stats) =
                h.join().map_err(|_| anyhow::anyhow!("executor thread panicked"))??;
            all_rows.extend(rows);
            merged.merge(&stats);
        }
        Ok(())
    })?;
    merged.secs = t0.elapsed().as_secs_f64();
    Ok((all_rows, merged))
}
