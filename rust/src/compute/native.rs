//! Pure-rust engine: the packed-panel kernels from [`crate::distmat::dense`]
//! parallelized over an intra-rank [`ThreadPool`].
//!
//! This is (a) the compute floor for the engine ablation, and (b) what the
//! sparklite baseline uses — the paper's Spark side never sees the HPC
//! library either.
//!
//! **Determinism contract** (`docs/compute.md`): every op splits its work
//! into chunks whose boundaries depend only on the problem shape — never
//! the thread count — and reductions combine per-chunk partials serially
//! in chunk order. Results are therefore bit-identical across
//! `engine.threads = 1/2/4/...` (and across a shared pool's steal
//! schedules), which is what keeps replicated SPMD solver state
//! (`it_linalg`'s cross-rank `assert_eq`) bitwise-equal when ranks run
//! with different effective pool sizes. The kernel ISA is resolved on the
//! op's calling thread and pinned into every pool job (`crate::simd`), so
//! one op never mixes kernel variants — and the variants are themselves
//! bit-identical anyway.
//!
//! **Cancellation check-ins** (`docs/tasks.md`): when the worker installs
//! a task's [`CancelToken`] via `Engine::set_cancel`, the long
//! collective-free kernels poll it — `gemm` at MC-panel boundaries,
//! `gram_matvec` per reduction wave — and bail with
//! [`crate::tasks::CANCELLED_MSG`], so a hard cancel lands within one
//! panel instead of at the routine's next collective.

use std::sync::Arc;

use crate::config::EngineKind;
use crate::distmat::dense::gemm_slices;
use crate::distmat::LocalMatrix;
use crate::tasks::CancelToken;

use super::pool::ThreadPool;
use super::{Engine, GemmVariant};

/// Fixed row grain for the engine's fused ops (`gram_matvec`'s reduction
/// chunks, `cg_update`/`rff_expand`'s row splits). Shape-derived chunking
/// only — the thread count never moves a boundary.
const CHUNK_ROWS: usize = 256;

/// Reduction chunks folded per pool wave in `gram_matvec`: bounds the
/// partials held alive at once to `GRAM_WAVE · d · nrhs` f64 (a very
/// tall panel would otherwise buffer `rows / CHUNK_ROWS` partials — a
/// d/CHUNK_ROWS-fold blow-up over the rows×nrhs intermediate). Wave
/// grouping never changes the combine order (still strictly chunk 0, 1,
/// 2, …), so results stay bit-identical for any wave or thread count.
const GRAM_WAVE: usize = 16;

pub struct NativeEngine {
    pool: ThreadPool,
    cancel: Option<Arc<CancelToken>>,
}

impl NativeEngine {
    /// Single-threaded engine (the determinism baseline and the seed
    /// behavior every existing caller gets).
    pub fn new() -> Self {
        Self::with_threads(1)
    }

    /// Engine with a private intra-rank pool of `threads` total threads
    /// (0 and 1 both mean "no spawned threads, run inline").
    pub fn with_threads(threads: usize) -> Self {
        Self::from_pool(ThreadPool::new(threads))
    }

    /// Engine driving an existing pool handle — how the server hands
    /// every rank a client of the shared work-stealing pool
    /// ([`ThreadPool::client`]) instead of a private thread set.
    pub fn from_pool(pool: ThreadPool) -> Self {
        NativeEngine { pool, cancel: None }
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    fn cancel_ref(&self) -> Option<&CancelToken> {
        self.cancel.as_deref()
    }

    /// Bail with [`crate::tasks::CANCELLED_MSG`] if the installed task
    /// token (if any) was cancelled — the op-level check-in.
    fn check_cancel(&self) -> crate::Result<()> {
        if self.cancel_ref().is_some_and(|t| t.is_cancelled()) {
            anyhow::bail!(crate::tasks::CANCELLED_MSG);
        }
        Ok(())
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for NativeEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("NativeEngine").field("threads", &self.pool.threads()).finish()
    }
}

impl Engine for NativeEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Native
    }

    fn set_threads(&mut self, threads: usize) {
        let threads = threads.max(1);
        if self.pool.is_client() {
            // shared pool: retarget the lease cap, no thread churn
            self.pool.set_cap(threads);
        } else if threads != self.pool.threads() {
            self.pool = ThreadPool::new(threads);
        }
    }

    fn set_cancel(&mut self, token: Option<Arc<CancelToken>>) {
        self.cancel = token;
    }

    fn gemm(
        &mut self,
        variant: GemmVariant,
        c: &mut LocalMatrix,
        a: &LocalMatrix,
        b: &LocalMatrix,
    ) -> crate::Result<()> {
        let pool = Some(&self.pool);
        let cancel = self.cancel_ref();
        let done = match variant {
            GemmVariant::NN => c.gemm_nn_with(a, b, pool, cancel),
            GemmVariant::TN => c.gemm_tn_with(a, b, pool, cancel),
            GemmVariant::NT => c.gemm_nt_with(a, b, pool, cancel),
        };
        anyhow::ensure!(done, crate::tasks::CANCELLED_MSG);
        Ok(())
    }

    fn gram_matvec(
        &mut self,
        a: &LocalMatrix,
        v: &LocalMatrix,
        reg: f64,
    ) -> crate::Result<LocalMatrix> {
        anyhow::ensure!(a.cols() == v.rows(), "gram_matvec: a {}x{} vs v {}x{}",
            a.rows(), a.cols(), v.rows(), v.cols());
        let d = a.cols();
        let nrhs = v.cols();
        // out = reg·v + Σ_chunks A_cᵀ(A_c·v): fixed CHUNK_ROWS row chunks
        // of A, each chunk's two small GEMMs run independently on the
        // pool, partials combined serially in chunk order (fixed combine
        // order ⇒ bit-identical for any thread count)
        let mut out = v.clone();
        out.scale(reg);
        if a.rows() == 0 || d == 0 || nrhs == 0 {
            return Ok(out);
        }
        let v_data = v.data();
        let isa = crate::simd::current();
        let cancel = self.cancel_ref();
        let chunks: Vec<&[f64]> = a.data().chunks(CHUNK_ROWS * d).collect();
        for wave in chunks.chunks(GRAM_WAVE) {
            // per-wave cancellation check-in; a cancelled wave's jobs may
            // also bail individually, leaving empty partials the final
            // check below turns into an error
            self.check_cancel()?;
            let jobs: Vec<_> = wave
                .iter()
                .map(|&chunk| {
                    move || {
                        crate::simd::with_isa(isa, || {
                            let rc = chunk.len() / d;
                            let mut av = vec![0.0f64; rc * nrhs];
                            // A_c (rc×d) · v (d×nrhs)
                            if !gemm_slices(
                                &mut av, rc, nrhs, d, chunk, d, 1, v_data, nrhs, 1, None, cancel,
                            ) {
                                return Vec::new();
                            }
                            let mut g = vec![0.0f64; d * nrhs];
                            // A_cᵀ (d×rc) · av (rc×nrhs)
                            gemm_slices(
                                &mut g, d, nrhs, rc, chunk, 1, d, &av, nrhs, 1, None, cancel,
                            );
                            g
                        })
                    }
                })
                .collect();
            for partial in self.pool.run(jobs) {
                for (o, x) in out.data_mut().iter_mut().zip(&partial) {
                    *o += *x;
                }
            }
        }
        self.check_cancel()?;
        Ok(out)
    }

    fn rff_expand(
        &mut self,
        x: &LocalMatrix,
        omega: &LocalMatrix,
        bias: &[f64],
        scale: f64,
    ) -> crate::Result<LocalMatrix> {
        anyhow::ensure!(x.cols() == omega.rows(), "rff_expand shape mismatch");
        anyhow::ensure!(bias.len() == omega.cols(), "rff bias length mismatch");
        let k0 = omega.rows();
        let d = omega.cols();
        let mut z = LocalMatrix::zeros(x.rows(), d);
        if x.rows() == 0 || d == 0 {
            return Ok(z);
        }
        if k0 == 0 {
            // empty feature dimension: x·Ω is all zeros
            for i in 0..z.rows() {
                for (zj, bj) in z.row_mut(i).iter_mut().zip(bias) {
                    *zj = scale * bj.cos();
                }
            }
            return Ok(z);
        }
        let omega_data = omega.data();
        let isa = crate::simd::current();
        let cancel = self.cancel_ref();
        let jobs: Vec<_> = z
            .data_mut()
            .chunks_mut(CHUNK_ROWS * d)
            .zip(x.data().chunks(CHUNK_ROWS * k0))
            .map(|(zc, xc)| {
                move || {
                    crate::simd::with_isa(isa, || {
                        let rc = xc.len() / k0;
                        if !gemm_slices(zc, rc, d, k0, xc, k0, 1, omega_data, d, 1, None, cancel)
                        {
                            return;
                        }
                        for row in zc.chunks_exact_mut(d) {
                            for (v, bj) in row.iter_mut().zip(bias) {
                                *v = scale * (*v + bj).cos();
                            }
                        }
                    })
                }
            })
            .collect();
        self.pool.run(jobs);
        self.check_cancel()?;
        Ok(z)
    }

    fn cg_update(
        &mut self,
        x: &mut LocalMatrix,
        r: &mut LocalMatrix,
        p: &LocalMatrix,
        q: &LocalMatrix,
        alpha: &[f64],
    ) -> crate::Result<()> {
        anyhow::ensure!(alpha.len() == x.cols(), "alpha length mismatch");
        // the zip-based chunking below silently truncates at the shortest
        // operand, so shape mismatches must be rejected up front (the old
        // row-indexed loop would at least have panicked)
        let shape = (x.rows(), x.cols());
        anyhow::ensure!((r.rows(), r.cols()) == shape, "cg_update: r shape mismatch");
        anyhow::ensure!((p.rows(), p.cols()) == shape, "cg_update: p shape mismatch");
        anyhow::ensure!((q.rows(), q.cols()) == shape, "cg_update: q shape mismatch");
        // memory-bound and short — one entry check-in suffices
        self.check_cancel()?;
        let c = x.cols();
        if c == 0 || x.rows() == 0 {
            return Ok(());
        }
        let chunk = CHUNK_ROWS * c;
        let jobs: Vec<_> = x
            .data_mut()
            .chunks_mut(chunk)
            .zip(r.data_mut().chunks_mut(chunk))
            .zip(p.data().chunks(chunk).zip(q.data().chunks(chunk)))
            .map(|((xc, rc), (pc, qc))| {
                move || {
                    for (xrow, prow) in xc.chunks_exact_mut(c).zip(pc.chunks_exact(c)) {
                        for j in 0..c {
                            xrow[j] += alpha[j] * prow[j];
                        }
                    }
                    for (rrow, qrow) in rc.chunks_exact_mut(c).zip(qc.chunks_exact(c)) {
                        for j in 0..c {
                            rrow[j] -= alpha[j] * qrow[j];
                        }
                    }
                }
            })
            .collect();
        self.pool.run(jobs);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random(rng: &mut Rng, r: usize, c: usize) -> LocalMatrix {
        LocalMatrix::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn gram_matvec_matches_composition() {
        let mut rng = Rng::new(1);
        let a = random(&mut rng, 20, 8);
        let v = random(&mut rng, 8, 3);
        let mut e = NativeEngine::new();
        let got = e.gram_matvec(&a, &v, 0.7).unwrap();
        // reference: Aᵀ(Av) + reg·v
        let mut av = LocalMatrix::zeros(20, 3);
        av.gemm_nn(&a, &v);
        let mut want = v.clone();
        want.scale(0.7);
        want.gemm_tn(&a, &av);
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn gram_matvec_multi_chunk_is_close_and_thread_invariant() {
        // rows straddle several CHUNK_ROWS reduction chunks
        let mut rng = Rng::new(8);
        let a = random(&mut rng, 3 * CHUNK_ROWS + 17, 24);
        let v = random(&mut rng, 24, 3);
        let base = NativeEngine::new().gram_matvec(&a, &v, 0.3).unwrap();
        for threads in [2usize, 4] {
            let got = NativeEngine::with_threads(threads).gram_matvec(&a, &v, 0.3).unwrap();
            assert_eq!(got, base, "threads={threads}");
        }
        // chunked reduction still agrees with the one-shot composition to
        // rounding error
        let mut av = LocalMatrix::zeros(a.rows(), 3);
        av.gemm_nn(&a, &v);
        let mut want = v.clone();
        want.scale(0.3);
        want.gemm_tn(&a, &av);
        assert!(base.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn rff_expand_is_bounded_and_correct() {
        let mut rng = Rng::new(2);
        let x = random(&mut rng, 5, 4);
        let omega = random(&mut rng, 4, 6);
        let bias: Vec<f64> = (0..6).map(|_| rng.uniform_in(0.0, 6.28)).collect();
        let scale = (2.0f64 / 6.0).sqrt();
        let mut e = NativeEngine::new();
        let z = e.rff_expand(&x, &omega, &bias, scale).unwrap();
        for i in 0..5 {
            for j in 0..6 {
                let mut acc = 0.0;
                for k in 0..4 {
                    acc += x.get(i, k) * omega.get(k, j);
                }
                let want = scale * (acc + bias[j]).cos();
                assert!((z.get(i, j) - want).abs() < 1e-12);
                assert!(z.get(i, j).abs() <= scale + 1e-12);
            }
        }
    }

    #[test]
    fn cg_update_both_halves() {
        let mut rng = Rng::new(3);
        let mut x = random(&mut rng, 6, 2);
        let mut r = random(&mut rng, 6, 2);
        let p = random(&mut rng, 6, 2);
        let q = random(&mut rng, 6, 2);
        let alpha = vec![0.5, -2.0];
        let (x0, r0) = (x.clone(), r.clone());
        NativeEngine::new().cg_update(&mut x, &mut r, &p, &q, &alpha).unwrap();
        for i in 0..6 {
            for j in 0..2 {
                assert!((x.get(i, j) - (x0.get(i, j) + alpha[j] * p.get(i, j))).abs() < 1e-14);
                assert!((r.get(i, j) - (r0.get(i, j) - alpha[j] * q.get(i, j))).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn set_threads_rebuilds_only_on_change() {
        let mut e = NativeEngine::new();
        assert_eq!(e.threads(), 1);
        e.set_threads(4);
        assert_eq!(e.threads(), 4);
        e.set_threads(0); // 0 clamps to 1
        assert_eq!(e.threads(), 1);
    }

    #[test]
    fn shared_pool_engine_retargets_cap_and_matches_private() {
        let root = ThreadPool::new(4);
        let mut shared = NativeEngine::from_pool(root.client(1));
        shared.set_threads(2);
        assert_eq!(shared.threads(), 2);

        let mut rng = Rng::new(9);
        let a = random(&mut rng, 3 * CHUNK_ROWS + 5, 16);
        let v = random(&mut rng, 16, 2);
        let want = NativeEngine::with_threads(1).gram_matvec(&a, &v, 0.4).unwrap();
        let got = shared.gram_matvec(&a, &v, 0.4).unwrap();
        // stealing on the shared pool must not move a single bit
        assert_eq!(got, want);
    }

    #[test]
    fn cancelled_token_fails_engine_ops() {
        use crate::tasks::CANCELLED_MSG;
        let mut rng = Rng::new(10);
        let a = random(&mut rng, 2 * CHUNK_ROWS, 8);
        let v = random(&mut rng, 8, 2);
        let mut e = NativeEngine::with_threads(2);
        let token = Arc::new(CancelToken::new());
        e.set_cancel(Some(token.clone()));
        assert!(e.gram_matvec(&a, &v, 0.1).is_ok(), "clear token must not interfere");
        token.cancel();
        let err = e.gram_matvec(&a, &v, 0.1).unwrap_err();
        assert!(err.to_string().contains(CANCELLED_MSG));
        let mut c = LocalMatrix::zeros(a.rows(), 2);
        let err = e.gemm(GemmVariant::NN, &mut c, &a, &v).unwrap_err();
        assert!(err.to_string().contains(CANCELLED_MSG));
        // uninstalling the token restores normal operation
        e.set_cancel(None);
        assert!(e.gram_matvec(&a, &v, 0.1).is_ok());
    }
}
