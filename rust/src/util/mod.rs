//! Small shared utilities: deterministic PRNG, timing, formatting, padding.

pub mod fmt;
pub mod prng;
pub mod timer;

/// Round `n` up to the next multiple of `m` (`m > 0`).
pub fn round_up(n: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    n.div_ceil(m) * m
}

/// Split `n` items into `parts` contiguous ranges as evenly as possible
/// (first `n % parts` ranges get one extra). Returns `(start, end)` pairs;
/// empty ranges are allowed when `parts > n`.
pub fn even_ranges(n: usize, parts: usize) -> Vec<(usize, usize)> {
    debug_assert!(parts > 0);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn even_ranges_cover_exactly() {
        for n in [0usize, 1, 7, 16, 100] {
            for parts in [1usize, 2, 3, 7, 16] {
                let r = even_ranges(n, parts);
                assert_eq!(r.len(), parts);
                assert_eq!(r[0].0, 0);
                assert_eq!(r[parts - 1].1, n);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                    // balanced: sizes differ by at most 1
                    let a = w[0].1 - w[0].0;
                    let b = w[1].1 - w[1].0;
                    assert!(a >= b && a - b <= 1);
                }
            }
        }
    }
}
