//! The Alchemist server — the paper's system contribution (§3.1).
//!
//! One driver + a pool of `w` workers, carved into **session-scoped
//! groups**: every client handshake negotiates a group size, the driver's
//! allocator grants an exclusive rank subset (FIFO-queueing requests that
//! exceed free capacity), and each session's tasks run SPMD over its own
//! [`crate::collectives::LocalComm::subgroup`] communicator — so sessions
//! on disjoint groups execute concurrently. The driver owns the control
//! socket (admission, matrix handles, task dispatch); each worker owns a
//! data socket (row push/pull), a matrix [`store`] namespaced by owning
//! session, and a [`crate::compute::Engine`] built on its own thread.
//! Tasks are SPMD and, since protocol v4, asynchronous: `SubmitTask`
//! enqueues on the session's bounded FIFO and a per-session dispatcher
//! sends the work to the group's member threads; each runs the same
//! [`registry::Library`] routine against its local blocks with the
//! session's communicator (under a [`crate::tasks::TaskScope`] carrying
//! the cooperative cancel token and a progress slot), collectives stitch
//! them together, and group-rank-0's metadata becomes the `Done` payload
//! clients poll or wait for (see `docs/tasks.md`).
//!
//! Since protocol v8 the pool has two shapes (`fabric.mode`,
//! `docs/fabric.md`): **local** ranks are threads in the server process
//! over [`crate::collectives::LocalComm`] mailboxes (the seed behavior),
//! **tcp** ranks are separate `alchemist worker` OS processes ([`remote`])
//! whose session groups communicate rank↔rank over a brokered
//! [`crate::collectives::TcpComm`] mesh — the paper's driver/worker
//! process split, with the MPI communicator replaced by TCP.
//!
//! Differences from the paper, all documented in DESIGN.md §2: worker
//! ranks live on one host (threads or localhost processes) rather than
//! MPI ranks across nodes (the transfer and collective paths are still
//! real TCP); libraries are compiled in and resolved through the same
//! `registerLibrary(name, path)` API instead of `dlopen`.

pub mod libs;
pub mod registry;
pub mod remote;
pub mod server;
pub mod store;
pub mod worker;

pub use registry::{Library, Registry, TaskOutput, WorkerCtx};
pub use server::{AlchemistServer, ServerHandle};
pub use store::{Block, MatrixStore};
