//! Table 2: per-iteration cost of CG, Spark vs Alchemist, across node
//! counts.
//!
//! Paper: 2,251,569×10,000 random-feature system, nodes ∈ {20,30,40};
//! Spark 75.3→40.6 s/iter vs Alchemist 2.5→1.2 s/iter (≈30×), totals
//! extrapolated over the 526-iteration solve. Here: rows and features
//! scale by ~1/500, node counts map to worker counts {2,3,4}, and the
//! per-iteration gap + anti-scaling shape are the reproduction targets.
//! Wall and simulated-cluster columns are both printed (one core;
//! DESIGN.md §2).

mod bench_common;

use alchemist::cli::Args;
use alchemist::client::AlchemistContext;
use alchemist::coordinator::AlchemistServer;
use alchemist::linalg::CgOptions;
use alchemist::metrics::{Stats, Table};
use alchemist::protocol::{Params, Value};
use alchemist::sparklite::{mllib, IndexedRowMatrix, SparkEngine};
use alchemist::workloads::TimitSpec;
use bench_common::{bench_config, is_quick, require_artifacts, PAPER_CG_ITERS};

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let cfg = bench_config(&args)?;
    if !require_artifacts(&cfg) {
        return Ok(());
    }
    let quick = is_quick(&args);
    let rows = args.get_usize("rows", if quick { 2048 } else { 4096 })?;
    let rff_d = args.get_usize("rff-d", 1024)?;
    let default_nodes: &[usize] = if quick { &[2] } else { &[2, 3, 4] };
    let node_counts = args.get_usize_list("workers", default_nodes)?;
    let spark_iters = args.get_usize("spark-iters", if quick { 2 } else { 3 })?;
    let alch_iters = args.get_usize("alch-iters", if quick { 4 } else { 8 })?;

    let spec = TimitSpec { train_rows: rows, test_rows: 1, ..TimitSpec::default() };
    let data = spec.generate();
    let gamma = 0.06;
    let lambda = 1e-5;

    let total_hdr = format!("total {PAPER_CG_ITERS} iters (s)");
    let mut table = Table::new(
        &format!("Table 2 (scaled ~1/500): CG per-iteration cost, {rows}x{rff_d} system"),
        &[
            "nodes", "system", "iter (s, mean±sd)", "iter sim (s)",
            &total_hdr, "total sim (s)",
        ],
    );

    for &workers in &node_counts {
        // ---- Spark baseline ----
        {
            let x = IndexedRowMatrix::from_local(&data.x_train, workers * 2);
            let y = IndexedRowMatrix::from_local(&data.y_train, workers * 2);
            let mut engine = SparkEngine::new(workers, &cfg);
            let map =
                alchemist::linalg::RffMap::generate(spec.raw_features, rff_d, gamma, 1);
            let z = mllib::rff_expand(&mut engine, &x, &map)?;
            let res = mllib::cg_solve(
                &mut engine,
                &z,
                &y,
                &CgOptions { lambda, tol: 0.0, max_iters: spark_iters },
            )?;
            let per: Stats = res.iter_secs.iter().copied().collect();
            let per_sim: Stats = res.iter_sim_secs.iter().copied().collect();
            table.row(&[
                workers.to_string(),
                "Spark".into(),
                per.mean_pm_std(3),
                format!("{:.3}", per_sim.mean()),
                format!("{:.0}", per.mean() * PAPER_CG_ITERS as f64),
                format!("{:.0}", per_sim.mean() * PAPER_CG_ITERS as f64),
            ]);
        }

        // ---- Alchemist offload ----
        {
            let server = AlchemistServer::start(cfg.clone(), workers)?;
            let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, workers)?;
            ac.register_library("skylark", "builtin:skylark")?;
            let x = IndexedRowMatrix::from_local(&data.x_train, workers * 2);
            let y = IndexedRowMatrix::from_local(&data.y_train, workers * 2);
            let (al_x, _) = ac.send_matrix("X", &x)?;
            let (al_y, _) = ac.send_matrix("Y", &y)?;
            let res = ac.run_task(
                "skylark",
                "cg_solve",
                Params::new()
                    .with_matrix("X", al_x.id)
                    .with_matrix("Y", al_y.id)
                    .with_f64("lambda", lambda)
                    .with_f64("tol", 0.0)
                    .with_i64("max_iters", alch_iters as i64)
                    .with_i64("rff_d", rff_d as i64)
                    .with_f64("rff_gamma", gamma)
                    .with_i64("rff_seed", 1),
            )?;
            let iters = res.scalars.i64("iters")? as usize;
            let iter_secs = match res.scalars.get("iter_secs") {
                Some(Value::F64s(v)) => v.clone(),
                _ => vec![],
            };
            let per: Stats = iter_secs.iter().copied().collect();
            let sim_per = res.timing("sim_secs") / iters.max(1) as f64;
            table.row(&[
                workers.to_string(),
                format!("Alchemist[{}]", cfg.engine.as_str()),
                per.mean_pm_std(3),
                format!("{sim_per:.3}"),
                format!("{:.0}", per.mean() * PAPER_CG_ITERS as f64),
                format!("{:.0}", sim_per * PAPER_CG_ITERS as f64),
            ]);
            ac.shutdown_server()?;
            server.shutdown_on_request();
        }
    }

    table.print();
    println!(
        "paper: 20/30/40 nodes -> Spark 75.3/55.9/40.6 s/iter, Alchemist 2.5/1.5/1.2 s/iter"
    );
    Ok(())
}
