//! Figure 3: weak-scaling truncated SVD on column-replicated data.
//!
//! Paper: 2.2 TB base replicated to 4.4/8.8/17.6 TB with node counts
//! doubling alongside; SVD compute time stays ~flat (weak scaling), HDF5
//! load shrinks with more nodes, send-to-Spark grows with output size.
//! Here the base is `--cells × --times` replicated ×{1,2,4,8} with
//! workers {2,4,8,16}; the flat-SVD shape is read from the simulated
//! cluster column (one core; DESIGN.md §2).

mod bench_common;

use alchemist::cli::Args;
use alchemist::client::AlchemistContext;
use alchemist::coordinator::AlchemistServer;
use alchemist::metrics::Table;
use alchemist::protocol::Params;
use alchemist::util::fmt;
use alchemist::workloads::OceanSpec;
use bench_common::{bench_config, is_quick, require_artifacts};

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let cfg = bench_config(&args)?;
    if !require_artifacts(&cfg) {
        return Ok(());
    }
    let quick = is_quick(&args);
    let cells = args.get_usize("cells", 2048)?;
    let times = args.get_usize("times", 256)?;
    let rank = args.get_usize("rank", 20)?;
    let steps = args.get_usize("steps", if quick { 24 } else { 48 })?;
    let default_reps: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
    let default_workers: &[usize] = if quick { &[2, 4] } else { &[2, 4, 8, 16] };
    let replicas = args.get_usize_list("replicas", default_reps)?;
    let workers_list = args.get_usize_list("workers", default_workers)?;
    anyhow::ensure!(replicas.len() == workers_list.len(), "sweep lengths differ");

    let spec = OceanSpec { cells, times, ..OceanSpec::default() };
    let dir = std::env::temp_dir().join("alchemist-ocean");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("ocean_{cells}x{times}.bin"));
    if !path.exists() {
        spec.write_file(&path)?;
    }

    let mut table = Table::new(
        "Figure 3 (scaled): weak-scaling SVD on column-replicated ocean data",
        &[
            "size", "workers", "load (s)", "svd wall (s)", "svd sim (s)",
            "send S<=A (s)",
        ],
    );

    for (&rep, &workers) in replicas.iter().zip(&workers_list) {
        let server = AlchemistServer::start(cfg.clone(), workers)?;
        let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 2)?;
        ac.register_library("elemental", "builtin:elemental")?;

        let load = ac.run_task(
            "elemental",
            "load_hdf5",
            Params::new().with_str("path", path.to_str().unwrap()),
        )?;
        let mut al_a = load.output("A")?.clone();
        if rep > 1 {
            let r = ac.run_task(
                "elemental",
                "replicate_cols",
                Params::new().with_matrix("A", al_a.id).with_i64("times", rep as i64),
            )?;
            al_a = r.output("A_rep")?.clone();
        }
        let res = ac.run_task(
            "elemental",
            "truncated_svd",
            Params::new()
                .with_matrix("A", al_a.id)
                .with_i64("rank", rank as i64)
                .with_i64("steps", steps as i64),
        )?;
        // one receiving executor, like the paper
        ac.executors = 1;
        let (_, su) = ac.to_indexed_row_matrix(res.output("U")?, 1)?;
        let (_, ss) = ac.to_indexed_row_matrix(res.output("S")?, 1)?;
        let (_, sv) = ac.to_indexed_row_matrix(res.output("V")?, 1)?;

        table.row(&[
            fmt::bytes(al_a.size_bytes() as u64),
            workers.to_string(),
            format!("{:.2}", load.timing("load")),
            format!("{:.2}", res.timing("compute")),
            format!("{:.2}", res.timing("sim_secs")),
            format!("{:.3}", su.secs + ss.secs + sv.secs),
        ]);

        ac.shutdown_server()?;
        server.shutdown_on_request();
    }

    table.print();
    println!(
        "paper shape: sim svd time ~flat as (size, workers) double together; \
         send time grows with size"
    );
    Ok(())
}
