//! Hand-rolled compute threadpool (rayon is not in the offline vendor
//! set) — since PR 6 a *shared, work-stealing* pool: one set of worker
//! threads per server, with a home queue per client (per rank engine) and
//! bounded stealing between queues.
//!
//! Two construction modes:
//!
//! * [`ThreadPool::new`] builds a private pool (its own workers, one home
//!   queue) — what direct `NativeEngine::with_threads` callers and tests
//!   get, and what the pre-PR 6 pool was.
//! * [`ThreadPool::client`] registers another home queue on the *same*
//!   workers and returns a new handle for it. The server builds one root
//!   pool sized to the machine and hands every rank a client handle; a
//!   rank's `engine.threads` lease becomes its queue's `cap` instead of a
//!   private set of threads.
//!
//! Scheduling: a worker first serves queues running under their own cap
//! (`active < cap`), then — bounded stealing — queues that have work but
//! are at cap, up to `min(2·cap, span)` concurrent jobs. So a rank
//! running a hot GEMM can borrow capacity an idle neighbor's lease isn't
//! using (the admission-time `granted_workers × threads ≤ cores` budget
//! becomes a cap, not a static partition), while the 2× borrow bound
//! keeps any one rank from monopolizing the machine the moment a
//! neighbor wakes up.
//!
//! Three properties matter more than raw scheduling cleverness:
//!
//! * **Caller participation** — the thread that calls
//!   [`run`](ThreadPool::run) drains its own queue alongside the pool
//!   threads, so `cap = n` targets `n` runnable threads (`n − 1` workers
//!   + the caller). With `cap = 1` (or a 1-wide pool) jobs execute
//!   inline, in order — the serial determinism baseline.
//! * **Deterministic result order** — [`run`](ThreadPool::run) returns
//!   job results *in job-index order* regardless of which thread (home,
//!   stolen, or caller) finished what first. Callers that reduce (e.g.
//!   the Gram partial sums in `NativeEngine::gram_matvec`) combine the
//!   returned vector left to right, so floating-point results are
//!   bit-identical for any thread count and any steal schedule (see
//!   `docs/compute.md`, "Determinism contract").
//! * **No stranded jobs** — every queued job belongs to exactly one
//!   in-flight `run`, whose caller drains its own queue to empty before
//!   waiting; even with every worker gone (root handle dropped), a
//!   client's `run` still completes on the caller alone.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased job as it sits in a queue. Lifetime is erased on entry
/// (see the SAFETY note in [`ThreadPool::run`]); the latch in `run`
/// guarantees every job finishes before the borrows it captured expire.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// One client's home queue plus its scheduling state.
struct ClientQueue {
    jobs: VecDeque<Job>,
    /// Jobs of this queue currently executing anywhere (workers + the
    /// owning caller).
    active: usize,
    /// The client's lease width (counting its caller). Workers serve the
    /// queue up to `cap` concurrent jobs before it becomes steal-only.
    cap: usize,
    /// Cleared when the owning handle drops. Entries are never removed —
    /// indices stay stable for workers still decrementing `active` — but
    /// a fully quiesced closed slot (`active == 0`, no jobs) is REUSED by
    /// the next [`ThreadPool::client`] call, so per-task client handles
    /// (protocol v9 runs one per dispatched task) don't grow the vec for
    /// the life of the server.
    open: bool,
}

struct PoolState {
    queues: Vec<ClientQueue>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    cond: Condvar,
    /// Total parallelism of the pool: spawned workers + 1 (a caller).
    span: usize,
}

/// Completion state of one `run` scope.
struct ScopeState<R> {
    /// One slot per job, filled by whichever thread executes it.
    results: Mutex<Vec<Option<R>>>,
    /// Jobs not yet finished; `run` returns when this hits zero.
    pending: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

/// A handle onto the compute pool: either a private pool
/// ([`ThreadPool::new`] — owns the workers) or a client of a shared one
/// ([`ThreadPool::client`] — owns a home queue on someone else's
/// workers).
pub struct ThreadPool {
    shared: Arc<Shared>,
    /// Index of this handle's home queue (stable for the pool's life).
    queue: usize,
    /// Worker threads; non-empty only on the root handle, which joins
    /// them on drop.
    workers: Vec<JoinHandle<()>>,
    is_client: bool,
}

impl ThreadPool {
    /// Build a private pool with `threads` total parallelism (0 is
    /// treated as 1): `new(4)` spawns 3 workers and `run` makes the
    /// caller the 4th.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                queues: vec![ClientQueue {
                    jobs: VecDeque::new(),
                    active: 0,
                    cap: threads,
                    open: true,
                }],
                shutdown: false,
            }),
            cond: Condvar::new(),
            span: threads,
        });
        let workers = (1..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("engine-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn engine pool thread")
            })
            .collect();
        ThreadPool { shared, queue: 0, workers, is_client: false }
    }

    /// Register a new home queue on this pool's workers and return a
    /// handle for it, leased `cap` concurrent jobs (0 is treated as 1).
    /// The handle shares the workers but schedules independently; drop it
    /// to retire the queue. Outliving the root handle is safe — `run`
    /// then executes entirely on the calling thread.
    pub fn client(&self, cap: usize) -> ThreadPool {
        let queue = {
            let mut st = self.shared.state.lock().unwrap();
            let fresh = ClientQueue {
                jobs: VecDeque::new(),
                active: 0,
                cap: cap.max(1),
                open: true,
            };
            // reuse a quiesced retired slot if one exists (safe under the
            // state lock: a worker only holds a queue index while that
            // queue's `active` is nonzero)
            match st
                .queues
                .iter()
                .position(|q| !q.open && q.active == 0 && q.jobs.is_empty())
            {
                Some(i) => {
                    st.queues[i] = fresh;
                    i
                }
                None => {
                    st.queues.push(fresh);
                    st.queues.len() - 1
                }
            }
        };
        ThreadPool { shared: self.shared.clone(), queue, workers: Vec::new(), is_client: true }
    }

    /// This handle's lease width (its queue's `cap`, counting the
    /// caller).
    pub fn threads(&self) -> usize {
        self.shared.state.lock().unwrap().queues[self.queue].cap
    }

    /// Retarget this handle's lease width without touching any threads
    /// (0 is treated as 1). On a shared client this is how a task's
    /// `engine_threads` grant lands; takes effect for the next `run`.
    pub fn set_cap(&self, cap: usize) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.queues[self.queue].cap = cap.max(1);
        }
        self.shared.cond.notify_all();
    }

    /// Whether this handle is a client of a shared pool (true) or owns a
    /// private pool (false).
    pub fn is_client(&self) -> bool {
        self.is_client
    }

    /// Total parallelism of the underlying pool (workers + one caller).
    pub fn span(&self) -> usize {
        self.shared.span
    }

    /// Execute every job, blocking until all have finished, and return
    /// their results **in job-index order**. The caller drains its home
    /// queue alongside the pool threads. If any job panics, `run` panics
    /// after all jobs have settled (no job is left half-running against
    /// freed borrows).
    pub fn run<'env, R, F>(&self, jobs: Vec<F>) -> Vec<R>
    where
        F: FnOnce() -> R + Send + 'env,
        R: Send + 'env,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        // serial fast path: nothing to coordinate with, run inline in
        // order (this is also the `threads = 1` determinism baseline)
        if self.shared.span == 1 || n == 1 || self.threads() <= 1 {
            return jobs.into_iter().map(|j| j()).collect();
        }
        let state = Arc::new(ScopeState::<R> {
            results: Mutex::new((0..n).map(|_| None).collect()),
            pending: Mutex::new(n),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            for (idx, job) in jobs.into_iter().enumerate() {
                let state = state.clone();
                let wrapped: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)) {
                        Ok(r) => state.results.lock().unwrap()[idx] = Some(r),
                        Err(_) => state.panicked.store(true, Ordering::SeqCst),
                    }
                    let mut pending = state.pending.lock().unwrap();
                    *pending -= 1;
                    if *pending == 0 {
                        state.done.notify_all();
                    }
                });
                // SAFETY: lifetime erasure only. `run` does not return
                // until `pending` reaches zero, i.e. until every job (and
                // its captured `'env` borrows) has finished executing, so
                // no job can outlive the environment it borrows. The fat
                // pointer layout of `Box<dyn FnOnce() + Send>` does not
                // depend on the erased lifetime.
                let wrapped: Job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(wrapped)
                };
                st.queues[self.queue].jobs.push_back(wrapped);
            }
            self.shared.cond.notify_all();
        }
        // caller participates: drain our own home queue (counting
        // ourselves in `active` so workers see the true width), then wait
        // for stragglers still running on pool threads
        loop {
            let job = {
                let mut st = self.shared.state.lock().unwrap();
                let q = &mut st.queues[self.queue];
                match q.jobs.pop_front() {
                    Some(j) => {
                        q.active += 1;
                        Some(j)
                    }
                    None => None,
                }
            };
            match job {
                Some(j) => {
                    j();
                    self.shared.state.lock().unwrap().queues[self.queue].active -= 1;
                    self.shared.cond.notify_all();
                }
                None => break,
            }
        }
        let mut pending = state.pending.lock().unwrap();
        while *pending > 0 {
            pending = state.done.wait(pending).unwrap();
        }
        drop(pending);
        if state.panicked.load(Ordering::SeqCst) {
            // drop the completed jobs' results NOW, on this thread, while
            // `'env` is still alive: a pool worker may release the last
            // ScopeState Arc after this frame has unwound, and an `R`
            // whose Drop touches `'env`-borrowed data would then run
            // against a dead stack frame
            state.results.lock().unwrap().clear();
            panic!("engine pool job panicked");
        }
        let mut results = state.results.lock().unwrap();
        results
            .drain(..)
            .map(|r| r.expect("pool job finished without storing a result"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            let q = &mut st.queues[self.queue];
            q.open = false;
            // `run` never returns with jobs still queued, so this is
            // belt-and-braces against a panicking caller
            q.jobs.clear();
            if !self.workers.is_empty() {
                // root handle going away takes the workers with it;
                // surviving clients fall back to caller-only execution
                st.shutdown = true;
            }
        }
        self.shared.cond.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool")
            .field("queue", &self.queue)
            .field("cap", &self.threads())
            .field("span", &self.shared.span)
            .field("client", &self.is_client)
            .finish()
    }
}

/// Pick the next job for a worker, or `None` if nothing is eligible.
/// Pass 1 serves queues under their own cap; pass 2 is the bounded
/// steal — queues with work already at cap, up to `min(2·cap, span)`.
/// Both passes prefer the queue with the fewest active jobs (fairness:
/// a starved queue is served before a wide one gets wider).
fn pick_job(st: &mut PoolState, span: usize) -> Option<(usize, Job)> {
    fn select(st: &PoolState, bound: impl Fn(&ClientQueue) -> usize) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, q) in st.queues.iter().enumerate() {
            if !q.open || q.jobs.is_empty() || q.active >= bound(q) {
                continue;
            }
            match best {
                Some(b) if st.queues[b].active <= q.active => {}
                _ => best = Some(i),
            }
        }
        best
    }
    let pick = select(st, |q| q.cap).or_else(|| select(st, |q| (2 * q.cap).min(span)))?;
    let q = &mut st.queues[pick];
    q.active += 1;
    let job = q.jobs.pop_front().expect("picked queue has a job");
    Some((pick, job))
}

fn worker_loop(shared: &Shared) {
    loop {
        let (qi, job) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(pick) = pick_job(&mut st, shared.span) {
                    break pick;
                }
                if st.shutdown {
                    return;
                }
                st = shared.cond.wait(st).unwrap();
            }
        };
        // wrapped jobs catch their own panics; this is a backstop so a
        // hypothetical raw panic can never kill a pool thread silently
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        shared.state.lock().unwrap().queues[qi].active -= 1;
        shared.cond.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_job_order() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<_> = (0..64)
            .map(|i| {
                move || {
                    // stagger so completion order differs from job order
                    if i % 7 == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    i * 2
                }
            })
            .collect();
        let got = pool.run(jobs);
        assert_eq!(got, (0..64).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn jobs_may_borrow_the_callers_stack() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0u64; 40];
        {
            let jobs: Vec<_> = data
                .chunks_mut(10)
                .enumerate()
                .map(|(c, chunk)| {
                    move || {
                        for (i, x) in chunk.iter_mut().enumerate() {
                            *x = (c * 10 + i) as u64;
                        }
                    }
                })
                .collect();
            pool.run(jobs);
        }
        assert_eq!(data, (0..40).collect::<Vec<u64>>());
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let caller = std::thread::current().id();
        let got = pool.run(vec![
            move || std::thread::current().id() == caller,
            move || std::thread::current().id() == caller,
        ]);
        assert_eq!(got, vec![true, true]);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.threads(), 1);
        assert_eq!(pool.run(vec![|| 7]), vec![7]);
    }

    #[test]
    fn panicking_job_propagates_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("boom")),
            Box::new(|| 3),
        ];
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.run(jobs)));
        assert!(err.is_err());
        // the pool is still usable after a scope panicked
        assert_eq!(pool.run(vec![|| 5, || 6]), vec![5, 6]);
    }

    #[test]
    fn many_more_jobs_than_threads() {
        let pool = ThreadPool::new(2);
        let got = pool.run((0..500).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(got.len(), 500);
        assert!(got.iter().enumerate().all(|(i, &v)| v == i));
    }

    #[test]
    fn clients_share_workers_and_results_stay_ordered() {
        // two clients on one 4-wide pool, run from two threads at once:
        // the queues interleave on the shared workers, yet each run's
        // results come back complete and in job-index order
        let root = ThreadPool::new(4);
        let c1 = root.client(2);
        let c2 = root.client(2);
        assert!(c1.is_client() && !root.is_client());
        assert_eq!(c1.span(), 4);
        std::thread::scope(|s| {
            let h1 = s.spawn(|| c1.run((0..200).map(|i| move || i).collect::<Vec<_>>()));
            let h2 = s.spawn(|| c2.run((0..200).map(|i| move || 1000 + i).collect::<Vec<_>>()));
            let r1 = h1.join().unwrap();
            let r2 = h2.join().unwrap();
            assert!(r1.iter().enumerate().all(|(i, &v)| v == i));
            assert!(r2.iter().enumerate().all(|(i, &v)| v == 1000 + i));
        });
    }

    #[test]
    fn idle_capacity_is_stolen_by_a_busy_client() {
        // one busy client (cap 2) on a 4-wide pool with an idle
        // neighbor: bounded stealing lets its jobs run on more distinct
        // threads than its own lease provides
        let root = ThreadPool::new(4);
        let busy = root.client(2);
        let _idle = root.client(2);
        let ids = busy.run(
            (0..64)
                .map(|_| {
                    move || {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                        std::thread::current().id()
                    }
                })
                .collect::<Vec<_>>(),
        );
        let distinct: std::collections::HashSet<_> = ids.iter().copied().collect();
        // its own lease alone would bound this at 2 (caller + 1 worker);
        // with stealing the 64×2ms of work should spread wider. Keep the
        // assertion at ≥ 2 to stay scheduler-proof — the >2 case is
        // exercised, not required, on a loaded CI box.
        assert!(distinct.len() >= 2, "expected parallel execution, got {distinct:?}");
        assert_eq!(ids.len(), 64);
    }

    #[test]
    fn set_cap_retargets_without_rebuilding() {
        let root = ThreadPool::new(4);
        let client = root.client(1);
        assert_eq!(client.threads(), 1);
        // cap 1 runs inline even on a wide pool
        let caller = std::thread::current().id();
        let got = client.run(vec![
            move || std::thread::current().id() == caller,
            move || std::thread::current().id() == caller,
        ]);
        assert_eq!(got, vec![true, true]);
        client.set_cap(4);
        assert_eq!(client.threads(), 4);
        assert_eq!(client.run((0..10).map(|i| move || i).collect::<Vec<_>>()).len(), 10);
        client.set_cap(0); // clamps
        assert_eq!(client.threads(), 1);
    }

    #[test]
    fn client_survives_root_shutdown() {
        let root = ThreadPool::new(3);
        let client = root.client(2);
        drop(root); // workers join; the client's queue stays registered
        let got = client.run((0..20).map(|i| move || i * 3).collect::<Vec<_>>());
        assert_eq!(got, (0..20).map(|i| i * 3).collect::<Vec<_>>());
    }
}
