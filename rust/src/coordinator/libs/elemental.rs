//! The Elemental-routines stand-in (paper §4.2): dense distributed
//! building blocks the ocean-SVD experiments use.
//!
//! Routines:
//!
//! * `truncated_svd(A, rank [, steps, seed])` → `U, S, V`
//! * `qr(A)` → `Q, R` (the Figure 2 API example)
//! * `gemm(A, B)` → `C = A·B` (B allgathered; tall-skinny B)
//! * `load_hdf5(path)` → `A` — workers read their row ranges straight
//!   from the file (Table 5 use-case 3 / Figure 3 load path)
//! * `replicate_cols(A, times)` → column-wise replication (Figure 3's
//!   2.2→17.6 TB construction)
//! * `rand_matrix(rows, cols, seed)` → synthetic dense matrix
//! * `fro_norm(A)` → scalar
//! * `sleep(millis)` → scheduling diagnostic: every group rank parks for
//!   `millis` in cancellable 10 ms slices (reporting one progress tick per
//!   slice), then the group barriers — used by the multi-tenant tests to
//!   prove disjoint session groups run concurrently, and by the
//!   async-task tests as the pollable/cancellable long-running routine
//! * `burn(millis [, size])` → collective-free compute hog (diagnostic):
//!   repeated dense engine GEMMs for up to `millis`, never polling the
//!   cooperative token and never entering a collective — cancellable
//!   only through the engine-level kernel check-ins (the worker installs
//!   the task token into the engine; see `docs/compute.md`)
//! * `spin(millis)` → cancellation-contract violator (diagnostic): runs
//!   `millis` of collectively-synchronized 10 ms slices while
//!   deliberately ignoring the cooperative cancel token — only a hard
//!   cancel (`CancelTask { hard_after_ms }` poisoning the group) can end
//!   it early, which is exactly what the fault-isolation tests need
//! * `fail_on(rank [, panic, strand])` → failure-injection diagnostic:
//!   that group-local rank fails (`panic=1`: by panicking instead of
//!   erroring); with `strand=1` the surviving ranks enter an allreduce
//!   the dead rank never joins, so only failure propagation (the group
//!   poison) releases them (exercises per-rank failure tagging and
//!   root-cause vs collateral reporting)

use std::path::Path;

use crate::collectives::allgather;
use crate::compute::GemmVariant;
use crate::distmat::{LocalMatrix, RowBlockLayout};
use crate::linalg::lanczos::{truncated_svd_panels, truncated_svd_scoped, SvdOptions};
use crate::linalg::qr::cholesky_qr2;
use crate::protocol::{Params, Value};
use crate::util::prng::Rng;
use crate::util::timer::Stopwatch;

use super::super::registry::{Library, OutputMatrix, TaskOutput, WorkerCtx};
use super::distribute_replicated;

pub struct Elemental;

impl Library for Elemental {
    fn name(&self) -> &'static str {
        "elemental"
    }

    fn routines(&self) -> Vec<&'static str> {
        vec![
            "truncated_svd",
            "qr",
            "gemm",
            "load_hdf5",
            "replicate_cols",
            "rand_matrix",
            "fro_norm",
            "sleep",
            "burn",
            "spin",
            "fail_on",
        ]
    }

    fn run(
        &self,
        routine: &str,
        params: &Params,
        ctx: &mut WorkerCtx,
    ) -> crate::Result<TaskOutput> {
        match routine {
            "truncated_svd" => svd(params, ctx),
            "qr" => qr(params, ctx),
            "gemm" => gemm(params, ctx),
            "load_hdf5" => load_hdf5(params, ctx),
            "replicate_cols" => replicate_cols(params, ctx),
            "rand_matrix" => rand_matrix(params, ctx),
            "fro_norm" => fro_norm(params, ctx),
            "sleep" => sleep_routine(params, ctx),
            "burn" => burn_routine(params, ctx),
            "spin" => spin_routine(params, ctx),
            "fail_on" => fail_on(params, ctx),
            other => anyhow::bail!("elemental has no routine {other:?}"),
        }
    }
}

fn svd(params: &Params, ctx: &mut WorkerCtx) -> crate::Result<TaskOutput> {
    let a_id = params.matrix("A")?;
    let opts = SvdOptions {
        rank: params.i64_or("rank", 20)? as usize,
        steps: params.i64_or("steps", 0)? as usize,
        seed: params.i64_or("seed", 0x53D5)? as u64,
    };
    // `panel_rows > 0` selects the out-of-core path: the routine streams
    // that many rows at a time through the block handle (mapped blocks
    // serve from the page cache, spilled ones off disk), so A never has
    // to fit in the session's storage budget. 0 = classic in-memory
    // snapshot (bit-identical results; see linalg::lanczos).
    let panel_rows = params.i64_or("panel_rows", 0)? as usize;
    let block = ctx.block(a_id)?;
    let layout = block.layout.clone();

    let mut sw = Stopwatch::new();
    sw.start("compute");
    let res = if panel_rows > 0 {
        truncated_svd_panels(
            ctx.comm,
            ctx.engine,
            block.as_ref(),
            panel_rows,
            &opts,
            ctx.scope,
        )?
    } else {
        let (_, a_local) = ctx.local_block(a_id)?;
        truncated_svd_scoped(ctx.comm, ctx.engine, &a_local, &opts, ctx.scope)?
    };
    sw.stop();

    let k = res.sigma.len();
    // U inherits A's row layout
    let mut u_layout = layout.clone();
    u_layout.cols = k;
    // S as a k×1 distributed column, V (K×k) distributed by rows
    let s_mat = LocalMatrix::from_data(k, 1, res.sigma.clone());
    let workers = ctx.comm.size();
    let (s_layout, s_local) = distribute_replicated(&s_mat, workers, ctx.rank);
    let (v_layout, v_local) = distribute_replicated(&res.v, workers, ctx.rank);

    Ok(TaskOutput {
        matrices: vec![
            OutputMatrix { name: "U".into(), layout: u_layout, local: res.u_local },
            OutputMatrix { name: "S".into(), layout: s_layout, local: s_local },
            OutputMatrix { name: "V".into(), layout: v_layout, local: v_local },
        ],
        scalars: Params::new()
            .with_i64("steps", res.steps as i64)
            .set("sigma", Value::F64s(res.sigma)),
        timings: vec![("compute".into(), sw.secs("compute"))],
    })
}

fn qr(params: &Params, ctx: &mut WorkerCtx) -> crate::Result<TaskOutput> {
    let a_id = params.matrix("A")?;
    let (layout, a_local) = ctx.local_block(a_id)?;
    let mut sw = Stopwatch::new();
    sw.start("compute");
    let (q_local, r) = cholesky_qr2(ctx.comm, ctx.engine, &a_local)?;
    sw.stop();
    let (r_layout, r_local) = distribute_replicated(&r, ctx.comm.size(), ctx.rank);
    Ok(TaskOutput {
        matrices: vec![
            OutputMatrix { name: "Q".into(), layout: layout.clone(), local: q_local },
            OutputMatrix { name: "R".into(), layout: r_layout, local: r_local },
        ],
        scalars: Params::new(),
        timings: vec![("compute".into(), sw.secs("compute"))],
    })
}

fn gemm(params: &Params, ctx: &mut WorkerCtx) -> crate::Result<TaskOutput> {
    let a_id = params.matrix("A")?;
    let b_id = params.matrix("B")?;
    let (a_layout, a_local) = ctx.local_block(a_id)?;
    let (b_layout, b_local) = ctx.local_block(b_id)?;
    anyhow::ensure!(
        a_layout.cols == b_layout.rows,
        "gemm: A is {}x{}, B is {}x{}",
        a_layout.rows,
        a_layout.cols,
        b_layout.rows,
        b_layout.cols
    );

    let mut sw = Stopwatch::new();
    sw.start("compute");
    // allgather B's row blocks so every rank holds the full right factor
    let parts = allgather(ctx.comm, 0x4D4D_0000, b_local.into_data())?;
    let mut b_full = LocalMatrix::zeros(b_layout.rows, b_layout.cols);
    for (rank, part) in parts.into_iter().enumerate() {
        let (lo, hi) = b_layout.ranges[rank];
        b_full.write_rows(
            lo,
            &LocalMatrix::from_data(hi - lo, b_layout.cols, part),
        );
    }
    let mut c_local = LocalMatrix::zeros(a_local.rows(), b_layout.cols);
    ctx.engine.gemm(GemmVariant::NN, &mut c_local, &a_local, &b_full)?;
    sw.stop();

    let mut c_layout = a_layout.clone();
    c_layout.cols = b_layout.cols;
    Ok(TaskOutput {
        matrices: vec![OutputMatrix { name: "C".into(), layout: c_layout, local: c_local }],
        scalars: Params::new(),
        timings: vec![("compute".into(), sw.secs("compute"))],
    })
}

fn load_hdf5(params: &Params, ctx: &mut WorkerCtx) -> crate::Result<TaskOutput> {
    let path_s = params.str("path")?.to_string();
    let path = Path::new(&path_s);
    let (rows, cols) = crate::hdf5sim::read_header(path)?;
    let layout = RowBlockLayout::even(rows, cols, ctx.comm.size());
    let (lo, hi) = layout.ranges[ctx.rank];

    let mut sw = Stopwatch::new();
    sw.start("load");
    let local = crate::hdf5sim::read_rows(path, lo, hi)?;
    sw.stop();

    Ok(TaskOutput {
        matrices: vec![OutputMatrix { name: "A".into(), layout, local }],
        scalars: Params::new()
            .with_i64("rows", rows as i64)
            .with_i64("cols", cols as i64),
        timings: vec![("load".into(), sw.secs("load"))],
    })
}

fn replicate_cols(params: &Params, ctx: &mut WorkerCtx) -> crate::Result<TaskOutput> {
    let a_id = params.matrix("A")?;
    let times = params.i64("times")? as usize;
    anyhow::ensure!(times >= 1, "times must be >= 1");
    let (layout, a_local) = ctx.local_block(a_id)?;
    let mut sw = Stopwatch::new();
    sw.start("replicate");
    let local = a_local.tile_cols(times);
    sw.stop();
    let mut out_layout = layout.clone();
    out_layout.cols *= times;
    Ok(TaskOutput {
        matrices: vec![OutputMatrix { name: "A_rep".into(), layout: out_layout, local }],
        scalars: Params::new(),
        timings: vec![("replicate".into(), sw.secs("replicate"))],
    })
}

fn rand_matrix(params: &Params, ctx: &mut WorkerCtx) -> crate::Result<TaskOutput> {
    let rows = params.i64("rows")? as usize;
    let cols = params.i64("cols")? as usize;
    let seed = params.i64_or("seed", 7)? as u64;
    let layout = RowBlockLayout::even(rows, cols, ctx.comm.size());
    let (lo, hi) = layout.ranges[ctx.rank];
    // per-row streams keyed by global index: layout-independent content
    let base = Rng::new(seed);
    let mut local = LocalMatrix::zeros(hi - lo, cols);
    for gi in lo..hi {
        let mut row_rng = base.derive(gi as u64);
        let row = local.row_mut(gi - lo);
        for v in row.iter_mut() {
            *v = row_rng.normal();
        }
    }
    Ok(TaskOutput {
        matrices: vec![OutputMatrix { name: "A".into(), layout, local }],
        scalars: Params::new(),
        timings: vec![],
    })
}

fn sleep_routine(params: &Params, ctx: &mut WorkerCtx) -> crate::Result<TaskOutput> {
    let millis = params.i64("millis")?;
    anyhow::ensure!((0..=60_000).contains(&millis), "millis must be in [0, 60000]");
    let mut sw = Stopwatch::new();
    sw.start("compute");
    // park in small slices so cancellation is observed promptly and the
    // task shows live progress (one "iteration" per slice) — this is the
    // long-running stand-in the async-task tests poll and cancel
    const SLICE_MS: u64 = 10;
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_millis(millis as u64);
    let mut slices = 0u64;
    loop {
        if ctx.scope.is_cancelled() {
            break;
        }
        let left = deadline.saturating_duration_since(std::time::Instant::now());
        if left.is_zero() {
            break;
        }
        std::thread::sleep(left.min(std::time::Duration::from_millis(SLICE_MS)));
        slices += 1;
        ctx.scope.report(slices, crate::tasks::NO_RESIDUAL);
    }
    // cancellation must be decided collectively: every rank reaches this
    // check (cancelled ranks early, the rest at the deadline), so either
    // all bail or none — a unilateral bail would strand peers in the
    // final barrier
    ctx.scope.collective_check_cancelled(ctx.comm, 0x534C_0000)?;
    // a group barrier proves every member executed on this session's own
    // communicator (a wrong-sized group would hang, not silently pass)
    ctx.comm.barrier()?;
    sw.stop();
    Ok(TaskOutput {
        matrices: vec![],
        scalars: Params::new().with_i64("ranks", ctx.comm.size() as i64),
        timings: vec![("compute".into(), sw.secs("compute"))],
    })
}

/// Collective-free compute hog (diagnostic): repeated dense engine GEMMs
/// for up to `millis`, never polling the cooperative token and never
/// entering a collective — the pre-v6 worst case for cancellation (no
/// poison point for a hard cancel to land on, no cooperative check-in).
/// The engine-level kernel check-ins are the only early exit: the worker
/// installs the task's token into the engine, whose GEMM polls it at
/// MC-panel boundaries and bails with `CANCELLED_MSG` within one panel.
fn burn_routine(params: &Params, ctx: &mut WorkerCtx) -> crate::Result<TaskOutput> {
    let millis = params.i64("millis")?;
    anyhow::ensure!((0..=60_000).contains(&millis), "millis must be in [0, 60000]");
    let size = params.i64_or("size", 256)?;
    anyhow::ensure!((16..=1024).contains(&size), "size must be in [16, 1024]");
    let n = size as usize;
    let mut sw = Stopwatch::new();
    sw.start("compute");
    let mut rng = Rng::new(0xB0B1 + ctx.rank as u64);
    let a = LocalMatrix::from_fn(n, n, |_, _| rng.normal());
    let b = LocalMatrix::from_fn(n, n, |_, _| rng.normal());
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_millis(millis as u64);
    let mut iters = 0i64;
    let mut checksum = 0.0;
    while std::time::Instant::now() < deadline {
        let mut c = LocalMatrix::zeros(n, n);
        // the engine call is where a cancelled task unwinds: the
        // installed token fails the kernel mid-GEMM (note: deliberately
        // no ctx.scope poll anywhere on this path)
        ctx.engine.gemm(GemmVariant::NN, &mut c, &a, &b)?;
        checksum += c.get(0, 0);
        iters += 1;
    }
    sw.stop();
    Ok(TaskOutput {
        matrices: vec![],
        scalars: Params::new().with_i64("iters", iters).with_f64("checksum", checksum),
        timings: vec![("compute".into(), sw.secs("compute"))],
    })
}

/// Cancellation-contract violator (diagnostic): collectively-synchronized
/// 10 ms slices for `millis`, *deliberately ignoring* the cooperative
/// cancel token. A plain `CancelTask` has no effect on it; a hard cancel
/// (`hard_after_ms` escalation) poisons the group and the next collective
/// unwinds every rank — the fault-isolation tests use it to prove the
/// escalation path bounds uncooperative routines.
fn spin_routine(params: &Params, ctx: &mut WorkerCtx) -> crate::Result<TaskOutput> {
    let millis = params.i64("millis")?;
    anyhow::ensure!((0..=60_000).contains(&millis), "millis must be in [0, 60000]");
    let mut sw = Stopwatch::new();
    sw.start("compute");
    const SLICE_MS: u64 = 10;
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_millis(millis as u64);
    let mut slices = 0u64;
    loop {
        // the exit decision must be COLLECTIVE: ranks start the routine
        // at slightly different instants, so per-rank deadline checks
        // between collectives would let the earliest rank leave while a
        // peer re-enters and waits forever. The allreduce keeps the
        // group in lockstep and is where the hard cancel's poison lands;
        // the cooperative token is never consulted (tag rotates like
        // cg's per-iteration windows so back-to-back rounds never mix)
        let mut done =
            [if std::time::Instant::now() >= deadline { 1.0 } else { 0.0 }];
        crate::collectives::allreduce_sum(
            ctx.comm,
            0x5350_0000 + (slices % 64) * crate::collectives::TAG_WINDOW,
            &mut done,
        )?;
        if done[0] > 0.0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(SLICE_MS));
        slices += 1;
        ctx.scope.report(slices, crate::tasks::NO_RESIDUAL);
    }
    sw.stop();
    Ok(TaskOutput {
        matrices: vec![],
        scalars: Params::new().with_i64("ranks", ctx.comm.size() as i64),
        timings: vec![("compute".into(), sw.secs("compute"))],
    })
}

/// Failure-injection diagnostic: the given group-local rank fails, the
/// rest succeed with no outputs — the async-task tests use it to prove a
/// one-rank wedge is reported distinguishably from a group-wide failure.
///
/// `panic = 1` makes the chosen rank panic instead of returning an error
/// (exercising the worker loop's `catch_unwind` → poison path), and
/// `strand = 1` sends the surviving ranks into an allreduce the dead rank
/// never joins — without failure propagation they would block there
/// forever, which is precisely the bug protocol v5 fixes.
fn fail_on(params: &Params, ctx: &mut WorkerCtx) -> crate::Result<TaskOutput> {
    let rank = params.i64("rank")?;
    anyhow::ensure!(
        (0..ctx.comm.size() as i64).contains(&rank),
        "rank {rank} outside the group of {}",
        ctx.comm.size()
    );
    let panic_mode = params.i64_or("panic", 0)? != 0;
    let strand = params.i64_or("strand", 0)? != 0;
    if ctx.rank as i64 == rank {
        // fail BEFORE the peers' collective below: with `strand` they are
        // (or soon will be) blocked in it, and only the group poison this
        // rank's worker loop applies can release them
        if panic_mode {
            panic!("diagnostic panic injected on rank {rank}");
        }
        anyhow::bail!("diagnostic failure injected on rank {rank}");
    }
    if strand {
        let mut probe = [1.0];
        crate::collectives::allreduce_sum(ctx.comm, 0x464F_0000, &mut probe)?;
    }
    Ok(TaskOutput::default())
}

fn fro_norm(params: &Params, ctx: &mut WorkerCtx) -> crate::Result<TaskOutput> {
    let a_id = params.matrix("A")?;
    let (_, a_local) = ctx.local_block(a_id)?;
    let mut sq = vec![a_local.fro_sq()];
    crate::collectives::allreduce_sum(ctx.comm, 0x4652_0000, &mut sq)?;
    Ok(TaskOutput {
        matrices: vec![],
        scalars: Params::new().with_f64("norm", sq[0].sqrt()),
        timings: vec![],
    })
}
