//! Property tests: wire protocol total-roundtrip invariants (the
//! proptest-style suite; see `alchemist::testkit`).

use alchemist::protocol::{
    ControlMsg, DataMsg, MatrixInfo, Params, TaskProgress, TaskState, Value,
};
use alchemist::testkit::{props, Gen};

fn random_task_state(g: &mut Gen) -> TaskState {
    match g.usize_in(0, 4) {
        0 => TaskState::Queued,
        1 => TaskState::Running {
            progress: TaskProgress {
                iters: g.u64() % 1_000_000,
                residual: g.f64_in(0.0, 1.0),
                ranks: g.u64() as u32 % 64,
            },
        },
        2 => TaskState::Done {
            outputs: (0..g.usize_in(0, 3)).map(|_| random_info(g)).collect(),
            scalars: random_params(g),
            timings: (0..g.usize_in(0, 4))
                .map(|_| (g.ident(10), g.f64_in(0.0, 100.0)))
                .collect(),
        },
        3 => TaskState::Failed {
            message: g.ident(30),
            failed_ranks: (0..g.usize_in(0, 4)).map(|_| g.u64() as u32 % 64).collect(),
            total_ranks: g.u64() as u32 % 64,
        },
        _ => TaskState::Cancelled,
    }
}

fn random_params(g: &mut Gen) -> Params {
    let mut p = Params::new();
    for _ in 0..g.usize_in(0, 6) {
        let key = g.ident(8);
        let v = match g.usize_in(0, 5) {
            0 => Value::I64(g.u64() as i64),
            1 => Value::F64(g.normal() * 1e3),
            2 => Value::Bool(g.bool()),
            3 => Value::Str(g.ident(16)),
            4 => Value::Matrix(g.u64()),
            _ => {
                let n = g.usize_in(0, 32);
                Value::F64s(g.vec_normal(n))
            }
        };
        p = p.set(&key, v);
    }
    p
}

fn random_info(g: &mut Gen) -> MatrixInfo {
    MatrixInfo {
        id: g.u64(),
        rows: g.u64() % 1_000_000,
        cols: g.u64() % 10_000,
        name: g.ident(12),
    }
}

#[test]
fn control_messages_roundtrip() {
    props(300, |g| {
        let msg = match g.usize_in(0, 9) {
            0 => ControlMsg::Handshake {
                client_name: g.ident(20),
                version: g.u64() as u32,
                request_workers: g.u64() as u32,
                rows_per_frame: g.u64() as u32,
                buf_bytes: g.u64() % (1 << 30),
                priority: g.u64() as u32 % 4,
            },
            1 => ControlMsg::RegisterLibrary { name: g.ident(8), path: g.ident(30) },
            2 => ControlMsg::CreateMatrix {
                name: g.ident(8),
                rows: g.u64() % 1_000_000,
                cols: g.u64() % 10_000,
            },
            3 => ControlMsg::SubmitTask {
                lib: g.ident(8),
                routine: g.ident(12),
                params: random_params(g),
            },
            4 => {
                let n = g.usize_in(0, 5);
                ControlMsg::HandshakeAck {
                    session_id: g.u64(),
                    version: 1,
                    granted_workers: g.u64() as u32,
                    worker_addrs: (0..n).map(|_| g.ident(21)).collect(),
                    rows_per_frame: g.u64() as u32,
                    buf_bytes: g.u64() % (1 << 30),
                    session_token: g.u64(),
                }
            }
            5 => {
                let n = g.usize_in(0, 4);
                let mut start = 0u64;
                let row_ranges = (0..n)
                    .map(|_| {
                        let len = g.u64() % 1000;
                        let r = (start, start + len);
                        start += len;
                        r
                    })
                    .collect();
                ControlMsg::MatrixCreated { id: g.u64(), row_ranges }
            }
            6 => ControlMsg::TaskStatusReply {
                task_id: g.u64(),
                state: random_task_state(g),
            },
            7 => ControlMsg::FetchReady {
                info: random_info(g),
                row_ranges: vec![],
                worker_addrs: (0..g.usize_in(0, 3)).map(|_| g.ident(21)).collect(),
            },
            8 => ControlMsg::Error { message: g.ident(40) },
            _ => ControlMsg::MatrixList {
                infos: (0..g.usize_in(0, 4)).map(|_| random_info(g)).collect(),
            },
        };
        let bytes = msg.encode();
        let back = ControlMsg::decode(&bytes).expect("decode");
        assert_eq!(msg, back);
    });
}

#[test]
fn data_messages_roundtrip() {
    props(300, |g| {
        let msg = match g.usize_in(0, 3) {
            0 => {
                let nrows = g.usize_in(1, 16) as u32;
                let ncols = g.usize_in(1, 32) as u32;
                DataMsg::PushRows {
                    matrix_id: g.u64(),
                    start_row: g.u64() % 1_000_000,
                    nrows,
                    ncols,
                    data: g.vec_normal((nrows * ncols) as usize),
                }
            }
            1 => DataMsg::PullRows {
                matrix_id: g.u64(),
                start_row: g.u64() % 1_000_000,
                nrows: g.u64() as u32 % 1000,
                start_col: g.u64() % 1000,
                sel_cols: g.u64() as u32 % 100,
            },
            2 => {
                let nrows = g.usize_in(1, 8) as u32;
                let ncols = g.usize_in(1, 8) as u32;
                DataMsg::RowsData {
                    matrix_id: g.u64(),
                    start_row: g.u64() % 100,
                    nrows,
                    ncols,
                    data: g.vec_normal((nrows * ncols) as usize),
                }
            }
            _ => DataMsg::PushDoneAck { matrix_id: g.u64(), rows_received: g.u64() },
        };
        let bytes = msg.encode();
        assert_eq!(msg, DataMsg::decode(&bytes).expect("decode"));
    });
}

#[test]
fn hand_built_little_endian_frames_decode_identically() {
    use alchemist::protocol::{le_f64s_to_vec, DataMsgView, ROWS_HEADER_LEN};
    // a byte-by-byte little-endian PushRows frame built WITHOUT the
    // Writer: whatever the host endianness, the wire format is LE, so
    // this pins the #[cfg(target_endian)] encode/decode fallbacks
    let vals = [1.5f64, -2.25, 1e-300, 0.0, f64::MAX, -7.125];
    let mut bytes = Vec::new();
    bytes.push(1u8); // PushRows tag
    bytes.extend_from_slice(&42u64.to_le_bytes()); // matrix_id
    bytes.extend_from_slice(&100u64.to_le_bytes()); // start_row
    bytes.extend_from_slice(&2u32.to_le_bytes()); // nrows
    bytes.extend_from_slice(&3u32.to_le_bytes()); // ncols
    for v in &vals {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    assert_eq!(bytes.len(), ROWS_HEADER_LEN + vals.len() * 8);

    // owned decode
    match DataMsg::decode(&bytes).unwrap() {
        DataMsg::PushRows { matrix_id, start_row, nrows, ncols, data } => {
            assert_eq!((matrix_id, start_row, nrows, ncols), (42, 100, 2, 3));
            for (a, b) in data.iter().zip(&vals) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        other => panic!("{other:?}"),
    }
    // borrowed decode hands out the raw LE payload bytes in place
    match DataMsgView::decode(&bytes).unwrap() {
        DataMsgView::PushRows { payload, .. } => {
            assert_eq!(payload, &bytes[ROWS_HEADER_LEN..]);
            let back = le_f64s_to_vec(payload);
            for (a, b) in back.iter().zip(&vals) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        other => panic!("{other:?}"),
    }
    // and the encoder emits exactly these canonical bytes
    let owned = DataMsg::PushRows {
        matrix_id: 42,
        start_row: 100,
        nrows: 2,
        ncols: 3,
        data: vals.to_vec(),
    };
    assert_eq!(owned.encode(), bytes);
}

#[test]
fn borrowed_and_owned_decodes_agree() {
    use alchemist::protocol::{le_f64s_to_vec, DataMsgView};
    props(200, |g| {
        let nrows = g.usize_in(1, 16) as u32;
        let ncols = g.usize_in(1, 32) as u32;
        let msg = DataMsg::RowsData {
            matrix_id: g.u64(),
            start_row: g.u64() % 1_000_000,
            nrows,
            ncols,
            data: g.vec_normal((nrows * ncols) as usize),
        };
        let bytes = msg.encode();
        let (m1, s1, n1, c1, d1) = match &msg {
            DataMsg::RowsData { matrix_id, start_row, nrows, ncols, data } => {
                (*matrix_id, *start_row, *nrows, *ncols, data.clone())
            }
            _ => unreachable!(),
        };
        match DataMsgView::decode(&bytes).unwrap() {
            DataMsgView::RowsData { matrix_id, start_row, nrows, ncols, payload } => {
                assert_eq!((matrix_id, start_row, nrows, ncols), (m1, s1, n1, c1));
                assert_eq!(le_f64s_to_vec(payload), d1);
            }
            other => panic!("{other:?}"),
        }
    });
}

#[test]
fn corrupted_frames_never_panic() {
    // decode must return Err (not panic) for arbitrary mutations
    props(400, |g| {
        let msg = ControlMsg::TaskStatusReply {
            task_id: g.u64(),
            state: TaskState::Done {
                outputs: vec![random_info(g)],
                scalars: random_params(g),
                timings: vec![(g.ident(6), 1.0)],
            },
        };
        let mut bytes = msg.encode();
        match g.usize_in(0, 2) {
            0 => {
                let keep = g.usize_in(0, bytes.len().saturating_sub(1));
                bytes.truncate(keep);
            }
            1 => {
                if !bytes.is_empty() {
                    let top = bytes.len() - 1;
                    let i = g.usize_in(0, top);
                    bytes[i] ^= 1 << g.usize_in(0, 7);
                }
            }
            _ => bytes.push(g.u64() as u8),
        }
        // must not panic; Err or (for benign bit flips) a decoded message
        let _ = ControlMsg::decode(&bytes);
    });
}

#[test]
fn params_accessors_total() {
    props(200, |g| {
        let p = random_params(g);
        for key in ["a", "b", "zzz"] {
            let _ = p.i64(key);
            let _ = p.f64(key);
            let _ = p.str(key);
            let _ = p.matrix(key);
        }
    });
}
