//! Measurement plumbing: streaming statistics, paper-style ASCII tables,
//! and the simulated cluster clock.

pub mod simclock;
pub mod stats;
pub mod table;

pub use simclock::SimClock;
pub use stats::Stats;
pub use table::Table;
