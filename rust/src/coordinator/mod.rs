//! The Alchemist server — the paper's system contribution (§3.1).
//!
//! One driver + `w` workers. The driver owns the control socket (sessions,
//! matrix handles, task dispatch); each worker owns a data socket (row
//! push/pull), a rank in the worker [`crate::collectives`] group, a matrix
//! [`store`], and a [`crate::compute::Engine`] built on its own thread.
//! Tasks are SPMD: the driver broadcasts a `RunTask` to every worker
//! thread, each runs the same [`registry::Library`] routine against its
//! local blocks, collectives stitch them together, and rank 0's metadata
//! becomes the reply.
//!
//! Differences from the paper, all documented in DESIGN.md §2: workers are
//! threads in the server process rather than MPI ranks across nodes (the
//! transfer path is still real TCP); libraries are compiled in and
//! resolved through the same `registerLibrary(name, path)` API instead of
//! `dlopen`.

pub mod libs;
pub mod registry;
pub mod server;
pub mod store;
pub mod worker;

pub use registry::{Library, Registry, TaskOutput, WorkerCtx};
pub use server::{AlchemistServer, ServerHandle};
pub use store::{Block, MatrixStore};
