//! Property tests: collective algorithms equal their serial semantics for
//! arbitrary group sizes, lengths, and roots.

use alchemist::collectives::{
    allgather, allreduce_sum, broadcast, gather, reduce_sum, scatter, Communicator,
    LocalComm,
};
use alchemist::testkit::props;

/// Run `f` on every rank; collect per-rank results sorted by rank.
fn run_group<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(&LocalComm) -> T + Send + Sync + Clone + 'static,
{
    let comms = LocalComm::group(n, None);
    let mut handles = Vec::new();
    for c in comms {
        let f = f.clone();
        handles.push(std::thread::spawn(move || (c.rank(), f(&c))));
    }
    let mut out: Vec<(usize, T)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    out.sort_by_key(|(r, _)| *r);
    out.into_iter().map(|(_, t)| t).collect()
}

#[test]
fn allreduce_equals_serial_sum() {
    props(40, |g| {
        let p = g.usize_in(1, 6);
        let n = g.usize_in(0, 200);
        let inputs: Vec<Vec<f64>> = (0..p).map(|_| g.vec_normal(n)).collect();
        let want: Vec<f64> = (0..n)
            .map(|i| inputs.iter().map(|v| v[i]).sum())
            .collect();
        let inputs2 = inputs.clone();
        let results = run_group(p, move |c| {
            let mut buf = inputs2[c.rank()].clone();
            allreduce_sum(c, 7, &mut buf);
            buf
        });
        for got in results {
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()));
            }
        }
    });
}

#[test]
fn broadcast_from_random_root() {
    props(40, |g| {
        let p = g.usize_in(1, 7);
        let root = g.usize_in(0, p - 1);
        let n = g.usize_in(0, 64);
        let payload = g.vec_normal(n);
        let payload2 = payload.clone();
        let results = run_group(p, move |c| {
            let mut buf = if c.rank() == root { payload2.clone() } else { vec![] };
            broadcast(c, 9, root, &mut buf);
            buf
        });
        for got in results {
            assert_eq!(got, payload);
        }
    });
}

#[test]
fn reduce_then_scatter_then_allgather_chain() {
    props(25, |g| {
        let p = g.usize_in(1, 5);
        let n = g.usize_in(1, 32);
        let inputs: Vec<Vec<f64>> = (0..p).map(|_| g.vec_normal(n)).collect();
        let want_sum: Vec<f64> = (0..n)
            .map(|i| inputs.iter().map(|v| v[i]).sum())
            .collect();
        let inputs2 = inputs.clone();
        let results = run_group(p, move |c| {
            // reduce to root 0
            let mut buf = inputs2[c.rank()].clone();
            reduce_sum(c, 11, 0, &mut buf);
            // root scatters equal shares back (pad to p*n for evenness)
            let parts = if c.rank() == 0 {
                Some(vec![buf.clone(); c.size()])
            } else {
                None
            };
            let share = scatter(c, 12, 0, parts);
            // everyone allgathers their share
            let all = allgather(c, 13, share);
            (c.rank(), all)
        });
        for (_, all) in results {
            assert_eq!(all.len(), p);
            for part in all {
                for (a, b) in part.iter().zip(&want_sum) {
                    assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()));
                }
            }
        }
    });
}

#[test]
fn gather_preserves_rank_payloads() {
    props(30, |g| {
        let p = g.usize_in(1, 6);
        let sizes: Vec<usize> = (0..p).map(|_| g.usize_in(0, 20)).collect();
        let sizes2 = sizes.clone();
        let results = run_group(p, move |c| {
            let mine = vec![c.rank() as f64; sizes2[c.rank()]];
            gather(c, 15, 0, mine)
        });
        let root_view = results[0].as_ref().expect("root gathers");
        for (r, part) in root_view.iter().enumerate() {
            assert_eq!(part, &vec![r as f64; sizes[r]]);
        }
        for other in &results[1..] {
            assert!(other.is_none());
        }
    });
}

#[test]
fn concurrent_collectives_with_distinct_tags() {
    // two interleaved allreduces on different tag windows must not mix
    let results = run_group(4, |c| {
        let mut a = vec![c.rank() as f64; 16];
        let mut b = vec![(c.rank() * 10) as f64; 16];
        // interleave manually: start both, alternating chunks
        allreduce_sum(c, 0x1000, &mut a);
        allreduce_sum(c, 0x2000, &mut b);
        (a[0], b[0])
    });
    for (a, b) in results {
        assert_eq!(a, 6.0); // 0+1+2+3
        assert_eq!(b, 60.0);
    }
}
