#![allow(dead_code)] // each bench uses a subset of these helpers
//! Shared helpers for the paper-table benches (harness = false mains;
//! criterion is not in the offline vendor set).

use alchemist::cli::Args;
use alchemist::config::Config;

/// Paper iteration count for the 10k-feature CG run (§4.1: "CG takes
/// approximately 526 iterations"); totals are extrapolated to this count
/// from the measured per-iteration mean, exactly as a full run would cost.
pub const PAPER_CG_ITERS: usize = 526;

/// Build the bench config: defaults + `--engine` + `--set k=v,...`
/// overrides shared by all benches.
pub fn bench_config(args: &Args) -> alchemist::Result<Config> {
    let mut cfg = Config::default();
    if let Some(engine) = args.get("engine") {
        cfg.apply("engine", engine)?;
    }
    if let Some(pairs) = args.get("set") {
        for pair in pairs.split(',') {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--set expects k=v, got {pair:?}"))?;
            cfg.apply(k.trim(), v.trim())?;
        }
    }
    Ok(cfg)
}

/// `--quick` trims sweeps for smoke runs.
pub fn is_quick(args: &Args) -> bool {
    args.flag("quick")
}

pub fn require_artifacts(cfg: &Config) -> bool {
    let ok = cfg.resolved_artifacts_dir().join("manifest.txt").exists();
    if !ok {
        println!("SKIP: artifacts missing; run `make artifacts` first");
    }
    ok
}
