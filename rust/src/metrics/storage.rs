//! Storage-plane counters: spill/page-back activity per worker rank
//! (ROADMAP "out-of-core storage plane"). One [`StorageMetrics`] lives in
//! each rank's `MatrixStore`; every update is a lock-free atomic.
//! [`ServerHandle::storage_metrics`] sums the per-rank snapshots, which
//! is how tests (and the `ocean_svd_outofcore` acceptance run) prove
//! blocks actually cycled to disk and back.

use std::sync::atomic::{AtomicU64, Ordering};

/// Cumulative storage-plane counters for one worker rank's store.
#[derive(Debug, Default)]
pub struct StorageMetrics {
    /// Sealed blocks written out to the rank's spill file.
    blocks_spilled: AtomicU64,
    /// Payload bytes those spills moved to disk.
    bytes_spilled: AtomicU64,
    /// Spilled blocks promoted back to heap residency (whole-block
    /// page-in when the session's budget has room again).
    blocks_paged_in: AtomicU64,
    /// Payload bytes page-ins moved back to the heap.
    bytes_paged_in: AtomicU64,
    /// Bytes served *transiently* from the spill file (span reads that
    /// stream through a bounded buffer without promoting the block —
    /// the out-of-core read path).
    bytes_read_spilled: AtomicU64,
    /// mmap-backed blocks registered by direct `LoadMatrix` ingest.
    blocks_mapped: AtomicU64,
}

/// Point-in-time copy (plain data; [`merge`](StorageSnapshot::merge)
/// sums across ranks).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StorageSnapshot {
    pub blocks_spilled: u64,
    pub bytes_spilled: u64,
    pub blocks_paged_in: u64,
    pub bytes_paged_in: u64,
    pub bytes_read_spilled: u64,
    pub blocks_mapped: u64,
}

impl StorageSnapshot {
    /// Accumulate another rank's counters into this one.
    pub fn merge(&mut self, other: &StorageSnapshot) {
        self.blocks_spilled += other.blocks_spilled;
        self.bytes_spilled += other.bytes_spilled;
        self.blocks_paged_in += other.blocks_paged_in;
        self.bytes_paged_in += other.bytes_paged_in;
        self.bytes_read_spilled += other.bytes_read_spilled;
        self.blocks_mapped += other.blocks_mapped;
    }

    /// True iff at least one block went to disk AND bytes came back off
    /// the spill file (page-in or streaming read) — the "cycled to disk
    /// and back" proof the out-of-core acceptance run asserts.
    pub fn cycled(&self) -> bool {
        self.blocks_spilled > 0
            && (self.bytes_paged_in > 0 || self.bytes_read_spilled > 0)
    }
}

impl StorageMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn spilled(&self, bytes: u64) {
        self.blocks_spilled.fetch_add(1, Ordering::Relaxed);
        self.bytes_spilled.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn paged_in(&self, bytes: u64) {
        self.blocks_paged_in.fetch_add(1, Ordering::Relaxed);
        self.bytes_paged_in.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn read_spilled(&self, bytes: u64) {
        self.bytes_read_spilled.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn mapped_block(&self) {
        self.blocks_mapped.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StorageSnapshot {
        StorageSnapshot {
            blocks_spilled: self.blocks_spilled.load(Ordering::Relaxed),
            bytes_spilled: self.bytes_spilled.load(Ordering::Relaxed),
            blocks_paged_in: self.blocks_paged_in.load(Ordering::Relaxed),
            bytes_paged_in: self.bytes_paged_in.load(Ordering::Relaxed),
            bytes_read_spilled: self.bytes_read_spilled.load(Ordering::Relaxed),
            blocks_mapped: self.blocks_mapped.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_merge() {
        let m = StorageMetrics::new();
        m.spilled(100);
        m.spilled(50);
        m.paged_in(100);
        m.read_spilled(30);
        m.mapped_block();
        let s = m.snapshot();
        assert_eq!(s.blocks_spilled, 2);
        assert_eq!(s.bytes_spilled, 150);
        assert_eq!(s.blocks_paged_in, 1);
        assert_eq!(s.bytes_paged_in, 100);
        assert_eq!(s.bytes_read_spilled, 30);
        assert_eq!(s.blocks_mapped, 1);
        assert!(s.cycled());

        let mut total = StorageSnapshot::default();
        assert!(!total.cycled());
        total.merge(&s);
        total.merge(&s);
        assert_eq!(total.bytes_spilled, 300);
        assert_eq!(total.blocks_mapped, 2);
    }

    #[test]
    fn cycled_requires_both_directions() {
        let m = StorageMetrics::new();
        m.spilled(10);
        assert!(!m.snapshot().cycled()); // went out, never came back
        m.read_spilled(10);
        assert!(m.snapshot().cycled());
    }
}
