//! Tall-skinny distributed QR via CholeskyQR2 — the library routine behind
//! the paper's Figure 2 API example (`QRDecomposition(alA)`).
//!
//! CholeskyQR: `G = AᵀA` (one allreduce), `G = RᵀR`, `Q = A·R⁻¹`.
//! Repeating once (CholeskyQR2) restores orthogonality to machine
//! precision for the condition numbers these workloads see.

use crate::collectives::{allreduce_sum, Communicator};
use crate::compute::{Engine, GemmVariant};
use crate::distmat::LocalMatrix;

use super::dense::{cholesky_upper, matmul, solve_right_upper};

const TAG: u64 = 0x5152_0000;

/// One CholeskyQR pass: returns (Q_local, R).
fn cholesky_qr_once(
    comm: &dyn Communicator,
    engine: &mut dyn Engine,
    a_local: &LocalMatrix,
    tag: u64,
) -> crate::Result<(LocalMatrix, LocalMatrix)> {
    let k = a_local.cols();
    let mut g = LocalMatrix::zeros(k, k);
    engine.gemm(GemmVariant::TN, &mut g, a_local, a_local)?;
    allreduce_sum(comm, tag, g.data_mut())?;
    let r = cholesky_upper(&g)?;
    let q = solve_right_upper(a_local, &r)?;
    Ok((q, r))
}

/// SPMD CholeskyQR2 of a row-distributed tall matrix. Returns this rank's
/// rows of Q plus the (replicated) upper-triangular R with `A = Q·R`.
pub fn cholesky_qr2(
    comm: &dyn Communicator,
    engine: &mut dyn Engine,
    a_local: &LocalMatrix,
) -> crate::Result<(LocalMatrix, LocalMatrix)> {
    let (q1, r1) = cholesky_qr_once(comm, engine, a_local, TAG)?;
    let (q2, r2) =
        cholesky_qr_once(comm, engine, &q1, TAG + crate::collectives::TAG_WINDOW)?;
    let r = matmul(&r2, &r1);
    Ok((q2, r))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::LocalComm;
    use crate::compute::NativeEngine;
    use crate::distmat::RowBlockLayout;
    use crate::util::prng::Rng;

    fn check_qr(n: usize, k: usize, workers: usize) {
        let mut rng = Rng::new(17);
        let a = LocalMatrix::from_fn(n, k, |_, _| rng.normal());
        let layout = RowBlockLayout::even(n, k, workers);
        let comms = LocalComm::group(workers, None);
        let mut handles = Vec::new();
        for comm in comms {
            let (ra, rb) = layout.ranges[comm.rank()];
            let local = a.slice_rows(ra, rb);
            handles.push(std::thread::spawn(move || {
                let (q, r) = cholesky_qr2(&comm, &mut NativeEngine::new(), &local).unwrap();
                (comm.rank(), q, r)
            }));
        }
        let mut results: Vec<(usize, LocalMatrix, LocalMatrix)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        results.sort_by_key(|(r, _, _)| *r);

        // reassemble Q
        let mut q = LocalMatrix::zeros(n, k);
        for (rank, ql, _) in &results {
            q.write_rows(layout.ranges[*rank].0, ql);
        }
        let r = &results[0].2;

        // A = Q R
        let mut qr = LocalMatrix::zeros(n, k);
        qr.gemm_nn(&q, r);
        assert!(qr.max_abs_diff(&a) < 1e-9, "reconstruction");

        // QᵀQ = I
        let mut qtq = LocalMatrix::zeros(k, k);
        qtq.gemm_tn(&q, &q);
        assert!(qtq.max_abs_diff(&LocalMatrix::identity(k)) < 1e-10, "orthogonality");

        // R upper-triangular with positive diagonal
        for i in 0..k {
            assert!(r.get(i, i) > 0.0);
            for j in 0..i {
                assert_eq!(r.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn qr_serial_and_distributed() {
        check_qr(30, 5, 1);
        check_qr(48, 8, 3);
        check_qr(64, 16, 4);
    }

    #[test]
    fn rank_deficient_reported() {
        // duplicate columns -> Gram matrix singular -> clear error
        let a = LocalMatrix::from_fn(10, 2, |i, _| i as f64);
        let comms = LocalComm::group(1, None);
        assert!(cholesky_qr2(&comms[0], &mut NativeEngine::new(), &a).is_err());
    }
}
