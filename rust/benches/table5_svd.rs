//! Table 5: rank-20 truncated SVD of the ocean data, three use cases.
//!
//! Paper: 400 GB CFSR subset, 12 nodes for whichever system computes;
//! totals 553.1 s (Spark) vs 121.9 s (Spark-load) vs 69.7 s
//! (Alchemist-load) — speedups 4.5× and 7.9×. Here the field scales to
//! `--cells × --times` and the case ordering + rough factors are the
//! targets. (This bench drives the same code path as
//! `examples/ocean_svd.rs`, reduced to the paper's exact row format.)

mod bench_common;

use alchemist::cli::Args;
use alchemist::client::AlchemistContext;
use alchemist::coordinator::AlchemistServer;
use alchemist::linalg::SvdOptions;
use alchemist::metrics::Table;
use alchemist::protocol::Params;
use alchemist::sparklite::{mllib, IndexedRow, IndexedRowMatrix, Rdd, SparkEngine};
use alchemist::workloads::OceanSpec;
use bench_common::{bench_config, is_quick, require_artifacts};

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let cfg = bench_config(&args)?;
    if !require_artifacts(&cfg) {
        return Ok(());
    }
    let quick = is_quick(&args);
    let cells = args.get_usize("cells", if quick { 2048 } else { 8192 })?;
    let times = args.get_usize("times", if quick { 512 } else { 1024 })?;
    let rank = args.get_usize("rank", 20)?;
    let steps = args.get_usize("steps", if quick { 32 } else { 48 })?;
    let workers = args.get_usize("workers", 3)?;

    let spec = OceanSpec { cells, times, ..OceanSpec::default() };
    let dir = std::env::temp_dir().join("alchemist-ocean");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("ocean_{cells}x{times}.bin"));
    if !path.exists() {
        spec.write_file(&path)?;
    }
    let opts = SvdOptions { rank, steps, seed: 0x53D5 };

    let mut table = Table::new(
        &format!("Table 5 (scaled): rank-{rank} SVD of {cells}x{times} ocean field"),
        &[
            "S", "A", "load (s)", "S=>A (s)", "svd (s)", "S<=A (s)",
            "total (s)", "svd sim (s)",
        ],
    );
    let mut totals = Vec::new();

    // ---- case 1: Spark everything ----
    {
        let mut engine = SparkEngine::new(workers, &cfg);
        let ranges = alchemist::util::even_ranges(cells, workers * 2);
        let t0 = std::time::Instant::now();
        let parts = engine.run_stage("load", &ranges, |_, &(a, b)| {
            let m = alchemist::hdf5sim::read_rows(&path, a, b).unwrap();
            (a, m)
        });
        let load_secs = t0.elapsed().as_secs_f64();
        let mut rows = Vec::new();
        for (start, m) in parts {
            for i in 0..m.rows() {
                rows.push(IndexedRow { index: (start + i) as u64, vector: m.row(i).to_vec() });
            }
        }
        let irm = IndexedRowMatrix {
            rdd: Rdd::parallelize(rows, workers * 2),
            rows: cells,
            cols: times,
        };
        let sim0 = engine.sim_elapsed_secs();
        let t1 = std::time::Instant::now();
        let _res = mllib::truncated_svd(&mut engine, &irm, &opts)?;
        let svd_secs = t1.elapsed().as_secs_f64();
        let sim_svd = engine.sim_elapsed_secs() - sim0;
        totals.push(svd_secs);
        table.row(&[
            workers.to_string(),
            "0".into(),
            format!("{load_secs:.2}"),
            "NA".into(),
            format!("{svd_secs:.2}"),
            "NA".into(),
            format!("{svd_secs:.2}"),
            format!("{sim_svd:.2}"),
        ]);
    }

    let server = AlchemistServer::start(cfg.clone(), workers)?;

    // ---- case 2: Spark load, Alchemist compute ----
    {
        let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, workers)?;
        ac.register_library("elemental", "builtin:elemental")?;
        let t0 = std::time::Instant::now();
        let a = alchemist::hdf5sim::read_matrix(&path)?;
        let irm = IndexedRowMatrix::from_local(&a, workers * 2);
        let load_secs = t0.elapsed().as_secs_f64();
        let (al_a, push) = ac.send_matrix("A", &irm)?;
        let res = ac.run_task(
            "elemental",
            "truncated_svd",
            Params::new()
                .with_matrix("A", al_a.id)
                .with_i64("rank", rank as i64)
                .with_i64("steps", steps as i64),
        )?;
        let (_, su) = ac.to_indexed_row_matrix(res.output("U")?, workers)?;
        let (_, sv) = ac.to_indexed_row_matrix(res.output("V")?, 1)?;
        let svd_secs = res.timing("compute");
        let back = su.secs + sv.secs;
        let total = push.secs + svd_secs + back;
        totals.push(total);
        table.row(&[
            workers.to_string(),
            workers.to_string(),
            format!("{load_secs:.2}"),
            format!("{:.2}", push.secs),
            format!("{svd_secs:.2}"),
            format!("{back:.2}"),
            format!("{total:.2}"),
            format!("{:.2}", res.timing("sim_secs")),
        ]);
        ac.stop();
    }

    // ---- case 3: Alchemist load + compute ----
    {
        let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 2)?;
        ac.register_library("elemental", "builtin:elemental")?;
        let load = ac.run_task(
            "elemental",
            "load_hdf5",
            Params::new().with_str("path", path.to_str().unwrap()),
        )?;
        let al_a = load.output("A")?.clone();
        let res = ac.run_task(
            "elemental",
            "truncated_svd",
            Params::new()
                .with_matrix("A", al_a.id)
                .with_i64("rank", rank as i64)
                .with_i64("steps", steps as i64),
        )?;
        let (_, su) = ac.to_indexed_row_matrix(res.output("U")?, 2)?;
        let (_, sv) = ac.to_indexed_row_matrix(res.output("V")?, 1)?;
        let svd_secs = res.timing("compute");
        let back = su.secs + sv.secs;
        let total = svd_secs + back;
        totals.push(total);
        table.row(&[
            "2".into(),
            workers.to_string(),
            format!("{:.2}", load.timing("load")),
            "NA".into(),
            format!("{svd_secs:.2}"),
            format!("{back:.2}"),
            format!("{total:.2}"),
            format!("{:.2}", res.timing("sim_secs")),
        ]);
        ac.shutdown_server()?;
    }
    server.shutdown_on_request();

    table.print();
    if totals.len() == 3 {
        println!(
            "speedups vs Spark-only: case2 {:.1}x, case3 {:.1}x  (paper: 4.5x, 7.9x)",
            totals[0] / totals[1],
            totals[0] / totals[2]
        );
    }
    Ok(())
}
