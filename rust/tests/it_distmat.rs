//! Property tests: layouts, local matrix algebra, and store invariants.

use alchemist::coordinator::MatrixStore;
use alchemist::distmat::{LocalMatrix, RowBlockLayout};
use alchemist::testkit::{props, Gen};

fn random_matrix(g: &mut Gen, r: usize, c: usize) -> LocalMatrix {
    let data = g.vec_normal(r * c);
    LocalMatrix::from_data(r, c, data)
}

#[test]
fn layout_partitions_rows_exactly_once() {
    props(200, |g| {
        let rows = g.usize_in(1, 5000);
        let cols = g.usize_in(1, 64);
        let workers = g.usize_in(1, 16);
        let l = RowBlockLayout::even(rows, cols, workers);
        l.validate().unwrap();
        assert_eq!(l.workers(), workers);
        // sizes balanced within 1
        let sizes: Vec<usize> = l.ranges.iter().map(|&(a, b)| b - a).collect();
        let min = *sizes.iter().min().unwrap();
        let max = *sizes.iter().max().unwrap();
        assert!(max - min <= 1);
        // owner_of agrees with ranges at boundaries
        for &(a, b) in &l.ranges {
            if a < b {
                let r0 = l.owner_of(a);
                let r1 = l.owner_of(b - 1);
                assert_eq!(l.ranges[r0].0, a);
                assert_eq!(l.ranges[r1].1, b);
            }
        }
        // wire roundtrip
        assert_eq!(RowBlockLayout::from_wire(rows as u64, cols as u64, &l.to_wire()).unwrap(), l);
    });
}

#[test]
fn gemm_variants_agree_on_random_shapes() {
    props(40, |g| {
        let m = g.usize_in(1, 40);
        let n = g.usize_in(1, 40);
        let k = g.usize_in(1, 40);
        let a = random_matrix(g, m, k);
        let b = random_matrix(g, k, n);
        let mut c_nn = LocalMatrix::zeros(m, n);
        c_nn.gemm_nn(&a, &b);
        let mut c_tn = LocalMatrix::zeros(m, n);
        c_tn.gemm_tn(&a.transpose(), &b);
        let mut c_nt = LocalMatrix::zeros(m, n);
        c_nt.gemm_nt(&a, &b.transpose());
        assert!(c_nn.max_abs_diff(&c_tn) < 1e-10);
        assert!(c_nn.max_abs_diff(&c_nt) < 1e-10);
    });
}

#[test]
fn pad_shrink_tile_invariants() {
    props(100, |g| {
        let r = g.usize_in(1, 30);
        let c = g.usize_in(1, 30);
        let a = random_matrix(g, r, c);
        let pr = r + g.usize_in(0, 20);
        let pc = c + g.usize_in(0, 20);
        let p = a.padded(pr, pc);
        assert_eq!(p.shrunk(r, c), a);
        assert!((p.fro_sq() - a.fro_sq()).abs() < 1e-9);
        let times = g.usize_in(1, 4);
        let t = a.tile_cols(times);
        assert_eq!(t.cols(), c * times);
        assert!((t.fro_sq() - times as f64 * a.fro_sq()).abs() < 1e-6 * (1.0 + a.fro_sq()));
    });
}

#[test]
fn store_ingest_covers_matrix_in_any_order() {
    props(60, |g| {
        let rows = g.usize_in(1, 200);
        let cols = g.usize_in(1, 8);
        let workers = g.usize_in(1, 4);
        let layout = RowBlockLayout::even(rows, cols, workers);
        let full = random_matrix(g, rows, cols);

        // build stores, write each row to its owner in shuffled order
        let mut stores: Vec<MatrixStore> =
            (0..workers).map(MatrixStore::new).collect();
        for (slot, s) in stores.iter_mut().enumerate() {
            // slot = the store's group-local rank in this layout
            s.alloc(1, "X", layout.clone(), slot, 1).unwrap();
        }
        let mut order: Vec<usize> = (0..rows).collect();
        // shuffle via Gen
        for i in (1..order.len()).rev() {
            let j = g.usize_in(0, i);
            order.swap(i, j);
        }
        for &i in &order {
            let owner = layout.owner_of(i);
            stores[owner]
                .write_rows(1, i as u64, cols, full.row(i))
                .unwrap();
        }
        // seal: counts add up
        let total: u64 = stores.iter_mut().map(|s| s.seal(1).unwrap()).sum();
        assert_eq!(total, rows as u64);
        // read back via global coordinates
        for &i in order.iter().take(20) {
            let owner = layout.owner_of(i);
            assert_eq!(stores[owner].read_rows(1, i as u64, 1).unwrap(), full.row(i));
        }
    });
}

#[test]
fn col_dots_and_axpy_linearity() {
    props(100, |g| {
        let r = g.usize_in(1, 30);
        let c = g.usize_in(1, 10);
        let a = random_matrix(g, r, c);
        let b = random_matrix(g, r, c);
        let alpha = g.f64_in(-3.0, 3.0);
        // <a + alpha b, a + alpha b> per column == aa + 2 alpha ab + alpha^2 bb
        let mut apb = a.clone();
        apb.axpy(alpha, &b);
        let lhs = apb.col_dots(&apb);
        let aa = a.col_dots(&a);
        let ab = a.col_dots(&b);
        let bb = b.col_dots(&b);
        for j in 0..c {
            let rhs = aa[j] + 2.0 * alpha * ab[j] + alpha * alpha * bb[j];
            assert!((lhs[j] - rhs).abs() < 1e-8 * (1.0 + rhs.abs()));
        }
    });
}
