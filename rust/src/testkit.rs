//! Mini property-testing runner (proptest is not in the offline vendor
//! set). Deterministic, seed-addressable, with failure reporting that
//! names the seed so a case can be replayed:
//!
//! ```no_run
//! use alchemist::testkit::{props, Gen};
//! props(100, |g| {
//!     let n = g.usize_in(1, 50);
//!     let xs = g.vec_f64(n, -1.0, 1.0);
//!     assert!(xs.len() == n);
//! });
//! ```

use crate::util::prng::Rng;

/// Synthesize an artifact set under `dir` so tests can exercise the XLA
/// engines without `make artifacts`: only `manifest.txt` is written — the
/// PJRT stand-in derives each computation from the manifest entry's op +
/// shapes ([`crate::runtime::pjrtsim`]), never from the HLO payloads.
///
/// Exports, for both the `xla` and `pallas` families:
/// * `gemm_{nn,tn,nt}` at `tile`³;
/// * `gram_matvec` at `(panel_rows, panel_k, panel_c)`;
/// * `rff_expand` at `(panel_rows, panel_k, panel_k)` (Ω padded square);
/// * `cg_update` at `(panel_rows, panel_c)`.
pub fn write_sim_artifacts(
    dir: &std::path::Path,
    tile: usize,
    panel_rows: usize,
    panel_k: usize,
    panel_c: usize,
) -> crate::Result<()> {
    use std::fmt::Write as _;
    let mut text = String::from("# synthesized by testkit::write_sim_artifacts\n");
    let (t, pm, pk, pc) = (tile, panel_rows, panel_k, panel_c);
    for family in ["xla", "pallas"] {
        for op in ["gemm_nn", "gemm_tn", "gemm_nt"] {
            writeln!(
                text,
                "name={family}_{op}_{t}x{t}x{t} op={op} engine={family} \
                 dtype=f64 dims={t},{t},{t} inputs={t}x{t};{t}x{t};{t}x{t} \
                 outputs={t}x{t} sha=sim"
            )
            .expect("write to String");
        }
        writeln!(
            text,
            "name={family}_gram_matvec_{pm}x{pk}x{pc} op=gram_matvec \
             engine={family} dtype=f64 dims={pm},{pk},{pc} \
             inputs={pm}x{pk};{pk}x{pc};1x1 outputs={pk}x{pc} sha=sim"
        )
        .expect("write to String");
        writeln!(
            text,
            "name={family}_rff_expand_{pm}x{pk}x{pk} op=rff_expand \
             engine={family} dtype=f64 dims={pm},{pk},{pk} \
             inputs={pm}x{pk};{pk}x{pk};1x{pk};1x1 outputs={pm}x{pk} sha=sim"
        )
        .expect("write to String");
        writeln!(
            text,
            "name={family}_cg_update_{pm}x{pc} op=cg_update engine={family} \
             dtype=f64 dims={pm},{pc} \
             inputs={pm}x{pc};{pm}x{pc};{pm}x{pc};{pm}x{pc};1x{pc} \
             outputs={pm}x{pc};{pm}x{pc} sha=sim"
        )
        .expect("write to String");
    }
    std::fs::create_dir_all(dir)
        .map_err(|e| anyhow::anyhow!("creating {dir:?}: {e}"))?;
    std::fs::write(dir.join("manifest.txt"), text)
        .map_err(|e| anyhow::anyhow!("writing manifest to {dir:?}: {e}"))?;
    Ok(())
}

/// Generator handed to each property case.
pub struct Gen {
    rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        assert!(hi_inclusive >= lo);
        lo + self.rng.below(hi_inclusive - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// ASCII identifier-ish string.
    pub fn ident(&mut self, max_len: usize) -> String {
        let n = self.usize_in(1, max_len.max(1));
        (0..n)
            .map(|_| (b'a' + self.rng.below(26) as u8) as char)
            .collect()
    }
}

/// Run `cases` property cases with the default seed.
pub fn props(cases: usize, f: impl FnMut(&mut Gen)) {
    props_seeded(0xA1C4_E5D1, cases, f)
}

/// Run `cases` property cases; each case gets an independent stream so a
/// failure report's `(seed, case)` pair replays exactly.
pub fn props_seeded(seed: u64, cases: usize, mut f: impl FnMut(&mut Gen)) {
    let env_seed = std::env::var("ALCHEMIST_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(seed);
    let base = Rng::new(env_seed);
    for case in 0..cases {
        let mut g = Gen { rng: base.derive(case as u64), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut g)
        }));
        if let Err(payload) = result {
            eprintln!(
                "property failed at seed={env_seed:#x} case={case} \
                 (replay: ALCHEMIST_PROP_SEED={env_seed})"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_in_range() {
        props(200, |g| {
            let n = g.usize_in(3, 9);
            assert!((3..=9).contains(&n));
            let x = g.f64_in(-2.0, 5.0);
            assert!((-2.0..5.0).contains(&x));
            let v = g.vec_f64(n, 0.0, 1.0);
            assert_eq!(v.len(), n);
            let s = g.ident(8);
            assert!(!s.is_empty() && s.len() <= 8);
            let pick = *g.choose(&[1, 2, 3]);
            assert!([1, 2, 3].contains(&pick));
        });
    }

    #[test]
    fn cases_are_independent_streams() {
        let mut first = Vec::new();
        props(5, |g| {
            // same call pattern in every case must still differ across cases
            first.push(g.u64());
        });
        let unique: std::collections::HashSet<_> = first.iter().collect();
        assert_eq!(unique.len(), first.len());
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        props(10, |g| {
            assert!(g.case < 5, "deliberate failure");
        });
    }
}
