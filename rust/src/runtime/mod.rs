//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! This is the only module that touches the `xla` crate. A [`Runtime`]
//! owns one PJRT CPU client plus a lazily-compiled executable cache keyed
//! by artifact name; `compute::XlaEngine` resolves (op, engine, dims) →
//! artifact through the [`manifest`] and calls [`Runtime::run`].
//!
//! PJRT wrapper types hold raw pointers and are not `Send`, so each worker
//! thread owns its own `Runtime` — the same shape as MPI ranks each
//! holding their own library context (and on this one-core box there is no
//! parallelism to lose).
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`), never
//! serialized protos — see `python/compile/aot.py` for why.

pub mod manifest;

pub use manifest::{ArtifactEntry, Manifest};

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::Context;

/// An executed output: flat row-major data plus its shape.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f64>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f64>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data }
    }
}

/// An operand resident on the PJRT device — upload once, execute many
/// (§Perf: re-uploading the static Gram panel every CG iteration was the
/// top bottleneck before buffer caching).
pub struct DeviceBuf {
    buf: xla::PjRtBuffer,
    pub dims: Vec<usize>,
}

impl DeviceBuf {
    pub fn bytes(&self) -> usize {
        self.dims.iter().product::<usize>() * 8
    }
}

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative seconds spent inside PJRT `execute` (perf accounting).
    pub exec_secs: f64,
    /// Number of `run` calls (perf accounting).
    pub exec_calls: u64,
}

impl Runtime {
    /// Load the manifest from `dir` and create the PJRT CPU client.
    /// Executables compile lazily on first use.
    pub fn load(dir: &std::path::Path) -> crate::Result<Self> {
        // silence TfrtCpuClient created/destroyed chatter unless the user
        // asked for it
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let manifest = Manifest::load(&dir.join("manifest.txt"))
            .with_context(|| format!("loading artifact manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: HashMap::new(),
            exec_secs: 0.0,
            exec_calls: 0,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the named artifact.
    fn executable(&mut self, name: &str) -> crate::Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let entry = self
                .manifest
                .by_name(name)
                .with_context(|| format!("artifact {name:?} not in manifest"))?;
            let path = self.dir.join(format!("{}.hlo.txt", entry.name));
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
            log::debug!(
                "compiled artifact {name} in {:.3}s",
                t0.elapsed().as_secs_f64()
            );
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute artifact `name` on the given inputs (shape-checked against
    /// the manifest). Returns the tuple elements as [`Tensor`]s.
    pub fn run(&mut self, name: &str, inputs: &[(&[f64], &[usize])]) -> crate::Result<Vec<Tensor>> {
        let entry = self
            .manifest
            .by_name(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))?
            .clone();
        anyhow::ensure!(
            inputs.len() == entry.in_shapes.len(),
            "artifact {name} wants {} inputs, got {}",
            entry.in_shapes.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, dims)) in inputs.iter().enumerate() {
            anyhow::ensure!(
                dims == &entry.in_shapes[i].as_slice(),
                "artifact {name} input {i}: want shape {:?}, got {dims:?}",
                entry.in_shapes[i]
            );
            anyhow::ensure!(
                data.len() == dims.iter().product::<usize>(),
                "artifact {name} input {i}: data/shape mismatch"
            );
            // Safety: f64 -> u8 reinterpretation; PJRT copies the bytes.
            let bytes = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 8)
            };
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F64,
                dims,
                bytes,
            )
            .map_err(|e| anyhow::anyhow!("building literal for {name} input {i}: {e}"))?;
            literals.push(lit);
        }

        let t0 = std::time::Instant::now();
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} output: {e}"))?;
        self.exec_secs += t0.elapsed().as_secs_f64();
        self.exec_calls += 1;

        // aot.py lowers with return_tuple=True: root is always a tuple.
        let elems = root
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name} output: {e}"))?;
        anyhow::ensure!(
            elems.len() == entry.out_shapes.len(),
            "artifact {name}: manifest promises {} outputs, got {}",
            entry.out_shapes.len(),
            elems.len()
        );
        let mut out = Vec::with_capacity(elems.len());
        for (lit, dims) in elems.into_iter().zip(&entry.out_shapes) {
            let data = lit
                .to_vec::<f64>()
                .map_err(|e| anyhow::anyhow!("reading {name} output: {e}"))?;
            out.push(Tensor::new(dims.clone(), data));
        }
        Ok(out)
    }

    /// Convenience for the common single-output case.
    pub fn run1(&mut self, name: &str, inputs: &[(&[f64], &[usize])]) -> crate::Result<Tensor> {
        let mut out = self.run(name, inputs)?;
        anyhow::ensure!(out.len() == 1, "artifact {name} has {} outputs", out.len());
        Ok(out.pop().unwrap())
    }

    /// Upload an operand to the device once; reuse across many executions
    /// (static operands like the CG Gram panel — §Perf).
    pub fn upload(&self, data: &[f64], dims: &[usize]) -> crate::Result<DeviceBuf> {
        let buf = self
            .client
            .buffer_from_host_buffer::<f64>(data, dims, None)
            .map_err(|e| anyhow::anyhow!("uploading operand: {e}"))?;
        Ok(DeviceBuf { buf, dims: dims.to_vec() })
    }

    /// Execute with device-resident operands (single-output artifacts).
    pub fn run1_b(&mut self, name: &str, inputs: &[&DeviceBuf]) -> crate::Result<Tensor> {
        let entry = self
            .manifest
            .by_name(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))?
            .clone();
        anyhow::ensure!(
            inputs.len() == entry.in_shapes.len(),
            "artifact {name} wants {} inputs, got {}",
            entry.in_shapes.len(),
            inputs.len()
        );
        for (i, b) in inputs.iter().enumerate() {
            anyhow::ensure!(
                b.dims == entry.in_shapes[i],
                "artifact {name} input {i}: want shape {:?}, got {:?}",
                entry.in_shapes[i],
                b.dims
            );
        }
        let t0 = std::time::Instant::now();
        let exe = self.executable(name)?;
        let bufs: Vec<&xla::PjRtBuffer> = inputs.iter().map(|b| &b.buf).collect();
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&bufs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} output: {e}"))?;
        self.exec_secs += t0.elapsed().as_secs_f64();
        self.exec_calls += 1;
        let elems = root
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name} output: {e}"))?;
        anyhow::ensure!(elems.len() == 1, "run1_b expects a single output");
        let data = elems[0]
            .to_vec::<f64>()
            .map_err(|e| anyhow::anyhow!("reading {name} output: {e}"))?;
        Ok(Tensor::new(entry.out_shapes[0].clone(), data))
    }
}
