//! Artifact manifest parsing (`artifacts/manifest.txt`, written by
//! `python/compile/aot.py` as whitespace-separated `key=value` lines).

use std::path::Path;

use anyhow::Context;

/// One AOT artifact as described by the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    /// Semantic op (`gemm_nn`, `gram_matvec`, `rff_expand`, `cg_update`...)
    pub op: String,
    /// Lowering engine: `pallas` (interpret-mode kernels) or `xla` (jnp).
    pub engine: String,
    /// Op-specific dimension tuple (gemm: m,n,k; gram: m,k,c; ...).
    pub dims: Vec<usize>,
    pub in_shapes: Vec<Vec<usize>>,
    pub out_shapes: Vec<Vec<usize>>,
    pub sha: String,
}

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    entries: Vec<ArtifactEntry>,
}

fn parse_shape_list(s: &str) -> crate::Result<Vec<Vec<usize>>> {
    s.split(';')
        .map(|shape| {
            shape
                .split('x')
                .map(|d| d.parse::<usize>().context("bad shape dim"))
                .collect()
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> crate::Result<Self> {
        let mut entries = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut kv = std::collections::BTreeMap::new();
            for tok in line.split_whitespace() {
                let (k, v) = tok
                    .split_once('=')
                    .with_context(|| format!("manifest line {}: token {tok:?}", lineno + 1))?;
                kv.insert(k.to_string(), v.to_string());
            }
            let get = |k: &str| -> crate::Result<String> {
                kv.get(k)
                    .cloned()
                    .with_context(|| format!("manifest line {}: missing {k}", lineno + 1))
            };
            anyhow::ensure!(
                get("dtype")? == "f64",
                "manifest line {}: only f64 artifacts supported",
                lineno + 1
            );
            entries.push(ArtifactEntry {
                name: get("name")?,
                op: get("op")?,
                engine: get("engine")?,
                dims: get("dims")?
                    .split(',')
                    .map(|d| d.parse().context("bad dim"))
                    .collect::<crate::Result<_>>()?,
                in_shapes: parse_shape_list(&get("inputs")?)?,
                out_shapes: parse_shape_list(&get("outputs")?)?,
                sha: kv.get("sha").cloned().unwrap_or_default(),
            });
        }
        anyhow::ensure!(!entries.is_empty(), "manifest has no artifacts");
        Ok(Manifest { entries })
    }

    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Resolve by semantics: op + engine + exact dims.
    pub fn find(&self, op: &str, engine: &str, dims: &[usize]) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.op == op && e.engine == engine && e.dims == dims)
    }

    /// All dims available for (op, engine) — engines pick the best match.
    pub fn dims_for(&self, op: &str, engine: &str) -> Vec<Vec<usize>> {
        self.entries
            .iter()
            .filter(|e| e.op == op && e.engine == engine)
            .map(|e| e.dims.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
name=xla_gemm_nn_256x256x256 op=gemm_nn engine=xla dtype=f64 dims=256,256,256 inputs=256x256;256x256;256x256 outputs=256x256 sha=abc

name=pallas_cg_update_1024x32 op=cg_update engine=pallas dtype=f64 dims=1024,32 inputs=1024x32;1024x32;1024x32;1024x32;1x32 outputs=1024x32;1024x32
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries().len(), 2);
        let e = m.by_name("xla_gemm_nn_256x256x256").unwrap();
        assert_eq!(e.op, "gemm_nn");
        assert_eq!(e.dims, vec![256, 256, 256]);
        assert_eq!(e.in_shapes.len(), 3);
        assert_eq!(e.sha, "abc");
        let c = m.find("cg_update", "pallas", &[1024, 32]).unwrap();
        assert_eq!(c.out_shapes.len(), 2);
        assert_eq!(c.in_shapes[4], vec![1, 32]);
        assert!(m.find("cg_update", "xla", &[1024, 32]).is_none());
        assert_eq!(m.dims_for("gemm_nn", "xla"), vec![vec![256, 256, 256]]);
    }

    #[test]
    fn rejects_non_f64_and_garbage() {
        assert!(Manifest::parse("name=a op=b engine=c dtype=f32 dims=1 inputs=1 outputs=1").is_err());
        assert!(Manifest::parse("notakv").is_err());
        assert!(Manifest::parse("# only comments\n").is_err());
    }
}
