//! Synthetic ocean-temperature field (paper §4.2) — the CFSR substitute.
//!
//! The real data: global ocean temperature on a 0.5° grid at 40 depths,
//! six-hourly, Jan 1979 – mid 1984; as a matrix, one row per grid cell and
//! one column per time step (6,177,583 × 8,096, 400 GB). Climate fields
//! have strong low-rank structure (seasonal harmonics + trends + spatially
//! coherent modes) over spatially-correlated noise — that structure is
//! exactly why rank-20 truncated SVD is the paper's workload. The
//! generator builds `A = Σ_r σ_r·u_r·v_r(t) + ε` with smooth spatial modes
//! u_r, seasonal/trend temporal modes v_r, and a geometrically decaying
//! σ spectrum, so the truncated SVD has a meaningful, testable target.

use crate::distmat::LocalMatrix;
use crate::util::prng::Rng;

#[derive(Debug, Clone)]
pub struct OceanSpec {
    /// Grid cells (paper: 6,177,583).
    pub cells: usize,
    /// Time steps (paper: 8,096 for the 400 GB subset).
    pub times: usize,
    /// Number of structured modes.
    pub modes: usize,
    /// Leading singular value scale.
    pub sigma0: f64,
    /// Geometric spectrum decay per mode.
    pub decay: f64,
    /// White-noise floor.
    pub noise: f64,
    pub seed: u64,
}

impl Default for OceanSpec {
    fn default() -> Self {
        // ~1/512 of the 400 GB subset; bench configs scale further
        OceanSpec {
            cells: 16_384,
            times: 2_048,
            modes: 24,
            sigma0: 100.0,
            decay: 0.80,
            noise: 0.05,
            seed: 0x0CEA_0000,
        }
    }
}

impl OceanSpec {
    /// σ_r = sigma0 · decay^r for the structured modes.
    pub fn spectrum(&self) -> Vec<f64> {
        (0..self.modes)
            .map(|r| self.sigma0 * self.decay.powi(r as i32))
            .collect()
    }

    /// Generate rows `[row_start, row_end)` of the field — workers call
    /// this with their shard ranges, so the 17.6 TB-analog cases never
    /// materialize the full matrix in one place.
    pub fn generate_rows(&self, row_start: usize, row_end: usize) -> LocalMatrix {
        assert!(row_end <= self.cells && row_start <= row_end);
        let sigmas = self.spectrum();
        // temporal modes: seasonal harmonics with phase + slow trend
        let base = Rng::new(self.seed);
        let mut temporal = LocalMatrix::zeros(self.modes, self.times);
        for r in 0..self.modes {
            let mut mrng = base.derive(1_000 + r as u64);
            let freq = 1.0 + mrng.below(8) as f64; // cycles per "year"
            let phase = mrng.uniform_in(0.0, std::f64::consts::TAU);
            let trend = mrng.normal() * 0.1;
            let row = temporal.row_mut(r);
            let inv_norm = (2.0 / self.times as f64).sqrt();
            for (t, v) in row.iter_mut().enumerate() {
                let tt = t as f64 / self.times as f64;
                *v = inv_norm
                    * ((std::f64::consts::TAU * freq * tt + phase).sin()
                        + trend * (tt - 0.5));
            }
        }

        let mut out = LocalMatrix::zeros(row_end - row_start, self.times);
        for gi in row_start..row_end {
            // spatial weight of each mode at this cell: smooth in the cell
            // index (a 1-D stand-in for latitude bands) + per-cell jitter
            let mut cell_rng = base.derive(gi as u64);
            let li = gi - row_start;
            let pos = gi as f64 / self.cells as f64;
            let row = out.row_mut(li);
            for (r, sigma) in sigmas.iter().enumerate() {
                let spatial = ((r + 1) as f64 * std::f64::consts::PI * pos).sin()
                    * (2.0 / self.cells as f64).sqrt()
                    + 0.1 * cell_rng.normal() / (self.cells as f64).sqrt();
                let weight = sigma * spatial;
                let trow = temporal.row(r);
                for (t, v) in row.iter_mut().enumerate() {
                    *v += weight * trow[t];
                }
            }
            for v in row.iter_mut() {
                *v += self.noise * cell_rng.normal();
            }
        }
        out
    }

    /// Generate the full matrix (small configs only).
    pub fn generate(&self) -> LocalMatrix {
        self.generate_rows(0, self.cells)
    }

    /// Write the field to an `hdf5sim` file in row chunks (bounded
    /// memory), returning total bytes.
    pub fn write_file(&self, path: &std::path::Path) -> crate::Result<u64> {
        // materialize fully only when small; chunked writes otherwise
        let m = self.generate();
        crate::hdf5sim::write_matrix(path, &m)?;
        Ok((m.rows() * m.cols() * 8) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> OceanSpec {
        OceanSpec {
            cells: 256,
            times: 96,
            modes: 6,
            sigma0: 50.0,
            decay: 0.6,
            noise: 0.01,
            seed: 11,
        }
    }

    #[test]
    fn sharded_generation_matches_full() {
        let spec = small_spec();
        let full = spec.generate();
        let top = spec.generate_rows(0, 100);
        let bottom = spec.generate_rows(100, 256);
        assert_eq!(full.slice_rows(0, 100), top);
        assert_eq!(full.slice_rows(100, 256), bottom);
    }

    #[test]
    fn truncated_svd_captures_most_energy() {
        let spec = small_spec();
        let a = spec.generate();
        let comms = crate::collectives::LocalComm::group(1, None);
        let mut e = crate::compute::NativeEngine::new();
        let res = crate::linalg::truncated_svd(
            &comms[0],
            &mut e,
            &a,
            &crate::linalg::SvdOptions { rank: 6, steps: 40, seed: 2 },
        )
        .unwrap();
        let energy: f64 = res.sigma.iter().map(|s| s * s).sum();
        let total = a.fro_sq();
        assert!(
            energy / total > 0.95,
            "rank-6 captures {:.3} of energy",
            energy / total
        );
        // spectrum decays
        for w in res.sigma.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }
}
