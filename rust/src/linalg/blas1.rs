//! Shared level-1 vector kernels (dot / axpy / norm) for the iterative
//! solvers (cg, lanczos, qr) — unrolled into 4-lane `chunks_exact`
//! accumulators so LLVM emits straight-line vector FMA instead of a
//! single serial dependency chain.
//!
//! Determinism note: the 4-lane summation order is *fixed* (lanes
//! combined `(l0+l1) + (l2+l3)`, tail appended last), so every rank of an
//! SPMD solver computing a dot over replicated state gets the bit-same
//! answer — the same contract the engine's chunked reductions follow
//! (`docs/compute.md`).
//!
//! Like the GEMM micro-kernel, the hot loops carry runtime-dispatched
//! AVX2 variants (`crate::simd`) that map lane `j` of the fixed 4-lane
//! structure onto lane `j` of one 256-bit register and keep the identical
//! horizontal combine and unfused mul+add — bit-identical to the portable
//! path by construction. The 4-lane reduction shape pins the vector width
//! to 256 bits, so the (feature-gated) AVX-512 selection reuses the AVX2
//! variant here: an 8-lane dot would be a *different* (reassociated)
//! reduction, and these ops are memory-bound anyway.

/// Dot product with the fixed 4-lane reduction; dispatches to the widest
/// runnable variant for the calling thread.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if crate::simd::current() != crate::simd::Isa::Fallback {
        return dot_avx2(a, b);
    }
    dot_portable(a, b)
}

/// 4-lane unrolled portable dot product.
fn dot_portable(a: &[f64], b: &[f64]) -> f64 {
    let n4 = a.len() & !3;
    let mut lanes = [0.0f64; 4];
    for (x, y) in a[..n4].chunks_exact(4).zip(b[..n4].chunks_exact(4)) {
        lanes[0] += x[0] * y[0];
        lanes[1] += x[1] * y[1];
        lanes[2] += x[2] * y[2];
        lanes[3] += x[3] * y[3];
    }
    let mut tail = 0.0;
    for (x, y) in a[n4..].iter().zip(&b[n4..]) {
        tail += x * y;
    }
    (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]) + tail
}

#[cfg(target_arch = "x86_64")]
fn dot_avx2(a: &[f64], b: &[f64]) -> f64 {
    // SAFETY: `simd::current()` yields a non-fallback ISA only after
    // `is_x86_feature_detected!` confirmed avx2+fma on this host.
    unsafe { dot_avx2_impl(a, b) }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_avx2_impl(a: &[f64], b: &[f64]) -> f64 {
    use std::arch::x86_64::*;
    let n4 = a.len() & !3;
    // register lane j accumulates exactly what portable lane j does, in
    // the same order; mul+add unfused for bit-identity
    let mut acc = _mm256_setzero_pd();
    let mut i = 0;
    while i < n4 {
        let x = _mm256_loadu_pd(a.as_ptr().add(i));
        let y = _mm256_loadu_pd(b.as_ptr().add(i));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(x, y));
        i += 4;
    }
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
    let mut tail = 0.0;
    for (x, y) in a[n4..].iter().zip(&b[n4..]) {
        tail += x * y;
    }
    (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]) + tail
}

/// `y += alpha·x`; elementwise, so every variant is trivially
/// bit-identical to the naive loop.
pub fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    #[cfg(target_arch = "x86_64")]
    if crate::simd::current() != crate::simd::Isa::Fallback {
        // SAFETY: non-fallback ISA implies detected avx2+fma (see `dot`).
        unsafe { axpy_avx2_impl(y, alpha, x) };
        return;
    }
    axpy_portable(y, alpha, x);
}

/// 4-lane unrolled portable axpy.
fn axpy_portable(y: &mut [f64], alpha: f64, x: &[f64]) {
    let n4 = y.len() & !3;
    for (ys, xs) in y[..n4].chunks_exact_mut(4).zip(x[..n4].chunks_exact(4)) {
        ys[0] += alpha * xs[0];
        ys[1] += alpha * xs[1];
        ys[2] += alpha * xs[2];
        ys[3] += alpha * xs[3];
    }
    for (ys, xs) in y[n4..].iter_mut().zip(&x[n4..]) {
        *ys += alpha * xs;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_avx2_impl(y: &mut [f64], alpha: f64, x: &[f64]) {
    use std::arch::x86_64::*;
    let n4 = y.len() & !3;
    let al = _mm256_set1_pd(alpha);
    let mut i = 0;
    while i < n4 {
        let xs = _mm256_loadu_pd(x.as_ptr().add(i));
        let ys = _mm256_loadu_pd(y.as_ptr().add(i));
        // unfused mul+add, matching the portable path's rounding
        let r = _mm256_add_pd(ys, _mm256_mul_pd(al, xs));
        _mm256_storeu_pd(y.as_mut_ptr().add(i), r);
        i += 4;
    }
    for (ys, xs) in y[n4..].iter_mut().zip(&x[n4..]) {
        *ys += alpha * xs;
    }
}

/// Euclidean norm via [`dot`].
pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Scale to unit norm (no-op on the zero vector).
pub fn normalize(a: &mut [f64]) {
    let n = norm(a);
    if n > 0.0 {
        for x in a.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Kahan (compensated) dot product — the accuracy reference.
    fn kahan_dot(a: &[f64], b: &[f64]) -> f64 {
        let mut sum = 0.0f64;
        let mut c = 0.0f64;
        for (x, y) in a.iter().zip(b) {
            let term = x * y - c;
            let t = sum + term;
            c = (t - sum) - term;
            sum = t;
        }
        sum
    }

    #[test]
    fn dot_exact_on_integers_and_all_tail_lengths() {
        for n in 0..13usize {
            let a: Vec<f64> = (0..n).map(|i| (i + 1) as f64).collect();
            let b: Vec<f64> = (0..n).map(|i| (2 * i + 1) as f64).collect();
            let want: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert_eq!(dot(&a, &b), want, "n={n}");
        }
    }

    #[test]
    fn dot_accuracy_vs_kahan_on_adversarial_input() {
        // mixed magnitudes (1e-3 .. 1e3 spread per element) with sign
        // flips — heavy cancellation across lanes. The 4-lane sum must
        // stay within a few ULP-sums of the compensated reference:
        // |err| ≤ 1e-12 · Σ|aᵢbᵢ| is ~100x looser than the worst-case
        // n·ε bound for n ≈ 1000, so a regression to sloppier
        // accumulation (or a broken tail) trips it, while any correct
        // reassociation passes.
        let n = 1003usize;
        let a: Vec<f64> = (0..n)
            .map(|i| {
                let mag = 10f64.powi((i % 7) as i32 - 3);
                let sign = if (i / 3) % 2 == 0 { 1.0 } else { -1.0 };
                sign * mag * (1.0 + (i as f64) * 1e-4)
            })
            .collect();
        let b: Vec<f64> = (0..n)
            .map(|i| {
                let mag = 10f64.powi((i % 5) as i32 - 2);
                let sign = if (i / 7) % 2 == 0 { 1.0 } else { -1.0 };
                sign * mag * (2.0 - (i as f64) * 1e-4)
            })
            .collect();
        let want = kahan_dot(&a, &b);
        let got = dot(&a, &b);
        let scale: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
        assert!(
            (got - want).abs() <= 1e-12 * scale,
            "dot drifted from Kahan reference: got {got}, want {want} \
             (scale {scale})"
        );
    }

    #[test]
    fn isa_variants_bit_identical_to_portable() {
        use crate::simd::{available, with_isa, Isa};
        // all tail lengths around the 4-lane boundary plus a long
        // cancellation-heavy vector: every runnable ISA path must return
        // the exact bits of the portable path
        for n in [0usize, 1, 3, 4, 5, 8, 11, 1003] {
            let a: Vec<f64> = (0..n).map(|i| (i as f64 - 2.5) * 1.7e-3).collect();
            let b: Vec<f64> = (0..n).map(|i| (1.0 - i as f64) * 3.1e2).collect();
            let want_dot = with_isa(Isa::Fallback, || dot(&a, &b));
            let mut want_y = b.clone();
            with_isa(Isa::Fallback, || axpy(&mut want_y, -0.7, &a));
            for isa in available() {
                let got = with_isa(isa, || dot(&a, &b));
                assert_eq!(got.to_bits(), want_dot.to_bits(), "dot {} n={n}", isa.name());
                let mut y = b.clone();
                with_isa(isa, || axpy(&mut y, -0.7, &a));
                assert_eq!(y, want_y, "axpy {} n={n}", isa.name());
            }
        }
    }

    #[test]
    fn axpy_and_norm_match_naive() {
        let x: Vec<f64> = (0..11).map(|i| i as f64 * 0.5 - 2.0).collect();
        let mut y: Vec<f64> = (0..11).map(|i| 1.0 - i as f64 * 0.25).collect();
        let y0 = y.clone();
        axpy(&mut y, -1.5, &x);
        for i in 0..11 {
            assert_eq!(y[i], y0[i] + (-1.5) * x[i], "i={i}");
        }
        let want: f64 = x.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!((norm(&x) - want).abs() < 1e-12);
    }

    #[test]
    fn normalize_unit_and_zero_safe() {
        let mut v = vec![3.0, 4.0];
        normalize(&mut v);
        assert!((norm(&v) - 1.0).abs() < 1e-15);
        let mut z = vec![0.0; 5];
        normalize(&mut z);
        assert!(z.iter().all(|&x| x == 0.0));
    }
}
