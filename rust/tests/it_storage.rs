//! Out-of-core storage plane, end to end (protocol v7): direct mmap
//! ingest, per-session budgets with spill-to-disk, paneled SVD past the
//! budget, and clean teardown of everything the plane touched.
//!
//! Budgets here are deliberately tiny (kilobytes) so the spill machinery
//! is exercised on every CI run without large datasets.

use alchemist::client::AlchemistContext;
use alchemist::config::{Config, StorageConfig};
use alchemist::coordinator::{AlchemistServer, MatrixStore};
use alchemist::distmat::{LocalMatrix, RowBlockLayout};
use alchemist::linalg::SvdOptions;
use alchemist::metrics::StorageMetrics;
use alchemist::protocol::{Params, Value};
use alchemist::sparklite::IndexedRowMatrix;
use alchemist::workloads::{ocean_svd_outofcore, OceanSpec};
use std::sync::Arc;

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("alchemist-it-storage").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Direct `LoadMatrix` ingest of a file whose row count does not shard
/// evenly, zero payload bytes over the client link, exact roundtrip of
/// both the full pull and a column-range pull.
#[test]
fn direct_load_uneven_shards_roundtrip() {
    let spec = OceanSpec {
        cells: 257, // 3 workers -> uneven 86/86/85 shards
        times: 48,
        modes: 4,
        sigma0: 30.0,
        decay: 0.6,
        noise: 0.02,
        seed: 7,
    };
    let path = tmp_dir("direct").join("ocean.bin");
    spec.write_file(&path).unwrap();
    let want = alchemist::hdf5sim::read_matrix(&path).unwrap();

    let cfg = Config::default();
    let server = AlchemistServer::start(cfg.clone(), 3).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 2).unwrap();

    let (al_a, stats) = ac.load_matrix("A", path.to_str().unwrap()).unwrap();
    assert_eq!((al_a.rows, al_a.cols), (257, 48));
    assert_eq!(stats.bytes, 0, "direct ingest must move zero client payload bytes");

    let (full, pull) = ac.to_indexed_row_matrix(&al_a, 2).unwrap();
    assert_eq!(full.to_local().unwrap(), want);
    assert_eq!(pull.bytes, 257 * 48 * 8);

    // column-range pull: only the selected columns cross the wire
    let (sub, substats) = ac.to_indexed_row_matrix_cols(&al_a, 2, 5, 7).unwrap();
    let sub = sub.to_local().unwrap();
    assert_eq!((sub.rows(), sub.cols()), (257, 7));
    for i in 0..257 {
        for j in 0..7 {
            assert_eq!(sub.get(i, j), want.get(i, 5 + j));
        }
    }
    assert_eq!(substats.bytes, 257 * 7 * 8);

    // on platforms with the mmap path the blocks are registered mapped
    // (budget exempt); elsewhere the buffered fallback still serves them
    #[cfg(all(unix, target_endian = "little"))]
    assert!(server.storage_metrics().blocks_mapped >= 3);

    ac.free(&al_a).unwrap();
    ac.stop();
    server.shutdown();
}

/// Corrupt or truncated hdf5sim files are rejected driver-side, before
/// any worker registers a block.
#[test]
fn corrupt_file_rejected_before_any_block() {
    let dir = tmp_dir("corrupt");
    let bad_magic = dir.join("bad_magic.bin");
    std::fs::write(&bad_magic, b"NOTMAGIC\0\0\0\0\0\0\0\0junkjunkjunkjunk").unwrap();

    // valid header claiming 100x10, payload cut short
    let truncated = dir.join("truncated.bin");
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"ALCH5SIM");
    bytes.extend_from_slice(&1u32.to_le_bytes());
    bytes.extend_from_slice(&0u32.to_le_bytes());
    bytes.extend_from_slice(&100u64.to_le_bytes());
    bytes.extend_from_slice(&10u64.to_le_bytes());
    bytes.extend_from_slice(&[0u8; 128]); // 128 of the 8000 payload bytes
    std::fs::write(&truncated, bytes).unwrap();

    let cfg = Config::default();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 1).unwrap();

    for path in [&bad_magic, &truncated, &dir.join("does_not_exist.bin")] {
        let err = ac.load_matrix("A", path.to_str().unwrap());
        assert!(err.is_err(), "{path:?} must be rejected");
    }
    assert_eq!(server.total_blocks(), 0, "failed loads must register nothing");

    // the session is still healthy: a good load works afterwards
    let spec = OceanSpec { cells: 64, times: 16, modes: 2, ..OceanSpec::default() };
    let good = dir.join("good.bin");
    spec.write_file(&good).unwrap();
    let (al, _) = ac.load_matrix("A", good.to_str().unwrap()).unwrap();
    assert_eq!((al.rows, al.cols), (64, 16));
    ac.stop();
    server.shutdown();
}

/// The server-wide `storage.total_bytes` pool gates session admission:
/// a session whose `budget_bytes x ranks` cannot be committed is
/// rejected with a clean error and its ranks are returned to the pool.
#[test]
fn storage_admission_gates_sessions() {
    const B: u64 = 1 << 20;
    let mut cfg = Config::default();
    cfg.storage.budget_bytes = B;
    cfg.storage.total_bytes = 3 * B; // room for one 2-rank session, not two
    cfg.apply("scheduler.queue_timeout_s", "2").unwrap();

    let server = AlchemistServer::start(cfg.clone(), 4).unwrap();
    let ac1 =
        AlchemistContext::connect_with_workers(&server.control_addr, &cfg, 1, 2).unwrap();

    let err = AlchemistContext::connect_with_workers(&server.control_addr, &cfg, 1, 2)
        .expect_err("second session would overcommit the storage pool");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("storage admission rejected"),
        "want a storage admission error, got: {msg}"
    );

    // the rejected session's ranks went back; closing the first session
    // returns its commitment and a new session admits cleanly
    ac1.stop();
    let mut ok = None;
    for _ in 0..50 {
        match AlchemistContext::connect_with_workers(&server.control_addr, &cfg, 1, 2) {
            Ok(ac) => {
                ok = Some(ac);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(100)),
        }
    }
    let ac3 = ok.expect("admission must succeed after the first session closed");
    ac3.stop();
    server.shutdown();
}

/// Store-level race: readers stream spans out of blocks while inserts
/// keep forcing LRU spills of those same blocks. Every read must see
/// the block's exact payload regardless of which residency state it
/// caught, and the counters must show blocks cycling both directions.
#[test]
fn concurrent_pull_while_spill() {
    const ROWS: usize = 125;
    const COLS: usize = 8;
    const BYTES: u64 = (ROWS * COLS * 8) as u64;
    const SID: u64 = 1;

    let fill = |id: u64| {
        LocalMatrix::from_fn(ROWS, COLS, move |r, c| {
            (id * 1_000_000 + (r * COLS + c) as u64) as f64
        })
    };
    let store = Arc::new(MatrixStore::with_storage(
        0,
        &StorageConfig {
            budget_bytes: BYTES * 2 + BYTES / 2, // 2.5 blocks resident
            total_bytes: 0,
            spill_dir: String::new(),
            checkpoint_dir: String::new(),
        },
        Arc::new(StorageMetrics::new()),
    ));
    for id in 1..=2u64 {
        store
            .insert(id, "A", RowBlockLayout::even(ROWS, COLS, 1), fill(id), 0, SID)
            .unwrap();
    }

    let mut readers = Vec::new();
    for t in 0..3u64 {
        let store = store.clone();
        readers.push(std::thread::spawn(move || {
            for i in 0..300usize {
                let id = 1 + (t + i as u64) % 2;
                let start = i % (ROWS - 10);
                let n = 1 + i % 10;
                let data = store.read_rows(id, start as u64, n).unwrap();
                assert_eq!(data.len(), n * COLS);
                for (k, v) in data.iter().enumerate() {
                    let expect = (id * 1_000_000 + (start * COLS + k) as u64) as f64;
                    assert_eq!(*v, expect, "block {id} row-span [{start},+{n}) torn");
                }
            }
        }));
    }
    let writer = {
        let store = store.clone();
        std::thread::spawn(move || {
            for id in 3..=12u64 {
                store
                    .insert(id, "B", RowBlockLayout::even(ROWS, COLS, 1), fill(id), 0, SID)
                    .unwrap();
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        })
    };
    for r in readers {
        r.join().unwrap();
    }
    writer.join().unwrap();

    let snap = store.storage_metrics().snapshot();
    assert!(snap.blocks_spilled > 0, "inserts over budget must have spilled: {snap:?}");
    assert!(snap.cycled(), "reads must have come back off the spill file: {snap:?}");

    // teardown releases the spill segments with the blocks
    assert!(store.spill_segments() > 0);
    store.free_session(SID);
    assert_eq!(store.spill_segments(), 0);
    assert_eq!(store.len(), 0);
}

/// The acceptance run at test scale: the out-of-core path (mapped
/// ingest, tiny budget, paneled SVD, spilled U) must reproduce the
/// in-memory run — bit-for-bit when the panel covers each rank's whole
/// shard, and within Lanczos tolerance for genuinely small panels.
#[test]
fn out_of_core_svd_matches_in_memory() {
    let spec = OceanSpec {
        cells: 768,
        times: 96,
        modes: 6,
        sigma0: 60.0,
        decay: 0.7,
        noise: 0.02,
        seed: 21,
    };
    let path = tmp_dir("oocsvd").join("ocean.bin");
    spec.write_file(&path).unwrap();
    let opts = SvdOptions { rank: 6, steps: 30, seed: 0x53D5 };
    let workers = 3usize;

    // budget: far below the dataset (768*96*8 = 576 KiB) AND below U's
    // per-rank share (256*6*8 = 12 KiB) so the left factor must spill
    let budget = 8 * 1024u64;
    assert!(spec.bytes() >= 4 * budget);

    // in-memory reference on the same topology: unlimited budget, pushed
    // A (same bytes as the file), whole-block code path
    let ref_sigma = {
        let cfg = Config::default();
        let server = AlchemistServer::start(cfg.clone(), workers).unwrap();
        let mut ac =
            AlchemistContext::connect(&server.control_addr, &cfg, workers).unwrap();
        ac.register_library("elemental", "builtin:elemental").unwrap();
        let a = alchemist::hdf5sim::read_matrix(&path).unwrap();
        let (al_a, _) = ac
            .send_matrix("A", &IndexedRowMatrix::from_local(&a, workers))
            .unwrap();
        let res = ac
            .run_task(
                "elemental",
                "truncated_svd",
                Params::new()
                    .with_matrix("A", al_a.id)
                    .with_i64("rank", opts.rank as i64)
                    .with_i64("steps", opts.steps as i64)
                    .with_i64("seed", opts.seed as i64),
            )
            .unwrap();
        let sigma = match res.scalars.get("sigma") {
            Some(Value::F64s(v)) => v.clone(),
            other => panic!("sigma missing: {other:?}"),
        };
        ac.stop();
        server.shutdown();
        sigma
    };

    // out-of-core, panel covering each rank's whole shard: identical
    // engine-call sequence on identical data => bit-identical results
    let rep = ocean_svd_outofcore(&spec, &path, budget, workers, &opts, 256).unwrap();
    assert_eq!(rep.client_bytes_loaded, 0);
    assert_eq!(rep.sigma, ref_sigma, "whole-shard panels must be bit-identical");
    assert_eq!(rep.u_rows, 768);
    assert!(
        rep.storage.cycled(),
        "U exceeds the budget; blocks must have cycled to disk and back: {:?}",
        rep.storage
    );
    #[cfg(all(unix, target_endian = "little"))]
    assert!(rep.storage.blocks_mapped >= workers as u64);

    // genuinely streamed panels (37 rows): same spectrum within Lanczos
    // tolerance (summation order differs, nothing else)
    let rep2 = ocean_svd_outofcore(&spec, &path, budget, workers, &opts, 37).unwrap();
    for (a, b) in rep2.sigma.iter().zip(&ref_sigma) {
        assert!((a - b).abs() <= 1e-8 * (1.0 + b.abs()), "{a} vs {b}");
    }
}

/// Closing a session returns every storage resource it held: blocks,
/// budget-pool commitment, and spill-file segments.
#[test]
fn teardown_releases_budget_and_spill_segments() {
    let mut cfg = Config::default();
    cfg.storage.budget_bytes = 12_000; // 1.5 of the 8000-byte per-rank shards
    cfg.storage.total_bytes = 24_000; // exactly one 2-rank session at a time
    cfg.apply("scheduler.queue_timeout_s", "2").unwrap();

    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 2).unwrap();
    let a = LocalMatrix::from_fn(100, 20, |i, j| (i * 20 + j) as f64);
    let (al_a, _) = ac.send_matrix("A", &IndexedRowMatrix::from_local(&a, 2)).unwrap();
    let (_al_b, _) = ac.send_matrix("B", &IndexedRowMatrix::from_local(&a, 2)).unwrap();

    // B pushed A over the per-rank budget on both ranks
    assert!(server.total_spill_segments() >= 2);
    let usage = server.storage_usage();
    assert_eq!(usage.len(), 1);
    assert!(usage[0].1.bytes_spilled >= 16_000);

    // spilled data still reads back exactly
    let (back, _) = ac.to_indexed_row_matrix(&al_a, 2).unwrap();
    assert_eq!(back.to_local().unwrap(), a);

    ac.stop(); // drop the session without explicit frees
    for _ in 0..50 {
        if server.total_blocks() == 0 && server.total_spill_segments() == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    assert_eq!(server.total_blocks(), 0, "teardown must free every block");
    assert_eq!(server.total_spill_segments(), 0, "teardown must free spill segments");
    assert!(server.storage_usage().is_empty(), "ledger must be empty after teardown");

    // the pool commitment came back too: a new session (which needs the
    // whole pool) admits
    let ac2 = AlchemistContext::connect(&server.control_addr, &cfg, 2).unwrap();
    ac2.stop();
    server.shutdown();
}
