//! The Alchemist driver: control-socket sessions, per-session worker
//! groups, matrix handles, concurrent SPMD task dispatch (paper §3.1.1).
//!
//! The driver owns a pool of worker ranks and carves it into
//! session-scoped groups: each handshake negotiates a group size (the
//! paper's `requestWorkers`), the [`GroupAllocator`] grants an exclusive
//! rank subset (queueing FIFO when capacity is short), and every task the
//! session submits runs SPMD over that group's own communicator. Sessions
//! holding disjoint groups therefore execute tasks concurrently — the
//! multi-client serving mode of the Cray deployments (Rothauge et al.
//! 2019) — while matrix handles stay namespaced per session so teardown
//! frees one tenant without disturbing the others.

use std::collections::{HashMap, VecDeque};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::collectives::LocalComm;
use crate::config::{Config, SchedulerConfig, TransferConfig};
use crate::distmat::RowBlockLayout;
use crate::net::{Framed, Server};
use crate::protocol::{ControlMsg, MatrixInfo, Params, PROTOCOL_VERSION};

use super::registry::Registry;
use super::worker::{alloc_group, handle_data_conn, worker_main, WorkerCmd, WorkerShared};

/// Driver-side record of a live distributed matrix.
#[derive(Debug, Clone)]
struct HandleMeta {
    info: MatrixInfo,
    layout: RowBlockLayout,
}

/// One connected client and the worker group it holds exclusively.
struct Session {
    id: u64,
    /// Global worker ranks in group order: `ranks[i]` is the worker with
    /// group-local rank `i`.
    ranks: Vec<usize>,
    /// Per-session config snapshot (transfer knobs travel with the
    /// session so future PRs can negotiate them per client).
    transfer: TransferConfig,
    /// This session's matrix handles (namespaced: other sessions never
    /// see or free them).
    handles: Mutex<HashMap<u64, HandleMeta>>,
}

/// Admission state guarded by the allocator mutex.
struct AllocState {
    /// Sorted free global ranks.
    free: Vec<usize>,
    /// FIFO of queued session tickets; only the head may be granted.
    queue: VecDeque<u64>,
    active: usize,
    stopping: bool,
}

/// FIFO admission control over the worker pool. A handshake claims `n`
/// ranks exclusively; requests beyond current capacity (or beyond
/// `max_sessions`) wait in arrival order until a teardown frees enough,
/// up to `queue_timeout_s`.
struct GroupAllocator {
    total: usize,
    scheduler: SchedulerConfig,
    state: Mutex<AllocState>,
    cond: Condvar,
}

impl GroupAllocator {
    fn new(total: usize, scheduler: SchedulerConfig) -> Self {
        GroupAllocator {
            total,
            scheduler,
            state: Mutex::new(AllocState {
                free: (0..total).collect(),
                queue: VecDeque::new(),
                active: 0,
                stopping: false,
            }),
            cond: Condvar::new(),
        }
    }

    /// Map a client's requested size (0 = server default) to a concrete
    /// group size, rejecting requests the pool can never satisfy.
    fn resolve_request(&self, requested: usize) -> crate::Result<usize> {
        let want = if requested > 0 {
            requested
        } else if self.scheduler.default_group_size > 0 {
            self.scheduler.default_group_size.min(self.total)
        } else {
            self.total
        };
        anyhow::ensure!(
            want <= self.total,
            "requested {want} workers but the server only has {}",
            self.total
        );
        Ok(want)
    }

    /// Block until `want` ranks can be granted to `ticket` (FIFO order),
    /// the queue timeout passes, or the server stops.
    fn acquire(&self, ticket: u64, want: usize) -> crate::Result<Vec<usize>> {
        let timeout = Duration::from_secs_f64(self.scheduler.queue_timeout_s.max(0.0));
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        st.queue.push_back(ticket);
        loop {
            if st.stopping {
                st.queue.retain(|&t| t != ticket);
                anyhow::bail!("server is stopping");
            }
            if st.queue.front() == Some(&ticket)
                && st.active < self.scheduler.max_sessions
                && st.free.len() >= want
            {
                st.queue.pop_front();
                let ranks: Vec<usize> = st.free.drain(..want).collect();
                st.active += 1;
                // the next queued request may fit in what remains
                self.cond.notify_all();
                return Ok(ranks);
            }
            let now = Instant::now();
            if now >= deadline {
                let (free, active) = (st.free.len(), st.active);
                st.queue.retain(|&t| t != ticket);
                // our departure may unblock the request queued behind us
                self.cond.notify_all();
                anyhow::bail!(
                    "timed out after {:.1}s waiting for {want} of {} workers \
                     ({free} free, {active} sessions active)",
                    timeout.as_secs_f64(),
                    self.total,
                );
            }
            let (guard, _) = self.cond.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Return a torn-down session's ranks to the pool and wake the queue.
    fn release(&self, ranks: &[usize]) {
        let mut st = self.state.lock().unwrap();
        st.free.extend_from_slice(ranks);
        st.free.sort_unstable();
        st.active -= 1;
        self.cond.notify_all();
    }

    /// Fail every queued handshake (server shutdown).
    fn stop(&self) {
        self.state.lock().unwrap().stopping = true;
        self.cond.notify_all();
    }
}

struct Driver {
    cfg: Config,
    workers: Vec<Arc<WorkerShared>>,
    senders: Vec<mpsc::Sender<WorkerCmd>>,
    registry: Registry,
    allocator: GroupAllocator,
    next_id: AtomicU64,
    next_session: AtomicU64,
    sessions: Mutex<HashMap<u64, Arc<Session>>>,
    stopping: AtomicBool,
    /// Stop flags of every accept loop (control + per-worker data).
    listener_stops: Mutex<Vec<Arc<AtomicBool>>>,
    control_addr: Mutex<String>,
}

impl Driver {
    /// Flip every stop flag, end the worker loops, fail queued
    /// handshakes, and wake all accept loops so their threads can exit.
    fn stop_all(&self) {
        if self.stopping.swap(true, Ordering::SeqCst) {
            return;
        }
        self.allocator.stop();
        for s in &self.senders {
            let _ = s.send(WorkerCmd::Shutdown);
        }
        for flag in self.listener_stops.lock().unwrap().iter() {
            flag.store(true, Ordering::SeqCst);
        }
        for addr in self.worker_addrs() {
            let _ = TcpStream::connect(&addr);
        }
        let control = self.control_addr.lock().unwrap().clone();
        if !control.is_empty() {
            let _ = TcpStream::connect(&control);
        }
    }
}

impl Driver {
    fn worker_addrs(&self) -> Vec<String> {
        self.workers
            .iter()
            .map(|w| w.data_addr.lock().unwrap().clone())
            .collect()
    }

    /// Data addresses of one session's group, indexed by group-local rank.
    fn session_worker_addrs(&self, session: &Session) -> Vec<String> {
        session
            .ranks
            .iter()
            .map(|&r| self.workers[r].data_addr.lock().unwrap().clone())
            .collect()
    }

    /// Admit a session: resolve the requested group size, wait for
    /// capacity, negotiate the transfer knobs (requested values clamped
    /// by server-side limits), build the group's communicator, and bind
    /// each member worker to it.
    fn open_session(
        &self,
        client_name: &str,
        requested: u32,
        rows_per_frame: u32,
        buf_bytes: u64,
    ) -> crate::Result<Arc<Session>> {
        let want = self.allocator.resolve_request(requested as usize)?;
        let id = self.next_session.fetch_add(1, Ordering::SeqCst);
        let ranks = self.allocator.acquire(id, want)?;
        let comms = LocalComm::subgroup(&ranks, Some(self.cfg.simnet.clone()));
        for (&rank, comm) in ranks.iter().zip(comms) {
            self.workers[rank]
                .sessions
                .lock()
                .unwrap()
                .insert(id, Arc::new(comm));
        }
        let session = Arc::new(Session {
            id,
            ranks: ranks.clone(),
            transfer: self.cfg.transfer.negotiate(rows_per_frame, buf_bytes),
            handles: Mutex::new(HashMap::new()),
        });
        self.sessions.lock().unwrap().insert(id, session.clone());
        log::info!(
            "session {id}: client {client_name:?} granted {want} workers \
             (ranks {ranks:?}, {} rows/frame, {} buf bytes)",
            session.transfer.rows_per_frame,
            session.transfer.buf_bytes
        );
        Ok(session)
    }

    /// Tear a session down: unbind its communicator endpoints, free its
    /// matrices on every member worker, and return the ranks to the pool.
    fn close_session(&self, session: &Session) {
        if self.sessions.lock().unwrap().remove(&session.id).is_none() {
            return; // already closed
        }
        let mut freed = 0;
        for &rank in &session.ranks {
            let w = &self.workers[rank];
            w.sessions.lock().unwrap().remove(&session.id);
            freed += w.store.free_session(session.id);
        }
        self.allocator.release(&session.ranks);
        log::info!(
            "session {}: closed ({} blocks freed, {} workers released)",
            session.id,
            freed,
            session.ranks.len()
        );
    }

    fn create_matrix(
        &self,
        session: &Session,
        name: &str,
        rows: u64,
        cols: u64,
    ) -> crate::Result<ControlMsg> {
        anyhow::ensure!(rows > 0 && cols > 0, "matrix must be non-empty");
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let layout =
            RowBlockLayout::even(rows as usize, cols as usize, session.ranks.len());
        alloc_group(&self.workers, &session.ranks, session.id, id, name, &layout)?;
        session.handles.lock().unwrap().insert(
            id,
            HandleMeta {
                info: MatrixInfo { id, rows, cols, name: name.to_string() },
                layout: layout.clone(),
            },
        );
        Ok(ControlMsg::MatrixCreated { id, row_ranges: layout.to_wire() })
    }

    fn seal_matrix(&self, session: &Session, id: u64) -> crate::Result<ControlMsg> {
        let meta = self.handle(session, id)?;
        let mut received = 0;
        for &rank in &session.ranks {
            received += self.workers[rank].store.seal(id)?;
        }
        anyhow::ensure!(
            received == meta.info.rows,
            "matrix {id}: sealed with {received} of {} rows",
            meta.info.rows
        );
        Ok(ControlMsg::MatrixSealed { id, rows_received: received })
    }

    fn handle(&self, session: &Session, id: u64) -> crate::Result<HandleMeta> {
        session
            .handles
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("unknown matrix handle {id}"))
    }

    fn run_task(
        &self,
        session: &Session,
        lib_name: &str,
        routine: &str,
        params: &Params,
    ) -> crate::Result<ControlMsg> {
        let lib = self.registry.get(lib_name)?;
        // reserve an id window for the routine's outputs
        let out_base = self.next_id.fetch_add(64, Ordering::SeqCst);

        // dispatch to this session's group only; disjoint groups use
        // disjoint worker threads, so no global serialization here
        let mut replies = Vec::new();
        for &rank in &session.ranks {
            let (tx, rx) = mpsc::channel();
            self.senders[rank]
                .send(WorkerCmd::RunTask {
                    session_id: session.id,
                    lib: lib.clone(),
                    routine: routine.to_string(),
                    params: params.clone(),
                    out_base,
                    reply: tx,
                })
                .map_err(|_| anyhow::anyhow!("worker thread is gone"))?;
            replies.push(rx);
        }
        let results: Vec<super::worker::TaskReply> = {
            let mut ok = Vec::new();
            let mut first_err = None;
            for rx in replies {
                match rx.recv().map_err(|_| anyhow::anyhow!("worker died mid-task"))? {
                    Ok(r) => ok.push(r),
                    Err(e) => first_err = first_err.or(Some(e)),
                }
            }
            if let Some(e) = first_err {
                return Err(e);
            }
            ok
        };

        // consistency: every rank must report the same output set
        let r0 = &results[0];
        for r in &results[1..] {
            anyhow::ensure!(
                r.outputs.len() == r0.outputs.len(),
                "ranks disagree on output count for {lib_name}.{routine}"
            );
        }
        let mut outputs = Vec::new();
        {
            let mut handles = session.handles.lock().unwrap();
            for meta in &r0.outputs {
                let layout =
                    self.workers[session.ranks[0]].store.get(meta.id)?.layout.clone();
                let info = MatrixInfo {
                    id: meta.id,
                    rows: meta.rows,
                    cols: meta.cols,
                    name: meta.name.clone(),
                };
                handles.insert(meta.id, HandleMeta { info: info.clone(), layout });
                outputs.push(info);
            }
        }

        // timings: group-rank-0 laps + aggregated cluster metrics
        let mut timings = r0.timings.clone();
        let lap = |r: &super::worker::TaskReply, name: &str| -> f64 {
            r.timings
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, s)| *s)
                .unwrap_or(0.0)
        };
        let sim_secs = results
            .iter()
            .map(|r| lap(r, "cpu_busy") + lap(r, "comm_sim"))
            .fold(0.0f64, f64::max);
        timings.push(("sim_secs".into(), sim_secs));

        Ok(ControlMsg::TaskDone { outputs, scalars: r0.scalars.clone(), timings })
    }

    fn fetch_matrix(&self, session: &Session, id: u64) -> crate::Result<ControlMsg> {
        let meta = self.handle(session, id)?;
        Ok(ControlMsg::FetchReady {
            info: meta.info,
            row_ranges: meta.layout.to_wire(),
        })
    }

    fn free_matrix(&self, session: &Session, id: u64) -> crate::Result<ControlMsg> {
        let existed = session.handles.lock().unwrap().remove(&id).is_some();
        anyhow::ensure!(existed, "unknown matrix handle {id}");
        for &rank in &session.ranks {
            self.workers[rank].store.free(id);
        }
        Ok(ControlMsg::Freed { id })
    }

    fn list_matrices(&self, session: &Session) -> ControlMsg {
        let handles = session.handles.lock().unwrap();
        let mut infos: Vec<MatrixInfo> =
            handles.values().map(|m| m.info.clone()).collect();
        infos.sort_by_key(|i| i.id);
        ControlMsg::MatrixList { infos }
    }
}

/// Handle to a running server; dropping does NOT stop it — call
/// [`ServerHandle::shutdown`] (or send `ControlMsg::Shutdown` as a
/// client).
pub struct ServerHandle {
    pub control_addr: String,
    /// Data addresses of the whole pool, index = global worker rank
    /// (sessions are granted subsets; see the handshake ack).
    pub worker_addrs: Vec<String>,
    threads: Vec<JoinHandle<()>>,
    driver: Arc<Driver>,
}

impl ServerHandle {
    /// Stop the server from the owning process (benches/tests).
    pub fn shutdown(mut self) {
        self.driver.stop_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Block until some client sends `ControlMsg::Shutdown` (the
    /// `alchemist serve` foreground mode).
    pub fn shutdown_on_request(mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Live session count (test/debug introspection).
    pub fn active_sessions(&self) -> usize {
        self.driver.sessions.lock().unwrap().len()
    }

    /// Total matrix blocks across all worker stores (test/debug
    /// introspection: teardown must drive a session's share to zero).
    pub fn total_blocks(&self) -> usize {
        self.driver.workers.iter().map(|w| w.store.len()).sum()
    }
}

/// The Alchemist server factory.
pub struct AlchemistServer;

impl AlchemistServer {
    /// Start a driver with `num_workers` worker ranks on ephemeral
    /// localhost ports. Returns once all sockets are listening.
    pub fn start(cfg: Config, num_workers: usize) -> crate::Result<ServerHandle> {
        anyhow::ensure!(num_workers >= 1, "need at least one worker");
        let mut threads = Vec::new();

        // worker shared state; communicators are session-scoped and bound
        // at handshake time
        let mut workers = Vec::new();
        let mut senders = Vec::new();
        let mut worker_addrs = Vec::new();
        let mut listener_stops = Vec::new();

        for rank in 0..num_workers {
            let shared = Arc::new(WorkerShared {
                rank,
                store: super::store::MatrixStore::new(rank),
                data_addr: Mutex::new(String::new()),
                sessions: Mutex::new(HashMap::new()),
            });
            // data listener
            let listener = Server::bind(0)?;
            *shared.data_addr.lock().unwrap() = listener.addr().to_string();
            worker_addrs.push(listener.addr().to_string());
            listener_stops.push(listener.stop_flag());
            {
                let shared = shared.clone();
                let cfg = cfg.clone();
                threads.push(std::thread::spawn(move || {
                    let shared2 = shared.clone();
                    let _ = listener.serve(move |stream| {
                        handle_data_conn(&shared2, stream, &cfg);
                    });
                }));
            }
            // command loop
            let (tx, rx) = mpsc::channel();
            senders.push(tx);
            {
                let shared = shared.clone();
                let cfg = cfg.clone();
                threads.push(std::thread::spawn(move || {
                    worker_main(shared, cfg, rx);
                }));
            }
            workers.push(shared);
        }

        let control = Server::bind(0)?;
        let control_addr = control.addr().to_string();
        listener_stops.push(control.stop_flag());
        let driver = Arc::new(Driver {
            allocator: GroupAllocator::new(num_workers, cfg.scheduler.clone()),
            cfg: cfg.clone(),
            workers,
            senders,
            registry: Registry::new(),
            next_id: AtomicU64::new(1),
            next_session: AtomicU64::new(1),
            sessions: Mutex::new(HashMap::new()),
            stopping: AtomicBool::new(false),
            listener_stops: Mutex::new(listener_stops),
            control_addr: Mutex::new(control_addr.clone()),
        });

        {
            let driver = driver.clone();
            let buf = cfg.transfer.buf_bytes;
            threads.push(std::thread::spawn(move || {
                let _ = control.serve(move |stream| {
                    handle_control_conn(&driver, stream, buf);
                });
            }));
        }

        log::info!(
            "alchemist server up: control {control_addr}, {num_workers} workers, \
             engine {}, max {} sessions",
            cfg.engine.as_str(),
            cfg.scheduler.max_sessions
        );
        Ok(ServerHandle {
            control_addr,
            worker_addrs: driver.worker_addrs(),
            threads,
            driver,
        })
    }
}

/// Dispatch a control message that requires an admitted session.
fn handle_session_op(
    driver: &Driver,
    session: Option<&Arc<Session>>,
    msg: ControlMsg,
) -> crate::Result<ControlMsg> {
    let session = session
        .ok_or_else(|| anyhow::anyhow!("handshake required before {msg:?}"))?;
    match msg {
        ControlMsg::CreateMatrix { name, rows, cols } => {
            driver.create_matrix(session, &name, rows, cols)
        }
        ControlMsg::SealMatrix { id } => driver.seal_matrix(session, id),
        ControlMsg::RunTask { lib, routine, params } => {
            driver.run_task(session, &lib, &routine, &params)
        }
        ControlMsg::FetchMatrix { id } => driver.fetch_matrix(session, id),
        ControlMsg::FreeMatrix { id } => driver.free_matrix(session, id),
        ControlMsg::ListMatrices => Ok(driver.list_matrices(session)),
        other => Ok(ControlMsg::Error {
            message: format!("unexpected control message: {other:?}"),
        }),
    }
}

fn handle_control_conn(driver: &Arc<Driver>, stream: TcpStream, buf_bytes: usize) {
    if driver.stopping.load(Ordering::SeqCst) {
        return; // wake-up connection during shutdown
    }
    let mut framed = match Framed::tcp(stream, buf_bytes) {
        Ok(f) => f,
        Err(e) => {
            log::warn!("control conn setup failed: {e}");
            return;
        }
    };
    // the session admitted on this control socket; torn down when the
    // socket closes (client `stop()` / crash) or on Shutdown
    let mut session: Option<Arc<Session>> = None;
    loop {
        let msg = match framed.recv_ctrl() {
            Ok(m) => m,
            Err(_) => break, // client went away
        };
        let reply = match msg {
            ControlMsg::Handshake {
                client_name,
                version,
                request_workers,
                rows_per_frame,
                buf_bytes,
            } => {
                if version != PROTOCOL_VERSION {
                    Ok(ControlMsg::Error {
                        message: format!(
                            "protocol version mismatch: client {version}, server {PROTOCOL_VERSION}"
                        ),
                    })
                } else if session.is_some() {
                    Ok(ControlMsg::Error {
                        message: "session already established on this connection".into(),
                    })
                } else {
                    match driver.open_session(
                        &client_name,
                        request_workers,
                        rows_per_frame,
                        buf_bytes,
                    ) {
                        Ok(s) => {
                            let ack = ControlMsg::HandshakeAck {
                                session_id: s.id,
                                version: PROTOCOL_VERSION,
                                granted_workers: s.ranks.len() as u32,
                                worker_addrs: driver.session_worker_addrs(&s),
                                rows_per_frame: s.transfer.rows_per_frame as u32,
                                buf_bytes: s.transfer.buf_bytes as u64,
                            };
                            session = Some(s);
                            Ok(ack)
                        }
                        Err(e) => Err(e),
                    }
                }
            }
            ControlMsg::RegisterLibrary { name, path } => driver
                .registry
                .register(&name, &path)
                .map(|()| ControlMsg::LibraryRegistered { name }),
            ControlMsg::Shutdown => {
                if let Some(s) = session.take() {
                    driver.close_session(&s);
                }
                driver.stop_all();
                let _ = framed.send_ctrl(&ControlMsg::Bye);
                return;
            }
            other => handle_session_op(driver, session.as_ref(), other),
        };
        let out = match reply {
            Ok(m) => m,
            Err(e) => ControlMsg::Error { message: format!("{e:#}") },
        };
        if framed.send_ctrl(&out).is_err() {
            break;
        }
    }
    if let Some(s) = session.take() {
        driver.close_session(&s);
    }
}
