//! Scheduler metrics (ROADMAP "serving-grade scheduler", the telemetry
//! half): live gauges for the per-class admission queue and the
//! per-session task queues, counters over session and task outcomes, and
//! the Queued→Running wait-time distribution.
//!
//! Naming follows `metrics/storage.rs`: gauges are `noun_depth` /
//! `noun_active`, counters are `noun_verbed`, and the snapshot struct is
//! a plain-data point-in-time copy. [`SchedSnapshot`] is also the wire
//! payload of the v9 metrics stream — [`SchedSnapshot::to_json`] renders
//! the single-line JSON object a `MetricsSnapshot` frame carries, so the
//! polling path (`ServerHandle::sched_metrics`) and the push path share
//! one bookkeeping struct (see `docs/scheduler.md` for the schema).
//!
//! The driver holds one [`SchedMetrics`]; every update is a lock-free
//! atomic except the wait-time [`Stats`] (one short mutex per task
//! start).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::Stats;

/// Number of admission priority classes (v9): 0 = batch, 1 = normal,
/// 2 = interactive, 3 = urgent.
pub const PRIORITY_CLASSES: usize = 4;

/// Human labels for the classes, index-aligned with the depth gauges.
pub const PRIORITY_NAMES: [&str; PRIORITY_CLASSES] =
    ["batch", "normal", "interactive", "urgent"];

/// Counters and gauges the coordinator's admission and task paths feed.
#[derive(Debug, Default)]
pub struct SchedMetrics {
    /// Handshakes currently waiting in the admission queue, by effective
    /// priority class (clamped, pre-aging).
    admission_depth: [AtomicU64; PRIORITY_CLASSES],
    /// Sessions currently holding a worker group.
    sessions_active: AtomicU64,
    sessions_admitted: AtomicU64,
    /// Handshakes bounced from the admission queue (timeout / teardown).
    sessions_rejected: AtomicU64,
    /// Tasks currently queued (all sessions; per-session depth is bounded
    /// by `scheduler.task_queue_depth`).
    queued_tasks: AtomicU64,
    /// Tasks currently running (≤ `scheduler.tasks_per_group` per
    /// session group).
    running_tasks: AtomicU64,
    tasks_submitted: AtomicU64,
    tasks_done: AtomicU64,
    tasks_failed: AtomicU64,
    tasks_cancelled: AtomicU64,
    /// Submissions rejected because the session's queue was full.
    tasks_rejected: AtomicU64,
    /// Dead ranks re-formed around a spare mid-session (v10 survivable
    /// sessions; see `docs/recovery.md`).
    ranks_replaced: AtomicU64,
    /// Seconds from submission to dispatch (the backpressure signal).
    queued_wait: Mutex<Stats>,
}

/// One running task's live gauge inside a [`SessionGauge`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TaskGauge {
    pub task_id: u64,
    /// The task's tag lane in the group communicator.
    pub lane: u64,
    pub routine: String,
    /// Progress aggregated across the task's ranks.
    pub iters: u64,
    /// Latest residual, or a negative sentinel if none reported yet.
    pub residual: f64,
}

/// One live session's task-plane gauge, filled by the driver (the task
/// table is the single source — no second bookkeeping path).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SessionGauge {
    pub session_id: u64,
    /// The client name it handshook with (fair-share tenant key).
    pub client: String,
    /// Admitted priority class (post-clamp).
    pub priority: u32,
    /// Tasks waiting in this session's FIFO.
    pub queued: usize,
    /// Tasks currently executing on the session's group, one gauge each.
    pub running: Vec<TaskGauge>,
}

/// Point-in-time copy of every metric (plain data, safe to hold).
/// `sessions` is filled by the driver-side snapshot
/// (`ServerHandle::sched_metrics` / the metrics stream) and empty when
/// taken from a bare [`SchedMetrics`].
#[derive(Debug, Clone, Default)]
pub struct SchedSnapshot {
    /// Queued handshakes by priority class, index = class.
    pub admission_depth: [u64; PRIORITY_CLASSES],
    pub sessions_active: u64,
    pub sessions_admitted: u64,
    pub sessions_rejected: u64,
    pub queued_tasks: u64,
    pub running_tasks: u64,
    pub tasks_submitted: u64,
    pub tasks_done: u64,
    pub tasks_failed: u64,
    pub tasks_cancelled: u64,
    pub tasks_rejected: u64,
    pub ranks_replaced: u64,
    pub wait_count: u64,
    pub wait_mean_s: f64,
    pub wait_max_s: f64,
    pub sessions: Vec<SessionGauge>,
}

/// How a task left the table (feeds the outcome counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskOutcome {
    Done,
    Failed,
    Cancelled,
}

/// One live session's task backlog (reported by
/// `ServerHandle::session_queue_depths`): the global `queued_tasks`
/// gauge says how much work is waiting overall, this says *whose* — a
/// tenant pinned at its `scheduler.task_queue_depth` bound looks very
/// different from light load spread across sessions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SessionQueueDepth {
    pub session_id: u64,
    /// Tasks waiting in this session's FIFO.
    pub queued: usize,
    /// Tasks currently executing on the session's group (v9: up to
    /// `scheduler.tasks_per_group`).
    pub running: usize,
}

impl SchedMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Clamp a class index into the gauge array (callers already clamp
    /// to `scheduler.max_priority`; this is belt-and-braces).
    fn class(priority: u32) -> usize {
        (priority as usize).min(PRIORITY_CLASSES - 1)
    }

    pub fn admission_enqueued(&self, priority: u32) {
        self.admission_depth[Self::class(priority)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn admission_dequeued(&self, priority: u32) {
        self.admission_depth[Self::class(priority)].fetch_sub(1, Ordering::Relaxed);
    }

    /// Current queued handshakes in one class (rejection diagnostics).
    pub fn admission_depth(&self, priority: u32) -> u64 {
        self.admission_depth[Self::class(priority)].load(Ordering::Relaxed)
    }

    pub fn session_admitted(&self) {
        self.sessions_admitted.fetch_add(1, Ordering::Relaxed);
        self.sessions_active.fetch_add(1, Ordering::Relaxed);
    }

    pub fn session_released(&self) {
        self.sessions_active.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn session_rejected(&self) {
        self.sessions_rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn task_submitted(&self) {
        self.tasks_submitted.fetch_add(1, Ordering::Relaxed);
        self.queued_tasks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn task_rejected(&self) {
        self.tasks_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// A dead rank was replaced by a spare and the session re-formed.
    pub fn rank_replaced(&self) {
        self.ranks_replaced.fetch_add(1, Ordering::Relaxed);
    }

    /// A task left the queue for a worker group; `wait_secs` is its
    /// Queued→Running latency.
    pub fn task_started(&self, wait_secs: f64) {
        self.queued_tasks.fetch_sub(1, Ordering::Relaxed);
        self.running_tasks.fetch_add(1, Ordering::Relaxed);
        self.queued_wait.lock().unwrap().push(wait_secs);
    }

    /// A *running* task reached a terminal state.
    pub fn task_finished(&self, outcome: TaskOutcome) {
        self.running_tasks.fetch_sub(1, Ordering::Relaxed);
        self.count_outcome(outcome);
    }

    /// A *queued* task reached a terminal state without running
    /// (cancelled while queued, or drained at session teardown).
    pub fn task_dequeued(&self, outcome: TaskOutcome) {
        self.queued_tasks.fetch_sub(1, Ordering::Relaxed);
        self.count_outcome(outcome);
    }

    fn count_outcome(&self, outcome: TaskOutcome) {
        let c = match outcome {
            TaskOutcome::Done => &self.tasks_done,
            TaskOutcome::Failed => &self.tasks_failed,
            TaskOutcome::Cancelled => &self.tasks_cancelled,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> SchedSnapshot {
        let wait = self.queued_wait.lock().unwrap().clone();
        let mut admission_depth = [0u64; PRIORITY_CLASSES];
        for (slot, gauge) in admission_depth.iter_mut().zip(&self.admission_depth) {
            *slot = gauge.load(Ordering::Relaxed);
        }
        SchedSnapshot {
            admission_depth,
            sessions_active: self.sessions_active.load(Ordering::Relaxed),
            sessions_admitted: self.sessions_admitted.load(Ordering::Relaxed),
            sessions_rejected: self.sessions_rejected.load(Ordering::Relaxed),
            queued_tasks: self.queued_tasks.load(Ordering::Relaxed),
            running_tasks: self.running_tasks.load(Ordering::Relaxed),
            tasks_submitted: self.tasks_submitted.load(Ordering::Relaxed),
            tasks_done: self.tasks_done.load(Ordering::Relaxed),
            tasks_failed: self.tasks_failed.load(Ordering::Relaxed),
            tasks_cancelled: self.tasks_cancelled.load(Ordering::Relaxed),
            tasks_rejected: self.tasks_rejected.load(Ordering::Relaxed),
            ranks_replaced: self.ranks_replaced.load(Ordering::Relaxed),
            wait_count: wait.count(),
            wait_mean_s: if wait.count() > 0 { wait.mean() } else { 0.0 },
            wait_max_s: if wait.count() > 0 { wait.max() } else { 0.0 },
            sessions: Vec::new(),
        }
    }
}

/// Escape a string for a JSON string literal (quotes, backslashes,
/// control characters).
fn json_escape(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

/// A finite JSON number (JSON has no inf/nan — those become `null`).
fn json_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        out.push_str(&format!("{v}"));
    } else {
        out.push_str("null");
    }
}

impl SchedSnapshot {
    /// Render the snapshot as one line of JSON — the `MetricsSnapshot`
    /// wire payload and the `scripts/`-consumable stream format (one
    /// object per line, keys stable; see `docs/scheduler.md`).
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(512);
        s.push_str("{\"admission_depth\":{");
        for (i, name) in PRIORITY_NAMES.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\"{name}\":{}", self.admission_depth[i]));
        }
        s.push_str(&format!(
            "}},\"sessions\":{{\"active\":{},\"admitted\":{},\"rejected\":{}}}",
            self.sessions_active, self.sessions_admitted, self.sessions_rejected
        ));
        s.push_str(&format!(
            ",\"tasks\":{{\"queued\":{},\"running\":{},\"submitted\":{},\
             \"done\":{},\"failed\":{},\"cancelled\":{},\"rejected\":{}}}",
            self.queued_tasks,
            self.running_tasks,
            self.tasks_submitted,
            self.tasks_done,
            self.tasks_failed,
            self.tasks_cancelled,
            self.tasks_rejected
        ));
        s.push_str(&format!(",\"ranks_replaced\":{}", self.ranks_replaced));
        s.push_str(&format!(",\"queue_wait_s\":{{\"count\":{},", self.wait_count));
        s.push_str("\"mean\":");
        json_f64(&mut s, self.wait_mean_s);
        s.push_str(",\"max\":");
        json_f64(&mut s, self.wait_max_s);
        s.push_str("},\"session_gauges\":[");
        for (i, sess) in self.sessions.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("{{\"id\":{},\"client\":\"", sess.session_id));
            json_escape(&mut s, &sess.client);
            s.push_str(&format!(
                "\",\"priority\":{},\"queued\":{},\"running\":[",
                sess.priority, sess.queued
            ));
            for (j, t) in sess.running.iter().enumerate() {
                if j > 0 {
                    s.push(',');
                }
                s.push_str(&format!(
                    "{{\"task\":{},\"lane\":{},\"routine\":\"",
                    t.task_id, t.lane
                ));
                json_escape(&mut s, &t.routine);
                s.push_str(&format!("\",\"iters\":{},\"residual\":", t.iters));
                json_f64(&mut s, t.residual);
                s.push('}');
            }
            s.push_str("]}");
        }
        s.push_str("]}");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_counts_balance() {
        let m = SchedMetrics::new();
        m.admission_enqueued(2);
        assert_eq!(m.snapshot().admission_depth[2], 1);
        assert_eq!(m.admission_depth(2), 1);
        m.admission_dequeued(2);
        m.session_admitted();

        // one task runs to completion, one is cancelled while queued,
        // one submission is rejected
        m.task_submitted();
        m.task_submitted();
        m.task_rejected();
        m.task_started(0.25);
        m.task_finished(TaskOutcome::Done);
        m.task_dequeued(TaskOutcome::Cancelled);
        m.rank_replaced();
        m.session_released();

        let s = m.snapshot();
        assert_eq!(s.admission_depth, [0; PRIORITY_CLASSES]);
        assert_eq!(s.sessions_active, 0);
        assert_eq!(s.sessions_admitted, 1);
        assert_eq!(s.queued_tasks, 0);
        assert_eq!(s.running_tasks, 0);
        assert_eq!(s.tasks_submitted, 2);
        assert_eq!(s.tasks_done, 1);
        assert_eq!(s.tasks_cancelled, 1);
        assert_eq!(s.tasks_rejected, 1);
        assert_eq!(s.ranks_replaced, 1);
        assert_eq!(s.wait_count, 1);
        assert!((s.wait_mean_s - 0.25).abs() < 1e-12);
        assert_eq!(s.wait_max_s, 0.25);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = SchedMetrics::new().snapshot();
        assert_eq!(s.wait_count, 0);
        assert_eq!(s.wait_mean_s, 0.0);
        assert_eq!(s.wait_max_s, 0.0);
        assert!(s.sessions.is_empty());
    }

    #[test]
    fn snapshot_renders_one_json_line() {
        let m = SchedMetrics::new();
        m.admission_enqueued(0);
        m.session_admitted();
        m.task_submitted();
        m.task_started(0.5);
        let mut s = m.snapshot();
        s.sessions.push(SessionGauge {
            session_id: 7,
            client: "spark \"prod\"".into(),
            priority: 2,
            queued: 1,
            running: vec![TaskGauge {
                task_id: 12,
                lane: 3,
                routine: "cg_solve".into(),
                iters: 40,
                residual: 1e-6,
            }],
        });
        let json = s.to_json();
        assert!(!json.contains('\n'), "stream format is one object per line");
        assert!(json.contains("\"admission_depth\":{\"batch\":1"), "{json}");
        assert!(json.contains("\"sessions\":{\"active\":1"), "{json}");
        assert!(json.contains("\"running\":1"), "{json}");
        assert!(json.contains("\"client\":\"spark \\\"prod\\\"\""), "{json}");
        assert!(json.contains("\"routine\":\"cg_solve\""), "{json}");
        assert!(json.contains("\"lane\":3"), "{json}");
        // balanced braces/brackets (cheap well-formedness check without
        // a JSON parser in the dep tree)
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());

        // non-finite residual must not produce invalid JSON
        s.sessions[0].running[0].residual = f64::NAN;
        assert!(s.to_json().contains("\"residual\":null"));
    }
}
