//! Control- and data-plane message enums with binary encode/decode.

use super::value::Params;
use super::wire::{ProtocolError, Reader, Writer};

/// The default admission priority class (v9): 1 = "normal". Classes run
/// 0 (batch) ..= 3 (urgent); higher classes are admitted first. A
/// handshake at this class elides the field so default clients keep the
/// v8 frame shape.
pub const DEFAULT_PRIORITY: u32 = 1;

/// Metadata for a matrix living in the server's handle registry — the
/// server-side half of the paper's `AlMatrix` proxy.
#[derive(Debug, Clone, PartialEq)]
pub struct MatrixInfo {
    pub id: u64,
    pub rows: u64,
    pub cols: u64,
    pub name: String,
}

impl MatrixInfo {
    fn encode(&self, w: &mut Writer) {
        w.u64(self.id);
        w.u64(self.rows);
        w.u64(self.cols);
        w.str(&self.name);
    }

    fn decode(r: &mut Reader) -> Result<Self, ProtocolError> {
        Ok(MatrixInfo {
            id: r.u64()?,
            rows: r.u64()?,
            cols: r.u64()?,
            name: r.str()?,
        })
    }
}

fn encode_ranges(w: &mut Writer, ranges: &[(u64, u64)]) {
    w.u32(ranges.len() as u32);
    for (a, b) in ranges {
        w.u64(*a);
        w.u64(*b);
    }
}

fn decode_ranges(r: &mut Reader) -> Result<Vec<(u64, u64)>, ProtocolError> {
    let n = r.u32()?;
    (0..n).map(|_| Ok((r.u64()?, r.u64()?))).collect()
}

fn encode_timings(w: &mut Writer, timings: &[(String, f64)]) {
    w.u32(timings.len() as u32);
    for (name, secs) in timings {
        w.str(name);
        w.f64(*secs);
    }
}

fn decode_timings(r: &mut Reader) -> Result<Vec<(String, f64)>, ProtocolError> {
    let n = r.u32()?;
    (0..n)
        .map(|_| Ok((r.str()?, r.f64()?)))
        .collect::<Result<_, ProtocolError>>()
}

/// Cross-rank aggregated progress of a `Running` task: `iters` is the
/// minimum iteration any rank has completed (the group-wide frontier),
/// `residual` the worst (largest) residual any rank last reported —
/// [`crate::tasks::NO_RESIDUAL`] when no rank reported one — and `ranks`
/// the group size executing the task.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskProgress {
    pub iters: u64,
    pub residual: f64,
    pub ranks: u32,
}

/// The task state machine (protocol v4, `docs/tasks.md`):
/// `Queued → Running → Done | Failed | Cancelled` (queued tasks may also
/// go straight to `Cancelled`). Terminal states carry the payload the
/// blocking `RunTask` reply used to carry.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskState {
    Queued,
    Running { progress: TaskProgress },
    Done {
        outputs: Vec<MatrixInfo>,
        scalars: Params,
        /// Named timing laps measured server-side (compute, expand, ...).
        timings: Vec<(String, f64)>,
    },
    Failed {
        /// Human-readable summary: how many ranks failed and the first
        /// failing rank's error.
        message: String,
        /// Group-local ranks that returned an error (a one-rank wedge is
        /// distinguishable from a group-wide failure).
        failed_ranks: Vec<u32>,
        total_ranks: u32,
    },
    Cancelled,
}

impl TaskState {
    /// Terminal states never change again; `wait` returns on them.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            TaskState::Done { .. } | TaskState::Failed { .. } | TaskState::Cancelled
        )
    }

    fn encode(&self, w: &mut Writer) {
        match self {
            TaskState::Queued => w.u8(0),
            TaskState::Running { progress } => {
                w.u8(1);
                w.u64(progress.iters);
                w.f64(progress.residual);
                w.u32(progress.ranks);
            }
            TaskState::Done { outputs, scalars, timings } => {
                w.u8(2);
                w.u32(outputs.len() as u32);
                for o in outputs {
                    o.encode(w);
                }
                scalars.encode(w);
                encode_timings(w, timings);
            }
            TaskState::Failed { message, failed_ranks, total_ranks } => {
                w.u8(3);
                w.str(message);
                w.u32(failed_ranks.len() as u32);
                for rank in failed_ranks {
                    w.u32(*rank);
                }
                w.u32(*total_ranks);
            }
            TaskState::Cancelled => w.u8(4),
        }
    }

    fn decode(r: &mut Reader) -> Result<Self, ProtocolError> {
        Ok(match r.u8()? {
            0 => TaskState::Queued,
            1 => TaskState::Running {
                progress: TaskProgress {
                    iters: r.u64()?,
                    residual: r.f64()?,
                    ranks: r.u32()?,
                },
            },
            2 => {
                let n = r.u32()?;
                let outputs = (0..n)
                    .map(|_| MatrixInfo::decode(r))
                    .collect::<Result<_, _>>()?;
                let scalars = Params::decode(r)?;
                let timings = decode_timings(r)?;
                TaskState::Done { outputs, scalars, timings }
            }
            3 => {
                let message = r.str()?;
                let n = r.u32()?;
                let failed_ranks =
                    (0..n).map(|_| r.u32()).collect::<Result<_, _>>()?;
                TaskState::Failed { message, failed_ranks, total_ranks: r.u32()? }
            }
            4 => TaskState::Cancelled,
            tag => return Err(ProtocolError::BadTag { tag, what: "TaskState" }),
        })
    }
}

/// Driver⇄driver control messages (one TCP socket per session, paper
/// §3.1.2: "one socket connection between the two driver processes").
#[derive(Debug, Clone, PartialEq)]
pub enum ControlMsg {
    // client -> server
    Handshake {
        client_name: String,
        version: u32,
        /// Worker-group size this session asks for (the paper's
        /// `requestWorkers` API); 0 = server default policy.
        request_workers: u32,
        /// Requested rows-per-frame for this session's transfers
        /// (v3 negotiation); 0 = server default. The server clamps to its
        /// configured limits and echoes the effective value in the ack.
        rows_per_frame: u32,
        /// Requested socket buffer size in bytes (v3 negotiation);
        /// 0 = server default, clamped server-side.
        buf_bytes: u64,
        /// Requested admission priority class (v9): 0 = batch,
        /// 1 = normal, 2 = interactive, 3 = urgent. Clamped server-side
        /// to `scheduler.max_priority` before admission. Elided at
        /// [`DEFAULT_PRIORITY`] so default clients keep the v8 wire
        /// shape.
        priority: u32,
    },
    RegisterLibrary { name: String, path: String },
    /// Allocate a handle; rows will arrive on the data sockets.
    CreateMatrix { name: String, rows: u64, cols: u64 },
    /// All rows pushed; server verifies counts and freezes the layout.
    SealMatrix { id: u64 },
    /// Enqueue `lib.routine(params)` on the session's worker group and
    /// return immediately with a task id (v4; the blocking `RunTask` of
    /// v1–v3 is client-side sugar over submit + wait).
    SubmitTask { lib: String, routine: String, params: Params },
    FetchMatrix { id: u64 },
    FreeMatrix { id: u64 },
    ListMatrices,
    Shutdown,
    /// Poll a task's state (never blocks).
    TaskStatus { task_id: u64 },
    /// Request cooperative cancellation; replied with the task's state
    /// *after* the request (a running task stays `Running` until its
    /// ranks observe the token).
    ///
    /// v5: `hard_after_ms > 0` arms an escalation deadline — if the task
    /// is still running after the cooperative grace period, the server
    /// poisons the group's communicator so the routine is forcibly
    /// unwound at its next collective (see `docs/tasks.md`). 0 keeps the
    /// pure-cooperative v4 semantics and the v4 wire shape (the field is
    /// elided on encode).
    CancelTask { task_id: u64, hard_after_ms: u64 },
    /// Block server-side until the task reaches a terminal state or
    /// `timeout_ms` elapses (0 = poll: return the current state at once).
    /// The reply is a `TaskStatusReply` either way; a non-terminal state
    /// means the timeout fired first.
    WaitTask { task_id: u64, timeout_ms: u64 },
    /// v7 direct ingest: ask the server to have each worker map its row
    /// range of the `hdf5sim` file at `path` (a path on the SERVER's
    /// filesystem) and register it as an already-sealed mapped block —
    /// no payload bytes ever cross the client connection. Answered by
    /// `LoadDone` (or `Error` if the file fails validation, in which
    /// case no block was registered anywhere).
    LoadMatrix { name: String, path: String },
    /// v9: turn this control connection into a push-based scheduler
    /// metrics stream. Sent as the FIRST message on a fresh connection
    /// (no handshake, no session, no workers held) — the server then
    /// pushes a `MetricsSnapshot` every `interval_ms` milliseconds
    /// (0 = server default `scheduler.metrics_interval_ms`, clamped
    /// server-side) until either side closes. Keeps session connections
    /// strictly request/reply. See `docs/scheduler.md`.
    SubscribeMetrics { interval_ms: u64 },
    /// v10: reclaim a lingering session on a FRESH connection (instead
    /// of a handshake). `token` is the `session_token` the original
    /// handshake ack carried; within `scheduler.session_linger_s` of the
    /// old connection dropping, the server answers `ReattachAck` and the
    /// connection serves the session as if it had never dropped. An
    /// unknown or expired token answers `Error`. See `docs/recovery.md`.
    Reattach { token: u64 },

    // server -> client
    HandshakeAck {
        session_id: u64,
        version: u32,
        /// Size of the worker group granted to this session.
        granted_workers: u32,
        /// One `host:port` per granted worker, index = the session's
        /// group-local worker rank.
        worker_addrs: Vec<String>,
        /// Effective rows-per-frame for this session after server-side
        /// clamping (v3 negotiation); 0 only from pre-v3 servers.
        rows_per_frame: u32,
        /// Effective socket buffer size after clamping; 0 only from
        /// pre-v3 servers.
        buf_bytes: u64,
        /// Durable session identity for `Reattach` (v10); 0 from pre-v10
        /// servers, or when the server retains nothing on disconnect
        /// (`scheduler.session_linger_s = 0`). Elided at 0 so the frame
        /// keeps the v9 wire shape.
        session_token: u64,
    },
    LibraryRegistered { name: String },
    MatrixCreated {
        id: u64,
        /// Row range owned by each worker rank: `[start, end)`.
        row_ranges: Vec<(u64, u64)>,
    },
    MatrixSealed { id: u64, rows_received: u64 },
    /// Ack of `SubmitTask`: the task is queued (or already running).
    TaskSubmitted { task_id: u64 },
    /// Reply to `TaskStatus` / `CancelTask` / `WaitTask`.
    TaskStatusReply { task_id: u64, state: TaskState },
    /// Reply to `FetchMatrix`. v10: may carry refreshed worker pull
    /// addresses (index = group-local rank) when the session's group
    /// changed since the handshake — after a rank replacement the
    /// original ack's address for the dead slot points at a dead
    /// process. Empty (elided, the v9 wire shape) means the handshake
    /// addresses are still current.
    FetchReady {
        info: MatrixInfo,
        row_ranges: Vec<(u64, u64)>,
        worker_addrs: Vec<String>,
    },
    /// Ack of `LoadMatrix`: the file validated and every worker mapped
    /// and registered its shard. Shape comes from the file header.
    LoadDone { info: MatrixInfo, row_ranges: Vec<(u64, u64)> },
    Freed { id: u64 },
    MatrixList { infos: Vec<MatrixInfo> },
    Error { message: String },
    Bye,
    /// v9: one frame of the scheduler metrics stream (reply stream to
    /// `SubscribeMetrics`). `json` is a single-line JSON object — the
    /// serialized `SchedSnapshot` (see `docs/scheduler.md` for the
    /// schema) — so consumers can pipe the stream as JSON lines without
    /// a protocol decoder of their own. `seq` increments per snapshot so
    /// a consumer can detect drops.
    MetricsSnapshot { seq: u64, json: String },
    /// v10: ack of `Reattach` — everything a reconnecting client needs
    /// to resume: the session id, the (possibly re-formed) worker group
    /// and its current pull addresses, the effective transfer settings,
    /// and the ids of every task the retained table still knows about
    /// (re-queryable via `TaskStatus` / `WaitTask`). See
    /// `docs/recovery.md`.
    ReattachAck {
        session_id: u64,
        granted_workers: u32,
        worker_addrs: Vec<String>,
        rows_per_frame: u32,
        buf_bytes: u64,
        task_ids: Vec<u64>,
    },
}

impl ControlMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            ControlMsg::Handshake {
                client_name,
                version,
                request_workers,
                rows_per_frame,
                buf_bytes,
                priority,
            } => {
                w.u8(0);
                w.str(client_name);
                w.u32(*version);
                w.u32(*request_workers);
                // default transfer requests (0 = "server decides") are
                // elided so the frame keeps the v2 shape: a pre-v3
                // server's strict decoder can still read it and answer
                // with its version-mismatch diagnostic instead of
                // failing on trailing bytes and silently dropping the
                // connection. Explicit requests require a v3 server
                // anyway, so only those frames carry the fields. The v9
                // priority class extends the same chain: a non-default
                // class forces the transfer fields onto the wire
                // (explicit zeros still mean "server decides").
                let explicit_priority = *priority != DEFAULT_PRIORITY;
                if *rows_per_frame != 0 || *buf_bytes != 0 || explicit_priority {
                    w.u32(*rows_per_frame);
                    w.u64(*buf_bytes);
                    if explicit_priority {
                        w.u32(*priority);
                    }
                }
            }
            ControlMsg::RegisterLibrary { name, path } => {
                w.u8(1);
                w.str(name);
                w.str(path);
            }
            ControlMsg::CreateMatrix { name, rows, cols } => {
                w.u8(2);
                w.str(name);
                w.u64(*rows);
                w.u64(*cols);
            }
            ControlMsg::SealMatrix { id } => {
                w.u8(3);
                w.u64(*id);
            }
            ControlMsg::SubmitTask { lib, routine, params } => {
                // tag 4 was v1–v3's blocking RunTask; the payload shape is
                // unchanged, only the reply semantics moved (TaskSubmitted
                // instead of a blocking TaskDone) — gated by the v4 bump
                w.u8(4);
                w.str(lib);
                w.str(routine);
                params.encode(&mut w);
            }
            ControlMsg::FetchMatrix { id } => {
                w.u8(5);
                w.u64(*id);
            }
            ControlMsg::FreeMatrix { id } => {
                w.u8(6);
                w.u64(*id);
            }
            ControlMsg::ListMatrices => w.u8(7),
            ControlMsg::Shutdown => w.u8(8),
            ControlMsg::TaskStatus { task_id } => {
                w.u8(9);
                w.u64(*task_id);
            }
            ControlMsg::CancelTask { task_id, hard_after_ms } => {
                w.u8(10);
                w.u64(*task_id);
                // elided at 0 (pure cooperative cancel) so the frame
                // keeps the v4 wire shape — a v4 server still reads a
                // default cancel correctly
                if *hard_after_ms != 0 {
                    w.u64(*hard_after_ms);
                }
            }
            ControlMsg::WaitTask { task_id, timeout_ms } => {
                w.u8(11);
                w.u64(*task_id);
                w.u64(*timeout_ms);
            }
            ControlMsg::LoadMatrix { name, path } => {
                w.u8(12);
                w.str(name);
                w.str(path);
            }
            ControlMsg::SubscribeMetrics { interval_ms } => {
                w.u8(13);
                w.u64(*interval_ms);
            }
            ControlMsg::Reattach { token } => {
                w.u8(14);
                w.u64(*token);
            }
            ControlMsg::HandshakeAck {
                session_id,
                version,
                granted_workers,
                worker_addrs,
                rows_per_frame,
                buf_bytes,
                session_token,
            } => {
                w.u8(128);
                w.u64(*session_id);
                w.u32(*version);
                w.u32(*granted_workers);
                w.u32(worker_addrs.len() as u32);
                for a in worker_addrs {
                    w.str(a);
                }
                w.u32(*rows_per_frame);
                w.u64(*buf_bytes);
                // elided at 0 (no linger, nothing to reattach to) so the
                // frame keeps the v9 wire shape
                if *session_token != 0 {
                    w.u64(*session_token);
                }
            }
            ControlMsg::LibraryRegistered { name } => {
                w.u8(129);
                w.str(name);
            }
            ControlMsg::MatrixCreated { id, row_ranges } => {
                w.u8(130);
                w.u64(*id);
                encode_ranges(&mut w, row_ranges);
            }
            ControlMsg::MatrixSealed { id, rows_received } => {
                w.u8(131);
                w.u64(*id);
                w.u64(*rows_received);
            }
            // tag 132 (v1–v3 TaskDone) is retired: terminal results travel
            // inside TaskStatusReply's TaskState::Done
            ControlMsg::TaskSubmitted { task_id } => {
                w.u8(138);
                w.u64(*task_id);
            }
            ControlMsg::TaskStatusReply { task_id, state } => {
                w.u8(139);
                w.u64(*task_id);
                state.encode(&mut w);
            }
            ControlMsg::FetchReady { info, row_ranges, worker_addrs } => {
                w.u8(133);
                info.encode(&mut w);
                encode_ranges(&mut w, row_ranges);
                // elided when the handshake addresses are still current,
                // keeping the v9 wire shape
                if !worker_addrs.is_empty() {
                    w.u32(worker_addrs.len() as u32);
                    for a in worker_addrs {
                        w.str(a);
                    }
                }
            }
            ControlMsg::LoadDone { info, row_ranges } => {
                w.u8(140);
                info.encode(&mut w);
                encode_ranges(&mut w, row_ranges);
            }
            ControlMsg::Freed { id } => {
                w.u8(134);
                w.u64(*id);
            }
            ControlMsg::MatrixList { infos } => {
                w.u8(135);
                w.u32(infos.len() as u32);
                for i in infos {
                    i.encode(&mut w);
                }
            }
            ControlMsg::Error { message } => {
                w.u8(136);
                w.str(message);
            }
            ControlMsg::Bye => w.u8(137),
            ControlMsg::MetricsSnapshot { seq, json } => {
                w.u8(141);
                w.u64(*seq);
                w.str(json);
            }
            ControlMsg::ReattachAck {
                session_id,
                granted_workers,
                worker_addrs,
                rows_per_frame,
                buf_bytes,
                task_ids,
            } => {
                w.u8(142);
                w.u64(*session_id);
                w.u32(*granted_workers);
                w.u32(worker_addrs.len() as u32);
                for a in worker_addrs {
                    w.str(a);
                }
                w.u32(*rows_per_frame);
                w.u64(*buf_bytes);
                w.u32(task_ids.len() as u32);
                for t in task_ids {
                    w.u64(*t);
                }
            }
        }
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<Self, ProtocolError> {
        let mut r = Reader::new(buf);
        let msg = match r.u8()? {
            0 => {
                let client_name = r.str()?;
                let version = r.u32()?;
                // older frames stop early (v1 after `version`, v2 after
                // `request_workers`); tolerate the short forms so the
                // server can still answer with its version-mismatch
                // diagnostic instead of dropping the connection. The
                // reverse direction is covered by encode-side elision of
                // default fields — but a v3 client that EXPLICITLY
                // requests transfer settings emits the long form, which
                // a strict pre-v3 server rejects as trailing bytes
                // (silent disconnect, no diagnostic); that residual
                // asymmetry is accepted rather than moving negotiation
                // into a second message.
                let request_workers =
                    if r.remaining() > 0 { r.u32()? } else { 0 };
                let rows_per_frame = if r.remaining() > 0 { r.u32()? } else { 0 };
                let buf_bytes = if r.remaining() > 0 { r.u64()? } else { 0 };
                let priority =
                    if r.remaining() > 0 { r.u32()? } else { DEFAULT_PRIORITY };
                ControlMsg::Handshake {
                    client_name,
                    version,
                    request_workers,
                    rows_per_frame,
                    buf_bytes,
                    priority,
                }
            }
            1 => ControlMsg::RegisterLibrary { name: r.str()?, path: r.str()? },
            2 => ControlMsg::CreateMatrix {
                name: r.str()?,
                rows: r.u64()?,
                cols: r.u64()?,
            },
            3 => ControlMsg::SealMatrix { id: r.u64()? },
            4 => ControlMsg::SubmitTask {
                lib: r.str()?,
                routine: r.str()?,
                params: Params::decode(&mut r)?,
            },
            5 => ControlMsg::FetchMatrix { id: r.u64()? },
            6 => ControlMsg::FreeMatrix { id: r.u64()? },
            7 => ControlMsg::ListMatrices,
            8 => ControlMsg::Shutdown,
            9 => ControlMsg::TaskStatus { task_id: r.u64()? },
            10 => {
                let task_id = r.u64()?;
                // v4 frames stop after the task id (cooperative cancel)
                let hard_after_ms = if r.remaining() > 0 { r.u64()? } else { 0 };
                ControlMsg::CancelTask { task_id, hard_after_ms }
            }
            11 => ControlMsg::WaitTask { task_id: r.u64()?, timeout_ms: r.u64()? },
            12 => ControlMsg::LoadMatrix { name: r.str()?, path: r.str()? },
            13 => ControlMsg::SubscribeMetrics { interval_ms: r.u64()? },
            14 => ControlMsg::Reattach { token: r.u64()? },
            128 => {
                let session_id = r.u64()?;
                let version = r.u32()?;
                let granted_workers = r.u32()?;
                let n = r.u32()?;
                let worker_addrs =
                    (0..n).map(|_| r.str()).collect::<Result<_, _>>()?;
                // pre-v3 acks stop after the addresses
                let rows_per_frame = if r.remaining() > 0 { r.u32()? } else { 0 };
                let buf_bytes = if r.remaining() > 0 { r.u64()? } else { 0 };
                // pre-v10 acks stop after buf_bytes
                let session_token = if r.remaining() > 0 { r.u64()? } else { 0 };
                ControlMsg::HandshakeAck {
                    session_id,
                    version,
                    granted_workers,
                    worker_addrs,
                    rows_per_frame,
                    buf_bytes,
                    session_token,
                }
            }
            129 => ControlMsg::LibraryRegistered { name: r.str()? },
            130 => ControlMsg::MatrixCreated {
                id: r.u64()?,
                row_ranges: decode_ranges(&mut r)?,
            },
            131 => ControlMsg::MatrixSealed {
                id: r.u64()?,
                rows_received: r.u64()?,
            },
            138 => ControlMsg::TaskSubmitted { task_id: r.u64()? },
            139 => ControlMsg::TaskStatusReply {
                task_id: r.u64()?,
                state: TaskState::decode(&mut r)?,
            },
            133 => {
                let info = MatrixInfo::decode(&mut r)?;
                let row_ranges = decode_ranges(&mut r)?;
                // pre-v10 frames stop after the ranges (handshake
                // addresses still current)
                let worker_addrs = if r.remaining() > 0 {
                    let n = r.u32()?;
                    (0..n).map(|_| r.str()).collect::<Result<_, _>>()?
                } else {
                    Vec::new()
                };
                ControlMsg::FetchReady { info, row_ranges, worker_addrs }
            }
            140 => ControlMsg::LoadDone {
                info: MatrixInfo::decode(&mut r)?,
                row_ranges: decode_ranges(&mut r)?,
            },
            134 => ControlMsg::Freed { id: r.u64()? },
            135 => {
                let n = r.u32()?;
                let infos = (0..n)
                    .map(|_| MatrixInfo::decode(&mut r))
                    .collect::<Result<_, _>>()?;
                ControlMsg::MatrixList { infos }
            }
            136 => ControlMsg::Error { message: r.str()? },
            137 => ControlMsg::Bye,
            141 => ControlMsg::MetricsSnapshot { seq: r.u64()?, json: r.str()? },
            142 => {
                let session_id = r.u64()?;
                let granted_workers = r.u32()?;
                let n = r.u32()?;
                let worker_addrs =
                    (0..n).map(|_| r.str()).collect::<Result<_, _>>()?;
                let rows_per_frame = r.u32()?;
                let buf_bytes = r.u64()?;
                let n = r.u32()?;
                let task_ids = (0..n).map(|_| r.u64()).collect::<Result<_, _>>()?;
                ControlMsg::ReattachAck {
                    session_id,
                    granted_workers,
                    worker_addrs,
                    rows_per_frame,
                    buf_bytes,
                    task_ids,
                }
            }
            tag => return Err(ProtocolError::BadTag { tag, what: "ControlMsg" }),
        };
        r.finish()?;
        Ok(msg)
    }
}

/// Executor⇄worker data messages. Rows travel as raw f64 bytes — the
/// paper's "the Spark executor sends each row ... as sequences of bytes".
///
/// v3 pull protocol: `PullRows` is a *ranged* request — the worker
/// answers with a back-to-back stream of `RowsData` frames (each at most
/// the negotiated rows-per-frame) terminated by a `PullDone` trailer, so
/// the per-frame request/reply round-trip of v2 is gone. Clients may keep
/// several ranged requests outstanding per link (windowed pipelining);
/// the worker serves them strictly in arrival order.
#[derive(Debug, Clone, PartialEq)]
pub enum DataMsg {
    // executor -> worker
    DataHandshake {
        session_id: u64,
        executor_id: u32,
        /// Frame granularity the worker should stream pull replies at;
        /// 0 = server default. Normally the session's negotiated value.
        rows_per_frame: u32,
    },
    /// A contiguous batch of rows (row batching is ablation #3; the paper
    /// ships one row at a time, we default to 64/frame and sweep it).
    PushRows { matrix_id: u64, start_row: u64, nrows: u32, ncols: u32, data: Vec<f64> },
    PushDone { matrix_id: u64 },
    /// Ranged pull request; answered by `RowsData`* + `PullDone`.
    ///
    /// v7 adds an optional column range: `sel_cols == 0` means full
    /// width (and then `start_col` must be 0 too); a non-zero `sel_cols`
    /// pulls columns `[start_col, start_col + sel_cols)` of each row, so
    /// tall-skinny readers stop paying full-width frames. The fields are
    /// elided at the defaults, keeping the v6 wire shape.
    PullRows { matrix_id: u64, start_row: u64, nrows: u32, start_col: u64, sel_cols: u32 },
    DataBye,

    // worker -> executor
    DataHandshakeAck { worker_rank: u32 },
    PushDoneAck { matrix_id: u64, rows_received: u64 },
    RowsData { matrix_id: u64, start_row: u64, nrows: u32, ncols: u32, data: Vec<f64> },
    /// End-of-stream trailer for one ranged `PullRows` request.
    PullDone { matrix_id: u64 },
    DataError { message: String },
}

impl DataMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = match self {
            // pre-size payload frames to avoid realloc on the hot path
            DataMsg::PushRows { data, .. } | DataMsg::RowsData { data, .. } => {
                Writer::with_capacity(data.len() * 8 + 64)
            }
            _ => Writer::new(),
        };
        match self {
            DataMsg::DataHandshake { session_id, executor_id, rows_per_frame } => {
                w.u8(0);
                w.u64(*session_id);
                w.u32(*executor_id);
                // elided at the default (0 = "server decides") for the
                // same pre-v3 wire compatibility as ControlMsg::Handshake
                if *rows_per_frame != 0 {
                    w.u32(*rows_per_frame);
                }
            }
            DataMsg::PushRows { matrix_id, start_row, nrows, ncols, data } => {
                debug_assert_eq!(data.len(), *nrows as usize * *ncols as usize);
                w.u8(1);
                w.u64(*matrix_id);
                w.u64(*start_row);
                w.u32(*nrows);
                w.u32(*ncols);
                w.raw_f64s(data);
            }
            DataMsg::PushDone { matrix_id } => {
                w.u8(2);
                w.u64(*matrix_id);
            }
            DataMsg::PullRows { matrix_id, start_row, nrows, start_col, sel_cols } => {
                w.u8(3);
                w.u64(*matrix_id);
                w.u64(*start_row);
                w.u32(*nrows);
                // elided at the defaults (full width) so the frame keeps
                // the v6 wire shape — a v6 worker still serves a
                // full-width pull correctly
                if *start_col != 0 || *sel_cols != 0 {
                    w.u64(*start_col);
                    w.u32(*sel_cols);
                }
            }
            DataMsg::DataBye => w.u8(4),
            DataMsg::DataHandshakeAck { worker_rank } => {
                w.u8(128);
                w.u32(*worker_rank);
            }
            DataMsg::PushDoneAck { matrix_id, rows_received } => {
                w.u8(129);
                w.u64(*matrix_id);
                w.u64(*rows_received);
            }
            DataMsg::RowsData { matrix_id, start_row, nrows, ncols, data } => {
                debug_assert_eq!(data.len(), *nrows as usize * *ncols as usize);
                w.u8(130);
                w.u64(*matrix_id);
                w.u64(*start_row);
                w.u32(*nrows);
                w.u32(*ncols);
                w.raw_f64s(data);
            }
            DataMsg::PullDone { matrix_id } => {
                w.u8(132);
                w.u64(*matrix_id);
            }
            DataMsg::DataError { message } => {
                w.u8(131);
                w.str(message);
            }
        }
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<Self, ProtocolError> {
        let mut r = Reader::new(buf);
        let msg = match r.u8()? {
            0 => {
                let session_id = r.u64()?;
                let executor_id = r.u32()?;
                // pre-v3 frames stop after executor_id
                let rows_per_frame = if r.remaining() > 0 { r.u32()? } else { 0 };
                DataMsg::DataHandshake { session_id, executor_id, rows_per_frame }
            }
            1 => {
                let matrix_id = r.u64()?;
                let start_row = r.u64()?;
                let nrows = r.u32()?;
                let ncols = r.u32()?;
                let data = r.raw_f64s(checked_payload_len(nrows, ncols)?)?;
                DataMsg::PushRows { matrix_id, start_row, nrows, ncols, data }
            }
            2 => DataMsg::PushDone { matrix_id: r.u64()? },
            3 => {
                let matrix_id = r.u64()?;
                let start_row = r.u64()?;
                let nrows = r.u32()?;
                // v6 frames stop after nrows (full-width pull)
                let start_col = if r.remaining() > 0 { r.u64()? } else { 0 };
                let sel_cols = if r.remaining() > 0 { r.u32()? } else { 0 };
                DataMsg::PullRows { matrix_id, start_row, nrows, start_col, sel_cols }
            }
            4 => DataMsg::DataBye,
            128 => DataMsg::DataHandshakeAck { worker_rank: r.u32()? },
            129 => DataMsg::PushDoneAck {
                matrix_id: r.u64()?,
                rows_received: r.u64()?,
            },
            130 => {
                let matrix_id = r.u64()?;
                let start_row = r.u64()?;
                let nrows = r.u32()?;
                let ncols = r.u32()?;
                let data = r.raw_f64s(checked_payload_len(nrows, ncols)?)?;
                DataMsg::RowsData { matrix_id, start_row, nrows, ncols, data }
            }
            131 => DataMsg::DataError { message: r.str()? },
            132 => DataMsg::PullDone { matrix_id: r.u64()? },
            tag => return Err(ProtocolError::BadTag { tag, what: "DataMsg" }),
        };
        r.finish()?;
        Ok(msg)
    }
}

/// Element count of a rows payload, rejecting header combinations whose
/// byte size cannot be a real frame (guards the `nrows * ncols` multiply
/// against overflow before it sizes an allocation or a slice take).
fn checked_payload_len(nrows: u32, ncols: u32) -> Result<usize, ProtocolError> {
    let elems = nrows as u64 * ncols as u64; // u32 * u32 cannot overflow u64
    // compare in ELEMENT space: computing `elems * 8` first could itself
    // wrap u64 for adversarial headers (u32::MAX² · 8 ≈ 2^67), slipping
    // a huge frame past the very guard this function exists to provide
    if elems > (1 << 40) / 8 {
        return Err(ProtocolError::Oversized(elems.saturating_mul(8)));
    }
    let bytes = elems * 8; // ≤ 2^40, cannot wrap
    // the BYTE length must also fit usize, so the `len * 8` at the
    // decode call sites cannot wrap on 32-bit targets; reject rather
    // than truncate (`as usize` would wrap 2^32 elements to 0 and admit
    // the malformed header as an empty payload)
    if usize::try_from(bytes).is_err() {
        return Err(ProtocolError::Oversized(bytes));
    }
    Ok(elems as usize) // bytes fits usize ⇒ elems does too
}

/// Byte length of the fixed header preceding a rows payload on the wire:
/// tag + matrix_id + start_row + nrows + ncols.
pub const ROWS_HEADER_LEN: usize = 1 + 8 + 8 + 4 + 4;

/// Most rows one rows-payload frame may carry at width `ncols` so that
/// `ROWS_HEADER_LEN + rows·ncols·8` stays within `max_frame_bytes`;
/// `None` when even a single row cannot fit. Both legs of the transfer
/// path (client push and worker pull streams) clamp through this one
/// function so the cap can never diverge between them.
pub fn max_rows_per_frame_for(ncols: usize, max_frame_bytes: usize) -> Option<usize> {
    let row_bytes = ncols.max(1).checked_mul(8)?;
    let cap = max_frame_bytes.checked_sub(ROWS_HEADER_LEN)? / row_bytes;
    (cap >= 1).then_some(cap)
}

/// Borrowed-payload twin of the payload-carrying [`DataMsg`] variants —
/// the single-copy encode path. `Framed::send_data_ref` writes the header
/// and the payload's raw little-endian bytes straight into its socket
/// buffer, so the f64s are copied exactly once (payload slice → socket
/// buffer) with no intermediate `Writer` Vec. Wire format is identical to
/// the owned variants; either side may decode with either path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DataMsgRef<'a> {
    PushRows { matrix_id: u64, start_row: u64, nrows: u32, ncols: u32, data: &'a [f64] },
    RowsData { matrix_id: u64, start_row: u64, nrows: u32, ncols: u32, data: &'a [f64] },
}

impl<'a> DataMsgRef<'a> {
    pub fn payload(&self) -> &'a [f64] {
        match *self {
            DataMsgRef::PushRows { data, .. } | DataMsgRef::RowsData { data, .. } => data,
        }
    }

    /// Total frame length (header + payload bytes).
    pub fn frame_len(&self) -> usize {
        ROWS_HEADER_LEN + self.payload().len() * 8
    }

    /// Encode the fixed-size header; callers append the payload's raw
    /// little-endian bytes. Fails if the payload length does not match
    /// `nrows * ncols` (a malformed frame would desync the stream).
    pub fn encode_header(&self) -> Result<[u8; ROWS_HEADER_LEN], ProtocolError> {
        let (tag, matrix_id, start_row, nrows, ncols, data) = match *self {
            DataMsgRef::PushRows { matrix_id, start_row, nrows, ncols, data } => {
                (1u8, matrix_id, start_row, nrows, ncols, data)
            }
            DataMsgRef::RowsData { matrix_id, start_row, nrows, ncols, data } => {
                (130u8, matrix_id, start_row, nrows, ncols, data)
            }
        };
        let want = checked_payload_len(nrows, ncols)?;
        if data.len() != want {
            return Err(ProtocolError::PayloadMismatch {
                want: want * 8,
                got: data.len() * 8,
            });
        }
        let mut h = [0u8; ROWS_HEADER_LEN];
        h[0] = tag;
        h[1..9].copy_from_slice(&matrix_id.to_le_bytes());
        h[9..17].copy_from_slice(&start_row.to_le_bytes());
        h[17..21].copy_from_slice(&nrows.to_le_bytes());
        h[21..25].copy_from_slice(&ncols.to_le_bytes());
        Ok(h)
    }
}

/// Borrowed decode of a data frame — the single-copy decode path. The
/// payload-carrying variants hand out the payload as raw little-endian
/// bytes *pointing into the receive buffer* (not necessarily 8-aligned,
/// hence bytes rather than `&[f64]`); consumers copy exactly once into
/// their destination via [`crate::protocol::wire::copy_le_f64s`]. All
/// other messages decode owned as [`DataMsg`].
#[derive(Debug, PartialEq)]
pub enum DataMsgView<'a> {
    PushRows { matrix_id: u64, start_row: u64, nrows: u32, ncols: u32, payload: &'a [u8] },
    RowsData { matrix_id: u64, start_row: u64, nrows: u32, ncols: u32, payload: &'a [u8] },
    Other(DataMsg),
}

impl<'a> DataMsgView<'a> {
    pub fn decode(buf: &'a [u8]) -> Result<Self, ProtocolError> {
        let tag = buf.first().copied();
        if tag != Some(1) && tag != Some(130) {
            return Ok(DataMsgView::Other(DataMsg::decode(buf)?));
        }
        let mut r = Reader::new(buf);
        let _ = r.u8()?;
        let matrix_id = r.u64()?;
        let start_row = r.u64()?;
        let nrows = r.u32()?;
        let ncols = r.u32()?;
        let payload = r.raw_bytes(checked_payload_len(nrows, ncols)? * 8)?;
        r.finish()?;
        Ok(if tag == Some(1) {
            DataMsgView::PushRows { matrix_id, start_row, nrows, ncols, payload }
        } else {
            DataMsgView::RowsData { matrix_id, start_row, nrows, ncols, payload }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_roundtrip_all_variants() {
        let msgs = vec![
            ControlMsg::Handshake {
                client_name: "spark-app".into(),
                version: 3,
                request_workers: 4,
                rows_per_frame: 128,
                buf_bytes: 1 << 20,
                priority: DEFAULT_PRIORITY,
            },
            ControlMsg::Handshake {
                client_name: "urgent-app".into(),
                version: 10,
                request_workers: 2,
                rows_per_frame: 0,
                buf_bytes: 0,
                priority: 3,
            },
            ControlMsg::Reattach { token: 0xDEAD_BEEF_0123 },
            ControlMsg::RegisterLibrary { name: "skylark".into(), path: "builtin:skylark".into() },
            ControlMsg::CreateMatrix { name: "X".into(), rows: 10, cols: 4 },
            ControlMsg::SealMatrix { id: 3 },
            ControlMsg::SubmitTask {
                lib: "skylark".into(),
                routine: "cg_solve".into(),
                params: Params::new().with_f64("lambda", 1e-5).with_matrix("X", 3),
            },
            ControlMsg::FetchMatrix { id: 3 },
            ControlMsg::FreeMatrix { id: 3 },
            ControlMsg::ListMatrices,
            ControlMsg::Shutdown,
            ControlMsg::TaskStatus { task_id: 12 },
            ControlMsg::CancelTask { task_id: 12, hard_after_ms: 0 },
            ControlMsg::CancelTask { task_id: 12, hard_after_ms: 2_500 },
            ControlMsg::WaitTask { task_id: 12, timeout_ms: 30_000 },
            ControlMsg::LoadMatrix {
                name: "ocean".into(),
                path: "/data/ocean.h5sim".into(),
            },
            ControlMsg::LoadDone {
                info: MatrixInfo { id: 7, rows: 100, cols: 8, name: "ocean".into() },
                row_ranges: vec![(0, 50), (50, 100)],
            },
            ControlMsg::HandshakeAck {
                session_id: 9,
                version: 3,
                granted_workers: 2,
                worker_addrs: vec!["127.0.0.1:4001".into(), "127.0.0.1:4002".into()],
                rows_per_frame: 64,
                buf_bytes: 1 << 20,
                session_token: 0,
            },
            ControlMsg::HandshakeAck {
                session_id: 9,
                version: 10,
                granted_workers: 2,
                worker_addrs: vec!["127.0.0.1:4001".into(), "127.0.0.1:4002".into()],
                rows_per_frame: 64,
                buf_bytes: 1 << 20,
                session_token: 0x5E55_10F0,
            },
            ControlMsg::ReattachAck {
                session_id: 9,
                granted_workers: 2,
                worker_addrs: vec!["127.0.0.1:4001".into(), "127.0.0.1:4002".into()],
                rows_per_frame: 64,
                buf_bytes: 1 << 20,
                task_ids: vec![3, 7, 12],
            },
            ControlMsg::LibraryRegistered { name: "skylark".into() },
            ControlMsg::MatrixCreated { id: 3, row_ranges: vec![(0, 5), (5, 10)] },
            ControlMsg::MatrixSealed { id: 3, rows_received: 10 },
            ControlMsg::TaskSubmitted { task_id: 12 },
            ControlMsg::TaskStatusReply { task_id: 12, state: TaskState::Queued },
            ControlMsg::TaskStatusReply {
                task_id: 12,
                state: TaskState::Running {
                    progress: TaskProgress { iters: 37, residual: 4.5e-3, ranks: 4 },
                },
            },
            ControlMsg::TaskStatusReply {
                task_id: 12,
                state: TaskState::Done {
                    outputs: vec![MatrixInfo { id: 4, rows: 4, cols: 4, name: "W".into() }],
                    scalars: Params::new().with_i64("iters", 526),
                    timings: vec![("compute".into(), 1.5)],
                },
            },
            ControlMsg::TaskStatusReply {
                task_id: 12,
                state: TaskState::Failed {
                    message: "1 of 4 ranks failed; rank 2: boom".into(),
                    failed_ranks: vec![2],
                    total_ranks: 4,
                },
            },
            ControlMsg::TaskStatusReply { task_id: 12, state: TaskState::Cancelled },
            ControlMsg::FetchReady {
                info: MatrixInfo { id: 4, rows: 4, cols: 4, name: "W".into() },
                row_ranges: vec![(0, 4)],
                worker_addrs: vec![],
            },
            ControlMsg::FetchReady {
                info: MatrixInfo { id: 4, rows: 4, cols: 4, name: "W".into() },
                row_ranges: vec![(0, 2), (2, 4)],
                worker_addrs: vec!["127.0.0.1:4001".into(), "127.0.0.1:4005".into()],
            },
            ControlMsg::Freed { id: 4 },
            ControlMsg::MatrixList { infos: vec![] },
            ControlMsg::Error { message: "boom".into() },
            ControlMsg::Bye,
        ];
        for m in msgs {
            let buf = m.encode();
            let back = ControlMsg::decode(&buf).unwrap();
            assert_eq!(m, back);
        }
    }

    #[test]
    fn v1_handshake_without_request_workers_still_decodes() {
        // a protocol-v1 client's frame: tag, name, version — no group size
        let mut w = Writer::new();
        w.u8(0);
        w.str("old-client");
        w.u32(1);
        let msg = ControlMsg::decode(&w.into_bytes()).unwrap();
        assert_eq!(
            msg,
            ControlMsg::Handshake {
                client_name: "old-client".into(),
                version: 1,
                request_workers: 0,
                rows_per_frame: 0,
                buf_bytes: 0,
                priority: DEFAULT_PRIORITY,
            }
        );
    }

    #[test]
    fn v2_handshake_without_transfer_fields_still_decodes() {
        // a protocol-v2 client's frame stops after request_workers; the
        // transfer-negotiation fields default to "server decides"
        let mut w = Writer::new();
        w.u8(0);
        w.str("v2-client");
        w.u32(2);
        w.u32(3);
        let msg = ControlMsg::decode(&w.into_bytes()).unwrap();
        assert_eq!(
            msg,
            ControlMsg::Handshake {
                client_name: "v2-client".into(),
                version: 2,
                request_workers: 3,
                rows_per_frame: 0,
                buf_bytes: 0,
                priority: DEFAULT_PRIORITY,
            }
        );
        // same for the data-socket handshake
        let mut w = Writer::new();
        w.u8(0);
        w.u64(9);
        w.u32(1);
        let msg = DataMsg::decode(&w.into_bytes()).unwrap();
        assert_eq!(
            msg,
            DataMsg::DataHandshake { session_id: 9, executor_id: 1, rows_per_frame: 0 }
        );
    }

    #[test]
    fn default_v3_handshake_keeps_v2_wire_shape() {
        // a v3 client with default transfer settings must emit a frame a
        // STRICT pre-v3 decoder accepts (so an old server can reply with
        // its version-mismatch diagnostic, not a silent disconnect):
        // byte-identical to the hand-built v2 form, and still roundtrips
        let msg = ControlMsg::Handshake {
            client_name: "new-client".into(),
            version: 3,
            request_workers: 2,
            rows_per_frame: 0,
            buf_bytes: 0,
            priority: DEFAULT_PRIORITY,
        };
        let mut v2 = Writer::new();
        v2.u8(0);
        v2.str("new-client");
        v2.u32(3);
        v2.u32(2);
        assert_eq!(msg.encode(), v2.into_bytes());
        assert_eq!(ControlMsg::decode(&msg.encode()).unwrap(), msg);

        // same for the data-socket handshake
        let msg = DataMsg::DataHandshake {
            session_id: 9,
            executor_id: 1,
            rows_per_frame: 0,
        };
        let mut v2 = Writer::new();
        v2.u8(0);
        v2.u64(9);
        v2.u32(1);
        assert_eq!(msg.encode(), v2.into_bytes());
        assert_eq!(DataMsg::decode(&msg.encode()).unwrap(), msg);
    }

    #[test]
    fn tokenless_ack_and_addrless_fetch_keep_v9_wire_shape() {
        // a v10 server with linger disabled (token = 0) must emit an ack
        // byte-identical to the v9 frame, and a hand-built v9 ack must
        // decode with token 0 (nothing to reattach to)
        let msg = ControlMsg::HandshakeAck {
            session_id: 9,
            version: 10,
            granted_workers: 1,
            worker_addrs: vec!["127.0.0.1:4001".into()],
            rows_per_frame: 64,
            buf_bytes: 1 << 20,
            session_token: 0,
        };
        let mut v9 = Writer::new();
        v9.u8(128);
        v9.u64(9);
        v9.u32(10);
        v9.u32(1);
        v9.u32(1);
        v9.str("127.0.0.1:4001");
        v9.u32(64);
        v9.u64(1 << 20);
        assert_eq!(msg.encode(), v9.into_bytes());
        assert_eq!(ControlMsg::decode(&msg.encode()).unwrap(), msg);

        // same chain for FetchReady without refreshed addresses
        let msg = ControlMsg::FetchReady {
            info: MatrixInfo { id: 4, rows: 4, cols: 2, name: "W".into() },
            row_ranges: vec![(0, 4)],
            worker_addrs: vec![],
        };
        let mut v9 = Writer::new();
        v9.u8(133);
        v9.u64(4);
        v9.u64(4);
        v9.u64(2);
        v9.str("W");
        v9.u32(1);
        v9.u64(0);
        v9.u64(4);
        assert_eq!(msg.encode(), v9.into_bytes());
        assert_eq!(ControlMsg::decode(&msg.encode()).unwrap(), msg);
    }

    #[test]
    fn data_roundtrip_all_variants() {
        let msgs = vec![
            DataMsg::DataHandshake { session_id: 9, executor_id: 2, rows_per_frame: 64 },
            DataMsg::PushRows {
                matrix_id: 3,
                start_row: 100,
                nrows: 2,
                ncols: 3,
                data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            },
            DataMsg::PushDone { matrix_id: 3 },
            DataMsg::PullRows {
                matrix_id: 3,
                start_row: 0,
                nrows: 5,
                start_col: 0,
                sel_cols: 0,
            },
            DataMsg::PullRows {
                matrix_id: 3,
                start_row: 2,
                nrows: 5,
                start_col: 4,
                sel_cols: 2,
            },
            DataMsg::DataBye,
            DataMsg::DataHandshakeAck { worker_rank: 1 },
            DataMsg::PushDoneAck { matrix_id: 3, rows_received: 10 },
            DataMsg::RowsData {
                matrix_id: 3,
                start_row: 0,
                nrows: 1,
                ncols: 2,
                data: vec![7.0, 8.0],
            },
            DataMsg::PullDone { matrix_id: 3 },
            DataMsg::DataError { message: "nope".into() },
        ];
        for m in msgs {
            let buf = m.encode();
            assert_eq!(m, DataMsg::decode(&buf).unwrap());
        }
    }

    #[test]
    fn borrowed_encode_matches_owned_wire_format() {
        let data = vec![1.5, -2.5, 3.25, 0.0, 7.0, -8.0];
        let owned = DataMsg::PushRows {
            matrix_id: 11,
            start_row: 42,
            nrows: 2,
            ncols: 3,
            data: data.clone(),
        };
        let bytes = owned.encode();
        let r = DataMsgRef::PushRows {
            matrix_id: 11,
            start_row: 42,
            nrows: 2,
            ncols: 3,
            data: &data,
        };
        let header = r.encode_header().unwrap();
        assert_eq!(&bytes[..ROWS_HEADER_LEN], &header[..]);
        assert_eq!(bytes.len(), r.frame_len());
        // and the borrowed decode sees the same frame
        match DataMsgView::decode(&bytes).unwrap() {
            DataMsgView::PushRows { matrix_id, start_row, nrows, ncols, payload } => {
                assert_eq!((matrix_id, start_row, nrows, ncols), (11, 42, 2, 3));
                assert_eq!(payload, &bytes[ROWS_HEADER_LEN..]);
                let mut out = vec![0f64; 6];
                crate::protocol::wire::copy_le_f64s(payload, &mut out);
                assert_eq!(out, data);
            }
            other => panic!("unexpected view {other:?}"),
        }
    }

    #[test]
    fn borrowed_view_passes_other_messages_through() {
        let bye = DataMsg::PullDone { matrix_id: 5 };
        match DataMsgView::decode(&bye.encode()).unwrap() {
            DataMsgView::Other(m) => assert_eq!(m, bye),
            other => panic!("unexpected view {other:?}"),
        }
        // RowsData goes through the borrowed arm
        let rd = DataMsg::RowsData {
            matrix_id: 1,
            start_row: 0,
            nrows: 1,
            ncols: 1,
            data: vec![9.0],
        };
        assert!(matches!(
            DataMsgView::decode(&rd.encode()).unwrap(),
            DataMsgView::RowsData { .. }
        ));
    }

    #[test]
    fn borrowed_encode_rejects_mismatched_payload() {
        let data = vec![1.0, 2.0, 3.0];
        let bad = DataMsgRef::RowsData {
            matrix_id: 1,
            start_row: 0,
            nrows: 2,
            ncols: 2, // wants 4 values, slice has 3
            data: &data,
        };
        assert!(matches!(
            bad.encode_header(),
            Err(ProtocolError::PayloadMismatch { .. })
        ));
    }

    #[test]
    fn oversized_row_headers_rejected_before_allocation() {
        // nrows * ncols * 8 far beyond any real frame: decode must refuse
        // without trying to take (or allocate) the payload
        let mut w = Writer::new();
        w.u8(1); // PushRows
        w.u64(1);
        w.u64(0);
        w.u32(u32::MAX);
        w.u32(u32::MAX);
        let bytes = w.into_bytes();
        assert!(matches!(
            DataMsg::decode(&bytes),
            Err(ProtocolError::Oversized(_))
        ));
        assert!(matches!(
            DataMsgView::decode(&bytes),
            Err(ProtocolError::Oversized(_))
        ));

        // a header whose BYTE count wraps u64 to exactly 0 (2^31 rows ·
        // 2^30 cols · 8 = 2^64): must be rejected, not decoded as an
        // empty payload
        let mut w = Writer::new();
        w.u8(130); // RowsData
        w.u64(1);
        w.u64(0);
        w.u32(1 << 31);
        w.u32(1 << 30);
        let bytes = w.into_bytes();
        assert!(matches!(
            DataMsg::decode(&bytes),
            Err(ProtocolError::Oversized(_))
        ));
        assert!(matches!(
            DataMsgView::decode(&bytes),
            Err(ProtocolError::Oversized(_))
        ));
    }

    #[test]
    fn frame_row_cap_covers_header_for_any_width() {
        let max = 1usize << 30;
        let cap = max_rows_per_frame_for(1024, max).unwrap();
        assert!(ROWS_HEADER_LEN + cap * 1024 * 8 <= max);
        assert!(ROWS_HEADER_LEN + (cap + 1) * 1024 * 8 > max);
        // zero-width degenerates to width 1
        assert_eq!(max_rows_per_frame_for(0, max), max_rows_per_frame_for(1, max));
        // one row as wide as the whole frame budget cannot be framed
        assert_eq!(max_rows_per_frame_for(max / 8, max), None);
        // pathological widths must not overflow the byte math
        assert_eq!(max_rows_per_frame_for(usize::MAX, max), None);
    }

    #[test]
    fn default_pull_keeps_v6_wire_shape() {
        // a full-width pull must be byte-identical to the v6 frame, and
        // a hand-built v6 frame must decode as full width
        let msg = DataMsg::PullRows {
            matrix_id: 3,
            start_row: 10,
            nrows: 4,
            start_col: 0,
            sel_cols: 0,
        };
        let mut v6 = Writer::new();
        v6.u8(3);
        v6.u64(3);
        v6.u64(10);
        v6.u32(4);
        assert_eq!(msg.encode(), v6.into_bytes());

        let mut v6 = Writer::new();
        v6.u8(3);
        v6.u64(9);
        v6.u64(0);
        v6.u32(2);
        assert_eq!(
            DataMsg::decode(&v6.into_bytes()).unwrap(),
            DataMsg::PullRows {
                matrix_id: 9,
                start_row: 0,
                nrows: 2,
                start_col: 0,
                sel_cols: 0,
            }
        );
    }

    #[test]
    fn task_state_terminality() {
        assert!(!TaskState::Queued.is_terminal());
        assert!(!TaskState::Running {
            progress: TaskProgress { iters: 1, residual: -1.0, ranks: 2 }
        }
        .is_terminal());
        assert!(TaskState::Cancelled.is_terminal());
        assert!(TaskState::Failed {
            message: "x".into(),
            failed_ranks: vec![0],
            total_ranks: 1
        }
        .is_terminal());
        assert!(TaskState::Done {
            outputs: vec![],
            scalars: Params::new(),
            timings: vec![]
        }
        .is_terminal());
    }

    #[test]
    fn default_cancel_keeps_v4_wire_shape() {
        // a cooperative cancel (hard_after_ms = 0) must be byte-identical
        // to the v4 frame, and a hand-built v4 frame must decode with the
        // escalation disarmed
        let msg = ControlMsg::CancelTask { task_id: 7, hard_after_ms: 0 };
        let mut v4 = Writer::new();
        v4.u8(10);
        v4.u64(7);
        assert_eq!(msg.encode(), v4.into_bytes());

        let mut v4 = Writer::new();
        v4.u8(10);
        v4.u64(9);
        assert_eq!(
            ControlMsg::decode(&v4.into_bytes()).unwrap(),
            ControlMsg::CancelTask { task_id: 9, hard_after_ms: 0 }
        );
    }

    #[test]
    fn retired_taskdone_tag_rejected() {
        // tag 132 carried the blocking TaskDone reply through v3; v4
        // retired it (results travel inside TaskStatusReply::Done)
        assert!(ControlMsg::decode(&[132]).is_err());
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(ControlMsg::decode(&[250]).is_err());
        assert!(DataMsg::decode(&[]).is_err());
        // truncated PushRows payload
        let m = DataMsg::PushRows {
            matrix_id: 1,
            start_row: 0,
            nrows: 1,
            ncols: 2,
            data: vec![1.0, 2.0],
        };
        let buf = m.encode();
        assert!(DataMsg::decode(&buf[..buf.len() - 1]).is_err());
        // trailing bytes
        let mut buf2 = DataMsg::DataBye.encode();
        buf2.push(0);
        assert!(DataMsg::decode(&buf2).is_err());
    }
}
