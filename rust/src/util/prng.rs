//! Deterministic PRNG (xoshiro256++ seeded via splitmix64).
//!
//! `rand` is not in the offline vendor set, and determinism matters more
//! here than raw quality: workload generators, property tests, and the
//! straggler-jitter model all need replayable streams keyed by a config
//! seed.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box–Muller pair.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (e.g. per worker rank) from this seed.
    pub fn derive(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (n > 0), via rejection-free Lemire.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to keep ln finite.
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Vector of standard normals.
    pub fn normals(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let mut c = Rng::new(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs = r.normals(n);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn derive_streams_differ() {
        let base = Rng::new(5);
        let mut a = base.derive(0);
        let mut b = base.derive(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
