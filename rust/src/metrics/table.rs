//! ASCII table printer — benches print the same rows the paper's tables
//! report, so EXPERIMENTS.md can be filled by copy-paste.

#[derive(Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncols {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s
        };
        let sep: String = {
            let mut s = String::from("|");
            for w in &widths {
                s.push_str(&format!("{}|", "-".repeat(w + 2)));
            }
            s
        };
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "long header"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["wide cell".into(), "3".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        let lines: Vec<&str> = s.lines().skip(1).collect();
        // all body lines are equally wide
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
