//! [`Communicator`] over TCP: worker ranks as separate OS processes.
//!
//! `TcpComm` puts the collectives on the wire (ROADMAP item 1 /
//! `docs/fabric.md`). The coordinator brokers a full peer mesh once at
//! group formation — every pair of ranks holds one direct TCP link — and
//! from then on collective traffic flows rank↔rank without touching the
//! coordinator (control-plane only, exactly the paper's MPI deployment
//! shape).
//!
//! **Fast path.** `send` stays non-blocking and infallible: messages go
//! onto a per-peer queue drained by a dedicated sender thread per link.
//! Small messages ride the link's write buffer (eager — they coalesce
//! with neighbors and flush when the queue drains); payloads of
//! `fabric.eager_bytes` or more skip the buffer entirely and go out as
//! one gathered `writev` of length prefix + 17-byte header + the
//! `Vec<f64>`'s raw bytes — zero user-space copies of the payload on the
//! send leg. The receive leg decodes borrowed out of each link's
//! reusable frame buffer ([`crate::net::Framed::recv_ref`]) and performs
//! exactly one copy, frame buffer → delivered `Vec<f64>`.
//!
//! **Failure propagation.** The transport maps straight onto PR 4's
//! poison machinery: a dropped rank socket poisons the group with
//! [`PoisonCause::RankFailed`] naming the dead peer, so every rank
//! blocked in — or later entering — a collective wakes with
//! [`CommError::PeerFailed`] instead of hanging on a contribution that
//! will never come. A locally observed poison is also *broadcast* over
//! the mesh so peers learn the root cause even when their own link to
//! the failed rank is still healthy.
//!
//! **Epochs.** The dispatcher resets the fabric between tasks; on a
//! network transport a straggler frame from the previous task could
//! otherwise arrive after the reset and satisfy the wrong recv. Every
//! data/poison frame carries the sender's epoch: receivers drop frames
//! from past epochs, deliver the current one, and park future ones
//! (applied when the local reset catches up).

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Context;

use super::{lane_of_tag, CommError, Communicator, Fabric, PoisonCause, LANE_ALL};
use crate::net::{Framed, MAX_FRAME};
use crate::protocol::fabric::{fabric_data_header, FabricFrame};
use crate::protocol::le_f64s_to_vec;

/// Transport knobs for one mesh (`config.fabric`, see `docs/fabric.md`).
#[derive(Debug, Clone)]
pub struct FabricOptions {
    /// Payload bytes at or above which a data frame leaves the eager
    /// (buffered) path for a gathered `writev` (the rendezvous leg).
    pub eager_bytes: usize,
    /// Socket write-buffer size per link.
    pub buf_bytes: usize,
    /// How long mesh formation may wait for every peer link.
    pub form_timeout: Duration,
}

impl Default for FabricOptions {
    fn default() -> Self {
        FabricOptions {
            eager_bytes: 4 << 10,
            buf_bytes: 1 << 20,
            form_timeout: Duration::from_secs(20),
        }
    }
}

/// Largest Hello frame the mesh acceptor will read (a Hello is ~13
/// bytes; anything bigger is a stray connection, not a peer).
const MAX_HELLO_FRAME: u32 = 4 << 10;

/// Bit set on internal barrier tags so they can never collide with the
/// `TAG_WINDOW`-aligned tags the collectives use.
const BARRIER_TAG_BIT: u64 = 1 << 63;

/// What one rank's mailbox holds: messages are addressed by
/// `(from, tag)` and delivered in per-(sender, tag) order, exactly the
/// [`Communicator`] contract.
struct MailState {
    /// Current group epoch (bumped by [`TcpComm::reset`]).
    epoch: u64,
    queues: HashMap<(usize, u64), VecDeque<Vec<f64>>>,
    /// Frames stamped with a *future* epoch: the peer reset before we
    /// did. Applied (or re-parked) when our reset catches up.
    parked: Vec<ParkedFrame>,
    poison: Option<PoisonCause>,
    /// Per-lane poison (protocol v9): a hard-cancelled task's lane fails
    /// without touching a sibling task's traffic on this same mesh.
    /// Group-wide `poison` (above) overrides every lane.
    lane_poison: HashMap<u64, PoisonCause>,
    /// Lane retirement (protocol v9, monotonic lane numbering): every
    /// lane ≤ `retired_floor` is retired, plus the out-of-order tail in
    /// `retired`. Arriving data/poison frames for a retired lane are
    /// dropped — unlike [`LocalComm`](super::LocalComm), a TCP send can
    /// still be in flight when the task's last rank replies, so draining
    /// the queues alone would leak stragglers into the mailbox forever.
    /// Lane numbering survives `reset` (it is session-scoped, not
    /// epoch-scoped), so these fields are never cleared.
    retired_floor: u64,
    retired: std::collections::BTreeSet<u64>,
    /// Barrier invocation counter (scopes barrier tags; reset with the
    /// epoch so barriers across tasks cannot collide).
    barrier_gen: u64,
}

impl MailState {
    fn lane_retired(&self, lane: u64) -> bool {
        lane != 0 && (lane <= self.retired_floor || self.retired.contains(&lane))
    }

    /// The poison governing a tag in `lane`: group-wide first (root
    /// cause), then the lane's own.
    fn lane_poisoned(&self, lane: u64) -> Option<PoisonCause> {
        self.poison.or_else(|| self.lane_poison.get(&lane).copied())
    }
}

enum ParkedFrame {
    Data { epoch: u64, from: usize, tag: u64, data: Vec<f64> },
    Poison { epoch: u64, lane: u64, cause: PoisonCause },
}

struct NetShared {
    rank: usize,
    size: usize,
    mail: Mutex<MailState>,
    signal: Condvar,
    /// Mirrors `mail.poison.is_some()` for lock-free fast-path checks.
    poison_flag: AtomicBool,
    /// Set by `close`: subsequent socket errors/EOFs are orderly
    /// teardown, not rank failures.
    closing: AtomicBool,
    /// Epoch to stamp outgoing frames with (mirrors `mail.epoch`;
    /// senders read it without taking the mail lock).
    send_epoch: AtomicU64,
}

impl NetShared {
    /// First poison wins (it is the root cause); wake every waiter.
    fn poison(&self, cause: PoisonCause) {
        let mut mail = self.mail.lock().unwrap();
        if mail.poison.is_none() {
            mail.poison = Some(cause);
            self.poison_flag.store(true, Ordering::Release);
            self.signal.notify_all();
        }
    }

    /// Lane counterpart of [`NetShared::poison`]: first cause per lane
    /// wins; a retired lane's poison is dropped (its task already ended).
    fn poison_lane(&self, lane: u64, cause: PoisonCause) {
        let mut mail = self.mail.lock().unwrap();
        if mail.lane_retired(lane) {
            return;
        }
        mail.lane_poison.entry(lane).or_insert(cause);
        self.signal.notify_all();
    }
}

/// One peer link's outgoing queue, drained by its sender thread.
enum SendItem {
    Msg { epoch: u64, tag: u64, data: Vec<f64> },
    /// `lane == LANE_ALL` poisons the peer's whole group.
    Poison { epoch: u64, lane: u64, cause: PoisonCause },
    Shutdown,
}

struct SendQueue {
    q: Mutex<VecDeque<SendItem>>,
    cv: Condvar,
}

impl SendQueue {
    fn push(&self, item: SendItem) {
        self.q.lock().unwrap().push_back(item);
        self.cv.notify_one();
    }
}

/// A [`Communicator`] whose ranks are separate OS processes joined by a
/// full TCP mesh. See the module docs for the design.
pub struct TcpComm {
    shared: Arc<NetShared>,
    /// Per-peer send queues; `None` at this rank's own index.
    queues: Vec<Option<Arc<SendQueue>>>,
    /// One stream clone per peer, kept for `shutdown` at close.
    streams: Vec<Option<TcpStream>>,
    senders: Mutex<Vec<std::thread::JoinHandle<()>>>,
    receivers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    closed: AtomicBool,
}

// -- mesh formation ---------------------------------------------------------

/// Accepts incoming mesh links on behalf of every group this worker
/// process hosts, routing each freshly connected peer to the
/// [`TcpComm::form`] call for its session (by the `session_id` in the
/// peer's Hello). One acceptor (and one listening port) per worker
/// process, shared by all its sessions.
pub struct MeshAcceptor {
    addr: String,
    /// Loopback-reachable `host:port` of the actual listener, used to
    /// wake the blocking accept at drop (the advertised `addr` may be a
    /// hostname this process cannot dial, e.g. behind NAT).
    wake_addr: String,
    state: Arc<Mutex<AcceptorState>>,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

#[derive(Default)]
struct AcceptorState {
    /// Live `form` calls waiting for peers, by session id.
    routes: HashMap<u64, mpsc::Sender<(usize, TcpStream)>>,
    /// Peers that connected before their session's `form` registered
    /// (formation is concurrent across ranks — arrival order is free).
    pending: HashMap<u64, Vec<(usize, TcpStream)>>,
}

impl MeshAcceptor {
    /// Bind a mesh listener on an ephemeral loopback port and start
    /// accepting (the single-host default).
    pub fn bind() -> crate::Result<Self> {
        Self::bind_advertised("")
    }

    /// Bind a mesh listener and start accepting. `advertise` is the host
    /// (name or IP, no port) peers should dial — `fabric.advertise_addr`.
    /// Empty binds loopback and advertises `127.0.0.1:port` (identical to
    /// [`MeshAcceptor::bind`]); non-empty binds all interfaces and
    /// advertises `advertise:port`, so ranks on other hosts can form a
    /// mesh with this one (v10, `docs/fabric.md`).
    pub fn bind_advertised(advertise: &str) -> crate::Result<Self> {
        let bind_addr = if advertise.is_empty() { "127.0.0.1:0" } else { "0.0.0.0:0" };
        let listener =
            TcpListener::bind(bind_addr).context("binding mesh listener")?;
        let local = listener.local_addr().context("mesh listener addr")?;
        let addr = if advertise.is_empty() {
            local.to_string()
        } else {
            format!("{advertise}:{}", local.port())
        };
        let wake_addr = format!("127.0.0.1:{}", local.port());
        let state = Arc::new(Mutex::new(AcceptorState::default()));
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let state = Arc::clone(&state);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("mesh-accept".into())
                .spawn(move || accept_loop(listener, state, stop))
                .context("spawning mesh acceptor")?
        };
        Ok(MeshAcceptor { addr, wake_addr, state, stop, thread: Some(thread) })
    }

    /// The `host:port` peers should connect to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Route incoming links for `session_id` to the returned channel
    /// (any that already arrived are replayed in arrival order).
    fn register(&self, session_id: u64) -> mpsc::Receiver<(usize, TcpStream)> {
        let (tx, rx) = mpsc::channel();
        let mut state = self.state.lock().unwrap();
        if let Some(backlog) = state.pending.remove(&session_id) {
            for conn in backlog {
                let _ = tx.send(conn);
            }
        }
        state.routes.insert(session_id, tx);
        rx
    }

    fn unregister(&self, session_id: u64) {
        let mut state = self.state.lock().unwrap();
        state.routes.remove(&session_id);
        state.pending.remove(&session_id);
    }
}

impl Drop for MeshAcceptor {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        // wake the blocking accept with a throwaway connection (via the
        // loopback wake address — the advertised one may not be dialable
        // from this process)
        let _ = TcpStream::connect(&self.wake_addr);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    state: Arc<Mutex<AcceptorState>>,
    stop: Arc<AtomicBool>,
) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if stop.load(Ordering::Acquire) {
                    return;
                }
                continue;
            }
        };
        if stop.load(Ordering::Acquire) {
            return;
        }
        // read the Hello inline (peers send it immediately on connect; a
        // bounded read timeout keeps a wedged stray from stalling the
        // loop forever)
        let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
        let (session_id, from_rank) = match read_hello(&stream) {
            Ok(h) => h,
            Err(e) => {
                log::debug!("mesh acceptor: dropping connection: {e:#}");
                continue;
            }
        };
        let _ = stream.set_read_timeout(None);
        let mut state = state.lock().unwrap();
        match state.routes.get(&session_id) {
            Some(tx) => {
                // a closed route (form finished/failed) just drops the
                // connection, which is the right outcome for a straggler
                let _ = tx.send((from_rank, stream));
            }
            None => {
                state
                    .pending
                    .entry(session_id)
                    .or_default()
                    .push((from_rank, stream));
            }
        }
    }
}

fn read_hello(mut stream: &TcpStream) -> crate::Result<(u64, usize)> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf).context("reading hello length")?;
    let len = u32::from_le_bytes(len_buf);
    anyhow::ensure!(len <= MAX_HELLO_FRAME, "hello frame of {len} bytes");
    let mut buf = vec![0u8; len as usize];
    stream.read_exact(&mut buf).context("reading hello frame")?;
    match FabricFrame::decode(&buf)? {
        FabricFrame::Hello { session_id, from_rank } => {
            Ok((session_id, from_rank as usize))
        }
        other => anyhow::bail!("expected Hello, got {other:?}"),
    }
}

fn write_hello(stream: &mut TcpStream, session_id: u64, from_rank: usize) -> crate::Result<()> {
    let frame = FabricFrame::Hello { session_id, from_rank: from_rank as u32 }.encode();
    stream.write_all(&(frame.len() as u32).to_le_bytes()).context("writing hello")?;
    stream.write_all(&frame).context("writing hello")?;
    Ok(())
}

impl TcpComm {
    /// Join the full mesh for one group: connect to every lower-ranked
    /// peer (sending a Hello) and accept every higher-ranked one through
    /// `acceptor` — each pair of ranks ends up with exactly one link.
    /// `peer_addrs[j]` is rank `j`'s mesh listener; this rank's own
    /// entry is ignored. Blocks until the mesh is complete or
    /// `opts.form_timeout` expires.
    pub fn form(
        acceptor: &MeshAcceptor,
        session_id: u64,
        rank: usize,
        peer_addrs: &[String],
        opts: &FabricOptions,
    ) -> crate::Result<TcpComm> {
        let size = peer_addrs.len();
        anyhow::ensure!(rank < size, "rank {rank} outside group of {size}");
        let deadline = Instant::now() + opts.form_timeout;
        let rx = acceptor.register(session_id);
        let result = Self::form_inner(session_id, rank, peer_addrs, opts, deadline, &rx);
        acceptor.unregister(session_id);
        result
    }

    fn form_inner(
        session_id: u64,
        rank: usize,
        peer_addrs: &[String],
        opts: &FabricOptions,
        deadline: Instant,
        rx: &mpsc::Receiver<(usize, TcpStream)>,
    ) -> crate::Result<TcpComm> {
        let size = peer_addrs.len();
        let mut links: Vec<Option<TcpStream>> = (0..size).map(|_| None).collect();
        // dial every lower rank (they accept; ties are impossible, so the
        // mesh gets exactly one link per pair)
        for (j, addr) in peer_addrs.iter().enumerate().take(rank) {
            let mut stream = connect_until(addr, deadline)
                .with_context(|| format!("dialing mesh peer rank {j} at {addr}"))?;
            write_hello(&mut stream, session_id, rank)?;
            links[j] = Some(stream);
        }
        // accept every higher rank
        let mut missing = size - rank - 1;
        while missing > 0 {
            let remaining = deadline
                .checked_duration_since(Instant::now())
                .ok_or_else(|| anyhow::anyhow!("mesh formation timed out"))?;
            let (from, stream) = rx
                .recv_timeout(remaining)
                .map_err(|_| anyhow::anyhow!("mesh formation timed out"))?;
            anyhow::ensure!(
                from > rank && from < size,
                "unexpected mesh hello from rank {from}"
            );
            anyhow::ensure!(
                links[from].is_none(),
                "duplicate mesh hello from rank {from}"
            );
            links[from] = Some(stream);
            missing -= 1;
        }
        Self::from_links(rank, links, opts)
    }

    /// Wire up the threads over an already-complete set of links.
    fn from_links(
        rank: usize,
        links: Vec<Option<TcpStream>>,
        opts: &FabricOptions,
    ) -> crate::Result<TcpComm> {
        let size = links.len();
        let shared = Arc::new(NetShared {
            rank,
            size,
            mail: Mutex::new(MailState {
                epoch: 0,
                queues: HashMap::new(),
                parked: Vec::new(),
                poison: None,
                lane_poison: HashMap::new(),
                retired_floor: 0,
                retired: std::collections::BTreeSet::new(),
                barrier_gen: 0,
            }),
            signal: Condvar::new(),
            poison_flag: AtomicBool::new(false),
            closing: AtomicBool::new(false),
            send_epoch: AtomicU64::new(0),
        });
        let mut queues: Vec<Option<Arc<SendQueue>>> = Vec::with_capacity(size);
        let mut streams: Vec<Option<TcpStream>> = Vec::with_capacity(size);
        let mut senders = Vec::new();
        let mut receivers = Vec::new();
        for (peer, link) in links.into_iter().enumerate() {
            let Some(stream) = link else {
                queues.push(None);
                streams.push(None);
                continue;
            };
            let queue = Arc::new(SendQueue {
                q: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
            });
            let wstream = stream.try_clone().context("cloning mesh stream")?;
            let rstream = stream.try_clone().context("cloning mesh stream")?;
            let framed = Framed::tcp(wstream, opts.buf_bytes)?;
            senders.push(
                std::thread::Builder::new()
                    .name(format!("mesh-send-{rank}-{peer}"))
                    .spawn({
                        let queue = Arc::clone(&queue);
                        let shared = Arc::clone(&shared);
                        let eager = opts.eager_bytes;
                        move || sender_loop(framed, queue, shared, peer, eager)
                    })
                    .context("spawning mesh sender")?,
            );
            receivers.push(
                std::thread::Builder::new()
                    .name(format!("mesh-recv-{rank}-{peer}"))
                    .spawn({
                        let shared = Arc::clone(&shared);
                        move || receiver_loop(rstream, shared, peer)
                    })
                    .context("spawning mesh receiver")?,
            );
            queues.push(Some(queue));
            streams.push(Some(stream));
        }
        Ok(TcpComm {
            shared,
            queues,
            streams,
            senders: Mutex::new(senders),
            receivers: Mutex::new(receivers),
            closed: AtomicBool::new(false),
        })
    }

    /// Bump the group epoch and clear all transient state — queued
    /// messages, poison, barrier generations. Frames stamped with a past
    /// epoch that are still in flight will be dropped on arrival; frames
    /// from peers that reset before us are parked and applied here.
    pub fn reset(&self) {
        let mut mail = self.shared.mail.lock().unwrap();
        mail.epoch += 1;
        let epoch = mail.epoch;
        self.shared.send_epoch.store(epoch, Ordering::Release);
        mail.queues.clear();
        mail.poison = None;
        mail.lane_poison.clear();
        // lane retirement is NOT cleared: lane numbering is session-
        // scoped and monotonic, independent of the epoch
        mail.barrier_gen = 0;
        self.shared.poison_flag.store(false, Ordering::Release);
        // apply (or keep parking) frames from peers that are ahead of us
        for frame in std::mem::take(&mut mail.parked) {
            match frame {
                ParkedFrame::Data { epoch: e, from, tag, data } => {
                    if e == epoch {
                        if !mail.lane_retired(lane_of_tag(tag)) {
                            mail.queues.entry((from, tag)).or_default().push_back(data);
                        }
                    } else if e > epoch {
                        mail.parked.push(ParkedFrame::Data { epoch: e, from, tag, data });
                    }
                }
                ParkedFrame::Poison { epoch: e, lane, cause } => {
                    if e == epoch {
                        if lane == LANE_ALL {
                            if mail.poison.is_none() {
                                mail.poison = Some(cause);
                                self.shared.poison_flag.store(true, Ordering::Release);
                            }
                        } else if !mail.lane_retired(lane) {
                            mail.lane_poison.entry(lane).or_insert(cause);
                        }
                    } else if e > epoch {
                        mail.parked.push(ParkedFrame::Poison { epoch: e, lane, cause });
                    }
                }
            }
        }
        self.shared.signal.notify_all();
    }

    /// Retire one task's tag lane (protocol v9): drop its queued and
    /// parked messages, clear its lane poison, and record the lane so
    /// frames still in flight are dropped on arrival. Monotonic lane
    /// numbering keeps the bookkeeping O(concurrent tasks): the floor
    /// advances over every consecutive run of retired lanes.
    pub fn retire_lane(&self, lane: u64) {
        if lane == 0 {
            return; // lane 0 is the untasked tag space, never retired
        }
        let mut mail = self.shared.mail.lock().unwrap();
        mail.queues.retain(|&(_, tag), _| lane_of_tag(tag) != lane);
        mail.parked.retain(|f| match f {
            ParkedFrame::Data { tag, .. } => lane_of_tag(*tag) != lane,
            ParkedFrame::Poison { lane: l, .. } => *l != lane,
        });
        mail.lane_poison.remove(&lane);
        mail.retired.insert(lane);
        while mail.retired.remove(&(mail.retired_floor + 1)) {
            mail.retired_floor += 1;
        }
    }

    /// Orderly teardown: stop the sender threads (each sends a final
    /// Close frame so the peer's EOF is not mistaken for a rank
    /// failure), then unblock and join the receivers. Idempotent; also
    /// run by Drop.
    pub fn close(&self) {
        if self.closed.swap(true, Ordering::AcqRel) {
            return;
        }
        self.shared.closing.store(true, Ordering::Release);
        for queue in self.queues.iter().flatten() {
            queue.push(SendItem::Shutdown);
        }
        for t in self.senders.lock().unwrap().drain(..) {
            let _ = t.join();
        }
        // senders are done writing; now unblock receivers parked in
        // read_exact. Read-half only: a full shutdown's FIN could race
        // ahead of a slower peer's reads of our final frames.
        for stream in self.streams.iter().flatten() {
            let _ = stream.shutdown(Shutdown::Read);
        }
        for t in self.receivers.lock().unwrap().drain(..) {
            let _ = t.join();
        }
    }

    /// Test hook: kill every link abruptly (both directions, no Close
    /// frames) — what a dying rank process looks like to its peers.
    #[cfg(test)]
    fn sever(&self) {
        for stream in self.streams.iter().flatten() {
            let _ = stream.shutdown(Shutdown::Both);
        }
    }
}

impl Drop for TcpComm {
    fn drop(&mut self) {
        self.close();
    }
}

/// Dial with retry until `deadline`: during concurrent formation a
/// peer's listener exists but its accept loop may briefly lag.
fn connect_until(addr: &str, deadline: Instant) -> crate::Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e).context("mesh connect timed out");
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

fn sender_loop(
    mut framed: Framed<TcpStream, TcpStream>,
    queue: Arc<SendQueue>,
    shared: Arc<NetShared>,
    peer: usize,
    eager_bytes: usize,
) {
    let mut need_flush = false;
    loop {
        // pop one item; when the queue runs dry, flush buffered bytes
        // before parking so eager messages never wait on a full buffer
        let item = {
            let mut q = queue.q.lock().unwrap();
            loop {
                if let Some(item) = q.pop_front() {
                    break item;
                }
                if need_flush {
                    drop(q);
                    if let Err(e) = framed.flush() {
                        sender_fail(&shared, peer, e);
                        return;
                    }
                    need_flush = false;
                    q = queue.q.lock().unwrap();
                    continue;
                }
                q = queue.cv.wait(q).unwrap();
            }
        };
        match item {
            SendItem::Msg { epoch, tag, data } => {
                let header = fabric_data_header(epoch, tag);
                #[cfg(target_endian = "little")]
                let payload = crate::protocol::wire::f64s_as_le_bytes(&data);
                #[cfg(target_endian = "big")]
                let swapped: Vec<u8> = {
                    let mut w = crate::protocol::Writer::new();
                    w.raw_f64s(&data);
                    w.into_bytes()
                };
                #[cfg(target_endian = "big")]
                let payload = &swapped[..];
                if header.len() + payload.len() > MAX_FRAME as usize {
                    // cannot be framed: this rank's own send is at fault
                    log::error!(
                        "mesh send of {} bytes exceeds frame cap; poisoning group",
                        payload.len()
                    );
                    shared.poison(PoisonCause::RankFailed(shared.rank));
                    continue;
                }
                if let Err(e) = framed.send_gathered(&header, payload, eager_bytes) {
                    sender_fail(&shared, peer, e);
                    return;
                }
                need_flush = true;
            }
            SendItem::Poison { epoch, lane, cause } => {
                // poison is urgent: peers may be blocked in a recv on us
                let frame = FabricFrame::Poison { epoch, lane, cause }.encode();
                if framed.send(&frame).and_then(|()| framed.flush()).is_err() {
                    // the link is already gone; the peer learns through
                    // its own EOF instead
                    return;
                }
                need_flush = false;
            }
            SendItem::Shutdown => {
                let _ = framed.send(&FabricFrame::Close.encode());
                let _ = framed.flush();
                return;
            }
        }
    }
}

fn sender_fail(shared: &NetShared, peer: usize, e: anyhow::Error) {
    if !shared.closing.load(Ordering::Acquire) {
        log::warn!(
            "mesh link to rank {peer} failed on send: {e:#}; poisoning group"
        );
        shared.poison(PoisonCause::RankFailed(peer));
    }
}

fn receiver_loop(stream: TcpStream, shared: Arc<NetShared>, peer: usize) {
    // read-only Framed: frames decode borrowed out of its reusable
    // receive buffer; the write half is never used
    let mut framed = Framed::new(stream, std::io::sink());
    loop {
        let frame = match framed.recv_ref() {
            Ok(buf) => buf,
            Err(_) => {
                // EOF or error: a clean peer sends Close first, so this
                // is either our own teardown or the peer dying
                if !shared.closing.load(Ordering::Acquire) {
                    log::warn!("mesh link to rank {peer} dropped; poisoning group");
                    shared.poison(PoisonCause::RankFailed(peer));
                }
                return;
            }
        };
        match FabricFrame::decode(frame) {
            Ok(FabricFrame::Data { epoch, tag, payload }) => {
                // the one receive-leg copy: frame buffer -> delivered Vec
                let data = le_f64s_to_vec(payload);
                let mut mail = shared.mail.lock().unwrap();
                if mail.lane_retired(lane_of_tag(tag)) {
                    // straggler for a finished task's lane — drop
                } else if epoch == mail.epoch {
                    mail.queues.entry((peer, tag)).or_default().push_back(data);
                    shared.signal.notify_all();
                } else if epoch > mail.epoch {
                    mail.parked.push(ParkedFrame::Data { epoch, from: peer, tag, data });
                }
                // past epochs: straggler from a finished task — drop
            }
            Ok(FabricFrame::Poison { epoch, lane, cause }) => {
                let mut mail = shared.mail.lock().unwrap();
                if epoch == mail.epoch {
                    if lane == LANE_ALL {
                        if mail.poison.is_none() {
                            mail.poison = Some(cause);
                            shared.poison_flag.store(true, Ordering::Release);
                            shared.signal.notify_all();
                        }
                    } else if !mail.lane_retired(lane) {
                        mail.lane_poison.entry(lane).or_insert(cause);
                        shared.signal.notify_all();
                    }
                } else if epoch > mail.epoch {
                    mail.parked.push(ParkedFrame::Poison { epoch, lane, cause });
                }
            }
            Ok(FabricFrame::Close) => return,
            Ok(other) => {
                log::warn!("unexpected mesh frame from rank {peer}: {other:?}");
            }
            Err(e) => {
                if !shared.closing.load(Ordering::Acquire) {
                    log::warn!(
                        "corrupt mesh frame from rank {peer}: {e}; poisoning group"
                    );
                    shared.poison(PoisonCause::RankFailed(peer));
                }
                return;
            }
        }
    }
}

impl Communicator for TcpComm {
    fn rank(&self) -> usize {
        self.shared.rank
    }

    fn size(&self) -> usize {
        self.shared.size
    }

    fn send(&self, to: usize, tag: u64, data: Vec<f64>) {
        if to == self.shared.rank {
            // self-sends never touch the wire (and carry no epoch: they
            // cannot straddle a reset)
            let mut mail = self.shared.mail.lock().unwrap();
            mail.queues.entry((to, tag)).or_default().push_back(data);
            self.shared.signal.notify_all();
            return;
        }
        let Some(queue) = self.queues.get(to).and_then(|q| q.as_ref()) else {
            log::error!("mesh send to unknown rank {to}; dropping");
            return;
        };
        queue.push(SendItem::Msg {
            epoch: self.shared.send_epoch.load(Ordering::Acquire),
            tag,
            data,
        });
    }

    fn recv(&self, from: usize, tag: u64) -> Result<Vec<f64>, CommError> {
        let lane = lane_of_tag(tag);
        let mut mail = self.shared.mail.lock().unwrap();
        loop {
            if let Some(cause) = mail.lane_poisoned(lane) {
                return Err(cause.to_err());
            }
            if let Some(queue) = mail.queues.get_mut(&(from, tag)) {
                if let Some(data) = queue.pop_front() {
                    return Ok(data);
                }
            }
            mail = self.shared.signal.wait(mail).unwrap();
        }
    }

    fn recv_deadline(
        &self,
        from: usize,
        tag: u64,
        timeout: Duration,
    ) -> Result<Vec<f64>, CommError> {
        let lane = lane_of_tag(tag);
        let deadline = Instant::now() + timeout;
        let mut mail = self.shared.mail.lock().unwrap();
        loop {
            if let Some(cause) = mail.lane_poisoned(lane) {
                return Err(cause.to_err());
            }
            if let Some(queue) = mail.queues.get_mut(&(from, tag)) {
                if let Some(data) = queue.pop_front() {
                    return Ok(data);
                }
            }
            let Some(remaining) = deadline.checked_duration_since(Instant::now())
            else {
                return Err(CommError::Timeout { from, tag });
            };
            // on wake the loop re-polls; a timed-out wait falls through
            // to the deadline check above and returns Timeout
            let (guard, _) =
                self.shared.signal.wait_timeout(mail, remaining).unwrap();
            mail = guard;
        }
    }

    fn barrier(&self) -> Result<(), CommError> {
        let p = self.shared.size;
        let gen = {
            let mut mail = self.shared.mail.lock().unwrap();
            if let Some(cause) = mail.poison {
                return Err(cause.to_err());
            }
            let gen = mail.barrier_gen;
            mail.barrier_gen += 1;
            gen
        };
        if p == 1 {
            return Ok(());
        }
        // dissemination barrier: ⌈log2 p⌉ rounds, in round k every rank
        // signals rank + 2^k and hears from rank − 2^k — after the last
        // round every rank transitively covers all p arrivals
        let mut k = 0u64;
        let mut dist = 1usize;
        while dist < p {
            let tag = BARRIER_TAG_BIT | (gen << 8) | k;
            let to = (self.shared.rank + dist) % p;
            let from = (self.shared.rank + p - dist) % p;
            self.send(to, tag, Vec::new());
            self.recv(from, tag)?;
            dist <<= 1;
            k += 1;
        }
        Ok(())
    }

    fn poison(&self, cause: PoisonCause) {
        self.shared.poison(cause);
        // propagate the root cause over the mesh: peers may be blocked
        // on a rank whose link to *them* is still healthy
        let epoch = self.shared.send_epoch.load(Ordering::Acquire);
        for queue in self.queues.iter().flatten() {
            queue.push(SendItem::Poison { epoch, lane: LANE_ALL, cause });
        }
    }

    fn poison_cause(&self) -> Option<PoisonCause> {
        if !self.shared.poison_flag.load(Ordering::Acquire) {
            return None;
        }
        self.shared.mail.lock().unwrap().poison
    }

    fn poison_lane(&self, lane: u64, cause: PoisonCause) {
        self.shared.poison_lane(lane, cause);
        // lane poison crosses the mesh too: the cancelled task's peer
        // ranks may be blocked in a recv within the lane
        let epoch = self.shared.send_epoch.load(Ordering::Acquire);
        for queue in self.queues.iter().flatten() {
            queue.push(SendItem::Poison { epoch, lane, cause });
        }
    }

    fn lane_poison_cause(&self, lane: u64) -> Option<PoisonCause> {
        self.shared.mail.lock().unwrap().lane_poisoned(lane)
    }
}

impl Fabric for TcpComm {
    fn reset(&self) {
        TcpComm::reset(self)
    }

    fn retire_lane(&self, lane: u64) {
        TcpComm::retire_lane(self, lane)
    }

    fn as_comm(&self) -> &dyn Communicator {
        self
    }
}

/// Form an `n`-rank loopback mesh inside one process (tests/benches):
/// every rank gets its own acceptor and the meshes form concurrently,
/// exactly as the multi-process path does.
pub fn loopback_group(n: usize, opts: &FabricOptions) -> crate::Result<Vec<TcpComm>> {
    let acceptors: Vec<MeshAcceptor> =
        (0..n).map(|_| MeshAcceptor::bind()).collect::<crate::Result<_>>()?;
    let addrs: Vec<String> =
        acceptors.iter().map(|a| a.addr().to_string()).collect();
    let mut threads = Vec::new();
    for (rank, acceptor) in acceptors.into_iter().enumerate() {
        let addrs = addrs.clone();
        let opts = opts.clone();
        threads.push(std::thread::spawn(move || {
            TcpComm::form(&acceptor, 0, rank, &addrs, &opts)
        }));
    }
    threads
        .into_iter()
        .map(|t| t.join().expect("loopback form thread panicked"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::TAG_WINDOW;

    fn tiny_eager() -> FabricOptions {
        FabricOptions { eager_bytes: 64, ..FabricOptions::default() }
    }

    /// Run `f(comm)` on one thread per rank of a loopback mesh.
    fn run_group<F>(n: usize, opts: &FabricOptions, f: F)
    where
        F: Fn(&TcpComm) + Send + Sync + 'static,
    {
        let comms = loopback_group(n, opts).unwrap();
        let f = Arc::new(f);
        let threads: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    f(&comm);
                    comm.close();
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn advertised_acceptor_reports_configured_host() {
        // empty advertise = the loopback default
        let a = MeshAcceptor::bind().unwrap();
        assert!(a.addr().starts_with("127.0.0.1:"), "{}", a.addr());
        // a configured host is what peers are told to dial; the listener
        // itself binds all interfaces so the dial can actually land
        let b = MeshAcceptor::bind_advertised("localhost").unwrap();
        assert!(b.addr().starts_with("localhost:"), "{}", b.addr());
        let port: u16 =
            b.addr().rsplit(':').next().unwrap().parse().expect("port suffix");
        assert_ne!(port, 0);
        // reachable via loopback since it bound 0.0.0.0
        TcpStream::connect(("127.0.0.1", port)).unwrap();
    }

    #[test]
    fn point_to_point_roundtrip() {
        run_group(2, &FabricOptions::default(), |comm| {
            let me = comm.rank();
            let peer = 1 - me;
            comm.send(peer, 0, vec![me as f64; 3]);
            let got = comm.recv(peer, 0).unwrap();
            assert_eq!(got, vec![peer as f64; 3]);
        });
    }

    #[test]
    fn self_send_delivers_locally() {
        run_group(2, &FabricOptions::default(), |comm| {
            comm.send(comm.rank(), 7, vec![42.0]);
            assert_eq!(comm.recv(comm.rank(), 7).unwrap(), vec![42.0]);
        });
    }

    #[test]
    fn large_payloads_cross_the_writev_path() {
        // eager_bytes of 64 forces every real payload through the
        // gathered-writev rendezvous leg; values must survive exactly
        let n = 10_000usize;
        run_group(2, &tiny_eager(), move |comm| {
            let me = comm.rank();
            let peer = 1 - me;
            let data: Vec<f64> = (0..n).map(|i| (i + me) as f64 * 0.5).collect();
            comm.send(peer, TAG_WINDOW, data);
            let got = comm.recv(peer, TAG_WINDOW).unwrap();
            assert_eq!(got.len(), n);
            for (i, v) in got.iter().enumerate() {
                assert_eq!(*v, (i + peer) as f64 * 0.5);
            }
        });
    }

    #[test]
    fn per_sender_tag_order_is_preserved() {
        run_group(2, &FabricOptions::default(), |comm| {
            let peer = 1 - comm.rank();
            for i in 0..100 {
                comm.send(peer, 5, vec![i as f64]);
            }
            for i in 0..100 {
                assert_eq!(comm.recv(peer, 5).unwrap(), vec![i as f64]);
            }
        });
    }

    #[test]
    fn barrier_synchronizes_and_repeats() {
        use std::sync::atomic::AtomicUsize;
        let hits = Arc::new(AtomicUsize::new(0));
        let hits2 = Arc::clone(&hits);
        run_group(4, &FabricOptions::default(), move |comm| {
            for round in 0..5 {
                hits2.fetch_add(1, Ordering::SeqCst);
                comm.barrier().unwrap();
                // a completed barrier implies every rank entered it,
                // i.e. incremented for this round already
                let seen = hits2.load(Ordering::SeqCst);
                assert!(seen >= (round + 1) * 4, "barrier let a rank through early");
            }
        });
        assert_eq!(hits.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn recv_deadline_times_out_without_poisoning() {
        run_group(2, &FabricOptions::default(), |comm| {
            let peer = 1 - comm.rank();
            let err = comm
                .recv_deadline(peer, 99, Duration::from_millis(30))
                .unwrap_err();
            assert_eq!(err, CommError::Timeout { from: peer, tag: 99 });
            assert_eq!(comm.poison_cause(), None);
            // the link still works afterwards
            comm.send(peer, 100, vec![1.0]);
            assert_eq!(comm.recv(peer, 100).unwrap(), vec![1.0]);
        });
    }

    #[test]
    fn reset_drops_stale_messages_and_reuses_links() {
        let comms = loopback_group(2, &FabricOptions::default()).unwrap();
        let c1 = &comms[1];
        let c0 = &comms[0];
        // a message from the "previous task" that rank 1 never received
        c0.send(1, 3, vec![13.0]);
        // both ranks reset (the dispatcher does this between tasks);
        // rank 1's reset either clears the queued value or the epoch
        // stamp drops it on arrival — both orders must hide it
        c0.reset();
        c1.reset();
        c0.send(1, 3, vec![14.0]);
        assert_eq!(c1.recv(0, 3).unwrap(), vec![14.0]);
        // and the next epoch works in both directions
        c1.send(0, 4, vec![15.0]);
        assert_eq!(c0.recv(1, 4).unwrap(), vec![15.0]);
    }

    #[test]
    fn reset_clears_poison() {
        let comms = loopback_group(2, &FabricOptions::default()).unwrap();
        comms[0].shared.poison(PoisonCause::HardCancel);
        assert_eq!(comms[0].recv(1, 0).unwrap_err(), CommError::Cancelled);
        comms[0].reset();
        assert_eq!(comms[0].poison_cause(), None);
    }

    #[test]
    fn poison_propagates_to_peers() {
        run_group(3, &FabricOptions::default(), |comm| {
            if comm.rank() == 2 {
                comm.poison(PoisonCause::RankFailed(2));
            }
            // every rank (including the poisoner) unwinds with the root
            // cause, even though ranks 0/1 have healthy links
            let err = comm.recv((comm.rank() + 1) % 3, 0).unwrap_err();
            assert_eq!(err, CommError::PeerFailed { rank: 2 });
        });
    }

    #[test]
    fn lane_poison_crosses_mesh_and_spares_sibling() {
        use crate::collectives::lane_base;
        run_group(2, &FabricOptions::default(), |comm| {
            let peer = 1 - comm.rank();
            if comm.rank() == 0 {
                comm.poison_lane(1, PoisonCause::HardCancel);
            }
            // both ranks see lane 1 cancelled (rank 1 via the mesh frame)
            let err = comm.recv(peer, lane_base(1) + 7).unwrap_err();
            assert_eq!(err, CommError::Cancelled);
            // lane 2 and the group stay healthy
            comm.send(peer, lane_base(2) + 7, vec![comm.rank() as f64]);
            assert_eq!(comm.recv(peer, lane_base(2) + 7).unwrap(), vec![peer as f64]);
            assert_eq!(comm.poison_cause(), None);
        });
    }

    #[test]
    fn retired_lane_drops_stragglers_and_clears_poison() {
        use crate::collectives::lane_base;
        let comms = loopback_group(2, &FabricOptions::default()).unwrap();
        let c0 = &comms[0];
        let c1 = &comms[1];
        c1.send(0, lane_base(1) + 3, vec![1.0]);
        assert_eq!(c0.recv(1, lane_base(1) + 3).unwrap(), vec![1.0]);
        Communicator::poison_lane(c0, 1, PoisonCause::HardCancel);
        assert!(matches!(c0.lane_poison_cause(1), Some(PoisonCause::HardCancel)));
        c0.retire_lane(1);
        assert_eq!(c0.lane_poison_cause(1), None);
        // a straggler for the retired lane is dropped on arrival...
        c1.send(0, lane_base(1) + 3, vec![2.0]);
        let err = c0
            .recv_deadline(1, lane_base(1) + 3, Duration::from_millis(60))
            .unwrap_err();
        assert_eq!(err, CommError::Timeout { from: 1, tag: lane_base(1) + 3 });
        // ...while the next lane flows
        c1.send(0, lane_base(2) + 3, vec![3.0]);
        assert_eq!(c0.recv(1, lane_base(2) + 3).unwrap(), vec![3.0]);
        for c in &comms {
            c.close();
        }
    }

    #[test]
    fn dropped_link_poisons_with_failed_rank() {
        let comms = loopback_group(2, &FabricOptions::default()).unwrap();
        let mut it = comms.into_iter();
        let c0 = it.next().unwrap();
        let c1 = it.next().unwrap();
        let waiter = std::thread::spawn(move || {
            let err = c1.recv(0, 0).unwrap_err();
            assert_eq!(err, CommError::PeerFailed { rank: 0 });
        });
        // rank 0 dies without a Close frame
        c0.sever();
        waiter.join().unwrap();
    }

    #[test]
    fn orderly_close_does_not_poison_peer() {
        let comms = loopback_group(2, &FabricOptions::default()).unwrap();
        let mut it = comms.into_iter();
        let c0 = it.next().unwrap();
        let c1 = it.next().unwrap();
        c0.close();
        // give c1's receiver time to observe the Close frame
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(c1.poison_cause(), None);
    }
}
