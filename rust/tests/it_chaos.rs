//! Chaos soak: survivable sessions under composed failure injection
//! (protocol v10, `docs/recovery.md`).
//!
//! The deterministic pins, every failure mode by name:
//!
//! * a worker process killed mid-CG is replaced from the spare pool and
//!   the restarted task completes **bit-identical** to the failure-free
//!   run (`killed_rank_mid_cg_completes_on_spare_bit_identical`);
//! * a dropped client reattaches by session token within the linger
//!   window and collects a finished SVD — including `WaitTask` on the
//!   already-terminal task returning the retained result directly,
//!   with no status-poll race
//!   (`dropped_client_reattaches_by_token_and_collects_finished_svd`);
//! * an unclaimed token expires with the linger window and everything
//!   the session held is released
//!   (`linger_expiry_frees_workers_blocks_and_rejects_token`);
//! * a client that vanishes mid-ingest under `fabric.mode = tcp` leaks
//!   no unsealed blocks, reservations, or admission budget
//!   (`tcp_disconnect_during_ingest_releases_blocks_and_budget`);
//!
//! plus the randomized soak: ≥ 20 seeded rounds
//! ([`alchemist::testkit::chaos`]) composing kill / cancel / drop /
//! reattach under two concurrent tenants, asserting zero hangs (every
//! wait bounded, nextest timeout as backstop) and zero leaked blocks or
//! spill segments at round teardown. A failing round's plan is in the
//! failure report (`seed`, `case`) and, when `ALCHEMIST_CHAOS_LOG` is
//! set, on disk before the round runs.

use std::time::{Duration, Instant};

use alchemist::client::AlchemistContext;
use alchemist::config::{Config, EngineKind, FabricMode};
use alchemist::coordinator::AlchemistServer;
use alchemist::distmat::LocalMatrix;
use alchemist::protocol::{Params, TaskState};
use alchemist::testkit::chaos::{self, ChaosLog, TenantOp};
use alchemist::testkit::props_seeded;

fn native_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.engine = EngineKind::Native;
    cfg
}

/// Local-mode config switched onto the process fabric (the worker
/// executable must be named explicitly: inside an integration test
/// `current_exe()` is the test runner, not `alchemist`).
fn tcp_cfg() -> Config {
    let mut cfg = native_cfg();
    cfg.fabric.mode = FabricMode::Tcp;
    cfg.fabric.worker_exe = env!("CARGO_BIN_EXE_alchemist").into();
    cfg
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("alchemist-it-chaos")
        .join(format!("{name}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Poll until `f` returns true or the timeout fires (sleep-based tests
/// stay robust on slow CI runners).
fn eventually(timeout: Duration, what: &str, mut f: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !f() {
        assert!(t0.elapsed() < timeout, "timed out waiting for: {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Materialize a server matrix for exact (bit-level) comparison.
fn pull(ac: &mut AlchemistContext, m: &alchemist::client::AlMatrix) -> LocalMatrix {
    ac.to_indexed_row_matrix(m, 1).unwrap().0.to_local().unwrap()
}

/// `Reattach` races the server's EOF handling of the dropped socket (the
/// token is only parked once the control thread observes the close), so
/// a reconnecting client retries briefly.
fn reconnect_eventually(
    addr: &str,
    cfg: &Config,
    token: u64,
) -> (AlchemistContext, Vec<u64>) {
    let t0 = Instant::now();
    loop {
        match AlchemistContext::reconnect(addr, cfg, 1, token) {
            Ok(got) => return got,
            Err(e) => {
                assert!(
                    t0.elapsed() < Duration::from_secs(10),
                    "reattach never succeeded: {e:#}"
                );
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// Pin (a): kill a worker process mid-CG on a server with one spare.
/// The coordinator re-forms the mesh around the spare, replays the dead
/// rank's shards from the task-boundary checkpoints, restarts the task —
/// and the result is bit-identical to the failure-free run.
#[test]
fn killed_rank_mid_cg_completes_on_spare_bit_identical() {
    let mut cfg = tcp_cfg();
    cfg.apply("scheduler.spare_workers", "1").unwrap();
    let ckpt = tmp_dir("cg-ckpt");
    cfg.apply("storage.checkpoint_dir", ckpt.to_str().unwrap()).unwrap();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    assert_eq!(server.spare_workers(), 1);

    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 2).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();
    ac.register_library("skylark", "builtin:skylark").unwrap();

    let x = ac
        .run_task(
            "elemental",
            "rand_matrix",
            Params::new().with_i64("rows", 256).with_i64("cols", 64).with_i64("seed", 1),
        )
        .unwrap();
    let y = ac
        .run_task(
            "elemental",
            "rand_matrix",
            Params::new().with_i64("rows", 256).with_i64("cols", 4).with_i64("seed", 2),
        )
        .unwrap();
    // unconvergeable (tol 0) so the iteration count is the deterministic
    // cap, long enough that the kill below always lands mid-solve
    let cg = || {
        Params::new()
            .with_matrix("X", x.outputs[0].id)
            .with_matrix("Y", y.outputs[0].id)
            .with_f64("tol", 0.0)
            .with_i64("max_iters", 1500)
    };

    // failure-free baseline on the intact group
    let base = ac.run_task("skylark", "cg_solve", cg()).unwrap();
    let w0 = pull(&mut ac, &base.outputs[0]);

    // identical solve, but one rank dies mid-iteration
    let task_id = ac.submit("skylark", "cg_solve", cg()).unwrap().task_id;
    let t0 = Instant::now();
    loop {
        assert!(t0.elapsed() < Duration::from_secs(30), "CG never started");
        if let TaskState::Running { progress } = ac.task(task_id).status().unwrap() {
            if progress.iters >= 1 {
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let t_kill = Instant::now();
    assert!(server.kill_worker(1), "worker 1 should be live to kill");

    // NOT an error: the session recovered and the restarted task finished
    let res = ac.task(task_id).wait().unwrap();
    assert!(
        t_kill.elapsed() < Duration::from_secs(60),
        "recovery took {:?}",
        t_kill.elapsed()
    );
    assert!(server.sched_metrics().ranks_replaced >= 1, "no rank was replaced");

    // bit-identical to the failure-free run: same iteration count, same
    // final residual bits, same solution matrix (the replayed shards and
    // the shared reduction order leave no room for drift)
    assert_eq!(
        res.scalars.i64("iters").unwrap(),
        base.scalars.i64("iters").unwrap()
    );
    assert_eq!(
        res.scalars.f64("final_residual").unwrap().to_bits(),
        base.scalars.f64("final_residual").unwrap().to_bits()
    );
    let w1 = pull(&mut ac, &res.outputs[0]);
    assert_eq!(w1, w0);

    // the re-formed group keeps working like any other
    let ok = ac
        .run_task("elemental", "sleep", Params::new().with_i64("millis", 10))
        .unwrap();
    assert_eq!(ok.scalars.i64("ranks").unwrap(), 2);

    // teardown leaks nothing — not blocks, not spill, not checkpoints
    ac.stop();
    eventually(Duration::from_secs(15), "session teardown", || {
        server.active_sessions() == 0
            && server.total_blocks() == 0
            && server.total_spill_segments() == 0
    });
    eventually(Duration::from_secs(10), "checkpoint files to be deleted", || {
        std::fs::read_dir(&ckpt).unwrap().filter_map(|e| e.ok()).all(|e| {
            !e.file_name().to_string_lossy().starts_with("alchemist-ckpt")
        })
    });
    server.shutdown();
}

/// Pin (b): the task table and results survive the TCP connection. A
/// client that vanishes mid-SVD reattaches by token, re-lists its tasks,
/// and collects the finished result — bit-identical to the run that
/// never disconnected. Also pins the `WaitTask`-on-terminal fix: the
/// retained result comes back directly, no status-poll race.
#[test]
fn dropped_client_reattaches_by_token_and_collects_finished_svd() {
    let mut cfg = native_cfg();
    cfg.apply("scheduler.session_linger_s", "30").unwrap();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let addr = server.control_addr.clone();

    let mut ac = AlchemistContext::connect(&addr, &cfg, 1).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();
    let a = ac
        .run_task(
            "elemental",
            "rand_matrix",
            Params::new().with_i64("rows", 64).with_i64("cols", 8).with_i64("seed", 3),
        )
        .unwrap();
    let svd =
        || Params::new().with_matrix("A", a.outputs[0].id).with_i64("rank", 3);

    // failure-free baseline, collected over the original connection
    let base = ac.run_task("elemental", "truncated_svd", svd()).unwrap();
    let baseline: Vec<LocalMatrix> =
        (0..3).map(|i| pull(&mut ac, &base.outputs[i])).collect();

    let token = ac.session_token();
    assert_ne!(token, 0, "handshake must issue a session token");

    // an identical SVD is in flight when the client vanishes
    let task_id = ac.submit("elemental", "truncated_svd", svd()).unwrap().task_id;
    ac.stop();

    // a bogus token is rejected with a diagnosable message
    let err = AlchemistContext::reconnect(&addr, &cfg, 1, token ^ 0xdead).unwrap_err();
    assert!(
        format!("{err:#}").contains("unknown or expired"),
        "wrong rejection: {err:#}"
    );

    // the real token resumes the session: the task list names the
    // in-flight task, and waiting on it yields the retained result
    let (mut ac2, task_ids) = reconnect_eventually(&addr, &cfg, token);
    assert!(task_ids.contains(&task_id), "task table lost: {task_ids:?}");
    let res = ac2.task(task_id).wait().unwrap();
    let collected: Vec<LocalMatrix> =
        (0..3).map(|i| pull(&mut ac2, &res.outputs[i])).collect();
    assert_eq!(collected, baseline, "recovered SVD differs from baseline");

    // WaitTask on the already-completed task returns the retained
    // terminal result immediately (the reattach-and-collect contract)
    let t0 = Instant::now();
    let again = ac2.task(task_id).wait().unwrap();
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "retained result not returned directly ({:?})",
        t0.elapsed()
    );
    assert_eq!(again.outputs[0].id, res.outputs[0].id);

    // drop-and-reattach composes: a second cycle on the same token works
    // (the re-park re-arms the reaper under a fresh generation)
    ac2.stop();
    let (mut ac3, task_ids) = reconnect_eventually(&addr, &cfg, token);
    assert!(task_ids.contains(&task_id));
    assert!(matches!(
        ac3.task(task_id).status().unwrap(),
        TaskState::Done { .. }
    ));
    ac3.stop();
    server.shutdown();
}

/// An unclaimed token expires with the linger window: running work is
/// cancelled, blocks are freed, the worker group returns to the pool,
/// and a late `Reattach` is rejected instead of resuming freed state.
#[test]
fn linger_expiry_frees_workers_blocks_and_rejects_token() {
    let mut cfg = native_cfg();
    cfg.apply("scheduler.session_linger_s", "0.5").unwrap();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let addr = server.control_addr.clone();

    let token = {
        let mut ac = AlchemistContext::connect_with_workers(&addr, &cfg, 1, 2).unwrap();
        ac.register_library("elemental", "builtin:elemental").unwrap();
        // blocks in the store and a 30s task in flight at drop time
        ac.run_task(
            "elemental",
            "rand_matrix",
            Params::new().with_i64("rows", 32).with_i64("cols", 4).with_i64("seed", 7),
        )
        .unwrap();
        ac.submit("elemental", "sleep", Params::new().with_i64("millis", 30_000))
            .unwrap();
        let token = ac.session_token();
        ac.stop();
        token
    };

    // the reaper closes the parked session well before the sleep could
    // finish: cancellation is cooperative, teardown eager
    eventually(Duration::from_secs(15), "linger expiry teardown", || {
        server.active_sessions() == 0 && server.total_blocks() == 0
    });
    let err = AlchemistContext::reconnect(&addr, &cfg, 1, token).unwrap_err();
    assert!(
        format!("{err:#}").contains("unknown or expired"),
        "late reattach not rejected: {err:#}"
    );

    // the pool is whole again: a fresh session takes both workers
    let mut ac = AlchemistContext::connect_with_workers(&addr, &cfg, 1, 2).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();
    let res = ac
        .run_task("elemental", "sleep", Params::new().with_i64("millis", 10))
        .unwrap();
    assert_eq!(res.scalars.i64("ranks").unwrap(), 2);
    ac.stop();
    server.shutdown();
}

/// Satellite pin: a client that disconnects mid-ingest under
/// `fabric.mode = tcp` (half-pushed rows on a worker *process*, no
/// `PushDone`, no seal) leaks nothing — unsealed blocks, spill segments,
/// and the storage admission commitment are all released, and a fresh
/// session admits the full pool again. The local-pool twin lives in
/// `it_tasks.rs::disconnect_with_task_in_flight_cancels_and_frees_everything`.
#[test]
fn tcp_disconnect_during_ingest_releases_blocks_and_budget() {
    use alchemist::net::Framed;
    use alchemist::protocol::{ControlMsg, DataMsg, DEFAULT_PRIORITY, PROTOCOL_VERSION};

    let mut cfg = tcp_cfg();
    // kilobyte budgets: the half-pushed rows engage the spill plane, and
    // `total_bytes` makes session admission a real commitment to release
    cfg.apply("storage.budget_bytes", "4096").unwrap();
    cfg.apply("storage.total_bytes", "8192").unwrap();
    let spill = tmp_dir("ingest-spill");
    cfg.apply("storage.spill_dir", spill.to_str().unwrap()).unwrap();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let addr = server.control_addr.clone();

    // hand-rolled session: handshake, CreateMatrix, half-push to rank 0
    // over its data socket, then vanish without PushDone or SealMatrix
    {
        let mut control = Framed::connect(&addr, 1 << 16).unwrap();
        let ack = control
            .call(&ControlMsg::Handshake {
                client_name: "chaos-ingest".into(),
                version: PROTOCOL_VERSION,
                request_workers: 2,
                rows_per_frame: 0,
                buf_bytes: 0,
                priority: DEFAULT_PRIORITY,
            })
            .unwrap();
        let (session_id, worker_addrs) = match ack {
            ControlMsg::HandshakeAck { session_id, worker_addrs, .. } => {
                (session_id, worker_addrs)
            }
            other => panic!("{other:?}"),
        };
        let id = match control
            .call(&ControlMsg::CreateMatrix { name: "H".into(), rows: 64, cols: 8 })
            .unwrap()
        {
            ControlMsg::MatrixCreated { id, .. } => id,
            other => panic!("{other:?}"),
        };
        let mut data = Framed::connect(&worker_addrs[0], 1 << 16).unwrap();
        data.send_data_flush(&DataMsg::DataHandshake {
            session_id,
            executor_id: 0,
            rows_per_frame: 0,
        })
        .unwrap();
        assert!(matches!(data.recv_data().unwrap(), DataMsg::DataHandshakeAck { .. }));
        for frame in 0..4u64 {
            data.send_data_flush(&DataMsg::PushRows {
                matrix_id: id,
                start_row: frame * 4,
                nrows: 4,
                ncols: 8,
                data: vec![frame as f64; 32],
            })
            .unwrap();
        }
        // both sockets dropped here — disconnect mid-ingest
    }

    // everything the half-ingest touched is released, on the worker
    // processes too (the stats round-trip over the work sockets)
    eventually(Duration::from_secs(15), "mid-ingest teardown", || {
        server.active_sessions() == 0
            && server.total_blocks() == 0
            && server.total_spill_segments() == 0
    });

    // the admission budget came back with it: a second full-pool session
    // would overcommit `storage.total_bytes` if the first still held its
    // commitment, so this connect succeeding IS the budget assertion
    let mut ac = AlchemistContext::connect_with_workers(&addr, &cfg, 1, 2).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();
    let res = ac
        .run_task(
            "elemental",
            "rand_matrix",
            Params::new().with_i64("rows", 16).with_i64("cols", 4).with_i64("seed", 9),
        )
        .unwrap();
    let back = pull(&mut ac, &res.outputs[0]);
    assert_eq!((back.rows(), back.cols()), (16, 4));
    ac.stop();
    eventually(Duration::from_secs(10), "final teardown", || {
        server.active_sessions() == 0 && server.total_blocks() == 0
    });
    server.shutdown();
}

/// Pin (c): ≥ 20 seeded randomized rounds composing every failure mode
/// under two concurrent tenants. Each wait is bounded (a non-terminal
/// state past the bound IS a hang) and each round's server must tear
/// down to zero sessions, zero blocks, zero spill segments.
#[test]
fn seeded_chaos_rounds_under_concurrent_tenants_leak_nothing() {
    let log = ChaosLog::from_env();
    let ckpt = tmp_dir("soak-ckpt");
    props_seeded(0xC11A_05EE, 20, |g| {
        let plan = chaos::plan_round(g, true);
        // logged BEFORE the round runs: a hang leaves the plan on disk
        log.record(&format!("case {}: {}", g.case, plan.describe()));
        run_round(g.case, &plan, &ckpt);
        log.record(&format!("case {}: clean", g.case));
    });
}

fn run_round(case: usize, plan: &chaos::RoundPlan, ckpt: &std::path::Path) {
    let mut cfg = if plan.tcp { tcp_cfg() } else { native_cfg() };
    if plan.tcp {
        cfg.apply("scheduler.spare_workers", "1").unwrap();
        cfg.apply("storage.checkpoint_dir", ckpt.to_str().unwrap()).unwrap();
    }
    if plan.linger_s > 0.0 {
        cfg.apply("scheduler.session_linger_s", &format!("{}", plan.linger_s))
            .unwrap();
    }
    if plan.tight_budget {
        cfg.apply("storage.budget_bytes", "8192").unwrap();
        let spill = tmp_dir(&format!("soak-spill-{case}"));
        cfg.apply("storage.spill_dir", spill.to_str().unwrap()).unwrap();
    }
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let addr = server.control_addr.clone();

    let mut tenants = Vec::new();
    for (tenant, ops) in plan.tenants.iter().cloned().enumerate() {
        let (addr, cfg) = (addr.clone(), cfg.clone());
        tenants.push(std::thread::spawn(move || run_tenant(&addr, &cfg, tenant, ops)));
    }
    if let Some(rank) = plan.kill_rank {
        // mid-round: whichever tenant holds the rank either recovers on
        // the spare or fails diagnosably — both outcomes are terminal
        std::thread::sleep(Duration::from_millis(150));
        let _ = server.kill_worker(rank);
    }
    for t in tenants {
        t.join().expect("tenant panicked");
    }

    // the round's composed failures must leave the server spotless; the
    // linger window (if any) is allowed to elapse within the bound
    eventually(Duration::from_secs(30), "round session teardown", || {
        server.active_sessions() == 0
    });
    eventually(Duration::from_secs(15), "round store drain", || {
        server.total_blocks() == 0 && server.total_spill_segments() == 0
    });
    server.shutdown();
}

/// One tenant's scripted ops. Individual ops tolerate *errors* (a kill
/// round makes any of them fallible) but never tolerate a hang: every
/// wait is bounded and a non-terminal state past the bound panics.
fn run_tenant(addr: &str, cfg: &Config, tenant: usize, ops: Vec<TenantOp>) {
    let Ok(mut ac) = AlchemistContext::connect_with_workers(addr, cfg, 1, 1) else {
        return; // admission raced a kill — nothing held, nothing to leak
    };
    if ac.register_library("elemental", "builtin:elemental").is_err() {
        return;
    }
    for op in ops {
        match op {
            TenantOp::FailOneRank => {
                // deterministic routine failure; the process stays alive
                let _ = ac.run_task(
                    "elemental",
                    "fail_on",
                    Params::new().with_i64("rank", 0),
                );
            }
            TenantOp::SpinHardCancel => {
                if let Ok(sub) = ac.submit(
                    "elemental",
                    "spin",
                    Params::new().with_i64("millis", 20_000),
                ) {
                    let id = sub.task_id;
                    wait_until_past_queued(&mut ac, id);
                    let _ = ac.task(id).cancel_hard(100);
                    expect_terminal(&mut ac, id);
                }
            }
            TenantOp::SleepCancel => {
                if let Ok(sub) = ac.submit(
                    "elemental",
                    "sleep",
                    Params::new().with_i64("millis", 20_000),
                ) {
                    let id = sub.task_id;
                    wait_until_past_queued(&mut ac, id);
                    let _ = ac.task(id).cancel();
                    expect_terminal(&mut ac, id);
                }
            }
            TenantOp::SvdCollect => {
                let seed = 11 + tenant as i64;
                let Ok(a) = ac.run_task(
                    "elemental",
                    "rand_matrix",
                    Params::new()
                        .with_i64("rows", 48)
                        .with_i64("cols", 6)
                        .with_i64("seed", seed),
                ) else {
                    continue;
                };
                if let Ok(res) = ac.run_task(
                    "elemental",
                    "truncated_svd",
                    Params::new().with_matrix("A", a.outputs[0].id).with_i64("rank", 2),
                ) {
                    let _ = ac.to_indexed_row_matrix(&res.outputs[0], 1);
                }
            }
            TenantOp::DropClient { reattach } => {
                let token = ac.session_token();
                // leave work in flight so the drop exercises the
                // park-with-running-task path
                let _ = ac.submit(
                    "elemental",
                    "sleep",
                    Params::new().with_i64("millis", 20_000),
                );
                ac.stop();
                if !reattach {
                    return; // linger reaper (or eager close) cleans up
                }
                let t0 = Instant::now();
                loop {
                    match AlchemistContext::reconnect(addr, cfg, 1, token) {
                        Ok((resumed, task_ids)) => {
                            ac = resumed;
                            for id in task_ids {
                                let _ = ac.task(id).cancel();
                                expect_terminal(&mut ac, id);
                            }
                            break;
                        }
                        // the linger window is short by design: losing
                        // the race to the reaper is a legal outcome
                        Err(_) if t0.elapsed() > Duration::from_secs(5) => return,
                        Err(_) => std::thread::sleep(Duration::from_millis(20)),
                    }
                }
            }
        }
    }
    ac.stop();
}

/// Bounded wait for a submission to leave the queue (it may go straight
/// to a terminal state if the round killed the tenant's rank).
fn wait_until_past_queued(ac: &mut AlchemistContext, id: u64) {
    let t0 = Instant::now();
    loop {
        match ac.task(id).status() {
            Ok(TaskState::Queued) => {}
            _ => return,
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "task {id} stuck in queue — scheduler hang"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// The zero-hang pin for one task: within the bound it must reach SOME
/// terminal state (Done, Failed, or Cancelled — the round decides which;
/// a lost connection also counts, the server side is what must not
/// wedge). A live non-terminal state past the bound is a hang.
fn expect_terminal(ac: &mut AlchemistContext, id: u64) {
    match ac.task(id).wait_timeout(60_000) {
        Err(_) => {} // connection torn down under the wait — not a hang
        Ok(st) => assert!(
            matches!(
                st,
                TaskState::Done { .. } | TaskState::Failed { .. } | TaskState::Cancelled
            ),
            "task {id} not terminal after 60s: {st:?}"
        ),
    }
}
