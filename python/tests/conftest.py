import jax

# The whole stack is double precision (the paper's matrices are f64);
# enable x64 before any test imports kernels.
jax.config.update("jax_enable_x64", True)
