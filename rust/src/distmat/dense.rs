//! Dense row-major f64 matrix with a packed-panel native GEMM.
//!
//! This is the local-block storage for [`super::DistShard`] and the compute
//! floor for the engine ablation: `compute::NativeEngine` calls the packed
//! kernels here, while the XLA/Pallas engines only use this type as a
//! container.
//!
//! The GEMM is a BLIS-style packed kernel (see `docs/compute.md` for the
//! layout diagrams): operand panels are packed once per cache block — A
//! into [`GEMM_MC`]×[`GEMM_KC`] panels of [`GEMM_MR`]-row micro-panels, B
//! into [`GEMM_KC`]×n panels of [`GEMM_NR`]-column strips — and a
//! register-blocked [`GEMM_MR`]×[`GEMM_NR`] micro-tile drives a branch-free
//! multiply-add loop. All three storage variants (NN/TN/NT) funnel
//! through one strided packing path, so a transposed operand costs a
//! transposed *pack*, never a strided inner loop. The M dimension is
//! optionally split over the engine's [`ThreadPool`] in fixed
//! [`GEMM_MC`]-row panels; panel boundaries depend only on the problem
//! shape, so results are bit-identical for any thread count.
//!
//! The micro-kernel exists in per-ISA variants selected at runtime
//! through [`crate::simd`]: the portable fallback (auto-vectorized at the
//! build's baseline ISA), a 256-bit AVX2 variant, and a feature-gated
//! 512-bit AVX-512F variant. Every variant performs the identical
//! arithmetic in the identical order — each `acc[i][j]` accumulates its k
//! products serially via *unfused* multiply-then-add (an FMA would skip
//! the intermediate rounding) — so all paths are bit-identical and the
//! determinism contract is ISA-independent. The choice is resolved once
//! per [`gemm_slices`] call on the calling thread and carried into pool
//! jobs as a function pointer.
//!
//! Long kernels also take an optional [`CancelToken`]: a hard-cancelled
//! task stops within one MC panel instead of running a large GEMM to
//! completion (`docs/tasks.md`).

use crate::compute::pool::ThreadPool;
use crate::simd::{self, Isa};
use crate::tasks::CancelToken;

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct LocalMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

/// Micro-tile rows (register blocking; the micro-kernel computes an
/// `MR×NR` block of C per inner-loop pass).
pub const GEMM_MR: usize = 4;
/// Micro-tile columns.
pub const GEMM_NR: usize = 8;
/// Rows per packed A panel — also the fixed parallel grain for the
/// engine's M-split (thread-count independent; see `docs/compute.md`).
pub const GEMM_MC: usize = 64;
/// K-extent per packed panel pair (sized so an A panel stays L2-resident:
/// `MC×KC` f64 = 128 KiB).
pub const GEMM_KC: usize = 256;

impl LocalMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        LocalMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_data(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        LocalMatrix { rows, cols, data }
    }

    /// Build from a row-generating closure.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        LocalMatrix { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f64> {
        self.data
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Rows `[a, b)` as a new matrix.
    pub fn slice_rows(&self, a: usize, b: usize) -> LocalMatrix {
        assert!(a <= b && b <= self.rows);
        LocalMatrix {
            rows: b - a,
            cols: self.cols,
            data: self.data[a * self.cols..b * self.cols].to_vec(),
        }
    }

    /// Copy `src` into rows starting at `at`.
    pub fn write_rows(&mut self, at: usize, src: &LocalMatrix) {
        assert_eq!(src.cols, self.cols);
        assert!(at + src.rows <= self.rows);
        self.data[at * self.cols..(at + src.rows) * self.cols]
            .copy_from_slice(&src.data);
    }

    /// Columns `[a, b)` as a new matrix.
    pub fn slice_cols(&self, a: usize, b: usize) -> LocalMatrix {
        assert!(a <= b && b <= self.cols);
        let mut out = LocalMatrix::zeros(self.rows, b - a);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[a..b]);
        }
        out
    }

    pub fn transpose(&self) -> LocalMatrix {
        let mut out = LocalMatrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Pad to `(rows, cols)` with zeros (no-op if already that size).
    pub fn padded(&self, rows: usize, cols: usize) -> LocalMatrix {
        assert!(rows >= self.rows && cols >= self.cols);
        if rows == self.rows && cols == self.cols {
            return self.clone();
        }
        let mut out = LocalMatrix::zeros(rows, cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    /// Top-left `(rows, cols)` corner (inverse of [`padded`]).
    pub fn shrunk(&self, rows: usize, cols: usize) -> LocalMatrix {
        assert!(rows <= self.rows && cols <= self.cols);
        if rows == self.rows && cols == self.cols {
            return self.clone();
        }
        let mut out = LocalMatrix::zeros(rows, cols);
        for i in 0..rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[..cols]);
        }
        out
    }

    /// `[A A ... A]` — column-wise tiling (Figure 3 construction).
    pub fn tile_cols(&self, times: usize) -> LocalMatrix {
        assert!(times >= 1);
        let mut out = LocalMatrix::zeros(self.rows, self.cols * times);
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for t in 0..times {
                dst[t * self.cols..(t + 1) * self.cols].copy_from_slice(src);
            }
        }
        out
    }

    pub fn fro_sq(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum()
    }

    pub fn fro_norm(&self) -> f64 {
        self.fro_sq().sqrt()
    }

    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// `self += alpha * other` (4-lane unrolled; elementwise, so the
    /// result is identical to the naive loop bit-for-bit).
    pub fn axpy(&mut self, alpha: f64, other: &LocalMatrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        crate::linalg::blas1::axpy(&mut self.data, alpha, &other.data);
    }

    /// Per-column dot products: `out[j] = Σ_i a[i,j]·b[i,j]` (block-CG
    /// needs one inner product per right-hand side).
    pub fn col_dots(&self, other: &LocalMatrix) -> Vec<f64> {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let (ra, rb) = (self.row(i), other.row(i));
            for j in 0..self.cols {
                out[j] += ra[j] * rb[j];
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &LocalMatrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    // ---- packed-panel native GEMM: C += op(A)·op(B) ----

    /// `self += a · b` (a: m×k, b: k×n, self: m×n).
    pub fn gemm_nn(&mut self, a: &LocalMatrix, b: &LocalMatrix) {
        self.gemm_nn_with(a, b, None, None);
    }

    /// [`gemm_nn`](LocalMatrix::gemm_nn), optionally splitting the M
    /// dimension over `pool` in fixed [`GEMM_MC`]-row panels
    /// (bit-identical for any thread count) and polling `cancel` at
    /// MC-panel boundaries. Returns `false` (with `self` left partially
    /// updated — discard it) iff cancellation cut the kernel short.
    pub fn gemm_nn_with(
        &mut self,
        a: &LocalMatrix,
        b: &LocalMatrix,
        pool: Option<&ThreadPool>,
        cancel: Option<&CancelToken>,
    ) -> bool {
        assert_eq!(a.cols, b.rows);
        assert_eq!((self.rows, self.cols), (a.rows, b.cols));
        let (m, n, k) = (a.rows, b.cols, a.cols);
        gemm_slices(&mut self.data, m, n, k, &a.data, k, 1, &b.data, n, 1, pool, cancel)
    }

    /// `self += aᵀ · b` (a stored k×m, b: k×n, self: m×n).
    pub fn gemm_tn(&mut self, a: &LocalMatrix, b: &LocalMatrix) {
        self.gemm_tn_with(a, b, None, None);
    }

    /// [`gemm_tn`](LocalMatrix::gemm_tn) with an optional pool and cancel
    /// token; the transposed A costs a transposed pack, not a strided
    /// inner loop.
    pub fn gemm_tn_with(
        &mut self,
        a: &LocalMatrix,
        b: &LocalMatrix,
        pool: Option<&ThreadPool>,
        cancel: Option<&CancelToken>,
    ) -> bool {
        assert_eq!(a.rows, b.rows);
        assert_eq!((self.rows, self.cols), (a.cols, b.cols));
        let (m, n, k) = (a.cols, b.cols, a.rows);
        gemm_slices(&mut self.data, m, n, k, &a.data, 1, m, &b.data, n, 1, pool, cancel)
    }

    /// `self += a · bᵀ` (a: m×k, b stored n×k, self: m×n).
    pub fn gemm_nt(&mut self, a: &LocalMatrix, b: &LocalMatrix) {
        self.gemm_nt_with(a, b, None, None);
    }

    /// [`gemm_nt`](LocalMatrix::gemm_nt) with an optional pool and cancel
    /// token; the transposed B costs a transposed pack, not a strided
    /// inner loop.
    pub fn gemm_nt_with(
        &mut self,
        a: &LocalMatrix,
        b: &LocalMatrix,
        pool: Option<&ThreadPool>,
        cancel: Option<&CancelToken>,
    ) -> bool {
        assert_eq!(a.cols, b.cols);
        assert_eq!((self.rows, self.cols), (a.rows, b.rows));
        let (m, n, k) = (a.rows, b.rows, a.cols);
        gemm_slices(&mut self.data, m, n, k, &a.data, k, 1, &b.data, 1, k, pool, cancel)
    }
}

/// Strided packed GEMM over raw slices: `c += op(a)·op(b)` where
/// `op(a)[i][kk] = a[i·ars + kk·acs]` (m×k), `op(b)[kk][j] =
/// b[kk·brs + j·bcs]` (k×n) and `c` is row-major m×n. The one entry point
/// behind all three storage variants and the engine's row-chunked fused
/// ops (which is why it takes slices, not `LocalMatrix`).
///
/// Loop structure (BLIS-style, NC = n since every caller's n fits a
/// packed B panel comfortably):
///
/// * `k` is blocked by [`GEMM_KC`]; per block, B is packed once into
///   [`GEMM_NR`]-column strips (k-major, zero-padded to NR);
/// * `m` is blocked by [`GEMM_MC`]; each panel packs its A rows into
///   [`GEMM_MR`]-row micro-panels (k-major, zero-padded to MR) and is
///   independent of every other panel — the unit of parallelism;
/// * the micro-kernel accumulates an MR×NR register tile over the full
///   KC extent with no branches in the FMA chain, then adds the valid
///   region into C.
///
/// Per-cell arithmetic order is fixed by (shape, blocking constants)
/// alone — never by `pool`, its thread count, or the ISA variant — so
/// results are bit-identical across `threads = 1/2/4/...` and across
/// fallback/AVX2/AVX-512 paths.
///
/// `cancel` is polled at MC-panel boundaries (the engine-level check-in
/// for hard cancellation). Returns `false` iff the kernel stopped early
/// on a set token; `c` then holds a partial update the caller must
/// discard.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_slices(
    c: &mut [f64],
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    ars: usize,
    acs: usize,
    b: &[f64],
    brs: usize,
    bcs: usize,
    pool: Option<&ThreadPool>,
    cancel: Option<&CancelToken>,
) -> bool {
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return true;
    }
    let mk = micro_kernel(simd::current());
    let mut bp: Vec<f64> = Vec::new();
    for k0 in (0..k).step_by(GEMM_KC) {
        if is_cancelled(cancel) {
            return false;
        }
        let kc = GEMM_KC.min(k - k0);
        pack_b(&mut bp, b, brs, bcs, k0, kc, n, pool);
        match pool {
            Some(pool) if m > GEMM_MC => {
                let bp_ref: &[f64] = &bp;
                let jobs: Vec<_> = c
                    .chunks_mut(GEMM_MC * n)
                    .enumerate()
                    .map(|(pi, cc)| {
                        move || {
                            // a cancelled task skips its remaining panels;
                            // the bailing caller discards the partial C
                            if is_cancelled(cancel) {
                                return;
                            }
                            let mc = cc.len() / n;
                            gemm_panel(cc, mc, n, kc, a, ars, acs, pi * GEMM_MC, k0, bp_ref, mk);
                        }
                    })
                    .collect();
                pool.run(jobs);
                if is_cancelled(cancel) {
                    return false;
                }
            }
            _ => {
                for (pi, cc) in c.chunks_mut(GEMM_MC * n).enumerate() {
                    if is_cancelled(cancel) {
                        return false;
                    }
                    let mc = cc.len() / n;
                    gemm_panel(cc, mc, n, kc, a, ars, acs, pi * GEMM_MC, k0, &bp, mk);
                }
            }
        }
    }
    true
}

#[inline]
fn is_cancelled(cancel: Option<&CancelToken>) -> bool {
    cancel.is_some_and(|t| t.is_cancelled())
}

/// Pack the `kc`-deep, `n`-wide block of op(B) starting at row `k0` into
/// NR-column strips: strip `s` holds `op(b)[k0+kk][s·NR + j]` at
/// `s·NR·kc + kk·NR + j`, zero-padded to NR columns so the micro-kernel
/// never branches on the edge.
///
/// Wide blocks split the strip range over `pool`: the serial KC×N pack
/// dominates skinny-A shapes, where `m ≤ MC` leaves the panel loop with
/// no parallelism at all. Strips are disjoint destination regions written
/// from a read-only source, so the packed bytes are identical however
/// many threads produced them.
fn pack_b(
    bp: &mut Vec<f64>,
    b: &[f64],
    brs: usize,
    bcs: usize,
    k0: usize,
    kc: usize,
    n: usize,
    pool: Option<&ThreadPool>,
) {
    let strips = n.div_ceil(GEMM_NR);
    bp.clear();
    bp.resize(strips * GEMM_NR * kc, 0.0);
    // 8 strips per job = 16 KiB of packed output at full KC; below ~256
    // KiB total the pack is cheaper than dispatching jobs for it
    const PACK_STRIPS_PER_JOB: usize = 8;
    const PACK_PAR_MIN_ELEMS: usize = 32 * 1024;
    match pool {
        Some(pool) if strips > PACK_STRIPS_PER_JOB && bp.len() >= PACK_PAR_MIN_ELEMS => {
            let jobs: Vec<_> = bp
                .chunks_mut(PACK_STRIPS_PER_JOB * GEMM_NR * kc)
                .enumerate()
                .map(|(g, dst)| {
                    move || pack_b_strips(dst, b, brs, bcs, k0, kc, n, g * PACK_STRIPS_PER_JOB)
                })
                .collect();
            pool.run(jobs);
        }
        _ => pack_b_strips(bp, b, brs, bcs, k0, kc, n, 0),
    }
}

/// Pack strips `s0 ..` of the block into `dst` (pre-zeroed; its length
/// determines how many strips, the last possibly partial-width).
#[allow(clippy::too_many_arguments)]
fn pack_b_strips(
    dst: &mut [f64],
    b: &[f64],
    brs: usize,
    bcs: usize,
    k0: usize,
    kc: usize,
    n: usize,
    s0: usize,
) {
    for (si, strip) in dst.chunks_mut(GEMM_NR * kc).enumerate() {
        let j0 = (s0 + si) * GEMM_NR;
        let cols = GEMM_NR.min(n - j0);
        for kk in 0..kc {
            let src = (k0 + kk) * brs;
            let at = kk * GEMM_NR;
            for j in 0..cols {
                strip[at + j] = b[src + (j0 + j) * bcs];
            }
        }
    }
}

/// One MC-row panel of the packed GEMM: pack this panel's rows of op(A),
/// then sweep NR-column strips × MR-row micro-panels through the
/// micro-kernel `mk`. `cc` is the panel's contiguous C rows (`mc × n`),
/// `i0` the panel's first row in op(A).
#[allow(clippy::too_many_arguments)]
fn gemm_panel(
    cc: &mut [f64],
    mc: usize,
    n: usize,
    kc: usize,
    a: &[f64],
    ars: usize,
    acs: usize,
    i0: usize,
    k0: usize,
    bp: &[f64],
    mk: MicroKernel,
) {
    // pack op(A) rows i0..i0+mc into MR-row micro-panels, k-major,
    // zero-padded to MR rows
    let panels = mc.div_ceil(GEMM_MR);
    let mut ap = vec![0.0f64; panels * GEMM_MR * kc];
    for p in 0..panels {
        let ir = p * GEMM_MR;
        let rows = GEMM_MR.min(mc - ir);
        let base = p * GEMM_MR * kc;
        for r in 0..rows {
            let src = (i0 + ir + r) * ars;
            for kk in 0..kc {
                ap[base + kk * GEMM_MR + r] = a[src + (k0 + kk) * acs];
            }
        }
    }
    // NR strips outer so each packed B strip stays hot across the whole
    // panel; MR micro-panels inner
    for (s, j0) in (0..n).step_by(GEMM_NR).enumerate() {
        let nr = GEMM_NR.min(n - j0);
        let bs = &bp[s * GEMM_NR * kc..(s + 1) * GEMM_NR * kc];
        for p in 0..panels {
            let ir = p * GEMM_MR;
            let rows = GEMM_MR.min(mc - ir);
            let asl = &ap[p * GEMM_MR * kc..(p + 1) * GEMM_MR * kc];
            let mut acc = [[0.0f64; GEMM_NR]; GEMM_MR];
            mk(asl, bs, &mut acc);
            for i in 0..rows {
                let at = (ir + i) * n + j0;
                let crow = &mut cc[at..at + nr];
                for (cj, aj) in crow.iter_mut().zip(&acc[i][..nr]) {
                    *cj += *aj;
                }
            }
        }
    }
}

// ---- the register-blocked micro-kernel, in per-ISA variants ----
//
// All variants compute `acc[i][j] += Σ_kk asl[kk·MR + i] · bs[kk·NR + j]`
// with the k-products of each (i, j) cell accumulated serially in kk
// order through *unfused* multiply-then-add — never `fmadd`, whose single
// rounding would diverge from the portable path. Wider ISAs only change
// how many independent (i, j) cells one instruction carries, never the
// order of any cell's own additions, so every variant is bit-identical
// to `mk_portable` (pinned in `it_compute.rs`).

/// Signature of the micro-kernel: `asl` is an MR-row packed A micro-panel
/// (`MR·kc` long, k-major), `bs` a packed B strip (`NR·kc` long), and the
/// MR×NR accumulator tile is added to, not overwritten.
pub(crate) type MicroKernel = fn(&[f64], &[f64], &mut [[f64; GEMM_NR]; GEMM_MR]);

/// The micro-kernel variant for `isa`. The simd module only hands out
/// ISAs the host can run, so the cfg-gated arms cover every reachable
/// case; anything else routes to the portable kernel.
pub(crate) fn micro_kernel(isa: Isa) -> MicroKernel {
    match isa {
        Isa::Fallback => mk_portable,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => mk_avx2,
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        Isa::Avx512 => mk_avx512,
        #[allow(unreachable_patterns)]
        _ => mk_portable,
    }
}

/// Portable micro-kernel: branch-free MR×NR multiply-add chain over the
/// packed panels (`chunks_exact` gives LLVM fixed-width lanes to
/// auto-vectorize at the build's baseline ISA).
fn mk_portable(asl: &[f64], bs: &[f64], acc: &mut [[f64; GEMM_NR]; GEMM_MR]) {
    for (av, bv) in asl.chunks_exact(GEMM_MR).zip(bs.chunks_exact(GEMM_NR)) {
        for i in 0..GEMM_MR {
            let ai = av[i];
            let row = &mut acc[i];
            for j in 0..GEMM_NR {
                row[j] += ai * bv[j];
            }
        }
    }
}

/// AVX2 micro-kernel: the NR=8 accumulator row of each of the MR rows
/// lives in two 256-bit registers (8 of 16 ymm in accumulators).
#[cfg(target_arch = "x86_64")]
fn mk_avx2(asl: &[f64], bs: &[f64], acc: &mut [[f64; GEMM_NR]; GEMM_MR]) {
    // SAFETY: only reachable via `micro_kernel(Isa::Avx2)`, which the
    // simd module yields solely after `is_x86_feature_detected!` has
    // confirmed avx2+fma on this host.
    unsafe { mk_avx2_impl(asl, bs, acc) }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn mk_avx2_impl(asl: &[f64], bs: &[f64], acc: &mut [[f64; GEMM_NR]; GEMM_MR]) {
    use std::arch::x86_64::*;
    let mut c0 = [_mm256_setzero_pd(); GEMM_MR];
    let mut c1 = [_mm256_setzero_pd(); GEMM_MR];
    for i in 0..GEMM_MR {
        c0[i] = _mm256_loadu_pd(acc[i].as_ptr());
        c1[i] = _mm256_loadu_pd(acc[i].as_ptr().add(4));
    }
    for (av, bv) in asl.chunks_exact(GEMM_MR).zip(bs.chunks_exact(GEMM_NR)) {
        let b0 = _mm256_loadu_pd(bv.as_ptr());
        let b1 = _mm256_loadu_pd(bv.as_ptr().add(4));
        for i in 0..GEMM_MR {
            let ai = _mm256_set1_pd(av[i]);
            // unfused mul+add, NOT _mm256_fmadd_pd: bit-identity with the
            // portable path requires the intermediate rounding
            c0[i] = _mm256_add_pd(c0[i], _mm256_mul_pd(ai, b0));
            c1[i] = _mm256_add_pd(c1[i], _mm256_mul_pd(ai, b1));
        }
    }
    for i in 0..GEMM_MR {
        _mm256_storeu_pd(acc[i].as_mut_ptr(), c0[i]);
        _mm256_storeu_pd(acc[i].as_mut_ptr().add(4), c1[i]);
    }
}

/// AVX-512F micro-kernel: one 512-bit register holds a full NR=8
/// accumulator row. Feature-gated (`--features avx512`) and still
/// runtime-detected before selection.
#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
fn mk_avx512(asl: &[f64], bs: &[f64], acc: &mut [[f64; GEMM_NR]; GEMM_MR]) {
    // SAFETY: only reachable via `micro_kernel(Isa::Avx512)`, yielded
    // solely after `is_x86_feature_detected!("avx512f")` confirmed.
    unsafe { mk_avx512_impl(asl, bs, acc) }
}

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
#[target_feature(enable = "avx512f")]
unsafe fn mk_avx512_impl(asl: &[f64], bs: &[f64], acc: &mut [[f64; GEMM_NR]; GEMM_MR]) {
    use std::arch::x86_64::*;
    let mut c = [_mm512_setzero_pd(); GEMM_MR];
    for i in 0..GEMM_MR {
        c[i] = _mm512_loadu_pd(acc[i].as_ptr());
    }
    for (av, bv) in asl.chunks_exact(GEMM_MR).zip(bs.chunks_exact(GEMM_NR)) {
        let b = _mm512_loadu_pd(bv.as_ptr());
        for i in 0..GEMM_MR {
            let ai = _mm512_set1_pd(av[i]);
            // unfused mul+add for bit-identity with the portable path
            c[i] = _mm512_add_pd(c[i], _mm512_mul_pd(ai, b));
        }
    }
    for i in 0..GEMM_MR {
        _mm512_storeu_pd(acc[i].as_mut_ptr(), c[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random(rng: &mut Rng, r: usize, c: usize) -> LocalMatrix {
        LocalMatrix::from_fn(r, c, |_, _| rng.normal())
    }

    /// Naive reference product.
    fn gemm_ref(a: &LocalMatrix, b: &LocalMatrix) -> LocalMatrix {
        let mut c = LocalMatrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a.get(i, k) * b.get(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn gemm_variants_match_reference() {
        let mut rng = Rng::new(1);
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (5, 7, 3), (33, 17, 65), (128, 64, 70)] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            let want = gemm_ref(&a, &b);

            let mut c = LocalMatrix::zeros(m, n);
            c.gemm_nn(&a, &b);
            assert!(c.max_abs_diff(&want) < 1e-10, "nn {m}x{n}x{k}");

            let mut c = LocalMatrix::zeros(m, n);
            c.gemm_tn(&a.transpose(), &b);
            assert!(c.max_abs_diff(&want) < 1e-10, "tn {m}x{n}x{k}");

            let mut c = LocalMatrix::zeros(m, n);
            c.gemm_nt(&a, &b.transpose());
            assert!(c.max_abs_diff(&want) < 1e-10, "nt {m}x{n}x{k}");
        }
    }

    #[test]
    fn gemm_edge_shapes_match_reference_and_pool_is_bit_identical() {
        let mut rng = Rng::new(11);
        let pools = [ThreadPool::new(2), ThreadPool::new(4)];
        // shapes straddling every blocking boundary: micro-tile (MR=4,
        // NR=8), panel (MC=64), k-block (KC=256), degenerate vectors,
        // and empty-k
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (1, 8, 1),
            (8, 1, 8),
            (3, 5, 2),
            (4, 8, 4),
            (5, 9, 7),
            (63, 65, 129),
            (65, 7, 33),
            (129, 16, 257),
            (64, 8, 0),
        ] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            let want = gemm_ref(&a, &b);

            let mut serial = LocalMatrix::zeros(m, n);
            serial.gemm_nn(&a, &b);
            assert!(serial.max_abs_diff(&want) < 1e-10, "nn {m}x{n}x{k}");

            for pool in &pools {
                // NN/TN/NT through the pool must be BIT-identical to the
                // serial path (the engine determinism contract)
                let mut c = LocalMatrix::zeros(m, n);
                c.gemm_nn_with(&a, &b, Some(pool), None);
                assert_eq!(c, serial, "nn pooled {m}x{n}x{k}");

                let mut t = LocalMatrix::zeros(m, n);
                t.gemm_tn_with(&a.transpose(), &b, Some(pool), None);
                let mut t_serial = LocalMatrix::zeros(m, n);
                t_serial.gemm_tn(&a.transpose(), &b);
                assert_eq!(t, t_serial, "tn pooled {m}x{n}x{k}");
                assert!(t.max_abs_diff(&want) < 1e-10, "tn {m}x{n}x{k}");

                let mut u = LocalMatrix::zeros(m, n);
                u.gemm_nt_with(&a, &b.transpose(), Some(pool), None);
                let mut u_serial = LocalMatrix::zeros(m, n);
                u_serial.gemm_nt(&a, &b.transpose());
                assert_eq!(u, u_serial, "nt pooled {m}x{n}x{k}");
                assert!(u.max_abs_diff(&want) < 1e-10, "nt {m}x{n}x{k}");
            }
        }
    }

    #[test]
    fn isa_variants_bit_identical_to_fallback() {
        // every runnable ISA path (serial and pooled, which also covers
        // the threaded B-pack) must produce the exact bits of the
        // portable kernel — the dispatch determinism contract
        let mut rng = Rng::new(12);
        let pool = ThreadPool::new(4);
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (5, 9, 7),
            (63, 65, 129),
            (65, 100, 257),
            (130, 7, 33),
        ] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            let base = crate::simd::with_isa(crate::simd::Isa::Fallback, || {
                let mut c = LocalMatrix::zeros(m, n);
                c.gemm_nn(&a, &b);
                c
            });
            for isa in crate::simd::available() {
                let (serial, pooled) = crate::simd::with_isa(isa, || {
                    let mut c = LocalMatrix::zeros(m, n);
                    c.gemm_nn(&a, &b);
                    let mut p = LocalMatrix::zeros(m, n);
                    p.gemm_nn_with(&a, &b, Some(&pool), None);
                    (c, p)
                });
                assert_eq!(serial, base, "{} serial {m}x{n}x{k}", isa.name());
                assert_eq!(pooled, base, "{} pooled {m}x{n}x{k}", isa.name());
            }
        }
    }

    #[test]
    fn cancel_token_stops_gemm_early() {
        use crate::tasks::CancelToken;
        let mut rng = Rng::new(13);
        let a = random(&mut rng, 300, 64);
        let b = random(&mut rng, 64, 32);
        let mut c = LocalMatrix::zeros(300, 32);

        // a clear token changes nothing
        let token = CancelToken::new();
        assert!(c.gemm_nn_with(&a, &b, None, Some(&token)));

        // a pre-set token stops the kernel before it completes
        token.cancel();
        let mut d = LocalMatrix::zeros(300, 32);
        assert!(!d.gemm_nn_with(&a, &b, None, Some(&token)));

        let pool = ThreadPool::new(2);
        let mut e = LocalMatrix::zeros(300, 32);
        assert!(!e.gemm_nn_with(&a, &b, Some(&pool), Some(&token)));
    }

    #[test]
    fn gemm_accumulates() {
        let mut rng = Rng::new(2);
        let a = random(&mut rng, 4, 4);
        let b = random(&mut rng, 4, 4);
        let seed = random(&mut rng, 4, 4);
        let mut c = seed.clone();
        c.gemm_nn(&a, &b);
        let mut want = gemm_ref(&a, &b);
        want.axpy(1.0, &seed);
        assert!(c.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn pad_shrink_roundtrip() {
        let mut rng = Rng::new(3);
        let a = random(&mut rng, 5, 7);
        let p = a.padded(8, 16);
        assert_eq!(p.rows(), 8);
        assert_eq!(p.fro_sq(), a.fro_sq()); // zero padding adds nothing
        assert_eq!(p.shrunk(5, 7), a);
    }

    #[test]
    fn slice_write_roundtrip() {
        let mut rng = Rng::new(4);
        let a = random(&mut rng, 6, 3);
        let s = a.slice_rows(2, 5);
        let mut b = LocalMatrix::zeros(6, 3);
        b.write_rows(2, &s);
        assert_eq!(b.slice_rows(2, 5), s);
        assert_eq!(b.row(0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn transpose_involution_and_slice_cols() {
        let mut rng = Rng::new(5);
        let a = random(&mut rng, 4, 9);
        assert_eq!(a.transpose().transpose(), a);
        let c = a.slice_cols(2, 5);
        for i in 0..4 {
            for j in 0..3 {
                assert_eq!(c.get(i, j), a.get(i, j + 2));
            }
        }
    }

    #[test]
    fn col_dots_matches_naive() {
        let mut rng = Rng::new(6);
        let a = random(&mut rng, 10, 4);
        let b = random(&mut rng, 10, 4);
        let got = a.col_dots(&b);
        for j in 0..4 {
            let want: f64 = (0..10).map(|i| a.get(i, j) * b.get(i, j)).sum();
            assert!((got[j] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn identity_is_gemm_neutral() {
        let mut rng = Rng::new(7);
        let a = random(&mut rng, 6, 6);
        let mut c = LocalMatrix::zeros(6, 6);
        c.gemm_nn(&a, &LocalMatrix::identity(6));
        assert!(c.max_abs_diff(&a) < 1e-14);
    }
}
