//! In-process communicator: ranks are threads, messages are mailboxes.
//!
//! Used by the coordinator's worker group (the paper runs Alchemist's MPI
//! ranks inside one allocation; we run them inside one process). A
//! [`crate::config::SimNetConfig`] cost model charges each *received*
//! message with modeled interconnect time so the SimClock can reconstruct
//! what the same traffic would cost across nodes.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Condvar, Mutex};

use crate::config::SimNetConfig;

use super::Communicator;

type Key = (usize, u64); // (sender, tag)

#[derive(Default)]
struct Mailbox {
    // FIFO per (sender, tag)
    queues: Mutex<HashMap<Key, std::collections::VecDeque<Vec<f64>>>>,
    signal: Condvar,
}

struct Shared {
    boxes: Vec<Mailbox>,
    barrier: Barrier,
    simnet: Option<SimNetConfig>,
}

/// One rank's endpoint into the shared in-proc fabric.
pub struct LocalComm {
    rank: usize,
    size: usize,
    shared: Arc<Shared>,
    /// Modeled comm nanoseconds charged to this rank.
    sim_ns: Arc<AtomicU64>,
}

impl LocalComm {
    /// Create endpoints for a `size`-rank group.
    pub fn group(size: usize, simnet: Option<SimNetConfig>) -> Vec<LocalComm> {
        assert!(size > 0);
        let shared = Arc::new(Shared {
            boxes: (0..size).map(|_| Mailbox::default()).collect(),
            barrier: Barrier::new(size),
            simnet,
        });
        (0..size)
            .map(|rank| LocalComm {
                rank,
                size,
                shared: shared.clone(),
                sim_ns: Arc::new(AtomicU64::new(0)),
            })
            .collect()
    }

    fn charge(&self, bytes: usize) {
        if let Some(net) = &self.shared.simnet {
            let secs = net.transfer_secs(bytes);
            self.sim_ns
                .fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
        }
    }
}

impl Communicator for LocalComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send(&self, to: usize, tag: u64, data: Vec<f64>) {
        debug_assert!(to < self.size);
        let mbox = &self.shared.boxes[to];
        let mut queues = mbox.queues.lock().unwrap();
        queues.entry((self.rank, tag)).or_default().push_back(data);
        mbox.signal.notify_all();
    }

    fn recv(&self, from: usize, tag: u64) -> Vec<f64> {
        let mbox = &self.shared.boxes[self.rank];
        let mut queues = mbox.queues.lock().unwrap();
        loop {
            if let Some(q) = queues.get_mut(&(from, tag)) {
                if let Some(data) = q.pop_front() {
                    drop(queues);
                    self.charge(data.len() * 8);
                    return data;
                }
            }
            queues = mbox.signal.wait(queues).unwrap();
        }
    }

    fn barrier(&self) {
        self.shared.barrier.wait();
    }

    fn sim_comm_secs(&self) -> f64 {
        self.sim_ns.load(Ordering::Relaxed) as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spawn_ranks<F>(n: usize, f: F)
    where
        F: Fn(LocalComm) + Send + Sync + Clone + 'static,
    {
        let comms = LocalComm::group(n, None);
        let mut handles = Vec::new();
        for c in comms {
            let f = f.clone();
            handles.push(std::thread::spawn(move || f(c)));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn point_to_point_fifo_per_tag() {
        spawn_ranks(2, |c| {
            if c.rank() == 0 {
                c.send(1, 5, vec![1.0]);
                c.send(1, 5, vec![2.0]);
                c.send(1, 9, vec![3.0]);
            } else {
                // tag 9 can be read before tag 5's backlog
                assert_eq!(c.recv(0, 9), vec![3.0]);
                assert_eq!(c.recv(0, 5), vec![1.0]);
                assert_eq!(c.recv(0, 5), vec![2.0]);
            }
        });
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        COUNT.store(0, Ordering::SeqCst);
        spawn_ranks(4, |c| {
            COUNT.fetch_add(1, Ordering::SeqCst);
            c.barrier();
            // after the barrier every rank must observe all 4 arrivals
            assert_eq!(COUNT.load(Ordering::SeqCst), 4);
        });
    }

    #[test]
    fn sim_cost_charged_on_receive() {
        let comms = LocalComm::group(
            2,
            Some(crate::config::SimNetConfig { latency_s: 1e-6, bytes_per_s: 1e9 }),
        );
        let [c0, c1]: [LocalComm; 2] = comms.try_into().map_err(|_| ()).unwrap();
        let t = std::thread::spawn(move || {
            c0.send(1, 0, vec![0.0; 1000]);
            c0.sim_comm_secs()
        });
        let _ = c1.recv(0, 0);
        let sender_cost = t.join().unwrap();
        assert_eq!(sender_cost, 0.0);
        // 8000 bytes at 1 GB/s + 1 µs = 9 µs
        assert!((c1.sim_comm_secs() - 9e-6).abs() < 1e-7, "{}", c1.sim_comm_secs());
    }
}
