"""L1: tiled Pallas GEMM kernels (the worker compute hot-spot).

The paper's MPI libraries (libSkylark CG, the Elemental-based SVD) spend
essentially all their time in dense GEMM; here that hot-spot is a Pallas
kernel. The kernel is written TPU-style — the grid walks (M/bm, N/bn)
output tiles, each program holds one accumulator tile while K-panels of A
and B are streamed through BlockSpec-scheduled copies — and is lowered with
``interpret=True`` so the CPU PJRT client can execute the resulting HLO
(real-TPU lowering emits a Mosaic custom-call the CPU plugin cannot run;
see DESIGN.md §Hardware-Adaptation for the VMEM/MXU projection).

All three storage variants take ``C_in`` and return ``C_in + op(A)·op(B)``
so the rust runtime composes arbitrary GEMMs from fixed-shape artifacts by
looping tiles and threading the accumulator through:

* ``nn``: A[M,K] · B[K,N]
* ``tn``: A[K,M]ᵀ · B[K,N]   (A stored untransposed — Gram products)
* ``nt``: A[M,K] · B[N,K]ᵀ   (right factor stored row-major)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is <= ``want`` (grids must tile)."""
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


def _gemm_kernel(c_ref, a_ref, b_ref, o_ref, *, trans_a: bool, trans_b: bool):
    """One (i, j, k) grid step: o[i,j] (+)= op(a)·op(b), seeded with c[i,j].

    The K axis is the innermost grid dimension; the output block for a
    fixed (i, j) is revisited across k steps, which Pallas guarantees stays
    resident (the TPU analogue: the accumulator tile lives in VMEM while
    A/B panels stream past it).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _seed():
        o_ref[...] = c_ref[...]

    a = a_ref[...]
    b = b_ref[...]
    if trans_a:
        a = a.T
    if trans_b:
        b = b.T
    o_ref[...] += jnp.dot(a, b, preferred_element_type=o_ref.dtype)


def make_gemm(
    m: int,
    n: int,
    k: int,
    *,
    variant: str = "nn",
    dtype=jnp.float64,
    block: int = 128,
    interpret: bool = True,
):
    """Build ``fn(c, a, b) -> c + op(a)·op(b)`` as a Pallas call.

    Shapes: c [m,n]; nn: a [m,k], b [k,n]; tn: a [k,m], b [k,n];
    nt: a [m,k], b [n,k].
    """
    if variant not in ("nn", "tn", "nt"):
        raise ValueError(f"unknown gemm variant {variant!r}")
    trans_a = variant == "tn"
    trans_b = variant == "nt"

    bm = _pick_block(m, block)
    bn = _pick_block(n, block)
    bk = _pick_block(k, block)
    grid = (m // bm, n // bn, k // bk)

    # index maps are in units of blocks
    c_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    if trans_a:
        a_spec = pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, i))
        a_shape = (k, m)
    else:
        a_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
        a_shape = (m, k)
    if trans_b:
        b_spec = pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk))
        b_shape = (n, k)
    else:
        b_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
        b_shape = (k, n)

    kernel = functools.partial(_gemm_kernel, trans_a=trans_a, trans_b=trans_b)

    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[c_spec, a_spec, b_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), dtype),
        interpret=interpret,
    )

    def gemm(c, a, b):
        assert c.shape == (m, n), (c.shape, (m, n))
        assert a.shape == a_shape, (a.shape, a_shape)
        assert b.shape == b_shape, (b.shape, b_shape)
        return call(c, a, b)

    return gemm
