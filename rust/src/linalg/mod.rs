//! Distributed numerics: the libSkylark / ARPACK / Elemental-routine
//! stand-ins (DESIGN.md §2).
//!
//! All solvers are SPMD: every worker rank calls the same function with
//! its local row-block ([`crate::distmat::DistShard`]-style), a
//! [`crate::collectives::Communicator`], and its own
//! [`crate::compute::Engine`]. Small state (iterates, Lanczos vectors,
//! Gram matrices) is replicated; only Gram-operator partial sums travel
//! over the collectives — the same communication structure as the paper's
//! MPI routines.

pub mod blas1;
pub mod cg;
pub mod dense;
pub mod lanczos;
pub mod qr;
pub mod rff;
pub mod tridiag;

pub use cg::{cg_solve, cg_solve_scoped, CgOptions, CgResult};
pub use lanczos::{truncated_svd, truncated_svd_scoped, SvdOptions, SvdResult};
pub use qr::cholesky_qr2;
pub use rff::RffMap;
