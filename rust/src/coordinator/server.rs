//! The Alchemist driver: control-socket sessions, per-session worker
//! groups, matrix handles, concurrent SPMD task dispatch (paper §3.1.1).
//!
//! The driver owns a pool of worker ranks and carves it into
//! session-scoped groups: each handshake negotiates a group size (the
//! paper's `requestWorkers`), the [`GroupAllocator`] grants an exclusive
//! rank subset (queueing FIFO when capacity is short), and every task the
//! session submits runs SPMD over that group's own communicator. Sessions
//! holding disjoint groups therefore execute tasks concurrently — the
//! multi-client serving mode of the Cray deployments (Rothauge et al.
//! 2019) — while matrix handles stay namespaced per session so teardown
//! frees one tenant without disturbing the others.
//!
//! Since protocol v4 the task path is **asynchronous** (`docs/tasks.md`):
//! `SubmitTask` enqueues on the session's bounded FIFO and returns a task
//! id at once; a per-session dispatcher thread drains the FIFO over the
//! group; `TaskStatus` polls the `Queued → Running{progress} →
//! Done | Failed | Cancelled` state machine (progress aggregated across
//! ranks); `CancelTask` flips a cooperative token iterative routines
//! observe within one iteration; `WaitTask` blocks server-side with a
//! timeout so the classic synchronous call survives as submit + wait.
//! Teardown cancels queued and running work and joins the dispatcher
//! before freeing the session's store blocks, so nothing leaks.
//!
//! Since protocol v9 the scheduler is **serving-grade**
//! (`docs/scheduler.md`): admission is priority fair-share — the
//! handshake carries a priority class, clamped by `scheduler.max_priority`,
//! and the [`GroupAllocator`] grants by (aged) class then weighted tenant
//! load instead of flat FIFO; the dispatcher runs up to
//! `scheduler.tasks_per_group` tasks concurrently over one group, each on
//! its own tag lane of the group communicator (cancellation poisons only
//! the task's lane); and `SubscribeMetrics` streams push-based scheduler
//! snapshots to observers on a dedicated connection.
//!
//! Since protocol v8 the pool has two shapes (`fabric.mode`,
//! `docs/fabric.md`): **local** ranks are threads in this process (the
//! seed behavior, `LocalComm` mailboxes), **tcp** ranks are separate OS
//! processes (`alchemist worker`) reached over a multiplexed work socket,
//! with each session's collectives running rank↔rank over a brokered
//! `TcpComm` mesh. The driver stays control-plane only in both modes;
//! [`RankHandle`] and [`SessionFabric`] keep the dispatch/teardown paths
//! transport-agnostic, and the code matches on the variant only where a
//! store must be reached (direct call vs RPC).
//!
//! Since protocol v10 sessions are **survivable** (`docs/recovery.md`):
//! the pool holds `scheduler.spare_workers` standby ranks out of
//! admission, and when a worker process dies mid-task the executor
//! re-forms the group around a spare — `MeshForm` the replacement into
//! the session mesh, replay the dead slot's matrix shards from their
//! task-boundary snapshots (`storage.checkpoint_dir`; mapped matrices
//! replay from their source file), and re-run the task instead of
//! failing the session. On the client side the handshake ack carries a
//! `session_token`; a dropped connection parks the session for
//! `scheduler.session_linger_s` (tasks keep running), and `Reattach`
//! with the token resumes it — task table, results, and matrix handles
//! intact. Externally launched `alchemist worker --connect` processes
//! are adopted into the spare pool at runtime.

use std::collections::{HashMap, VecDeque};
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::hash::{BuildHasher, Hasher};
use std::sync::{mpsc, Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::collectives::{CommError, LocalComm, PoisonCause};
use crate::compute::ThreadPool;
use crate::config::{Config, FabricMode, SchedulerConfig, TransferConfig};
use crate::distmat::RowBlockLayout;
use crate::metrics::{
    SchedMetrics, SchedSnapshot, SessionGauge, StorageMetrics, StorageSnapshot,
    TaskGauge, TaskOutcome, PRIORITY_CLASSES, PRIORITY_NAMES,
};
use crate::net::{Framed, Server};
use crate::protocol::fabric::WorkMsg;
use crate::protocol::{
    ControlMsg, MatrixInfo, Params, TaskProgress, TaskState, PROTOCOL_VERSION,
};
use crate::tasks::{CancelToken, RankProgress, TaskScope};

use super::registry::{Library, Registry};
use super::remote::{wire_ranges, RankHandle, RemoteWorker, SessionFabric};
use super::store::checkpoint_path;
use super::worker::{alloc_group, handle_data_conn, worker_main, WorkerCmd, WorkerShared};

/// Driver-side record of a live distributed matrix.
#[derive(Debug, Clone)]
struct HandleMeta {
    info: MatrixInfo,
    layout: RowBlockLayout,
    /// For matrices ingested from a server-side file (`LoadMatrix`): the
    /// source path. On rank replacement the file itself is the snapshot —
    /// the replacement re-reads its shard from it (`docs/recovery.md`).
    source: Option<String>,
    /// Whether every shard is sealed. Only sealed matrices have
    /// task-boundary checkpoints (unsealed ingest state is not
    /// replayable, so a group holding one cannot be re-formed).
    sealed: bool,
}

/// One submitted task's immutable record. Mutable lifecycle state lives
/// in the session's [`TaskTable`]; live per-rank progress is read through
/// the `progress` slots while the task runs.
struct TaskRecord {
    id: u64,
    lib: Arc<dyn Library>,
    lib_name: String,
    routine: String,
    params: Params,
    /// Task-wide cooperative cancel token (shared by every rank's scope).
    cancel: Arc<CancelToken>,
    /// One live progress slot per group-local rank.
    progress: Vec<Arc<RankProgress>>,
    /// Earliest hard-cancel deadline armed for this task, if any. A
    /// repeat `CancelTask { hard_after_ms }` only spawns a new watchdog
    /// when it *tightens* the deadline — identical or looser requests
    /// must not each pin a sleeping thread (and the Session Arc) for the
    /// grace period, while a client correcting an over-long deadline
    /// still can.
    hard_deadline: Mutex<Option<Instant>>,
    /// The task's tag lane in the group communicator (protocol v9),
    /// assigned by the dispatcher when the task leaves the queue; 0 while
    /// still queued. Lanes are monotonic per session and never reused, so
    /// a finished task's stragglers land in a window nobody reads again.
    lane: AtomicU64,
    submitted: Instant,
}

impl TaskRecord {
    /// Aggregate the per-rank slots into the wire progress: `iters` is
    /// the minimum any rank completed (the group frontier), `residual`
    /// the worst residual reported so far.
    fn aggregate_progress(&self) -> TaskProgress {
        let iters = self.progress.iter().map(|p| p.iters()).min().unwrap_or(0);
        let residual = self
            .progress
            .iter()
            .map(|p| p.residual())
            .filter(|r| *r >= 0.0)
            .fold(crate::tasks::NO_RESIDUAL, f64::max);
        TaskProgress { iters, residual, ranks: self.progress.len() as u32 }
    }
}

/// Where one task id currently is in its lifecycle.
enum TaskSlot {
    Queued(Arc<TaskRecord>),
    Running(Arc<TaskRecord>),
    /// Done / Failed / Cancelled, ready for status/wait replies.
    Terminal(TaskState),
}

/// Terminal task slots retained per session for late status/wait
/// queries; beyond this the oldest are evicted (their ids then answer
/// "unknown task"). Bounds a long-lived session's memory — a tenant
/// polling thousands of solves must not grow the driver without limit.
const TERMINAL_RETENTION: usize = 1024;

/// Guarded task lifecycle state of one session.
struct TaskTableState {
    /// Pending task ids, FIFO (bounded by `scheduler.task_queue_depth`).
    queue: VecDeque<u64>,
    /// Tasks currently executing on the group, keyed by task id — up to
    /// `scheduler.tasks_per_group` of them (protocol v9), each on its own
    /// tag lane of the group communicator.
    running: HashMap<u64, Arc<TaskRecord>>,
    /// Next tag lane to assign (starts at 1; lane 0 is the untasked tag
    /// space). Monotonic, never reused.
    next_lane: u64,
    /// Tasks by id: everything queued/running plus the retained terminal
    /// window (see [`TERMINAL_RETENTION`]).
    slots: HashMap<u64, TaskSlot>,
    /// Terminal ids in completion order, oldest first (eviction order).
    terminal_order: VecDeque<u64>,
    /// Set at teardown: the dispatcher exits once the queue is drained.
    closing: bool,
}

impl TaskTableState {
    /// Record a terminal state, evicting the oldest retained terminal
    /// slot once the retention cap is exceeded.
    fn set_terminal(&mut self, id: u64, state: TaskState) {
        let prev = self.slots.insert(id, TaskSlot::Terminal(state));
        if matches!(prev, Some(TaskSlot::Terminal(_))) {
            return; // already counted in terminal_order
        }
        self.terminal_order.push_back(id);
        while self.terminal_order.len() > TERMINAL_RETENTION {
            if let Some(old) = self.terminal_order.pop_front() {
                self.slots.remove(&old);
            }
        }
    }
}

/// Per-session task table: one dispatcher thread pops the queue and
/// admits tasks onto the session's group (up to
/// `scheduler.tasks_per_group` concurrently, each on its own tag lane);
/// the condvar wakes the dispatcher (new work / a slot freeing /
/// teardown) and server-side `WaitTask` blockers (state transitions).
struct TaskTable {
    state: Mutex<TaskTableState>,
    cond: Condvar,
}

impl TaskTable {
    fn new() -> Self {
        TaskTable {
            state: Mutex::new(TaskTableState {
                queue: VecDeque::new(),
                running: HashMap::new(),
                next_lane: 1,
                slots: HashMap::new(),
                terminal_order: VecDeque::new(),
                closing: false,
            }),
            cond: Condvar::new(),
        }
    }
}

/// Wire state for one slot (aggregating live progress for running tasks).
fn wire_state(slot: &TaskSlot) -> TaskState {
    match slot {
        TaskSlot::Queued(_) => TaskState::Queued,
        TaskSlot::Running(rec) => {
            TaskState::Running { progress: rec.aggregate_progress() }
        }
        TaskSlot::Terminal(state) => state.clone(),
    }
}

/// A session's worker group: which global ranks it holds (in group
/// order — `ranks[i]` is group-local rank `i`) and the driver's
/// poison/reset/cancel handle on their communicator. One struct so rank
/// replacement (protocol v10) swaps both atomically: the group is
/// re-formed around a spare and the next dispatch sees the new
/// membership and the new mesh together.
#[derive(Clone)]
struct GroupState {
    ranks: Vec<usize>,
    /// Never used to send or receive: the hard-cancel watchdog poisons
    /// through it and the dispatcher resets the fabric through it
    /// between tasks. Local groups hold the rank-0 `LocalComm` endpoint
    /// directly; tcp groups hold the member work sockets and forward the
    /// same operations to each process's `TcpComm`.
    fabric: SessionFabric,
}

/// One connected client and the worker group it holds exclusively.
struct Session {
    id: u64,
    /// The client name it handshook with — the fair-share tenant key.
    client: String,
    /// Admitted priority class (requested, clamped to
    /// `scheduler.max_priority`).
    priority: u32,
    /// Opaque reconnect credential issued in the handshake ack (protocol
    /// v10, never 0 on the wire side — 0 means "no token"). A dropped
    /// client presents it in `Reattach` to resume this session while it
    /// lingers (`scheduler.session_linger_s`).
    token: u64,
    /// The group membership + fabric, swapped as a unit when a dead rank
    /// is replaced from the spare pool. Reads snapshot (clone) and never
    /// hold the lock across blocking I/O; the write side is
    /// `try_replace_dead_ranks`, which runs only while the failed task
    /// is the session's sole running task.
    group: RwLock<GroupState>,
    /// Per-session config snapshot (transfer knobs travel with the
    /// session so future PRs can negotiate them per client).
    transfer: TransferConfig,
    /// This session's matrix handles (namespaced: other sessions never
    /// see or free them).
    handles: Mutex<HashMap<u64, HandleMeta>>,
    /// Budget bytes this session committed against the server-wide
    /// `storage.total_bytes` pool at admission (0 when the pool is
    /// unlimited); returned to the pool at teardown.
    storage_demand: u64,
    /// This session's asynchronous task lifecycle (protocol v4).
    tasks: TaskTable,
    /// The dispatcher thread draining `tasks`; joined at teardown so no
    /// task can touch the store after the session's blocks are freed.
    dispatcher: Mutex<Option<JoinHandle<()>>>,
}

impl Session {
    /// Snapshot of the group's global ranks (see [`Session::group`]).
    fn ranks(&self) -> Vec<usize> {
        self.group.read().unwrap().ranks.clone()
    }

    fn group_size(&self) -> usize {
        self.group.read().unwrap().ranks.len()
    }

    /// Snapshot of the fabric handle. Operations through a stale snapshot
    /// (taken before a replacement committed) land on the old mesh, whose
    /// lanes are already poisoned/retired — harmless by construction.
    fn fabric(&self) -> SessionFabric {
        self.group.read().unwrap().fabric.clone()
    }
}

/// Generate a non-zero session token from the OS-seeded sip hasher (no
/// RNG dependency; 0 is the wire sentinel for "no token").
fn fresh_token() -> u64 {
    loop {
        let t = std::collections::hash_map::RandomState::new().build_hasher().finish();
        if t != 0 {
            return t;
        }
    }
}

/// One queued handshake awaiting admission.
struct Waiter {
    ticket: u64,
    /// Clamped priority class (index into [`PRIORITY_NAMES`]).
    priority: u32,
    /// Fair-share tenant key (the handshake's client name).
    client: String,
    enqueued: Instant,
}

/// Admission state guarded by the allocator mutex.
struct AllocState {
    /// Sorted free global ranks.
    free: Vec<usize>,
    /// Queued handshakes in arrival order. Arrival order is the FIFO
    /// tie-break *within* a class; the grant order across classes is
    /// decided by [`GroupAllocator::grant_index`].
    queue: Vec<Waiter>,
    active: usize,
    /// Active sessions per tenant (weighted fair-share bookkeeping).
    active_by_client: HashMap<String, usize>,
    /// Standby global ranks held out of admission (`scheduler.
    /// spare_workers`, plus any adopted `worker --connect` processes).
    /// Rank replacement pops one; a replaced session's eventual release
    /// returns the replacement to the *free* pool (the pool heals — the
    /// dead rank never comes back, the spare takes its admission slot).
    spares: Vec<usize>,
    stopping: bool,
}

/// Priority fair-share admission control over the worker pool (protocol
/// v9, `docs/scheduler.md`). A handshake claims `n` ranks exclusively;
/// requests beyond current capacity (or beyond `max_sessions`) queue and
/// are granted strictly best-head: highest effective priority first
/// (class + one level per `scheduler.age_secs` waited — the aging rule
/// that keeps batch work starvation-free), then, within a level, the
/// tenant with the lowest weighted share of active sessions, then
/// arrival order. Nothing is granted past the best head, so a large
/// request is delayed, never starved; requests wait up to
/// `queue_timeout_s`.
struct GroupAllocator {
    total: usize,
    scheduler: SchedulerConfig,
    state: Mutex<AllocState>,
    cond: Condvar,
    /// Backpressure gauges (per-class admission-queue depth).
    metrics: Arc<SchedMetrics>,
}

impl GroupAllocator {
    /// `total` ranks are admittable; `spares` are held out of admission
    /// as replacement standbys (their indices come after the admittable
    /// pool in the driver's rank table).
    fn new(
        total: usize,
        spares: Vec<usize>,
        scheduler: SchedulerConfig,
        metrics: Arc<SchedMetrics>,
    ) -> Self {
        GroupAllocator {
            total,
            scheduler,
            state: Mutex::new(AllocState {
                free: (0..total).collect(),
                queue: Vec::new(),
                active: 0,
                active_by_client: HashMap::new(),
                spares,
                stopping: false,
            }),
            cond: Condvar::new(),
            metrics,
        }
    }

    /// Pop a standby rank for replacement, if any.
    fn take_spare(&self) -> Option<usize> {
        self.state.lock().unwrap().spares.pop()
    }

    /// Return (or adopt) a standby rank into the spare pool.
    fn add_spare(&self, rank: usize) {
        self.state.lock().unwrap().spares.push(rank);
    }

    fn spare_count(&self) -> usize {
        self.state.lock().unwrap().spares.len()
    }

    /// Map a client's requested size (0 = server default) to a concrete
    /// group size, rejecting requests the pool can never satisfy.
    fn resolve_request(&self, requested: usize) -> crate::Result<usize> {
        let want = if requested > 0 {
            requested
        } else if self.scheduler.default_group_size > 0 {
            self.scheduler.default_group_size.min(self.total)
        } else {
            self.total
        };
        anyhow::ensure!(
            want <= self.total,
            "requested {want} workers but the server only has {}",
            self.total
        );
        Ok(want)
    }

    /// A queued handshake's effective priority: its class plus one level
    /// per `scheduler.age_secs` spent waiting (0 disables aging). The
    /// promotion is what makes the scheduler starvation-free — a batch
    /// request outranks a stream of fresh interactive arrivals once it
    /// has waited long enough.
    fn effective_priority(&self, w: &Waiter, now: Instant) -> u64 {
        let mut eff = w.priority as u64;
        if self.scheduler.age_secs > 0.0 {
            let waited = now.saturating_duration_since(w.enqueued).as_secs_f64();
            eff += (waited / self.scheduler.age_secs) as u64;
        }
        eff
    }

    /// Grant-order key of every queued waiter: (effective priority —
    /// higher first, weighted tenant load — lower first). Ties fall back
    /// to arrival order (the queue's index order).
    fn grant_keys(&self, st: &AllocState, now: Instant) -> Vec<(u64, f64)> {
        st.queue
            .iter()
            .map(|w| {
                let active =
                    st.active_by_client.get(&w.client).copied().unwrap_or(0);
                let ratio = active as f64 / self.scheduler.tenant_weight(&w.client);
                (self.effective_priority(w, now), ratio)
            })
            .collect()
    }

    /// Whether grant key `a` (queue index `ai`) outranks `b` (`bi`).
    fn outranks(a: (u64, f64), ai: usize, b: (u64, f64), bi: usize) -> bool {
        a.0 > b.0 || (a.0 == b.0 && (a.1 < b.1 || (a.1 == b.1 && ai < bi)))
    }

    /// Queue index of the waiter that would be granted next, if any.
    fn grant_index(&self, st: &AllocState, now: Instant) -> Option<usize> {
        let keys = self.grant_keys(st, now);
        let mut best: Option<usize> = None;
        for i in 0..keys.len() {
            best = match best {
                Some(b) if !Self::outranks(keys[i], i, keys[b], b) => Some(b),
                _ => Some(i),
            };
        }
        best
    }

    /// 1-based position of `ticket` in the current grant order (rejection
    /// diagnostics: "you were 4th of 7 in line").
    fn grant_position(&self, st: &AllocState, ticket: u64, now: Instant) -> usize {
        let Some(me) = st.queue.iter().position(|w| w.ticket == ticket) else {
            return 0;
        };
        let keys = self.grant_keys(st, now);
        1 + (0..keys.len())
            .filter(|&i| Self::outranks(keys[i], i, keys[me], me))
            .count()
    }

    /// Block until `want` ranks can be granted to `ticket`, the queue
    /// timeout passes, or the server stops. The grant order (see the type
    /// docs) is re-evaluated on every wake and at least every 500ms, so
    /// an aging promotion takes effect even when nothing is released.
    fn acquire(
        &self,
        ticket: u64,
        want: usize,
        priority: u32,
        client: &str,
    ) -> crate::Result<Vec<usize>> {
        let timeout = Duration::from_secs_f64(self.scheduler.queue_timeout_s.max(0.0));
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        st.queue.push(Waiter {
            ticket,
            priority,
            client: client.to_string(),
            enqueued: Instant::now(),
        });
        self.metrics.admission_enqueued(priority);
        loop {
            if st.stopping {
                st.queue.retain(|w| w.ticket != ticket);
                self.metrics.admission_dequeued(priority);
                self.metrics.session_rejected();
                anyhow::bail!("server is stopping");
            }
            let now = Instant::now();
            let is_best = self
                .grant_index(&st, now)
                .is_some_and(|i| st.queue[i].ticket == ticket);
            if is_best
                && st.active < self.scheduler.max_sessions
                && st.free.len() >= want
            {
                st.queue.retain(|w| w.ticket != ticket);
                self.metrics.admission_dequeued(priority);
                let ranks: Vec<usize> = st.free.drain(..want).collect();
                st.active += 1;
                *st.active_by_client.entry(client.to_string()).or_insert(0) += 1;
                self.metrics.session_admitted();
                // the next queued request may fit in what remains
                self.cond.notify_all();
                return Ok(ranks);
            }
            if now >= deadline {
                let position = self.grant_position(&st, ticket, now);
                let depth = st.queue.len();
                let (free, active) = (st.free.len(), st.active);
                st.queue.retain(|w| w.ticket != ticket);
                self.metrics.admission_dequeued(priority);
                self.metrics.session_rejected();
                // our departure may unblock a request ranked behind us
                self.cond.notify_all();
                anyhow::bail!(
                    "admission timed out after {:.1}s waiting for {want} of {} \
                     workers (class {}, grant position {position} of {depth} \
                     queued, {free} free, {active} sessions active)",
                    timeout.as_secs_f64(),
                    self.total,
                    PRIORITY_NAMES[(priority as usize).min(PRIORITY_CLASSES - 1)],
                );
            }
            // bounded wait slice: aging re-ranks the queue with time alone
            let wait = (deadline - now).min(Duration::from_millis(500));
            let (guard, _) = self.cond.wait_timeout(st, wait).unwrap();
            st = guard;
        }
    }

    /// Return a torn-down session's ranks to the pool and wake the queue.
    fn release(&self, ranks: &[usize], client: &str) {
        let mut st = self.state.lock().unwrap();
        st.free.extend_from_slice(ranks);
        st.free.sort_unstable();
        st.active -= 1;
        if let Some(n) = st.active_by_client.get_mut(client) {
            *n -= 1;
            if *n == 0 {
                st.active_by_client.remove(client);
            }
        }
        self.metrics.session_released();
        self.cond.notify_all();
    }

    /// Fail every queued handshake (server shutdown).
    fn stop(&self) {
        self.state.lock().unwrap().stopping = true;
        self.cond.notify_all();
    }
}

/// RAII lease on the driver's shared engine-thread budget: a running
/// task holds `group × engine_threads` of [`Driver::engine_threads_committed`]
/// and returns it on every exit path of `execute_task` (the pool size of
/// an in-flight task cannot change, so the budget is what keeps
/// *overlapping* dispatches from summing past the core count).
struct ThreadsLease<'a> {
    committed: &'a Mutex<usize>,
    amount: usize,
}

impl Drop for ThreadsLease<'_> {
    fn drop(&mut self) {
        *self.committed.lock().unwrap() -= self.amount;
    }
}

/// A parked (lingering) session awaiting `Reattach`, keyed by token.
struct LingerEntry {
    session: Arc<Session>,
    /// Generation stamp: the reaper thread armed at park time only
    /// expires the entry whose generation it was armed for, so a
    /// reattach-then-redrop cycle within the linger window cannot be
    /// killed by the first drop's stale reaper.
    gen: u64,
}

struct Driver {
    cfg: Config,
    /// The worker pool, index = global rank. Homogeneous by
    /// construction: `fabric.mode = local` builds every rank in-process,
    /// `tcp` spawns every rank as a worker process. Behind a lock since
    /// protocol v10: externally launched `worker --connect` processes
    /// are appended at runtime (indices are stable — ranks are never
    /// removed, a dead rank just stops being scheduled).
    ranks: RwLock<Vec<RankHandle>>,
    registry: Registry,
    allocator: GroupAllocator,
    /// Compute threads (`group × engine_threads`) leased to currently
    /// running tasks across all sessions (see `execute_task`).
    engine_threads_committed: Mutex<usize>,
    /// Budget bytes committed to admitted sessions against the
    /// server-wide `storage.total_bytes` pool (see `open_session`;
    /// unused — stays 0 — when the pool is unlimited).
    storage_committed: Mutex<u64>,
    /// Root of the server-wide work-stealing compute pool: one thread set
    /// sized to the machine, with a client queue per rank
    /// ([`ThreadPool::client`]). Each task retargets its rank's queue cap
    /// (the thread lease above), and idle capacity migrates to busy
    /// queues via bounded stealing instead of sitting in private
    /// per-rank pools. Held here so the worker threads live as long as
    /// the driver.
    compute_pool: ThreadPool,
    next_id: AtomicU64,
    next_session: AtomicU64,
    next_task: AtomicU64,
    sessions: Mutex<HashMap<u64, Arc<Session>>>,
    /// Parked sessions whose client connection dropped, keyed by session
    /// token, kept alive for `scheduler.session_linger_s` awaiting
    /// `Reattach` (protocol v10). Entries also stay in `sessions` (their
    /// dispatchers keep running queued tasks).
    lingering: Mutex<HashMap<u64, LingerEntry>>,
    linger_gen: AtomicU64,
    /// Attach listener address for late `worker --connect` adoption
    /// (tcp mode only; empty otherwise). `stop_all` wake-connects it.
    attach_addr: Mutex<String>,
    stopping: AtomicBool,
    /// Stop flags of every accept loop (control + per-worker data).
    listener_stops: Mutex<Vec<Arc<AtomicBool>>>,
    control_addr: Mutex<String>,
    /// Scheduler backpressure metrics (shared with the allocator).
    metrics: Arc<SchedMetrics>,
}

impl Driver {
    /// Close a session's task table: mark it closing (the dispatcher
    /// exits once idle, and further submissions are rejected), cancel
    /// queued tasks without running them, and set the running task's
    /// cooperative token — escalating to a group poison after the
    /// teardown grace period, so a routine that ignores the cooperative
    /// contract cannot delay teardown by its remaining runtime.
    /// Idempotent.
    fn drain_tasks(&self, session: &Arc<Session>) {
        let mut st = session.tasks.state.lock().unwrap();
        st.closing = true;
        let drained: Vec<u64> = st.queue.drain(..).collect();
        for id in drained {
            if st.slots.contains_key(&id) {
                st.set_terminal(id, TaskState::Cancelled);
                self.metrics.task_dequeued(TaskOutcome::Cancelled);
            }
        }
        let grace = self.cfg.scheduler.teardown_grace_ms;
        let fabric = session.fabric();
        for rec in st.running.values() {
            rec.cancel.cancel();
            // process-separated ranks observe the token through their own
            // copy — forward the flip (no-op for in-process groups)
            fabric.propagate_cancel(rec.id);
            if grace > 0 {
                schedule_hard_cancel(
                    session.clone(),
                    rec.id,
                    Duration::from_millis(grace),
                );
            }
        }
        session.tasks.cond.notify_all();
    }

    /// Flip every stop flag, cancel every session's in-flight tasks (a
    /// long-running routine must not be able to stall shutdown — the
    /// worker threads can only exit after it returns), end the worker
    /// loops, fail queued handshakes, and wake all accept loops so their
    /// threads can exit.
    fn stop_all(&self) {
        if self.stopping.swap(true, Ordering::SeqCst) {
            return;
        }
        self.allocator.stop();
        let sessions: Vec<Arc<Session>> =
            self.sessions.lock().unwrap().values().cloned().collect();
        for session in &sessions {
            self.drain_tasks(session);
        }
        // quiesce every dispatcher BEFORE ending the worker loops: a
        // Shutdown command racing a dispatcher's per-rank RunTask sends
        // could otherwise interleave per-channel (rank 0 gets RunTask
        // first, rank 1 gets Shutdown first), stranding a live rank
        // inside a group collective whose peer already exited — hanging
        // the worker thread and the shutdown join forever. The joins are
        // quick: tokens are set, so cooperative routines bail within one
        // iteration, and the workers are still alive to answer.
        for session in &sessions {
            let handle = session.dispatcher.lock().unwrap().take();
            if let Some(handle) = handle {
                let _ = handle.join();
            }
        }
        let ranks: Vec<RankHandle> = self.ranks.read().unwrap().clone();
        for r in &ranks {
            match r {
                RankHandle::Local { sender, .. } => {
                    let _ = sender.send(WorkerCmd::Shutdown);
                }
                RankHandle::Remote(w) => {
                    let _ = w.send(&WorkMsg::Shutdown);
                }
            }
        }
        for flag in self.listener_stops.lock().unwrap().iter() {
            flag.store(true, Ordering::SeqCst);
        }
        for addr in self.worker_addrs() {
            let _ = TcpStream::connect(&addr);
        }
        let control = self.control_addr.lock().unwrap().clone();
        if !control.is_empty() {
            let _ = TcpStream::connect(&control);
        }
        let attach = self.attach_addr.lock().unwrap().clone();
        if !attach.is_empty() {
            let _ = TcpStream::connect(&attach);
        }
    }
}

impl Driver {
    fn worker_addrs(&self) -> Vec<String> {
        self.ranks.read().unwrap().iter().map(|r| r.data_addr()).collect()
    }

    /// Snapshot of rank `r`'s handle (cheap: both variants are Arcs).
    fn rank(&self, r: usize) -> RankHandle {
        self.ranks.read().unwrap()[r].clone()
    }

    /// Data addresses of one session's group, indexed by group-local rank.
    fn session_worker_addrs(&self, session: &Session) -> Vec<String> {
        let ranks = self.ranks.read().unwrap();
        session.ranks().iter().map(|&r| ranks[r].data_addr()).collect()
    }

    /// The full pool as in-process handles — `Some` iff every rank is
    /// local (`fabric.mode = local`), indexed by global rank like
    /// [`Driver::ranks`]. Store paths take this fast path; a `None` pool
    /// reaches each rank's store over its work socket instead.
    fn local_pool(&self) -> Option<Vec<Arc<WorkerShared>>> {
        self.ranks.read().unwrap().iter().map(|r| r.local().cloned()).collect()
    }

    /// Global rank `rank` as a worker-process handle. Only meaningful in
    /// fabric mode, where the pool is all-remote by construction.
    fn remote_member(&self, rank: usize) -> Arc<RemoteWorker> {
        self.ranks.read().unwrap()[rank]
            .remote()
            .expect("fabric-mode pool is all-remote")
            .clone()
    }

    /// Build and bind a new group's communicator. A local pool wires
    /// `LocalComm` mailbox endpoints into each member's session map. A
    /// remote pool brokers a full `TcpComm` peer mesh: every member
    /// receives the group's mesh addresses — all `MeshForm` messages go
    /// out before any ack is awaited, because formation is collective
    /// (each process dials its lower-ranked peers and accepts its higher
    /// ones) — and collective traffic thereafter flows worker↔worker
    /// with the coordinator uninvolved (`docs/fabric.md`).
    fn bind_group_fabric(
        &self,
        id: u64,
        ranks: &[usize],
    ) -> crate::Result<SessionFabric> {
        if let Some(pool) = self.local_pool() {
            let comms: Vec<Arc<LocalComm>> =
                LocalComm::subgroup(ranks, Some(self.cfg.simnet.clone()))
                    .into_iter()
                    .map(Arc::new)
                    .collect();
            // the rank-0 endpoint doubles as the driver's handle
            let fabric = comms[0].clone();
            for (&rank, comm) in ranks.iter().zip(comms) {
                pool[rank].sessions.lock().unwrap().insert(id, comm);
            }
            return Ok(SessionFabric::Local(fabric));
        }
        let members: Vec<Arc<RemoteWorker>> =
            ranks.iter().map(|&r| self.remote_member(r)).collect();
        let peers: Vec<String> =
            members.iter().map(|w| w.mesh_addr.clone()).collect();
        let waits: Vec<_> = members
            .iter()
            .enumerate()
            .map(|(slot, w)| {
                let peers = peers.clone();
                w.start_ack(move |req_id| WorkMsg::MeshForm {
                    req_id,
                    session_id: id,
                    group_rank: slot as u32,
                    peers,
                })
            })
            .collect();
        let mut result = Ok(());
        for (w, wait) in members.iter().zip(waits) {
            let formed = wait.and_then(|rx| RemoteWorker::await_ack(w.rank, rx));
            if let (Err(e), true) = (formed, result.is_ok()) {
                result = Err(e.context(format!(
                    "forming session {id} mesh on worker process {}",
                    w.rank
                )));
            }
        }
        if let Err(e) = result {
            // best-effort teardown of the endpoints that did form
            for w in &members {
                let _ = w.start_ack(|req_id| WorkMsg::SessionClose {
                    req_id,
                    session_id: id,
                });
            }
            return Err(e);
        }
        Ok(SessionFabric::Remote { session_id: id, ranks: members })
    }

    /// Unbind a session's communicator endpoints and free its store
    /// blocks on every member rank; returns blocks freed. Remote members
    /// do both in one `SessionClose` round trip (pipelined across the
    /// group); a dead member's missing ack is logged, not fatal — its
    /// process (and store) is already gone.
    fn release_session_state(&self, session: &Session) -> usize {
        let mut freed = 0;
        match &session.fabric() {
            SessionFabric::Local(_) => {
                for &rank in &session.ranks() {
                    if let Some(shared) = self.rank(rank).local() {
                        shared.sessions.lock().unwrap().remove(&session.id);
                        // releases heap budget AND deletes the session's
                        // spill-file segments on this rank (see
                        // MatrixStore::free_session)
                        freed += shared.store.free_session(session.id);
                    }
                }
            }
            SessionFabric::Remote { session_id, ranks } => {
                let sid = *session_id;
                let waits: Vec<_> = ranks
                    .iter()
                    .map(|w| {
                        w.start_ack(move |req_id| WorkMsg::SessionClose {
                            req_id,
                            session_id: sid,
                        })
                    })
                    .collect();
                for (w, wait) in ranks.iter().zip(waits) {
                    match wait.and_then(|rx| RemoteWorker::await_ack(w.rank, rx))
                    {
                        Ok((n, _)) => freed += n as usize,
                        Err(e) => log::warn!(
                            "closing session {sid} on worker process {}: {e:#}",
                            w.rank
                        ),
                    }
                }
            }
        }
        freed
    }

    /// Remote counterpart of [`alloc_group`]: one `StoreAlloc` per member
    /// process (pipelined), rolled back with `StoreFree` on any failure
    /// so an error reply always means "no block exists".
    fn remote_alloc(
        &self,
        session: &Session,
        id: u64,
        name: &str,
        layout: &RowBlockLayout,
    ) -> crate::Result<()> {
        self.remote_register(session, id, |slot, req_id| WorkMsg::StoreAlloc {
            req_id,
            session_id: session.id,
            id,
            name: name.to_string(),
            rows: layout.rows as u64,
            cols: layout.cols as u64,
            ranges: wire_ranges(layout),
            slot: slot as u32,
        })
    }

    /// Remote counterpart of [`super::worker::load_group`]: each member
    /// process maps (or buffered-reads) its own row shard of the file —
    /// the payload path never touches the coordinator. Same all-or-nothing
    /// contract, with the rollback driven from here.
    fn remote_load(
        &self,
        session: &Session,
        id: u64,
        name: &str,
        path: &std::path::Path,
        layout: &RowBlockLayout,
    ) -> crate::Result<()> {
        let path = path.to_string_lossy().into_owned();
        self.remote_register(session, id, |slot, req_id| WorkMsg::StoreLoad {
            req_id,
            session_id: session.id,
            id,
            name: name.to_string(),
            path: path.clone(),
            rows: layout.rows as u64,
            cols: layout.cols as u64,
            ranges: wire_ranges(layout),
            slot: slot as u32,
        })
    }

    /// Register matrix `id` on every member of a remote group: send the
    /// per-slot request to all processes, await all acks, and free the
    /// id everywhere if any rank failed.
    fn remote_register(
        &self,
        session: &Session,
        id: u64,
        build: impl Fn(usize, u64) -> WorkMsg,
    ) -> crate::Result<()> {
        let group = session.ranks();
        let waits: Vec<_> = group
            .iter()
            .enumerate()
            .map(|(slot, &rank)| {
                let w = self.remote_member(rank);
                let wait = w.start_ack(|req_id| build(slot, req_id));
                (w, wait)
            })
            .collect();
        let mut result = Ok(());
        for (w, wait) in waits {
            let acked = wait.and_then(|rx| RemoteWorker::await_ack(w.rank, rx));
            if let (Err(e), true) = (acked, result.is_ok()) {
                result = Err(e);
            }
        }
        if result.is_err() {
            for &rank in &group {
                let _ = self.remote_member(rank).send(&WorkMsg::StoreFree { id });
            }
        }
        result
    }

    /// Admit a session: resolve the requested group size, wait for
    /// capacity, negotiate the transfer knobs (requested values clamped
    /// by server-side limits), build the group's communicator, and bind
    /// each member worker to it.
    fn open_session(
        self: &Arc<Self>,
        client_name: &str,
        requested: u32,
        rows_per_frame: u32,
        buf_bytes: u64,
        priority: u32,
    ) -> crate::Result<Arc<Session>> {
        let want = self.allocator.resolve_request(requested as usize)?;
        // clamp the requested class to server policy — a client asking
        // for more than `scheduler.max_priority` is admitted at the cap,
        // not rejected (the request is advisory, the policy is law)
        let priority = priority
            .min(self.cfg.scheduler.max_priority)
            .min(PRIORITY_CLASSES as u32 - 1);
        let id = self.next_session.fetch_add(1, Ordering::SeqCst);
        let ranks = self.allocator.acquire(id, want, priority, client_name)?;
        // storage admission (`storage.total_bytes`): a session commits its
        // per-rank heap budget × group size against the server-wide pool
        // up front, so tenants cannot collectively promise more resident
        // bytes than the machine has. An unlimited per-session budget
        // claims the whole pool — it could legally grow to any size.
        // Rejection is clean: ranks go back, nothing was registered.
        let storage_demand = {
            let pool = self.cfg.storage.total_bytes;
            if pool == 0 {
                0
            } else {
                let per_rank = self.cfg.storage.budget_bytes;
                let demand = if per_rank == 0 {
                    pool
                } else {
                    per_rank.saturating_mul(ranks.len() as u64)
                };
                let mut committed = self.storage_committed.lock().unwrap();
                if committed.saturating_add(demand) > pool {
                    let left = pool - *committed;
                    drop(committed);
                    self.allocator.release(&ranks, client_name);
                    anyhow::bail!(
                        "storage admission rejected: this session would commit \
                         {demand} budget bytes ({} rank(s)) but only {left} of \
                         {pool} remain uncommitted (storage.total_bytes)",
                        ranks.len(),
                    );
                }
                *committed += demand;
                demand
            }
        };
        // single-tenant engine-thread bound, logged below for operators
        // (0 = auto: each rank gets its share of the cores). The value
        // that actually governs a task is re-clamped per dispatch in
        // `execute_task` against every currently-granted rank, so
        // concurrent tenants cannot multiply past the core count.
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let engine_threads = self.cfg.engine_threads_for_group(ranks.len(), avail);
        let fabric = match self.bind_group_fabric(id, &ranks) {
            Ok(f) => f,
            Err(e) => {
                self.allocator.release(&ranks, client_name);
                *self.storage_committed.lock().unwrap() -= storage_demand;
                return Err(e);
            }
        };
        let session = Arc::new(Session {
            id,
            client: client_name.to_string(),
            priority,
            token: fresh_token(),
            group: RwLock::new(GroupState { ranks: ranks.clone(), fabric }),
            transfer: self.cfg.transfer.negotiate(rows_per_frame, buf_bytes),
            handles: Mutex::new(HashMap::new()),
            storage_demand,
            tasks: TaskTable::new(),
            dispatcher: Mutex::new(None),
        });
        // the session's task dispatcher: pops the FIFO and runs up to
        // `scheduler.tasks_per_group` tasks concurrently over this group,
        // each on its own tag lane; exits when teardown sets `closing`
        {
            let driver = self.clone();
            let session = session.clone();
            let handle = std::thread::spawn(move || {
                task_dispatcher(&driver, &session);
            });
            *session.dispatcher.lock().unwrap() = Some(handle);
        }
        // publish-or-bail atomically against stop_all: its shutdown
        // sequence drains and joins the sessions it snapshots under this
        // lock, so a session inserted here is either in that snapshot or
        // observes `stopping` (set before the snapshot) and undoes itself
        // — never a live dispatcher the shutdown path doesn't know about
        {
            let mut sessions = self.sessions.lock().unwrap();
            if self.stopping.load(Ordering::SeqCst) {
                drop(sessions);
                self.drain_tasks(&session);
                let handle = session.dispatcher.lock().unwrap().take();
                if let Some(handle) = handle {
                    let _ = handle.join();
                }
                self.release_session_state(&session);
                self.release_group(&session);
                *self.storage_committed.lock().unwrap() -= session.storage_demand;
                anyhow::bail!("server is stopping");
            }
            sessions.insert(id, session.clone());
        }
        log::info!(
            "session {id}: client {client_name:?} granted {want} workers \
             (class {}, ranks {ranks:?}, {} rows/frame, {} buf bytes, up to \
             {engine_threads} engine thread(s)/rank)",
            PRIORITY_NAMES[priority as usize],
            session.transfer.rows_per_frame,
            session.transfer.buf_bytes,
        );
        Ok(session)
    }

    /// Tear a session down: cancel queued and running tasks (escalating
    /// to a group poison after the teardown grace period), join the
    /// dispatcher (so no task inserts store blocks after we free them),
    /// unbind communicator endpoints, free the session's matrices on
    /// every member worker, and return the ranks to the pool.
    fn close_session(&self, session: &Arc<Session>) {
        if self.sessions.lock().unwrap().remove(&session.id).is_none() {
            return; // already closed
        }
        // a parked session closed by shutdown/timeout must also leave the
        // reattach table, or a late Reattach would resume freed state
        self.lingering.lock().unwrap().remove(&session.token);
        // drain the task table: queued tasks become Cancelled without
        // running; the running task's token is cancelled and the
        // dispatcher finalizes it as usual
        self.drain_tasks(session);
        let dispatcher = session.dispatcher.lock().unwrap().take();
        if let Some(handle) = dispatcher {
            let _ = handle.join();
        }
        let freed = self.release_session_state(session);
        let released = self.release_group(session);
        *self.storage_committed.lock().unwrap() -= session.storage_demand;
        log::info!(
            "session {}: closed ({} blocks freed, {} workers released)",
            session.id,
            freed,
            released,
        );
    }

    /// Return a session's ranks to the admission pool, keeping dead
    /// worker processes out of it — a killed rank's slot was healed by
    /// its replacement (which releases here in its place), so the pool
    /// stays the right size without ever re-granting a corpse.
    fn release_group(&self, session: &Session) -> usize {
        let group = session.ranks();
        let ranks = self.ranks.read().unwrap();
        let live: Vec<usize> = group
            .iter()
            .copied()
            .filter(|&r| !ranks[r].remote().is_some_and(|w| w.is_dead()))
            .collect();
        drop(ranks);
        if live.len() < group.len() {
            log::warn!(
                "session {}: {} dead worker process(es) not returned to the pool",
                session.id,
                group.len() - live.len(),
            );
        }
        self.allocator.release(&live, &session.client);
        live.len()
    }

    /// Re-form a session's group around spare ranks after a worker
    /// process died mid-task (protocol v10, `docs/recovery.md`). Returns
    /// true when the group was re-formed and the failed task can be
    /// retried; false degrades to the diagnosable v8 failure. Only runs
    /// while the failed task is the session's sole running task —
    /// concurrent lanes failing on the same broken mesh would race the
    /// swap, so a multi-lane failure is not retried.
    ///
    /// The steps, each of which can veto: (1) every live matrix handle
    /// must be replayable (sealed, with either a source file or a
    /// `storage.checkpoint_dir` snapshot); (2) a spare must exist per
    /// dead slot; (3) the mesh re-forms over the patched membership
    /// (workers replace their session comm on `MeshForm`); (4) each
    /// replacement replays the dead slot's shards — `StoreLoad` from the
    /// source file for mapped matrices, `StoreRestore` from the
    /// task-boundary checkpoint otherwise.
    fn try_replace_dead_ranks(&self, session: &Arc<Session>) -> bool {
        if self.stopping.load(Ordering::SeqCst) {
            return false;
        }
        {
            let st = session.tasks.state.lock().unwrap();
            if st.running.len() != 1 {
                return false;
            }
        }
        let mut group = session.group.write().unwrap();
        let dead: Vec<usize> = {
            let pool = self.ranks.read().unwrap();
            group
                .ranks
                .iter()
                .enumerate()
                .filter(|&(_, &r)| pool[r].remote().is_some_and(|w| w.is_dead()))
                .map(|(slot, _)| slot)
                .collect()
        };
        if dead.is_empty() {
            return false; // not a rank failure (routine error / local mode)
        }
        let metas: Vec<(u64, HandleMeta)> = {
            let handles = session.handles.lock().unwrap();
            handles.iter().map(|(id, m)| (*id, m.clone())).collect()
        };
        let ckpt_dir = self.cfg.storage.checkpoint_dir.clone();
        for (id, m) in &metas {
            let replayable =
                m.sealed && (m.source.is_some() || !ckpt_dir.is_empty());
            if !replayable {
                log::warn!(
                    "session {}: worker died but matrix {id} ({:?}) has no \
                     replayable snapshot ({}) — failing the task instead of \
                     re-forming",
                    session.id,
                    m.info.name,
                    if m.sealed {
                        "no storage.checkpoint_dir configured"
                    } else {
                        "unsealed ingest state cannot be replayed"
                    },
                );
                return false;
            }
        }
        let mut taken: Vec<usize> = Vec::new();
        for _ in &dead {
            match self.allocator.take_spare() {
                Some(r) => taken.push(r),
                None => {
                    for r in taken {
                        self.allocator.add_spare(r);
                    }
                    log::warn!(
                        "session {}: worker died and no spare workers remain \
                         (scheduler.spare_workers) — failing the task",
                        session.id,
                    );
                    return false;
                }
            }
        }
        let mut new_ranks = group.ranks.clone();
        for (&slot, &spare) in dead.iter().zip(&taken) {
            new_ranks[slot] = spare;
        }
        let fabric = match self.bind_group_fabric(session.id, &new_ranks) {
            Ok(f) => f,
            Err(e) => {
                for r in taken {
                    self.allocator.add_spare(r);
                }
                log::warn!(
                    "session {}: re-forming group mesh around spare(s) \
                     failed: {e:#}",
                    session.id,
                );
                return false;
            }
        };
        for (&slot, &spare) in dead.iter().zip(&taken) {
            let w = self.remote_member(spare);
            for (id, m) in &metas {
                let sid = session.id;
                let replayed = if let Some(src) = &m.source {
                    w.request_ack(|req_id| WorkMsg::StoreLoad {
                        req_id,
                        session_id: sid,
                        id: *id,
                        name: m.info.name.clone(),
                        path: src.clone(),
                        rows: m.layout.rows as u64,
                        cols: m.layout.cols as u64,
                        ranges: wire_ranges(&m.layout),
                        slot: slot as u32,
                    })
                } else {
                    let path = checkpoint_path(&ckpt_dir, sid, *id, slot);
                    w.request_ack(|req_id| WorkMsg::StoreRestore {
                        req_id,
                        session_id: sid,
                        id: *id,
                        name: m.info.name.clone(),
                        path: path.to_string_lossy().into_owned(),
                        rows: m.layout.rows as u64,
                        cols: m.layout.cols as u64,
                        ranges: wire_ranges(&m.layout),
                        slot: slot as u32,
                    })
                };
                if let Err(e) = replayed {
                    log::warn!(
                        "session {}: replaying matrix {id} slot {slot} onto \
                         spare worker {spare} failed: {e:#}",
                        session.id,
                    );
                    // retire the replacements again: drop their endpoint
                    // and any partially restored shards, return them to
                    // the spare pool
                    for &r in &taken {
                        let _ = self.remote_member(r).start_ack(|req_id| {
                            WorkMsg::SessionClose { req_id, session_id: sid }
                        });
                        self.allocator.add_spare(r);
                    }
                    return false;
                }
            }
        }
        group.ranks = new_ranks;
        group.fabric = fabric;
        for _ in &dead {
            self.metrics.rank_replaced();
        }
        log::info!(
            "session {}: re-formed group around spare worker(s) {taken:?} \
             (dead slot(s) {dead:?} replaced); retrying the failed task",
            session.id,
        );
        true
    }

    /// Handle a dropped control connection (protocol v10): when
    /// `scheduler.session_linger_s` is configured, park the session in
    /// the reattach table — tasks keep running, results are retained —
    /// and arm a reaper that closes it if no `Reattach` claims the token
    /// in time. Linger 0 (the default) closes immediately: the client's
    /// `stop()` IS a socket drop, so eager teardown is the wire contract.
    fn park_or_close(self: &Arc<Self>, session: &Arc<Session>) {
        let linger = self.cfg.scheduler.session_linger_s;
        if linger <= 0.0 || self.stopping.load(Ordering::SeqCst) {
            self.close_session(session);
            return;
        }
        let gen = self.linger_gen.fetch_add(1, Ordering::SeqCst);
        self.lingering.lock().unwrap().insert(
            session.token,
            LingerEntry { session: session.clone(), gen },
        );
        log::info!(
            "session {}: client disconnected; lingering {linger:.1}s \
             awaiting Reattach",
            session.id,
        );
        let driver = self.clone();
        let session = session.clone();
        std::thread::spawn(move || {
            let deadline = Instant::now() + Duration::from_secs_f64(linger);
            loop {
                if driver.stopping.load(Ordering::SeqCst) {
                    return; // shutdown owns global teardown
                }
                // only the reaper of the CURRENT park may expire the
                // entry: a reattach-then-redrop within the window re-arms
                // with a new generation, and this (stale) reaper stands
                // down instead of killing the re-parked session early
                match driver.lingering.lock().unwrap().get(&session.token) {
                    Some(e) if e.gen == gen => {}
                    _ => return,
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                std::thread::sleep((deadline - now).min(Duration::from_millis(50)));
            }
            let expired = {
                let mut lingering = driver.lingering.lock().unwrap();
                match lingering.get(&session.token) {
                    Some(e) if e.gen == gen => {
                        lingering.remove(&session.token);
                        true
                    }
                    _ => false,
                }
            };
            if expired {
                log::info!(
                    "session {}: linger window expired with no Reattach; closing",
                    session.id,
                );
                driver.close_session(&session);
            }
        });
    }

    /// Resume a parked session by token (protocol v10 `Reattach`).
    /// Removing the entry is what stands the reaper down; the session's
    /// task table (including retained terminal results) and matrix
    /// handles are untouched by the disconnect, so the client re-lists
    /// tasks and collects exactly what it would have seen on the
    /// original connection.
    fn reattach(&self, token: u64) -> crate::Result<Arc<Session>> {
        anyhow::ensure!(token != 0, "reattach requires a session token");
        anyhow::ensure!(
            !self.stopping.load(Ordering::SeqCst),
            "server is stopping"
        );
        let entry = self.lingering.lock().unwrap().remove(&token);
        match entry {
            Some(e) => {
                log::info!("session {}: client reattached", e.session.id);
                Ok(e.session)
            }
            None => anyhow::bail!(
                "unknown or expired session token (the linger window of \
                 scheduler.session_linger_s may have elapsed)"
            ),
        }
    }

    fn create_matrix(
        &self,
        session: &Session,
        name: &str,
        rows: u64,
        cols: u64,
    ) -> crate::Result<ControlMsg> {
        anyhow::ensure!(rows > 0 && cols > 0, "matrix must be non-empty");
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let group = session.ranks();
        let layout =
            RowBlockLayout::even(rows as usize, cols as usize, group.len());
        if let Some(pool) = self.local_pool() {
            alloc_group(&pool, &group, session.id, id, name, &layout)?;
        } else {
            self.remote_alloc(session, id, name, &layout)?;
        }
        session.handles.lock().unwrap().insert(
            id,
            HandleMeta {
                info: MatrixInfo { id, rows, cols, name: name.to_string() },
                layout: layout.clone(),
                source: None,
                sealed: false,
            },
        );
        Ok(ControlMsg::MatrixCreated { id, row_ranges: layout.to_wire() })
    }

    /// Direct file ingest (protocol v7 `LoadMatrix`): each member worker
    /// maps its row shard of an `hdf5sim` file on the SERVER's
    /// filesystem, so zero payload bytes ever cross the client
    /// connection. The file is validated driver-side — header magic,
    /// shape, exact payload length — BEFORE any block is registered;
    /// a failure inside `load_group` rolls every rank back, so an error
    /// reply always means "no block exists".
    fn load_matrix(
        &self,
        session: &Session,
        name: &str,
        path: &str,
    ) -> crate::Result<ControlMsg> {
        let path = std::path::Path::new(path);
        let (rows, cols) = crate::hdf5sim::validate(path)?;
        anyhow::ensure!(rows > 0 && cols > 0, "matrix must be non-empty");
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let group = session.ranks();
        let layout = RowBlockLayout::even(rows, cols, group.len());
        if let Some(pool) = self.local_pool() {
            super::worker::load_group(
                &pool,
                &group,
                session.id,
                id,
                name,
                path,
                &layout,
            )?;
        } else {
            self.remote_load(session, id, name, path, &layout)?;
        }
        let info = MatrixInfo {
            id,
            rows: rows as u64,
            cols: cols as u64,
            name: name.to_string(),
        };
        session.handles.lock().unwrap().insert(
            id,
            HandleMeta {
                info: info.clone(),
                layout: layout.clone(),
                source: Some(path.to_string_lossy().into_owned()),
                sealed: true,
            },
        );
        log::info!(
            "session {}: loaded {name:?} ({rows}x{cols}) from {path:?} as \
             matrix {id} across {} workers",
            session.id,
            group.len()
        );
        Ok(ControlMsg::LoadDone { info, row_ranges: layout.to_wire() })
    }

    fn seal_matrix(&self, session: &Session, id: u64) -> crate::Result<ControlMsg> {
        let meta = self.handle(session, id)?;
        let mut received = 0;
        for &rank in &session.ranks() {
            received += match &self.rank(rank) {
                RankHandle::Local { shared, .. } => shared.store.seal(id)?,
                RankHandle::Remote(w) => {
                    w.request_ack(|req_id| WorkMsg::StoreSeal { req_id, id })?.0
                }
            };
        }
        anyhow::ensure!(
            received == meta.info.rows,
            "matrix {id}: sealed with {received} of {} rows",
            meta.info.rows
        );
        // sealed shards have task-boundary checkpoints — the matrix is
        // now replayable onto a replacement rank (`docs/recovery.md`)
        if let Some(meta) = session.handles.lock().unwrap().get_mut(&id) {
            meta.sealed = true;
        }
        Ok(ControlMsg::MatrixSealed { id, rows_received: received })
    }

    fn handle(&self, session: &Session, id: u64) -> crate::Result<HandleMeta> {
        session
            .handles
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("unknown matrix handle {id}"))
    }

    /// Enqueue a task on the session's FIFO (protocol v4 `SubmitTask`).
    /// Rejects cleanly when the queue is at `scheduler.task_queue_depth`.
    fn submit_task(
        &self,
        session: &Session,
        lib_name: &str,
        routine: &str,
        params: Params,
    ) -> crate::Result<ControlMsg> {
        let lib = self.registry.get(lib_name)?;
        let depth = self.cfg.scheduler.task_queue_depth.max(1);
        let mut st = session.tasks.state.lock().unwrap();
        // admission checks before any allocation: a client hammering a
        // full queue (the backpressure case) must not make the server
        // clone params or burn task ids per rejected request
        anyhow::ensure!(!st.closing, "session is closing");
        if st.queue.len() >= depth {
            self.metrics.task_rejected();
            anyhow::bail!(
                "task queue full: {depth} tasks already queued on session {} \
                 (class {}, scheduler.task_queue_depth)",
                session.id,
                PRIORITY_NAMES[session.priority as usize],
            );
        }
        let task_id = self.next_task.fetch_add(1, Ordering::SeqCst);
        let rec = Arc::new(TaskRecord {
            id: task_id,
            lib,
            lib_name: lib_name.to_string(),
            routine: routine.to_string(),
            params,
            cancel: Arc::new(CancelToken::new()),
            progress: (0..session.group_size())
                .map(|_| Arc::new(RankProgress::new()))
                .collect(),
            hard_deadline: Mutex::new(None),
            lane: AtomicU64::new(0),
            submitted: Instant::now(),
        });
        st.queue.push_back(task_id);
        st.slots.insert(task_id, TaskSlot::Queued(rec));
        self.metrics.task_submitted();
        session.tasks.cond.notify_all();
        Ok(ControlMsg::TaskSubmitted { task_id })
    }

    /// Current state of a task (never blocks; running tasks aggregate
    /// live per-rank progress).
    fn task_status(&self, session: &Session, task_id: u64) -> crate::Result<ControlMsg> {
        let st = session.tasks.state.lock().unwrap();
        let slot = st
            .slots
            .get(&task_id)
            .ok_or_else(|| anyhow::anyhow!("unknown task {task_id}"))?;
        Ok(ControlMsg::TaskStatusReply { task_id, state: wire_state(slot) })
    }

    /// Request cooperative cancellation. Queued tasks become `Cancelled`
    /// immediately; a running task's token is set and the reply shows the
    /// state *after* the request (still `Running` until its ranks observe
    /// the token — poll or `WaitTask` for the terminal state). Terminal
    /// tasks are left untouched (idempotent).
    ///
    /// `hard_after_ms > 0` (protocol v5) arms the escalation watchdog: if
    /// the task is still running once the cooperative grace period
    /// elapses, the group's communicator is poisoned and the routine is
    /// forcibly unwound at its next collective — bounding how long a
    /// routine that ignores the cooperative contract can linger.
    fn cancel_task(
        &self,
        session: &Arc<Session>,
        task_id: u64,
        hard_after_ms: u64,
    ) -> crate::Result<ControlMsg> {
        let mut st = session.tasks.state.lock().unwrap();
        enum Act {
            CancelQueued,
            CancelRunning(Arc<TaskRecord>),
            Nothing,
        }
        let act = match st.slots.get(&task_id) {
            None => anyhow::bail!("unknown task {task_id}"),
            Some(TaskSlot::Queued(_)) => Act::CancelQueued,
            Some(TaskSlot::Running(rec)) => Act::CancelRunning(rec.clone()),
            Some(TaskSlot::Terminal(_)) => Act::Nothing,
        };
        match act {
            Act::CancelQueued => {
                st.set_terminal(task_id, TaskState::Cancelled);
                st.queue.retain(|&id| id != task_id);
                self.metrics.task_dequeued(TaskOutcome::Cancelled);
                session.tasks.cond.notify_all();
            }
            Act::CancelRunning(rec) => {
                rec.cancel.cancel();
                // worker processes hold their own token copy — forward
                // the flip (no-op for in-process groups)
                session.fabric().propagate_cancel(task_id);
                if hard_after_ms > 0 {
                    // clamp to an hour: the watchdog thread and its
                    // session Arc live until the deadline fires. Arm a
                    // new watchdog only when this request TIGHTENS the
                    // deadline: a client hammering cancel_hard must not
                    // pile up sleeping threads, but one correcting an
                    // over-long grace still can (the earliest watchdog
                    // fires first; later ones find the task gone).
                    let grace = Duration::from_millis(hard_after_ms.min(3_600_000));
                    let deadline = Instant::now() + grace;
                    let mut armed = rec.hard_deadline.lock().unwrap();
                    if armed.is_none_or(|cur| deadline < cur) {
                        *armed = Some(deadline);
                        schedule_hard_cancel(session.clone(), task_id, grace);
                    }
                }
            }
            Act::Nothing => {}
        }
        let state = wire_state(st.slots.get(&task_id).expect("slot exists"));
        Ok(ControlMsg::TaskStatusReply { task_id, state })
    }

    /// Block until the task is terminal or `timeout_ms` elapses (0 =
    /// return the current state immediately). The caller's control thread
    /// is the only thing blocked — other sessions, and this session's
    /// dispatcher, keep running.
    fn wait_task(
        &self,
        session: &Session,
        task_id: u64,
        timeout_ms: u64,
    ) -> crate::Result<ControlMsg> {
        // clamp to 24h per call: an adversarial u64::MAX must not overflow
        // the deadline arithmetic (clients just re-issue WaitTask)
        let timeout_ms = timeout_ms.min(24 * 60 * 60 * 1000);
        let deadline = Instant::now() + Duration::from_millis(timeout_ms);
        let mut st = session.tasks.state.lock().unwrap();
        loop {
            let slot = st
                .slots
                .get(&task_id)
                .ok_or_else(|| anyhow::anyhow!("unknown task {task_id}"))?;
            let state = wire_state(slot);
            if state.is_terminal() {
                return Ok(ControlMsg::TaskStatusReply { task_id, state });
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(ControlMsg::TaskStatusReply { task_id, state });
            }
            let (guard, _) = session
                .tasks
                .cond
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
        }
    }

    /// Run one task over the session's group (dispatcher context): SPMD
    /// dispatch to every member worker thread, gather per-rank replies,
    /// and produce the terminal state. Failed and cancelled tasks free
    /// any partially-inserted output blocks so nothing leaks.
    fn execute_task(&self, session: &Session, rec: &TaskRecord) -> TaskState {
        // snapshot the group once per attempt: a replacement committed by
        // a concurrent failure path must not tear this dispatch — every
        // send, poison, and free below targets the same membership + mesh
        let GroupState { ranks: group_ranks, fabric } =
            session.group.read().unwrap().clone();
        let handles: Vec<RankHandle> = {
            let pool = self.ranks.read().unwrap();
            group_ranks.iter().map(|&r| pool[r].clone()).collect()
        };
        // task-scoped output-id reservation, validated by each worker
        // before it inserts anything (see WorkerCmd::out_span)
        let out_span = self.cfg.scheduler.max_task_outputs.max(1);
        let out_base = self.next_id.fetch_add(out_span, Ordering::SeqCst);
        // the tag lane the dispatcher assigned when this task left the
        // queue: every rank wraps the group fabric in a LaneComm at this
        // lane, so concurrent tasks of one group never collide on tags
        let lane = rec.lane.load(Ordering::SeqCst);

        // intra-rank parallelism for THIS dispatch: the admission clamp
        // bounds one session, but disjoint groups run tasks concurrently
        // and a task's pool size cannot change mid-flight — so grants
        // are leased from a shared thread budget. Each running task
        // holds `group × threads` of the budget until it finishes
        // (the lease drops on every exit path); a new dispatch takes
        // min(its admission cap, its share of what is uncommitted),
        // floored at 1 (threads = 1 spawns nothing — the rank threads
        // themselves are the irreducible load). Overlapping tenants
        // therefore never sum extra pool threads past the core count,
        // idle tenants lease nothing and throttle nobody, and a lone
        // session still gets its full admission value. Results are
        // bit-identical for any thread count, so leasing is invisible
        // to clients.
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let group = group_ranks.len().max(1);
        let cap = self.cfg.engine_threads_for_group(group, avail);
        let engine_threads = {
            let mut committed = self.engine_threads_committed.lock().unwrap();
            let spare = avail.saturating_sub(*committed);
            let t = cap.min((spare / group).max(1));
            *committed += group * t;
            t
        };
        let _lease = ThreadsLease {
            committed: &self.engine_threads_committed,
            amount: group * engine_threads,
        };

        // dispatch to this session's group only; disjoint groups use
        // disjoint worker threads, so no global serialization here. A
        // failed send means that rank's worker thread is dead — stop
        // dispatching immediately: every further rank we started would
        // enter the routine's collectives and block forever waiting for
        // the dead rank (when the FIRST send fails, e.g. after server
        // stop closed every worker channel, the task fails cleanly with
        // no rank dispatched at all).
        let mut replies = Vec::new();
        let mut dead_slot: Option<usize> = None;
        for (slot, handle) in handles.iter().enumerate() {
            if dead_slot.is_some() {
                replies.push((slot, None));
                continue;
            }
            let rx = match handle {
                RankHandle::Local { sender, .. } => {
                    let (tx, rx) = mpsc::channel();
                    let sent = sender.send(WorkerCmd::RunTask {
                        session_id: session.id,
                        lib: rec.lib.clone(),
                        routine: rec.routine.clone(),
                        params: rec.params.clone(),
                        out_base,
                        out_span,
                        engine_threads,
                        scope: TaskScope::new(
                            rec.cancel.clone(),
                            rec.progress[slot].clone(),
                        )
                        .with_lane(lane),
                        reply: tx,
                    });
                    sent.ok().map(|()| rx)
                }
                // a worker process rebuilds the library from its
                // canonical name (never the client alias) and runs the
                // identical command loop; its reply channel is fed by the
                // work-socket reader, and if the process dies mid-task
                // the reader fails the channel — same semantics as a dead
                // in-process rank. Live progress slots are not mirrored
                // over the work socket (remote tasks report iters = 0
                // until terminal).
                RankHandle::Remote(w) => w
                    .run_task(
                        session.id,
                        rec.id,
                        rec.lib.name(),
                        &rec.routine,
                        rec.params.clone(),
                        out_base,
                        out_span,
                        engine_threads,
                        lane,
                    )
                    .ok(),
            };
            if rx.is_none() {
                dead_slot = Some(slot);
            }
            replies.push((slot, rx));
        }
        // a dead worker channel means that rank will never enter the
        // routine — but every rank already dispatched WILL, and would
        // block in its first collective waiting for the missing member.
        // Poison the fabric naming the dead slot so they unwind with
        // PeerFailed (collateral) and the reply gather below terminates;
        // the "worker thread is gone" error at the dead slot stays the
        // reported root cause.
        if let Some(slot) = dead_slot {
            fabric.poison(PoisonCause::RankFailed(slot));
        }
        let mut results = Vec::new();
        let mut failures: Vec<(u32, anyhow::Error)> = Vec::new();
        for (slot, rx) in replies {
            let reply = match rx {
                // the slot whose channel send failed is the root cause;
                // slots after it were never dispatched at all — their
                // "failure" is collateral of the dead slot, so tag them
                // with the same CommError the poisoned ranks report and
                // the aggregation below keeps failed_ranks = roots only
                None if dead_slot == Some(slot) => {
                    Err(anyhow::anyhow!("worker thread is gone"))
                }
                None => Err(anyhow::Error::new(CommError::PeerFailed {
                    rank: dead_slot.expect("undispatched slots follow a dead one"),
                })),
                Some(rx) => rx
                    .recv()
                    .unwrap_or_else(|_| Err(anyhow::anyhow!("worker died mid-task"))),
            };
            match reply {
                Ok(r) => results.push(r),
                Err(e) => failures.push((slot as u32, e)),
            }
        }

        // cancel wins races: even if every rank completed, a set token
        // means the client asked for cancellation — report Cancelled and
        // discard (free) any outputs rather than registering them
        let free_window = || {
            for handle in &handles {
                match handle {
                    RankHandle::Local { shared, .. } => {
                        for id in out_base..out_base + out_span {
                            shared.store.free(id);
                        }
                    }
                    RankHandle::Remote(w) => {
                        for id in out_base..out_base + out_span {
                            let _ = w.send(&WorkMsg::StoreFree { id });
                        }
                    }
                }
            }
        };
        if rec.cancel.is_cancelled() {
            free_window();
            return TaskState::Cancelled;
        }
        if !failures.is_empty() {
            let total = group_ranks.len();
            // root-cause-first reporting (protocol v5): a rank that
            // failed on its own is the cause; ranks whose errors are
            // `CommError` (PeerFailed / hard-cancel) merely unwound after
            // the group was poisoned — collateral, not causes. The client
            // must see "rank i panicked" with the peers' unwinding noted,
            // never a peer's PeerFailed as the headline.
            let is_collateral = |e: &anyhow::Error| {
                e.downcast_ref::<CommError>().is_some_and(CommError::is_collateral)
            };
            let roots: Vec<&(u32, anyhow::Error)> =
                failures.iter().filter(|(_, e)| !is_collateral(e)).collect();
            let collateral: Vec<u32> = failures
                .iter()
                .filter(|(_, e)| is_collateral(e))
                .map(|(r, _)| *r)
                .collect();
            let (message, failed_ranks) = if let Some((first_rank, first_err)) =
                roots.first().map(|(r, e)| (r, e))
            {
                let mut message = format!(
                    "{} of {total} ranks failed; rank {first_rank}: {first_err:#}",
                    roots.len()
                );
                if !collateral.is_empty() {
                    message.push_str(&format!(
                        "; {} peer rank(s) {collateral:?} aborted after the failure",
                        collateral.len()
                    ));
                }
                (message, roots.iter().map(|(r, _)| *r).collect())
            } else {
                // no local root cause (e.g. a poison raced a token that
                // cleared): report the collateral errors as-is
                let (first_rank, first_err) = &failures[0];
                (
                    format!(
                        "{} of {total} ranks failed; rank {first_rank}: {first_err:#}",
                        failures.len()
                    ),
                    failures.iter().map(|(r, _)| *r).collect(),
                )
            };
            free_window();
            return TaskState::Failed {
                message,
                failed_ranks,
                total_ranks: total as u32,
            };
        }

        let done = (|| -> crate::Result<TaskState> {
            // consistency: every rank must report the same output set
            let r0 = &results[0];
            for r in &results[1..] {
                anyhow::ensure!(
                    r.outputs.len() == r0.outputs.len(),
                    "ranks disagree on output count for {}.{}",
                    rec.lib_name,
                    rec.routine
                );
            }
            let mut outputs = Vec::new();
            {
                let mut handles = session.handles.lock().unwrap();
                for meta in &r0.outputs {
                    // every rank already agreed on the layout when the
                    // routine returned; it travels in the reply (for
                    // remote ranks the store itself is out of reach)
                    let layout = meta.layout.clone();
                    let info = MatrixInfo {
                        id: meta.id,
                        rows: meta.rows,
                        cols: meta.cols,
                        name: meta.name.clone(),
                    };
                    handles.insert(
                        meta.id,
                        HandleMeta {
                            info: info.clone(),
                            layout,
                            source: None,
                            sealed: true,
                        },
                    );
                    outputs.push(info);
                }
            }

            // timings: group-rank-0 laps + aggregated cluster metrics
            let mut timings = r0.timings.clone();
            let lap = |r: &super::worker::TaskReply, name: &str| -> f64 {
                r.timings
                    .iter()
                    .find(|(n, _)| n == name)
                    .map(|(_, s)| *s)
                    .unwrap_or(0.0)
            };
            let sim_secs = results
                .iter()
                .map(|r| lap(r, "cpu_busy") + lap(r, "comm_sim"))
                .fold(0.0f64, f64::max);
            timings.push(("sim_secs".into(), sim_secs));
            Ok(TaskState::Done { outputs, scalars: r0.scalars.clone(), timings })
        })();
        match done {
            Ok(state) => state,
            Err(e) => {
                free_window();
                TaskState::Failed {
                    message: format!("{e:#}"),
                    failed_ranks: vec![],
                    total_ranks: group_ranks.len() as u32,
                }
            }
        }
    }

    fn fetch_matrix(&self, session: &Session, id: u64) -> crate::Result<ControlMsg> {
        let meta = self.handle(session, id)?;
        // v10: the current group's data addresses travel with every fetch
        // — after a rank replacement the client must frame its row reads
        // to the replacement, not the corpse (`docs/recovery.md`)
        Ok(ControlMsg::FetchReady {
            info: meta.info,
            row_ranges: meta.layout.to_wire(),
            worker_addrs: self.session_worker_addrs(session),
        })
    }

    fn free_matrix(&self, session: &Session, id: u64) -> crate::Result<ControlMsg> {
        let existed = session.handles.lock().unwrap().remove(&id).is_some();
        anyhow::ensure!(existed, "unknown matrix handle {id}");
        for &rank in &session.ranks() {
            match &self.rank(rank) {
                RankHandle::Local { shared, .. } => {
                    shared.store.free(id);
                }
                RankHandle::Remote(w) => {
                    let _ = w.send(&WorkMsg::StoreFree { id });
                }
            }
        }
        Ok(ControlMsg::Freed { id })
    }

    fn list_matrices(&self, session: &Session) -> ControlMsg {
        let handles = session.handles.lock().unwrap();
        let mut infos: Vec<MatrixInfo> =
            handles.values().map(|m| m.info.clone()).collect();
        infos.sort_by_key(|i| i.id);
        ControlMsg::MatrixList { infos }
    }

    /// The full scheduler snapshot: the counter/gauge core from
    /// [`SchedMetrics::snapshot`] plus a per-session breakdown (tenant,
    /// class, queue backlog, running tasks with live aggregated
    /// progress). This is what `ServerHandle::sched_metrics` returns and
    /// what the `SubscribeMetrics` stream serializes every interval.
    fn sched_snapshot(&self) -> SchedSnapshot {
        let mut snap = self.metrics.snapshot();
        let sessions: Vec<Arc<Session>> =
            self.sessions.lock().unwrap().values().cloned().collect();
        for s in &sessions {
            let st = s.tasks.state.lock().unwrap();
            let mut running: Vec<TaskGauge> = st
                .running
                .values()
                .map(|rec| {
                    let p = rec.aggregate_progress();
                    TaskGauge {
                        task_id: rec.id,
                        lane: rec.lane.load(Ordering::SeqCst),
                        routine: format!("{}.{}", rec.lib_name, rec.routine),
                        iters: p.iters,
                        residual: p.residual,
                    }
                })
                .collect();
            running.sort_by_key(|t| t.task_id);
            snap.sessions.push(SessionGauge {
                session_id: s.id,
                client: s.client.clone(),
                priority: s.priority,
                queued: st.queue.len(),
                running,
            });
        }
        snap.sessions.sort_by_key(|g| g.session_id);
        snap
    }
}

/// One session's task dispatcher loop (protocol v9): pop the FIFO while
/// fewer than `scheduler.tasks_per_group` tasks are running, assign each
/// admitted task the session's next tag lane, and hand it to an executor
/// thread — so up to `tasks_per_group` tasks run concurrently over the
/// same group, isolated by their lanes. Exits when teardown sets
/// `closing` and both the queue and the running set are empty
/// (close_session empties the queue itself, so only the running tasks
/// remain to finish), joining every executor so no task can touch the
/// store after the session's blocks are freed.
fn task_dispatcher(driver: &Arc<Driver>, session: &Arc<Session>) {
    let cap = driver.cfg.scheduler.tasks_per_group.max(1);
    let mut executors: Vec<JoinHandle<()>> = Vec::new();
    loop {
        // claim the next task (or exit on teardown)
        let claimed = {
            let mut st = session.tasks.state.lock().unwrap();
            loop {
                if st.running.len() < cap {
                    if let Some(id) = st.queue.pop_front() {
                        let rec = match st.slots.get(&id) {
                            Some(TaskSlot::Queued(rec)) => rec.clone(),
                            // cancelled-while-queued slots are already
                            // Terminal; their id was removed from the
                            // queue, but guard anyway
                            _ => continue,
                        };
                        // lane assignment: monotonic per session, never
                        // reused — a finished task's straggler messages
                        // land in a tag window nobody reads again
                        let lane = st.next_lane;
                        st.next_lane += 1;
                        rec.lane.store(lane, Ordering::SeqCst);
                        st.slots.insert(id, TaskSlot::Running(rec.clone()));
                        st.running.insert(id, rec.clone());
                        // gauge moves before anyone can observe Running
                        // (a status poll after the lock drops must see
                        // the queued→running transition in the metrics)
                        driver
                            .metrics
                            .task_started(rec.submitted.elapsed().as_secs_f64());
                        session.tasks.cond.notify_all();
                        break Some(rec);
                    }
                }
                if st.closing && st.queue.is_empty() && st.running.is_empty() {
                    break None;
                }
                st = session.tasks.cond.wait(st).unwrap();
            }
        };
        let Some(rec) = claimed else { break };
        let wait_secs = rec.submitted.elapsed().as_secs_f64();
        log::debug!(
            "session {}: task {} ({}.{}) dispatched on lane {} after \
             {wait_secs:.3}s queued",
            session.id,
            rec.id,
            rec.lib_name,
            rec.routine,
            rec.lane.load(Ordering::SeqCst),
        );
        // one executor thread per running task — even at cap = 1, so
        // serial and concurrent dispatch share one code path. Reap
        // finished handles opportunistically; the stragglers are joined
        // on exit below.
        executors.retain(|h| !h.is_finished());
        let driver = driver.clone();
        let session = session.clone();
        executors.push(std::thread::spawn(move || {
            execute_and_finalize(&driver, &session, &rec);
        }));
    }
    for h in executors {
        let _ = h.join();
    }
}

/// Run one task to its terminal state and finalize it under the task
/// table lock: record the terminal slot, retire the task's tag lane (its
/// straggler messages are dropped from here on), and — only when it was
/// the LAST running task — reset the group fabric so a poisoned group
/// heals between tasks without yanking a live sibling's lanes.
/// Cap on replace-and-retry attempts per task: a second worker dying
/// during the retry is still survivable, but a pathological environment
/// (workers dying faster than spares replay) must converge on a failure
/// the client can see instead of looping forever.
const MAX_REPLACE_RETRIES: usize = 2;

fn execute_and_finalize(
    driver: &Arc<Driver>,
    session: &Arc<Session>,
    rec: &Arc<TaskRecord>,
) {
    let mut state = driver.execute_task(session, rec);
    // survivable failure path (protocol v10): when the attempt failed
    // because a worker process died — never for a cancelled task or a
    // routine's own error (try_replace finds no dead rank and declines)
    // — re-form the group around a spare, replay the dead slots' shards
    // from their task-boundary snapshots, and run the task again from
    // the start. Routines are deterministic functions of their (sealed,
    // replayed-bit-identical) inputs, so the retried result is exactly
    // the failure-free one.
    let mut retries = 0;
    while matches!(state, TaskState::Failed { .. })
        && !rec.cancel.is_cancelled()
        && retries < MAX_REPLACE_RETRIES
    {
        if !driver.try_replace_dead_ranks(session) {
            break;
        }
        retries += 1;
        log::info!(
            "session {}: retrying task {} ({}.{}) on the re-formed group \
             (attempt {})",
            session.id,
            rec.id,
            rec.lib_name,
            rec.routine,
            retries + 1,
        );
        state = driver.execute_task(session, rec);
    }
    let outcome = match &state {
        TaskState::Done { .. } => TaskOutcome::Done,
        TaskState::Cancelled => TaskOutcome::Cancelled,
        _ => TaskOutcome::Failed,
    };
    let lane = rec.lane.load(Ordering::SeqCst);
    {
        let fabric = session.fabric();
        let mut st = session.tasks.state.lock().unwrap();
        st.set_terminal(rec.id, state);
        st.running.remove(&rec.id);
        // retire the lane UNDER the table lock: the hard-cancel watchdog
        // checks `running` and poisons under this same lock, so a late
        // watchdog can never poison a lane after it was retired (it
        // observes the task gone from `running` and stands down). Every
        // rank has replied by now, so no rank is inside a collective on
        // this lane.
        fabric.retire_lane(lane);
        // reset the whole fabric only between tasks (running set empty):
        // it clears group-wide poison (e.g. a rank death) and drains
        // undelivered messages, which would be destructive while a
        // sibling task is mid-collective on its own lane
        if st.running.is_empty() {
            fabric.reset();
        }
        // count the outcome BEFORE waking waiters: a client whose
        // wait() just returned may read sched_metrics() immediately
        // and must see this task as finished, not still running
        driver.metrics.task_finished(outcome);
        session.tasks.cond.notify_all();
    }
}

/// Escalation watchdog for `CancelTask { hard_after_ms }` and session
/// teardown: once the cooperative grace period elapses, if the task is
/// still running, poison the task's tag lane so every rank blocked in
/// (or next entering) one of its collectives unwinds with
/// [`CommError::Cancelled`] instead of running to its natural end — a
/// sibling task on another lane keeps running untouched (protocol v9).
/// The running-check and the poison happen under the task-table lock —
/// the same lock the executor holds while finalizing and retiring the
/// lane — so a watchdog firing after the task ended is a no-op, never a
/// stale poison leaking into the next task.
fn schedule_hard_cancel(session: Arc<Session>, task_id: u64, grace: Duration) {
    std::thread::spawn(move || {
        std::thread::sleep(grace);
        let fabric = session.fabric();
        let st = session.tasks.state.lock().unwrap();
        if let Some(rec) = st.running.get(&task_id) {
            let lane = rec.lane.load(Ordering::SeqCst);
            fabric.poison_lane(lane, PoisonCause::HardCancel);
            log::warn!(
                "session {}: task {task_id} ignored cooperative cancellation for \
                 {grace:?}; lane {lane} poisoned (hard cancel)",
                session.id
            );
        }
    });
}

/// Handle to a running server; dropping does NOT stop it — call
/// [`ServerHandle::shutdown`] (or send `ControlMsg::Shutdown` as a
/// client).
pub struct ServerHandle {
    pub control_addr: String,
    /// Data addresses of the whole pool, index = global worker rank
    /// (sessions are granted subsets; see the handshake ack).
    pub worker_addrs: Vec<String>,
    threads: Vec<JoinHandle<()>>,
    /// Spawned worker processes, index = global rank (`fabric.mode =
    /// tcp`; empty for local pools). Reaped at shutdown; a `None` slot
    /// was killed (see [`ServerHandle::kill_worker`]) or already reaped.
    children: Mutex<Vec<Option<Child>>>,
    driver: Arc<Driver>,
}

impl ServerHandle {
    /// Stop the server from the owning process (benches/tests).
    pub fn shutdown(mut self) {
        self.driver.stop_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        self.reap_children();
    }

    /// Block until some client sends `ControlMsg::Shutdown` (the
    /// `alchemist serve` foreground mode).
    pub fn shutdown_on_request(mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        self.reap_children();
    }

    /// Kill worker process `rank` outright — SIGKILL, no shutdown
    /// message (fault injection: the rank's peers must detect the dead
    /// mesh links themselves and poison the group with
    /// `PoisonCause::RankFailed`). Returns false for local pools, unknown
    /// ranks, and ranks already gone.
    pub fn kill_worker(&self, rank: usize) -> bool {
        let mut children = self.children.lock().unwrap();
        match children.get_mut(rank) {
            Some(slot @ Some(_)) => {
                let mut child = slot.take().expect("matched Some");
                let killed = child.kill().is_ok();
                let _ = child.wait();
                killed
            }
            _ => false,
        }
    }

    /// Wait for the worker processes to exit (they do so on `Shutdown`,
    /// or when the work socket drops), escalating to a kill after a
    /// bounded grace so a wedged child can never hang shutdown.
    fn reap_children(&self) {
        let mut children = self.children.lock().unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        for slot in children.iter_mut() {
            let Some(mut child) = slot.take() else { continue };
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
    }

    /// Live session count (test/debug introspection).
    pub fn active_sessions(&self) -> usize {
        self.driver.sessions.lock().unwrap().len()
    }

    /// Total matrix blocks across all worker stores (test/debug
    /// introspection: teardown must drive a session's share to zero).
    /// In-process ranks are read directly; live worker processes answer
    /// a `StoreStats` round trip (v10) — dead ones hold nothing.
    pub fn total_blocks(&self) -> usize {
        self.remote_store_stats().0
            + self
                .driver
                .ranks
                .read()
                .unwrap()
                .iter()
                .filter_map(|r| r.local())
                .map(|w| w.store.len())
                .sum::<usize>()
    }

    /// `(blocks, spill_segments)` summed over live worker processes
    /// (empty/zero for local pools).
    fn remote_store_stats(&self) -> (usize, usize) {
        let remotes: Vec<Arc<RemoteWorker>> = self
            .driver
            .ranks
            .read()
            .unwrap()
            .iter()
            .filter_map(|r| r.remote().cloned())
            .filter(|w| !w.is_dead())
            .collect();
        let (mut blocks, mut segs) = (0usize, 0usize);
        for w in remotes {
            match w.request_ack(|req_id| WorkMsg::StoreStats { req_id }) {
                Ok((packed, _)) => {
                    blocks += (packed >> 32) as usize;
                    segs += (packed & 0xffff_ffff) as usize;
                }
                Err(e) => {
                    log::warn!("store stats from worker {}: {e:#}", w.rank)
                }
            }
        }
        (blocks, segs)
    }

    /// Scheduler backpressure snapshot: per-class admission-queue depth,
    /// task-queue gauges, outcome counters, Queued→Running wait-time
    /// distribution, plus per-session gauges (tenant, class, backlog,
    /// running tasks with live progress) — the same snapshot the
    /// `SubscribeMetrics` stream pushes.
    pub fn sched_metrics(&self) -> SchedSnapshot {
        self.driver.sched_snapshot()
    }

    /// Storage-plane counters (blocks spilled / paged in / mapped, bytes
    /// each way), merged across every worker rank's store. The
    /// out-of-core proof reads this: `cycled()` says blocks went to disk
    /// AND came back during the run.
    pub fn storage_metrics(&self) -> StorageSnapshot {
        let mut total = StorageSnapshot::default();
        for w in self.driver.ranks.read().unwrap().iter().filter_map(|r| r.local()) {
            total.merge(&w.store.storage_metrics().snapshot());
        }
        total
    }

    /// Per-session storage totals (resident / spilled / mapped bytes)
    /// summed across ranks, sorted by session id. Teardown must drive a
    /// closed session's entry to zero — and off this list.
    pub fn storage_usage(&self) -> Vec<(u64, super::store::SessionUsage)> {
        let mut by: HashMap<u64, super::store::SessionUsage> = HashMap::new();
        for w in self.driver.ranks.read().unwrap().iter().filter_map(|r| r.local()) {
            for (sid, u) in w.store.usage() {
                let e = by.entry(sid).or_default();
                e.bytes_resident += u.bytes_resident;
                e.bytes_spilled += u.bytes_spilled;
                e.bytes_mapped += u.bytes_mapped;
            }
        }
        let mut v: Vec<(u64, super::store::SessionUsage)> = by.into_iter().collect();
        v.sort_by_key(|(id, _)| *id);
        v
    }

    /// Live spill-file segments across all ranks (a freed session must
    /// leave none behind). Live worker processes are polled over their
    /// work socket (v10 `StoreStats`), same as [`ServerHandle::total_blocks`].
    pub fn total_spill_segments(&self) -> usize {
        self.remote_store_stats().1
            + self
                .driver
                .ranks
                .read()
                .unwrap()
                .iter()
                .filter_map(|r| r.local())
                .map(|w| w.store.spill_segments())
                .sum::<usize>()
    }

    /// The attach listener address for late `alchemist worker --connect`
    /// adoption (`None` for local pools).
    pub fn attach_addr(&self) -> Option<String> {
        let addr = self.driver.attach_addr.lock().unwrap().clone();
        if addr.is_empty() {
            None
        } else {
            Some(addr)
        }
    }

    /// Standby ranks currently in the spare pool
    /// (`scheduler.spare_workers` plus adopted late joiners, minus
    /// replacements consumed by rank failures).
    pub fn spare_workers(&self) -> usize {
        self.driver.allocator.spare_count()
    }

    /// Per-session task backlog (which tenant the global `queued_tasks`
    /// gauge belongs to), sorted by session id.
    pub fn session_queue_depths(&self) -> Vec<crate::metrics::SessionQueueDepth> {
        let sessions: Vec<Arc<Session>> =
            self.driver.sessions.lock().unwrap().values().cloned().collect();
        let mut depths: Vec<crate::metrics::SessionQueueDepth> = sessions
            .iter()
            .map(|s| {
                let st = s.tasks.state.lock().unwrap();
                crate::metrics::SessionQueueDepth {
                    session_id: s.id,
                    queued: st.queue.len(),
                    running: st.running.len(),
                }
            })
            .collect();
        depths.sort_by_key(|d| d.session_id);
        depths
    }
}

/// The Alchemist server factory.
pub struct AlchemistServer;

impl AlchemistServer {
    /// Start a driver with `num_workers` worker ranks on ephemeral
    /// localhost ports. Returns once all sockets are listening.
    /// `fabric.mode` picks the pool's shape: threads in this process
    /// (`local`, the seed behavior) or spawned `alchemist worker`
    /// processes attached over TCP (`tcp`, protocol v8 —
    /// `docs/fabric.md`). `scheduler.spare_workers` additional standby
    /// ranks are built alongside the pool, held out of admission, and
    /// consumed by rank replacement (protocol v10, `docs/recovery.md`).
    pub fn start(cfg: Config, num_workers: usize) -> crate::Result<ServerHandle> {
        anyhow::ensure!(num_workers >= 1, "need at least one worker");
        match cfg.fabric.mode {
            FabricMode::Local => Self::start_local(cfg, num_workers),
            FabricMode::Tcp => Self::start_fabric(cfg, num_workers),
        }
    }

    /// In-process pool: one data listener + command-loop thread per rank.
    fn start_local(cfg: Config, num_workers: usize) -> crate::Result<ServerHandle> {
        let mut threads = Vec::new();

        // server-wide work-stealing compute plane: ONE thread set sized
        // to the machine; each rank drives a client queue of it, and
        // `execute_task`'s per-task lease retargets the queue's cap —
        // `granted_workers × threads ≤ cores` stays a cap, not a static
        // partition, because idle queues' capacity is stolen by busy
        // ones (docs/compute.md)
        let avail =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let compute_pool = ThreadPool::new(avail);

        // worker shared state; communicators are session-scoped and bound
        // at handshake time. Ranks past `num_workers` are the standby
        // spares (admission never grants them; see GroupAllocator).
        let total = num_workers + cfg.scheduler.spare_workers;
        let mut ranks = Vec::new();
        let mut listener_stops = Vec::new();

        for rank in 0..total {
            let shared = Arc::new(WorkerShared {
                rank,
                // each rank gets its own counters (no cross-rank atomic
                // contention); ServerHandle::storage_metrics merges them
                store: super::store::MatrixStore::with_storage(
                    rank,
                    &cfg.storage,
                    Arc::new(StorageMetrics::new()),
                ),
                data_addr: Mutex::new(String::new()),
                sessions: Mutex::new(HashMap::new()),
            });
            // data listener
            let listener = Server::bind(0)?;
            *shared.data_addr.lock().unwrap() = listener.addr().to_string();
            listener_stops.push(listener.stop_flag());
            {
                let shared = shared.clone();
                let cfg = cfg.clone();
                threads.push(std::thread::spawn(move || {
                    let shared2 = shared.clone();
                    let _ = listener.serve(move |stream| {
                        handle_data_conn(&shared2, stream, &cfg);
                    });
                }));
            }
            // command loop; each rank's engine rides a client queue of
            // the shared compute pool (cap retargeted per task)
            let (tx, rx) = mpsc::channel();
            {
                let shared = shared.clone();
                let cfg = cfg.clone();
                let pool = compute_pool.client(1);
                threads.push(std::thread::spawn(move || {
                    worker_main(shared, cfg, rx, Some(pool));
                }));
            }
            ranks.push(RankHandle::Local { shared, sender: tx });
        }

        Self::finish_start(
            cfg,
            ranks,
            num_workers,
            compute_pool,
            threads,
            listener_stops,
            Vec::new(),
            None,
        )
    }

    /// Process-separated pool: spawn `alchemist worker --connect` children
    /// against a one-shot attach socket and wait (bounded by
    /// `fabric.attach_timeout_s`) for every rank to complete the attach
    /// handshake. Config travels to the children as `--set` override
    /// pairs; the coordinator runs no engines in this mode, so its
    /// compute pool shrinks to a stub.
    fn start_fabric(cfg: Config, num_workers: usize) -> crate::Result<ServerHandle> {
        let listener =
            TcpListener::bind(("127.0.0.1", 0)).context("binding attach socket")?;
        let attach_addr = listener.local_addr()?.to_string();
        let exe = if cfg.fabric.worker_exe.is_empty() {
            std::env::current_exe().context("locating the alchemist binary")?
        } else {
            std::path::PathBuf::from(&cfg.fabric.worker_exe)
        };
        let overrides = cfg
            .worker_override_pairs()
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(",");
        // ranks past `num_workers` are the standby spares
        let total = num_workers + cfg.scheduler.spare_workers;
        let mut children: Vec<Option<Child>> = Vec::with_capacity(total);
        let attached = (|| -> crate::Result<Vec<RankHandle>> {
            for rank in 0..total {
                let mut cmd = Command::new(&exe);
                cmd.arg("worker")
                    .arg("--connect")
                    .arg(&attach_addr)
                    .arg("--rank-id")
                    .arg(rank.to_string());
                if !overrides.is_empty() {
                    cmd.arg("--set").arg(&overrides);
                }
                let child = cmd
                    .spawn()
                    .with_context(|| format!("spawning worker process {rank}"))?;
                children.push(Some(child));
            }
            let attach_timeout =
                Duration::from_secs_f64(cfg.fabric.attach_timeout_s.max(0.1));
            let deadline = Instant::now() + attach_timeout;
            listener.set_nonblocking(true).context("attach socket setup")?;
            let mut slots: Vec<Option<RankHandle>> =
                (0..total).map(|_| None).collect();
            let mut count = 0;
            while count < total {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false)?;
                        let remaining = deadline
                            .saturating_duration_since(Instant::now())
                            .max(Duration::from_millis(100));
                        let w = RemoteWorker::attach(
                            stream,
                            cfg.transfer.buf_bytes,
                            remaining,
                        )?;
                        anyhow::ensure!(
                            w.rank < total,
                            "worker attached claiming rank {} of a \
                             {total}-rank pool",
                            w.rank
                        );
                        anyhow::ensure!(
                            slots[w.rank].is_none(),
                            "two workers attached claiming rank {}",
                            w.rank
                        );
                        slots[w.rank] = Some(RankHandle::Remote(w));
                        count += 1;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        anyhow::ensure!(
                            Instant::now() < deadline,
                            "only {count} of {total} worker processes \
                             attached within {:.1}s (fabric.attach_timeout_s)",
                            attach_timeout.as_secs_f64()
                        );
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => return Err(e).context("accepting worker attach"),
                }
            }
            Ok(slots.into_iter().map(|s| s.expect("all slots filled")).collect())
        })();
        let ranks = match attached {
            Ok(r) => r,
            Err(e) => {
                // failed startup leaves no orphans behind
                for c in children.iter_mut().flatten() {
                    let _ = c.kill();
                }
                for c in children.iter_mut() {
                    if let Some(mut c) = c.take() {
                        let _ = c.wait();
                    }
                }
                return Err(e);
            }
        };
        let compute_pool = ThreadPool::new(1);
        Self::finish_start(
            cfg,
            ranks,
            num_workers,
            compute_pool,
            Vec::new(),
            Vec::new(),
            children,
            Some((listener, attach_addr)),
        )
    }

    /// Common tail of both modes: control listener, driver, log line.
    /// `num_workers` is the admittable pool size — `ranks` may be longer,
    /// the tail being the standby spares. A tcp pool passes its attach
    /// listener back in so it keeps serving: externally launched
    /// `worker --connect` processes are adopted into the spare pool.
    #[allow(clippy::too_many_arguments)]
    fn finish_start(
        cfg: Config,
        ranks: Vec<RankHandle>,
        num_workers: usize,
        compute_pool: ThreadPool,
        mut threads: Vec<JoinHandle<()>>,
        mut listener_stops: Vec<Arc<AtomicBool>>,
        children: Vec<Option<Child>>,
        attach: Option<(TcpListener, String)>,
    ) -> crate::Result<ServerHandle> {
        let spares: Vec<usize> = (num_workers..ranks.len()).collect();
        let num_spares = spares.len();
        let control = Server::bind(0)?;
        let control_addr = control.addr().to_string();
        listener_stops.push(control.stop_flag());
        let metrics = Arc::new(SchedMetrics::new());
        let driver = Arc::new(Driver {
            allocator: GroupAllocator::new(
                num_workers,
                spares,
                cfg.scheduler.clone(),
                metrics.clone(),
            ),
            cfg: cfg.clone(),
            ranks: RwLock::new(ranks),
            registry: Registry::new(),
            engine_threads_committed: Mutex::new(0),
            storage_committed: Mutex::new(0),
            compute_pool,
            next_id: AtomicU64::new(1),
            next_session: AtomicU64::new(1),
            next_task: AtomicU64::new(1),
            sessions: Mutex::new(HashMap::new()),
            lingering: Mutex::new(HashMap::new()),
            linger_gen: AtomicU64::new(1),
            attach_addr: Mutex::new(String::new()),
            stopping: AtomicBool::new(false),
            listener_stops: Mutex::new(listener_stops),
            control_addr: Mutex::new(control_addr.clone()),
            metrics,
        });

        {
            let driver = driver.clone();
            let buf = cfg.transfer.buf_bytes;
            threads.push(std::thread::spawn(move || {
                let _ = control.serve(move |stream| {
                    handle_control_conn(&driver, stream, buf);
                });
            }));
        }

        // keep the attach socket open (tcp pools): externally launched
        // `alchemist worker --connect <attach_addr>` processes are
        // adopted into the spare pool at runtime. stop_all wake-connects
        // the address so this thread exits with the other accept loops.
        if let Some((listener, attach_addr)) = attach {
            let stop = Arc::new(AtomicBool::new(false));
            driver.listener_stops.lock().unwrap().push(stop.clone());
            *driver.attach_addr.lock().unwrap() = attach_addr;
            let driver2 = driver.clone();
            let buf = cfg.transfer.buf_bytes;
            threads.push(std::thread::spawn(move || {
                let _ = listener.set_nonblocking(false);
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst)
                        || driver2.stopping.load(Ordering::SeqCst)
                    {
                        break;
                    }
                    match conn {
                        Ok(stream) => adopt_external_worker(&driver2, stream, buf),
                        Err(_) => break,
                    }
                }
            }));
        }

        log::info!(
            "alchemist server up: control {control_addr}, {num_workers} {} \
             workers (+{num_spares} spare), shared compute pool of {} \
             threads, engine {}, max {} sessions",
            match cfg.fabric.mode {
                FabricMode::Local => "in-process",
                FabricMode::Tcp => "process-separated",
            },
            driver.compute_pool.threads(),
            cfg.engine.as_str(),
            cfg.scheduler.max_sessions
        );
        Ok(ServerHandle {
            control_addr,
            worker_addrs: driver.worker_addrs(),
            threads,
            children: Mutex::new(children),
            driver,
        })
    }
}

/// Dispatch a control message that requires an admitted session.
fn handle_session_op(
    driver: &Driver,
    session: Option<&Arc<Session>>,
    msg: ControlMsg,
) -> crate::Result<ControlMsg> {
    let session = session
        .ok_or_else(|| anyhow::anyhow!("handshake required before {msg:?}"))?;
    match msg {
        ControlMsg::CreateMatrix { name, rows, cols } => {
            driver.create_matrix(session, &name, rows, cols)
        }
        ControlMsg::LoadMatrix { name, path } => {
            driver.load_matrix(session, &name, &path)
        }
        ControlMsg::SealMatrix { id } => driver.seal_matrix(session, id),
        ControlMsg::SubmitTask { lib, routine, params } => {
            driver.submit_task(session, &lib, &routine, params)
        }
        ControlMsg::TaskStatus { task_id } => driver.task_status(session, task_id),
        ControlMsg::CancelTask { task_id, hard_after_ms } => {
            driver.cancel_task(session, task_id, hard_after_ms)
        }
        ControlMsg::WaitTask { task_id, timeout_ms } => {
            driver.wait_task(session, task_id, timeout_ms)
        }
        ControlMsg::FetchMatrix { id } => driver.fetch_matrix(session, id),
        ControlMsg::FreeMatrix { id } => driver.free_matrix(session, id),
        ControlMsg::ListMatrices => Ok(driver.list_matrices(session)),
        other => Ok(ControlMsg::Error {
            message: format!("unexpected control message: {other:?}"),
        }),
    }
}

/// Adopt an externally launched `alchemist worker --connect` process
/// into the spare pool (protocol v10, `docs/recovery.md`): the same
/// attach handshake as startup, but the claimed rank id is advisory —
/// the pool index is the next rank-table slot, and the worker goes
/// straight into the allocator's spare list, never into admission.
fn adopt_external_worker(driver: &Arc<Driver>, stream: TcpStream, buf_bytes: usize) {
    match RemoteWorker::attach(stream, buf_bytes, Duration::from_secs(10)) {
        Ok(w) => {
            let claimed = w.rank;
            let rank = {
                let mut ranks = driver.ranks.write().unwrap();
                ranks.push(RankHandle::Remote(w));
                ranks.len() - 1
            };
            driver.allocator.add_spare(rank);
            log::info!(
                "late worker adopted as global rank {rank} (spare{})",
                if claimed == rank {
                    String::new()
                } else {
                    format!("; its --rank-id {claimed} is advisory")
                },
            );
        }
        Err(e) => log::warn!("late worker attach failed: {e:#}"),
    }
}

fn handle_control_conn(driver: &Arc<Driver>, stream: TcpStream, buf_bytes: usize) {
    if driver.stopping.load(Ordering::SeqCst) {
        return; // wake-up connection during shutdown
    }
    let mut framed = match Framed::tcp(stream, buf_bytes) {
        Ok(f) => f,
        Err(e) => {
            log::warn!("control conn setup failed: {e}");
            return;
        }
    };
    // the session admitted on this control socket; torn down when the
    // socket closes (client `stop()` / crash) or on Shutdown
    let mut session: Option<Arc<Session>> = None;
    loop {
        let msg = match framed.recv_ctrl() {
            Ok(m) => m,
            Err(_) => break, // client went away
        };
        let reply = match msg {
            ControlMsg::Handshake {
                client_name,
                version,
                request_workers,
                rows_per_frame,
                buf_bytes,
                priority,
            } => {
                if version != PROTOCOL_VERSION {
                    Ok(ControlMsg::Error {
                        message: format!(
                            "protocol version mismatch: client {version}, server {PROTOCOL_VERSION}"
                        ),
                    })
                } else if session.is_some() {
                    Ok(ControlMsg::Error {
                        message: "session already established on this connection".into(),
                    })
                } else {
                    match driver.open_session(
                        &client_name,
                        request_workers,
                        rows_per_frame,
                        buf_bytes,
                        priority,
                    ) {
                        Ok(s) => {
                            let ack = ControlMsg::HandshakeAck {
                                session_id: s.id,
                                version: PROTOCOL_VERSION,
                                granted_workers: s.group_size() as u32,
                                worker_addrs: driver.session_worker_addrs(&s),
                                rows_per_frame: s.transfer.rows_per_frame as u32,
                                buf_bytes: s.transfer.buf_bytes as u64,
                                // the reconnect credential (protocol v10):
                                // present it in Reattach within the linger
                                // window to resume this session
                                session_token: s.token,
                            };
                            session = Some(s);
                            Ok(ack)
                        }
                        Err(e) => Err(e),
                    }
                }
            }
            // resume a parked session on a fresh connection (protocol
            // v10): the token from the original handshake ack is the
            // credential; the ack carries everything `connect` would
            // have negotiated plus the ids of every retained task, so
            // the client can re-list and collect results it missed
            ControlMsg::Reattach { token } => {
                if session.is_some() {
                    Ok(ControlMsg::Error {
                        message: "session already established on this connection"
                            .into(),
                    })
                } else {
                    match driver.reattach(token) {
                        Ok(s) => {
                            let mut task_ids: Vec<u64> = {
                                let st = s.tasks.state.lock().unwrap();
                                st.slots.keys().copied().collect()
                            };
                            task_ids.sort_unstable();
                            let ack = ControlMsg::ReattachAck {
                                session_id: s.id,
                                granted_workers: s.group_size() as u32,
                                worker_addrs: driver.session_worker_addrs(&s),
                                rows_per_frame: s.transfer.rows_per_frame as u32,
                                buf_bytes: s.transfer.buf_bytes as u64,
                                task_ids,
                            };
                            session = Some(s);
                            Ok(ack)
                        }
                        Err(e) => Err(e),
                    }
                }
            }
            // the metrics stream claims the whole connection: no session,
            // no further requests — just periodic snapshot pushes until
            // the subscriber hangs up or the server stops (protocol v9)
            ControlMsg::SubscribeMetrics { interval_ms } => {
                if session.is_some() {
                    Ok(ControlMsg::Error {
                        message: "SubscribeMetrics must be the first message \
                                  on its own connection"
                            .into(),
                    })
                } else {
                    stream_metrics(driver, &mut framed, interval_ms);
                    return;
                }
            }
            ControlMsg::RegisterLibrary { name, path } => driver
                .registry
                .register(&name, &path)
                .map(|()| ControlMsg::LibraryRegistered { name }),
            ControlMsg::Shutdown => {
                if let Some(s) = session.take() {
                    driver.close_session(&s);
                }
                driver.stop_all();
                let _ = framed.send_ctrl(&ControlMsg::Bye);
                return;
            }
            other => handle_session_op(driver, session.as_ref(), other),
        };
        let out = match reply {
            Ok(m) => m,
            Err(e) => ControlMsg::Error { message: format!("{e:#}") },
        };
        if framed.send_ctrl(&out).is_err() {
            break;
        }
    }
    // dropped connection: close immediately (the seed contract — client
    // `stop()` IS a socket drop) unless lingering is configured, in which
    // case the session parks awaiting Reattach (protocol v10)
    if let Some(s) = session.take() {
        driver.park_or_close(&s);
    }
}

/// Push-based metrics stream (protocol v9 `SubscribeMetrics`): serialize
/// a full scheduler snapshot every `interval_ms` (0 = the server's
/// `scheduler.metrics_interval_ms` default; clamped to [10ms, 60s]) as a
/// `MetricsSnapshot { seq, json }` frame until the subscriber disconnects
/// or the server stops. The sleep is sliced so shutdown never waits a
/// full interval on an idle subscriber.
fn stream_metrics(
    driver: &Arc<Driver>,
    framed: &mut Framed<TcpStream, TcpStream>,
    interval_ms: u64,
) {
    let ms = if interval_ms == 0 {
        driver.cfg.scheduler.metrics_interval_ms
    } else {
        interval_ms
    };
    let interval = Duration::from_millis(ms.clamp(10, 60_000));
    let mut seq: u64 = 0;
    loop {
        if driver.stopping.load(Ordering::SeqCst) {
            return;
        }
        let json = driver.sched_snapshot().to_json();
        if framed.send_ctrl(&ControlMsg::MetricsSnapshot { seq, json }).is_err() {
            return; // subscriber went away
        }
        seq += 1;
        let deadline = Instant::now() + interval;
        while Instant::now() < deadline {
            if driver.stopping.load(Ordering::SeqCst) {
                return;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            std::thread::sleep(left.min(Duration::from_millis(50)));
        }
    }
}
