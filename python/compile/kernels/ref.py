"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an entry here with an identical signature;
``python/tests`` asserts allclose between the Pallas lowering and these, and
``aot.py`` can lower these instead of the kernels (the ``xla_*`` artifact
variants) so the rust runtime can ablate pallas-interpret vs native XLA dot.
"""

from __future__ import annotations

import jax.numpy as jnp


def gemm_nn(c, a, b):
    """C + A @ B."""
    return c + a @ b


def gemm_tn(c, a, b):
    """C + A.T @ B (A is stored untransposed, shape [K, M])."""
    return c + a.T @ b


def gemm_nt(c, a, b):
    """C + A @ B.T (B is stored untransposed, shape [N, K])."""
    return c + a @ b.T


def rff_finalize(acc, bias, scale):
    """Random Fourier features finalize: scale * cos(acc + bias).

    ``acc`` is the accumulated X @ Omega projection tile [M, N], ``bias``
    the per-feature phase row [1, N] broadcast over rows, ``scale`` the
    sqrt(2/D) normalization as a [1, 1] array (an array input so the same
    HLO artifact serves any D).
    """
    return scale * jnp.cos(acc + bias)


def cg_update(x, r, p, q, alpha):
    """Fused CG pair-AXPY: X += alpha*P ; R -= alpha*Q.

    ``alpha`` is a [1, C] row (one scalar per right-hand side / class
    column) broadcast down the rows; returns (x_new, r_new).
    """
    return x + alpha * p, r - alpha * q


def gram_matvec(a_panel, v, reg):
    """Regularized Gram-operator panel product: A.T @ (A @ V) + reg * V.

    ``a_panel`` [M, K] is one row-panel of the feature matrix, ``v`` [K, C]
    the block of Lanczos/CG vectors, ``reg`` a [1, 1] regularizer (0 for the
    SVD Gram operator). Workers allreduce the partial results.
    """
    return a_panel.T @ (a_panel @ v) + reg * v
