//! Cooperative task scoping: the cancellation token and per-rank progress
//! slot a running routine shares with the coordinator's task table
//! (protocol v4, `docs/tasks.md`).
//!
//! A [`TaskScope`] is what a routine sees: `is_cancelled` /
//! `check_cancelled` observe the task-wide cancel token (one token per
//! task, shared by every rank), and [`TaskScope::report`] publishes this
//! rank's iteration count and residual for the driver to aggregate into
//! `TaskStatus` replies. Both sides are lock-free atomics — a status poll
//! never contends with the compute loop.
//!
//! **Cancellation contract** (see `docs/tasks.md` for the full version):
//! cancellation is *cooperative and collective*. A routine that runs
//! collectives must not let one rank bail while peers are already inside
//! an allreduce — ranks would deadlock. Iterative SPMD routines therefore
//! agree on cancellation with a tiny allreduce of the locally-observed
//! token at each iteration boundary (see `linalg::cg`), and bail together
//! with [`CANCELLED_MSG`]. Rank-local routines may simply poll the token.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// The error text a cooperatively-cancelled routine bails with. The
/// dispatcher classifies outcomes by the token, not this string — it
/// exists so logs and direct callers read well.
pub const CANCELLED_MSG: &str = "task cancelled";

/// Residual value meaning "nothing reported yet" (residuals are
/// non-negative, so any negative value is safe as the sentinel).
pub const NO_RESIDUAL: f64 = -1.0;

/// One task's cancel flag, shared by the driver (setter) and every rank
/// of the group running the task (observers).
#[derive(Debug, Default)]
pub struct CancelToken {
    flag: AtomicBool,
}

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation; idempotent.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
}

/// One rank's live progress: iteration count plus the latest residual,
/// written by the routine, read by the driver's status aggregation.
#[derive(Debug)]
pub struct RankProgress {
    iters: AtomicU64,
    residual_bits: AtomicU64,
}

impl Default for RankProgress {
    fn default() -> Self {
        RankProgress {
            iters: AtomicU64::new(0),
            residual_bits: AtomicU64::new(NO_RESIDUAL.to_bits()),
        }
    }
}

impl RankProgress {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&self, iters: u64, residual: f64) {
        self.iters.store(iters, Ordering::Relaxed);
        self.residual_bits.store(residual.to_bits(), Ordering::Relaxed);
    }

    pub fn iters(&self) -> u64 {
        self.iters.load(Ordering::Relaxed)
    }

    /// Latest reported residual, or [`NO_RESIDUAL`] if none yet.
    pub fn residual(&self) -> f64 {
        f64::from_bits(self.residual_bits.load(Ordering::Relaxed))
    }
}

/// What one rank of a running task holds: the task-wide cancel token and
/// this rank's progress slot. Routines receive it through `WorkerCtx`.
#[derive(Debug, Clone)]
pub struct TaskScope {
    cancel: Arc<CancelToken>,
    progress: Arc<RankProgress>,
    /// The task's tag lane in the group communicator (protocol v9):
    /// the dispatcher assigns each task a monotonic per-session lane and
    /// wraps the session fabric in a `LaneComm` at `lane << LANE_SHIFT`,
    /// so concurrent tasks in one group never collide on tags. 0 for
    /// detached / pre-v9 scopes (the untasked tag space).
    lane: u64,
    /// Detached scopes skip the collective cancellation checks entirely,
    /// so direct library callers pay zero extra collectives per
    /// iteration (benchmark fidelity: the paper-table CG/SVD numbers
    /// must not shift with cancellability they never use).
    detached: bool,
}

impl TaskScope {
    pub fn new(cancel: Arc<CancelToken>, progress: Arc<RankProgress>) -> Self {
        TaskScope { cancel, progress, lane: 0, detached: false }
    }

    /// The same scope pinned to a task lane (see [`TaskScope::lane`]).
    pub fn with_lane(mut self, lane: u64) -> Self {
        self.lane = lane;
        self
    }

    /// The task's tag lane; 0 = untasked (detached or lane-less fabric).
    pub fn lane(&self) -> u64 {
        self.lane
    }

    /// A scope attached to nothing: progress goes nowhere and
    /// [`TaskScope::collective_check_cancelled`] is free (no collective
    /// is issued — all ranks of a detached SPMD run must therefore be
    /// uniformly detached, which direct callers trivially are). The
    /// rank-local [`TaskScope::check_cancelled`] still reads the token
    /// for callers that keep one via [`TaskScope::token`].
    pub fn detached() -> Self {
        TaskScope {
            cancel: Arc::new(CancelToken::new()),
            progress: Arc::new(RankProgress::new()),
            lane: 0,
            detached: true,
        }
    }

    /// This rank's local view of the token. SPMD routines must not act on
    /// it unilaterally between collectives — see the module docs.
    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    /// Bail with [`CANCELLED_MSG`] if cancellation was requested. Safe to
    /// call at any point of a rank-local (collective-free) routine.
    pub fn check_cancelled(&self) -> crate::Result<()> {
        if self.is_cancelled() {
            anyhow::bail!(CANCELLED_MSG);
        }
        Ok(())
    }

    /// Publish this rank's progress (iterations done, latest residual —
    /// pass [`NO_RESIDUAL`] when the routine has no residual notion).
    pub fn report(&self, iters: u64, residual: f64) {
        self.progress.set(iters, residual);
    }

    /// The collective cancellation check SPMD routines call at iteration
    /// boundaries: allreduce the locally-observed token so either every
    /// rank bails together (with [`CANCELLED_MSG`]) or none does — one
    /// rank bailing unilaterally would strand its peers inside the
    /// routine's next collective. All ranks must reach this call in
    /// lockstep (iterative routines are synchronized by their own
    /// collectives, so the iteration boundary qualifies). `tag` must be
    /// [`crate::collectives::TAG_WINDOW`]-aligned and must not collide
    /// with any concurrently-outstanding collective of the same routine.
    /// Free (no collective) on detached scopes.
    ///
    /// If the group is poisoned (a peer failed, or a hard cancel pulled
    /// the plug — protocol v5), the allreduce itself errors and the
    /// [`crate::collectives::CommError`] propagates so the dispatcher can
    /// tell collateral unwinding apart from a root-cause failure.
    pub fn collective_check_cancelled(
        &self,
        comm: &dyn crate::collectives::Communicator,
        tag: u64,
    ) -> crate::Result<()> {
        if self.detached {
            return Ok(());
        }
        let mut flag = [if self.is_cancelled() { 1.0 } else { 0.0 }];
        crate::collectives::allreduce_sum(comm, tag, &mut flag)?;
        if flag[0] > 0.0 {
            anyhow::bail!(CANCELLED_MSG);
        }
        Ok(())
    }

    /// The task-wide token (the driver's handle for requesting cancel).
    pub fn token(&self) -> &Arc<CancelToken> {
        &self.cancel
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_cancels_once_and_stays() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        t.cancel();
        t.cancel();
        assert!(t.is_cancelled());
    }

    #[test]
    fn progress_roundtrips_and_defaults() {
        let p = RankProgress::new();
        assert_eq!(p.iters(), 0);
        assert_eq!(p.residual(), NO_RESIDUAL);
        p.set(17, 1e-6);
        assert_eq!(p.iters(), 17);
        assert_eq!(p.residual(), 1e-6);
    }

    #[test]
    fn collective_check_is_free_when_detached_and_bails_when_attached() {
        use crate::collectives::{LocalComm, TAG_WINDOW};
        let comm = LocalComm::group(1, None).pop().unwrap();

        // detached: no collective issued, never bails — even with the
        // token set (direct callers pay nothing for cancellability)
        let detached = TaskScope::detached();
        detached.token().cancel();
        assert!(detached.collective_check_cancelled(&comm, 0).is_ok());

        // attached: passes while the token is clear, bails once set
        let scope =
            TaskScope::new(Arc::new(CancelToken::new()), Arc::new(RankProgress::new()));
        assert!(scope.collective_check_cancelled(&comm, TAG_WINDOW).is_ok());
        scope.token().cancel();
        let err = scope
            .collective_check_cancelled(&comm, 2 * TAG_WINDOW)
            .unwrap_err();
        assert!(err.to_string().contains(CANCELLED_MSG));
    }

    #[test]
    fn detached_scope_never_cancels_but_token_can() {
        let s = TaskScope::detached();
        assert!(s.check_cancelled().is_ok());
        s.report(3, 0.5);
        let token = s.token().clone();
        token.cancel();
        let err = s.check_cancelled().unwrap_err();
        assert!(err.to_string().contains(CANCELLED_MSG));
    }
}
