//! Quickstart — the paper's Figure 2 session, verbatim API shape.
//!
//! Starts an in-process Alchemist server, connects a client, ships a
//! matrix, runs the hypothetical `libA` QR decomposition (here: the
//! `elemental` builtin), materializes Q and R back on the client, and
//! verifies `A = Q·R`.
//!
//! ```sh
//! cargo run --release --example quickstart -- [--workers 3] [--engine xla|pallas|native]
//! ```

use alchemist::cli::Args;
use alchemist::client::AlchemistContext;
use alchemist::config::Config;
use alchemist::coordinator::AlchemistServer;
use alchemist::distmat::LocalMatrix;
use alchemist::protocol::Params;
use alchemist::sparklite::IndexedRowMatrix;
use alchemist::util::prng::Rng;

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let mut cfg = Config::default();
    if let Some(engine) = args.get("engine") {
        cfg.apply("engine", engine)?;
    } else {
        // quickstart should run even before `make artifacts`
        cfg.apply("engine", "native")?;
    }
    let workers = args.get_usize("workers", 3)?;

    // server side (normally `alchemist serve`; in-proc here)
    let server = AlchemistServer::start(cfg.clone(), workers)?;
    println!("server: {} ({} workers)", server.control_addr, workers);

    // --- the Figure 2 session ---
    // val ac = new Alchemist.AlchemistContext(sc, numWorkers)
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 2)?;
    // ac.registerLibrary("libA", ALIlibALocation)
    ac.register_library("libA", "builtin:elemental")?;

    // A is an IndexedRowMatrix
    let mut rng = Rng::new(7);
    let a = LocalMatrix::from_fn(1000, 16, |_, _| rng.normal());
    let irm = IndexedRowMatrix::from_local(&a, 8);

    // val alA = AlMatrix(A)
    let (al_a, stats) = ac.send_matrix("A", &irm)?;
    println!(
        "sent A ({} rows x {} cols, {}) in {:.3}s ({:.2} GB/s)",
        al_a.rows,
        al_a.cols,
        alchemist::util::fmt::bytes(al_a.size_bytes() as u64),
        stats.secs,
        stats.throughput_gbps()
    );

    // val (alQ, alR) = QRDecomposition(alA)
    let res = ac.run_task("libA", "qr", Params::new().with_matrix("A", al_a.id))?;
    let al_q = res.output("Q")?.clone();
    let al_r = res.output("R")?.clone();
    println!(
        "QR done in {:.3}s server-side (simulated cluster time {:.3}s)",
        res.timing("compute"),
        res.timing("sim_secs")
    );

    // val Q = alQ.toIndexedRowMatrix(); val R = alR.toIndexedRowMatrix()
    let (q_irm, _) = ac.to_indexed_row_matrix(&al_q, 8)?;
    let (r_irm, _) = ac.to_indexed_row_matrix(&al_r, 1)?;
    let q = q_irm.to_local()?;
    let r = r_irm.to_local()?;

    // verify A = Q·R and QᵀQ = I
    let mut qr = LocalMatrix::zeros(a.rows(), a.cols());
    qr.gemm_nn(&q, &r);
    let recon = qr.max_abs_diff(&a);
    let mut qtq = LocalMatrix::zeros(16, 16);
    qtq.gemm_tn(&q, &q);
    let ortho = qtq.max_abs_diff(&LocalMatrix::identity(16));
    println!("‖A − QR‖max = {recon:.2e}, ‖QᵀQ − I‖max = {ortho:.2e}");
    anyhow::ensure!(recon < 1e-9 && ortho < 1e-10, "QR verification failed");

    // ac.stop()
    ac.shutdown_server()?;
    server.shutdown_on_request();
    println!("quickstart OK");
    Ok(())
}
