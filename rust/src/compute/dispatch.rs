//! Cost-model backend selection (`engine = "auto"`): per call, pick the
//! native packed kernels or the XLA tiled engine from a small calibrated
//! table keyed on `(op, problem dims, engine threads)`.
//!
//! The model is deliberately *static*: selection depends only on the
//! problem shape, the configured thread budget, and whether the XLA
//! artifacts loaded — never on measured timings. Every rank of an SPMD
//! session sees the same inputs (the scheduler hands the whole group one
//! `engine_threads` clamp), so replicated solver state stays bitwise
//! identical across ranks even though the two backends only agree to
//! rounding error with each other.
//!
//! Cost table. Rates are f64 GFLOP/s on the CI runner class, seeded from
//! the `BENCH_compute.json` pin; the current constants are provisional
//! (the PR 5 baseline is still `baseline-pending`, see the JSON header)
//! and should be re-derived from the pinned cells:
//!
//! * native GEMM scales with the thread budget (packed panels over the
//!   intra-rank pool, zero reductions);
//! * the XLA runtime is single-stream, but its *fused* panel ops
//!   (gram_matvec, rff_expand) make one pass per panel where the native
//!   engine composes two dependent GEMMs plus an intermediate — so the
//!   fused XLA rate is higher than the fused native per-thread rate;
//! * the XLA path additionally pays per-executable-run dispatch overhead,
//!   zero-padding to the exported artifact shapes, and host↔device
//!   marshalling — except for [`Engine::gram_matvec_keyed`] re-calls,
//!   where the device-resident operand cache drops the marshalling to the
//!   small right-hand side (the "large static panel" win).
//!
//! Net effect with these constants: composed GEMM always dispatches
//! native (the packed kernels are never slower — which is also what the
//! `auto >= packed` bench gate checks), while the fused Gram operator
//! dispatches to XLA for large panels at small thread budgets and back to
//! native once the pool is wide enough to out-scale the fused rate.
//!
//! Construction degrades gracefully: if the artifact manifest is missing
//! (`make artifacts` not run), `auto` logs once and dispatches everything
//! native rather than failing the session handshake.

use std::collections::HashSet;
use std::sync::Arc;

use crate::config::{Config, EngineKind};
use crate::distmat::LocalMatrix;
use crate::tasks::CancelToken;
use crate::util::round_up;

use super::{Engine, GemmVariant, NativeEngine, XlaEngine};

/// Native composed-GEMM rate, per pool thread.
const NATIVE_GEMM_GFLOPS: f64 = 3.2;
/// Native fused-op rate, per pool thread (two dependent GEMMs + an
/// intermediate panel of memory traffic).
const NATIVE_FUSED_GFLOPS: f64 = 2.4;
/// XLA composed-GEMM rate (single-stream runtime, tile at a time).
const XLA_GEMM_GFLOPS: f64 = 3.0;
/// XLA fused panel-op rate (one pass per panel, no intermediate).
const XLA_FUSED_GFLOPS: f64 = 5.0;
/// Per-executable-invocation dispatch overhead (s).
const XLA_RUN_OVERHEAD_S: f64 = 25e-6;
/// Host↔device staging bandwidth for padding/tilizing operands (B/s).
const MARSHAL_BYTES_PER_S: f64 = 6e9;

/// Which engine a dispatch decision landed on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Backend {
    Native,
    Xla,
}

/// Shape-derived inputs to the cost table — everything the model is
/// allowed to look at.
#[derive(Debug, Clone, Copy)]
pub(crate) struct CostInput {
    /// Fused panel op (gram/rff) vs composed tile GEMM.
    pub fused: bool,
    /// True flop count of the call.
    pub flops: f64,
    /// Flops after zero-padding to the exported artifact shapes.
    pub padded_flops: f64,
    /// Executable invocations the XLA path needs.
    pub runs: usize,
    /// Bytes the XLA path stages host↔device for this call.
    pub marshal_bytes: f64,
    /// Engine thread budget (`Engine::set_threads`).
    pub threads: usize,
}

/// The table lookup: estimated seconds per backend, cheapest wins.
/// Returns `(choice, native_secs, xla_secs)`; `xla_secs` is infinite when
/// the XLA backend is unavailable.
pub(crate) fn select_backend(inp: &CostInput, xla_available: bool) -> (Backend, f64, f64) {
    let t = inp.threads.max(1) as f64;
    let native_rate =
        1e9 * t * if inp.fused { NATIVE_FUSED_GFLOPS } else { NATIVE_GEMM_GFLOPS };
    let native_secs = inp.flops / native_rate;
    if !xla_available {
        return (Backend::Native, native_secs, f64::INFINITY);
    }
    let xla_rate = 1e9 * if inp.fused { XLA_FUSED_GFLOPS } else { XLA_GEMM_GFLOPS };
    let xla_secs = inp.padded_flops / xla_rate
        + inp.runs as f64 * XLA_RUN_OVERHEAD_S
        + inp.marshal_bytes / MARSHAL_BYTES_PER_S;
    let choice = if xla_secs < native_secs { Backend::Xla } else { Backend::Native };
    (choice, native_secs, xla_secs)
}

/// The `engine = "auto"` engine: owns both backends and routes per call.
pub struct DispatchEngine {
    native: NativeEngine,
    xla: Option<XlaEngine>,
    tile: usize,
    panel_rows: usize,
    threads: usize,
    cancel: Option<Arc<CancelToken>>,
    /// Operand keys whose panels are already device-resident (a prior
    /// keyed call dispatched XLA), so re-calls only marshal the RHS.
    warm_keys: HashSet<u64>,
}

impl DispatchEngine {
    /// Wrap `native` (built by the caller so it can ride the server's
    /// shared pool) and try to stand up the XLA side; a missing manifest
    /// degrades to native-only dispatch instead of erroring.
    pub fn new(cfg: &Config, native: NativeEngine) -> Self {
        let xla = match XlaEngine::new(cfg, "xla") {
            Ok(e) => Some(e),
            Err(err) => {
                log::info!(
                    "engine=auto: XLA backend unavailable ({err:#}); \
                     dispatching native-only"
                );
                None
            }
        };
        let threads = native.threads().max(1);
        DispatchEngine {
            native,
            xla,
            tile: cfg.tile.max(1),
            panel_rows: cfg.panel_rows.max(1),
            threads,
            cancel: None,
            warm_keys: HashSet::new(),
        }
    }

    /// Whether the XLA side loaded (tests and the worker's startup log).
    pub fn has_xla(&self) -> bool {
        self.xla.is_some()
    }

    fn check_cancel(&self) -> crate::Result<()> {
        if self.cancel.as_deref().is_some_and(|t| t.is_cancelled()) {
            anyhow::bail!(crate::tasks::CANCELLED_MSG);
        }
        Ok(())
    }

    fn route(&self, op: &str, inp: &CostInput) -> Backend {
        let (backend, native_secs, xla_secs) = select_backend(inp, self.xla.is_some());
        log::debug!(
            "dispatch {op}: {backend:?} (native {native_secs:.3e}s vs xla \
             {xla_secs:.3e}s, threads={})",
            inp.threads
        );
        backend
    }

    fn gemm_cost(&self, m: usize, n: usize, k: usize) -> CostInput {
        let t = self.tile;
        let (pm, pn, pk) = (round_up(m, t), round_up(n, t), round_up(k, t));
        CostInput {
            fused: false,
            flops: 2.0 * m as f64 * n as f64 * k as f64,
            padded_flops: 2.0 * pm as f64 * pn as f64 * pk as f64,
            runs: (pm / t) * (pn / t) * (pk / t),
            // tilize a + b, seed + untile the c accumulator
            marshal_bytes: 8.0 * (pm * pk + pk * pn + 2 * pm * pn) as f64,
            threads: self.threads,
        }
    }

    fn gram_cost(&self, rows: usize, d: usize, c: usize, warm: bool) -> CostInput {
        let prows = round_up(rows.max(1), self.panel_rows);
        // artifact widths pad the RHS column count to at least 8
        let pc = c.max(8);
        CostInput {
            fused: true,
            flops: 4.0 * rows as f64 * d as f64 * c as f64,
            padded_flops: 4.0 * prows as f64 * d as f64 * pc as f64,
            runs: prows / self.panel_rows,
            marshal_bytes: if warm {
                // device-resident panels: only the RHS moves per call
                8.0 * (2 * d * pc) as f64
            } else {
                8.0 * (prows * d + 2 * d * pc) as f64
            },
            threads: self.threads,
        }
    }

    fn rff_cost(&self, rows: usize, k0: usize, d: usize) -> CostInput {
        let prows = round_up(rows.max(1), self.panel_rows);
        // projection GEMM + ~8 flops/element for the cos tail
        CostInput {
            fused: true,
            flops: (2.0 * k0 as f64 + 8.0) * rows as f64 * d as f64,
            padded_flops: (2.0 * k0 as f64 + 8.0) * prows as f64 * d as f64,
            runs: prows / self.panel_rows,
            marshal_bytes: 8.0 * (prows * k0 + k0 * d + prows * d) as f64,
            threads: self.threads,
        }
    }
}

impl Engine for DispatchEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Auto
    }

    fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
        self.native.set_threads(threads);
    }

    fn set_cancel(&mut self, token: Option<Arc<CancelToken>>) {
        // the native kernels poll at panel granularity; the dispatcher
        // itself adds an entry check so an XLA-routed op still observes a
        // token cancelled before it started
        self.native.set_cancel(token.clone());
        self.cancel = token;
    }

    fn gemm(
        &mut self,
        variant: GemmVariant,
        c: &mut LocalMatrix,
        a: &LocalMatrix,
        b: &LocalMatrix,
    ) -> crate::Result<()> {
        self.check_cancel()?;
        let (m, n, k) = variant.problem_dims(a, b);
        let inp = self.gemm_cost(m, n, k);
        match self.route(variant.op_name(), &inp) {
            Backend::Xla => self.xla.as_mut().unwrap().gemm(variant, c, a, b),
            Backend::Native => self.native.gemm(variant, c, a, b),
        }
    }

    fn gram_matvec(
        &mut self,
        a: &LocalMatrix,
        v: &LocalMatrix,
        reg: f64,
    ) -> crate::Result<LocalMatrix> {
        self.check_cancel()?;
        let inp = self.gram_cost(a.rows(), a.cols(), v.cols(), false);
        match self.route("gram_matvec", &inp) {
            Backend::Xla => self.xla.as_mut().unwrap().gram_matvec(a, v, reg),
            Backend::Native => self.native.gram_matvec(a, v, reg),
        }
    }

    fn gram_matvec_keyed(
        &mut self,
        key: u64,
        a: &LocalMatrix,
        v: &LocalMatrix,
        reg: f64,
    ) -> crate::Result<LocalMatrix> {
        self.check_cancel()?;
        let warm = self.warm_keys.contains(&key);
        let inp = self.gram_cost(a.rows(), a.cols(), v.cols(), warm);
        match self.route("gram_matvec_keyed", &inp) {
            Backend::Xla => {
                if self.warm_keys.len() > 4096 {
                    // keys are per solver invocation; a long-lived worker
                    // would otherwise grow this without bound
                    self.warm_keys.clear();
                }
                self.warm_keys.insert(key);
                self.xla.as_mut().unwrap().gram_matvec_keyed(key, a, v, reg)
            }
            Backend::Native => self.native.gram_matvec_keyed(key, a, v, reg),
        }
    }

    fn rff_expand(
        &mut self,
        x: &LocalMatrix,
        omega: &LocalMatrix,
        bias: &[f64],
        scale: f64,
    ) -> crate::Result<LocalMatrix> {
        self.check_cancel()?;
        let inp = self.rff_cost(x.rows(), x.cols(), omega.cols());
        match self.route("rff_expand", &inp) {
            Backend::Xla => self.xla.as_mut().unwrap().rff_expand(x, omega, bias, scale),
            Backend::Native => self.native.rff_expand(x, omega, bias, scale),
        }
    }

    fn cg_update(
        &mut self,
        x: &mut LocalMatrix,
        r: &mut LocalMatrix,
        p: &LocalMatrix,
        q: &LocalMatrix,
        alpha: &[f64],
    ) -> crate::Result<()> {
        // memory-bound either way; the native path avoids padding and
        // marshalling entirely, so no table lookup is needed
        self.check_cancel()?;
        log::debug!("dispatch cg_update: Native (memory-bound, fixed)");
        self.native.cg_update(x, r, p, q, alpha)
    }

    fn exec_stats(&self) -> (u64, f64) {
        self.xla.as_ref().map_or((0, 0.0), |e| e.exec_stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn gemm_inp(m: usize, n: usize, k: usize, threads: usize) -> CostInput {
        CostInput {
            fused: false,
            flops: 2.0 * (m * n * k) as f64,
            padded_flops: 2.0 * (m * n * k) as f64,
            runs: (m / 256) * (n / 256) * (k / 256),
            marshal_bytes: 8.0 * (m * k + k * n + 2 * m * n) as f64,
            threads,
        }
    }

    #[test]
    fn composed_gemm_prefers_native_at_any_thread_count() {
        for threads in [1usize, 2, 4] {
            let (b, _, _) = select_backend(&gemm_inp(512, 512, 512, threads), true);
            assert_eq!(b, Backend::Native, "threads={threads}");
        }
    }

    #[test]
    fn fused_gram_flips_with_thread_budget() {
        // large panel, warm operand cache: at 1 thread the fused XLA rate
        // beats the native two-GEMM composition ...
        let warm = CostInput {
            fused: true,
            flops: 4.0 * (4096 * 512 * 16) as f64,
            padded_flops: 4.0 * (4096 * 512 * 16) as f64,
            runs: 2,
            marshal_bytes: 8.0 * (2 * 512 * 16) as f64,
            threads: 1,
        };
        let (b, native_secs, xla_secs) = select_backend(&warm, true);
        assert_eq!(b, Backend::Xla);
        assert!(xla_secs < native_secs);
        // ... and a 4-wide pool out-scales it
        let wide = CostInput { threads: 4, ..warm };
        let (b, _, _) = select_backend(&wide, true);
        assert_eq!(b, Backend::Native);
    }

    #[test]
    fn unavailable_xla_always_dispatches_native() {
        let inp = CostInput {
            fused: true,
            flops: 1e12,
            padded_flops: 1e12,
            runs: 1,
            marshal_bytes: 0.0,
            threads: 1,
        };
        let (b, _, xla_secs) = select_backend(&inp, false);
        assert_eq!(b, Backend::Native);
        assert!(xla_secs.is_infinite());
    }

    #[test]
    fn tiny_ops_are_overhead_dominated_and_stay_native() {
        let inp = CostInput {
            fused: true,
            flops: 4.0 * (8 * 8 * 1) as f64,
            padded_flops: 4.0 * (2048 * 8 * 8) as f64,
            runs: 1,
            marshal_bytes: 8.0 * (2048 * 8) as f64,
            threads: 1,
        };
        assert_eq!(select_backend(&inp, true).0, Backend::Native);
    }

    #[test]
    fn degrades_to_native_without_artifacts_and_still_computes() {
        let cfg = Config {
            artifacts_dir: std::path::PathBuf::from("/nonexistent/alchemist-artifacts"),
            ..Config::default()
        };
        let mut e = DispatchEngine::new(&cfg, NativeEngine::new());
        assert_eq!(e.kind(), EngineKind::Auto);
        assert!(!e.has_xla());

        let mut rng = Rng::new(5);
        let a = LocalMatrix::from_fn(13, 7, |_, _| rng.normal());
        let b = LocalMatrix::from_fn(7, 9, |_, _| rng.normal());
        let mut c = LocalMatrix::zeros(13, 9);
        e.gemm(GemmVariant::NN, &mut c, &a, &b).unwrap();
        let mut want = LocalMatrix::zeros(13, 9);
        want.gemm_nn(&a, &b);
        assert_eq!(c, want);

        let v = LocalMatrix::from_fn(7, 2, |_, _| rng.normal());
        let got = e.gram_matvec(&a, &v, 0.3).unwrap();
        let want = NativeEngine::new().gram_matvec(&a, &v, 0.3).unwrap();
        assert_eq!(got, want);
    }
}
