//! Measurement plumbing: streaming statistics, paper-style ASCII tables,
//! the simulated cluster clock, and the coordinator's scheduler
//! backpressure gauges.

pub mod sched;
pub mod simclock;
pub mod stats;
pub mod storage;
pub mod table;

pub use sched::{
    SchedMetrics, SchedSnapshot, SessionGauge, SessionQueueDepth, TaskGauge,
    TaskOutcome, PRIORITY_CLASSES, PRIORITY_NAMES,
};
pub use simclock::SimClock;
pub use stats::Stats;
pub use storage::{StorageMetrics, StorageSnapshot};
pub use table::Table;
