"""L2 correctness: composed model graphs, pallas engine vs xla engine."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SMALL = st.sampled_from([32, 64, 128])


def _rng(seed):
    return np.random.default_rng(seed)


@settings(max_examples=10, deadline=None)
@given(m=SMALL, k=SMALL, c=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 2**31))
def test_gram_matvec_engines_agree(m, k, c, seed):
    rng = _rng(seed)
    a = rng.normal(size=(m, k))
    v = rng.normal(size=(k, c))
    reg = np.array([[0.37]])
    got = model.make_gram_matvec(m, k, c, engine="pallas", block=32)(a, v, reg)
    want = model.make_gram_matvec(m, k, c, engine="xla")(a, v, reg)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_gram_matvec_is_gram_plus_reg():
    rng = _rng(7)
    a = rng.normal(size=(64, 32))
    v = rng.normal(size=(32, 8))
    reg = np.array([[2.5]])
    got = model.make_gram_matvec(64, 32, 8, engine="pallas", block=32)(a, v, reg)
    want = a.T @ (a @ v) + 2.5 * v
    np.testing.assert_allclose(got, want, rtol=1e-9)


@settings(max_examples=10, deadline=None)
@given(m=SMALL, k0=st.sampled_from([16, 32]), d=SMALL,
       seed=st.integers(0, 2**31))
def test_rff_expand_engines_agree(m, k0, d, seed):
    rng = _rng(seed)
    x = rng.normal(size=(m, k0))
    omega = rng.normal(size=(k0, d))
    bias = rng.uniform(0, 2 * np.pi, size=(1, d))
    scale = np.array([[np.sqrt(2.0 / d)]])
    got = model.make_rff_expand(m, k0, d, engine="pallas", block=32)(
        x, omega, bias, scale)
    want = model.make_rff_expand(m, k0, d, engine="xla")(x, omega, bias, scale)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_rff_expand_range_bounded():
    # |scale * cos| <= scale everywhere — catches phase/scale mix-ups.
    rng = _rng(11)
    x = rng.normal(size=(32, 16))
    omega = rng.normal(size=(16, 64))
    bias = rng.uniform(0, 2 * np.pi, size=(1, 64))
    scale = np.array([[np.sqrt(2.0 / 64)]])
    z = model.make_rff_expand(32, 16, 64, engine="pallas", block=16)(
        x, omega, bias, scale)
    assert float(jnp.max(jnp.abs(z))) <= float(scale[0, 0]) + 1e-12


def test_cg_update_engines_agree():
    rng = _rng(13)
    m, n = 128, 32
    x, r, p, q = (rng.normal(size=(m, n)) for _ in range(4))
    alpha = rng.normal(size=(1, n))
    gx, gr = model.make_cg_update(m, n, engine="pallas", block=32)(
        x, r, p, q, alpha)
    wx, wr = ref.cg_update(x, r, p, q, alpha)
    np.testing.assert_allclose(gx, wx, rtol=1e-12)
    np.testing.assert_allclose(gr, wr, rtol=1e-12)
