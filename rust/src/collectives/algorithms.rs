//! Collective algorithms over point-to-point send/recv.
//!
//! These are the textbook implementations the MPI runtimes the paper
//! depends on would use at this scale: binomial trees for
//! broadcast/reduce, a bandwidth-optimal ring for allreduce, linear
//! gather/scatter rooted at rank 0 (the Alchemist driver-adjacent rank).

use crate::util::even_ranges;

use super::Communicator;

/// Binomial-tree broadcast from `root`. Every rank passes the same `buf`
/// in; on return all ranks hold root's data.
pub fn broadcast(comm: &dyn Communicator, base_tag: u64, root: usize, buf: &mut Vec<f64>) {
    let size = comm.size();
    if size == 1 {
        return;
    }
    // Relative rank so any root works with the rank-0 tree.
    let vrank = (comm.rank() + size - root) % size;
    let mut mask = 1usize;
    // receive phase: find the bit where our parent contacted us
    while mask < size {
        if vrank & mask != 0 {
            let parent = (vrank - mask + root) % size;
            *buf = comm.recv(parent, base_tag);
            break;
        }
        mask <<= 1;
    }
    // send phase: forward to children below our lowest set bit
    let mut child_mask = if vrank == 0 {
        // root starts at the highest power of two < size
        let mut m = 1usize;
        while m < size {
            m <<= 1;
        }
        m >> 1
    } else {
        mask >> 1
    };
    while child_mask > 0 {
        let vchild = vrank | child_mask;
        if vchild < size && vchild != vrank {
            let child = (vchild + root) % size;
            comm.send(child, base_tag, buf.clone());
        }
        child_mask >>= 1;
    }
}

/// Binomial-tree sum-reduce to `root`; on root, `buf` holds the elementwise
/// sum over all ranks; other ranks' buffers are consumed (contents
/// unspecified after the call).
pub fn reduce_sum(comm: &dyn Communicator, base_tag: u64, root: usize, buf: &mut Vec<f64>) {
    let size = comm.size();
    if size == 1 {
        return;
    }
    let vrank = (comm.rank() + size - root) % size;
    let mut mask = 1usize;
    while mask < size {
        if vrank & mask != 0 {
            // send to parent and exit
            let parent = (vrank - mask + root) % size;
            comm.send(parent, base_tag + mask as u64, std::mem::take(buf));
            return;
        }
        // receive from child (if it exists) and accumulate
        let vchild = vrank | mask;
        if vchild < size {
            let child = (vchild + root) % size;
            let other = comm.recv(child, base_tag + mask as u64);
            debug_assert_eq!(other.len(), buf.len());
            for (a, b) in buf.iter_mut().zip(&other) {
                *a += b;
            }
        }
        mask <<= 1;
    }
}

/// Ring allreduce (reduce-scatter + allgather): bandwidth-optimal,
/// 2·(p−1)/p · n elements over the wire per rank. All ranks end with the
/// elementwise sum.
pub fn allreduce_sum(comm: &dyn Communicator, base_tag: u64, buf: &mut [f64]) {
    let p = comm.size();
    if p == 1 {
        return;
    }
    let rank = comm.rank();
    let chunks = even_ranges(buf.len(), p);
    let next = (rank + 1) % p;
    let prev = (rank + p - 1) % p;

    // Phase 1: reduce-scatter. In step s, send chunk (rank - s) and
    // receive + accumulate chunk (rank - s - 1).
    for s in 0..p - 1 {
        let send_idx = (rank + p - s) % p;
        let recv_idx = (rank + p - s - 1) % p;
        let (a, b) = chunks[send_idx];
        comm.send(next, base_tag + s as u64, buf[a..b].to_vec());
        let incoming = comm.recv(prev, base_tag + s as u64);
        let (a, b) = chunks[recv_idx];
        debug_assert_eq!(incoming.len(), b - a);
        for (dst, src) in buf[a..b].iter_mut().zip(&incoming) {
            *dst += src;
        }
    }
    // Phase 2: allgather of the reduced chunks. In step s, send chunk
    // (rank + 1 - s) and receive chunk (rank - s).
    for s in 0..p - 1 {
        let send_idx = (rank + 1 + p - s) % p;
        let recv_idx = (rank + p - s) % p;
        let (a, b) = chunks[send_idx];
        comm.send(next, base_tag + (p + s) as u64, buf[a..b].to_vec());
        let incoming = comm.recv(prev, base_tag + (p + s) as u64);
        let (a, b) = chunks[recv_idx];
        buf[a..b].copy_from_slice(&incoming);
    }
}

/// Gather each rank's (possibly differently-sized) vector to `root`.
/// Returns `Some(parts)` on root (index = rank), `None` elsewhere.
pub fn gather(
    comm: &dyn Communicator,
    base_tag: u64,
    root: usize,
    mine: Vec<f64>,
) -> Option<Vec<Vec<f64>>> {
    if comm.rank() == root {
        let mut parts = vec![Vec::new(); comm.size()];
        for r in 0..comm.size() {
            if r == root {
                parts[r] = mine.clone();
            } else {
                parts[r] = comm.recv(r, base_tag + r as u64);
            }
        }
        Some(parts)
    } else {
        comm.send(root, base_tag + comm.rank() as u64, mine);
        None
    }
}

/// Scatter `parts` (index = rank) from `root`; returns this rank's part.
pub fn scatter(
    comm: &dyn Communicator,
    base_tag: u64,
    root: usize,
    parts: Option<Vec<Vec<f64>>>,
) -> Vec<f64> {
    if comm.rank() == root {
        let parts = parts.expect("root must supply parts");
        assert_eq!(parts.len(), comm.size());
        let mut mine = Vec::new();
        for (r, part) in parts.into_iter().enumerate() {
            if r == root {
                mine = part;
            } else {
                comm.send(r, base_tag + r as u64, part);
            }
        }
        mine
    } else {
        comm.recv(root, base_tag + comm.rank() as u64)
    }
}

/// Allgather: everyone ends with the concatenation (by rank) of all
/// inputs. Implemented as ring rotation, (p−1) steps.
pub fn allgather(comm: &dyn Communicator, base_tag: u64, mine: Vec<f64>) -> Vec<Vec<f64>> {
    let p = comm.size();
    let rank = comm.rank();
    let mut parts: Vec<Vec<f64>> = vec![Vec::new(); p];
    parts[rank] = mine;
    let next = (rank + 1) % p;
    let prev = (rank + p - 1) % p;
    for s in 0..p - 1 {
        let send_idx = (rank + p - s) % p;
        let recv_idx = (rank + p - s - 1) % p;
        comm.send(next, base_tag + s as u64, parts[send_idx].clone());
        parts[recv_idx] = comm.recv(prev, base_tag + s as u64);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::LocalComm;

    /// Run `f` on every rank of an n-group and return the per-rank results.
    pub fn run_group<T, F>(n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(&LocalComm) -> T + Send + Sync + Clone + 'static,
    {
        let comms = LocalComm::group(n, None);
        let mut handles = Vec::new();
        for c in comms {
            let f = f.clone();
            handles.push(std::thread::spawn(move || f(&c)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn broadcast_all_roots_all_sizes() {
        for p in 1..=5usize {
            for root in 0..p {
                let out = run_group(p, move |c| {
                    let mut buf = if c.rank() == root {
                        vec![3.5, -1.0, 7.0]
                    } else {
                        Vec::new()
                    };
                    broadcast(c, 10, root, &mut buf);
                    buf
                });
                for v in out {
                    assert_eq!(v, vec![3.5, -1.0, 7.0], "p={p} root={root}");
                }
            }
        }
    }

    #[test]
    fn reduce_sum_matches_serial() {
        for p in 1..=6usize {
            let out = run_group(p, move |c| {
                let mut buf = vec![c.rank() as f64 + 1.0, 10.0];
                reduce_sum(c, 20, 0, &mut buf);
                (c.rank(), buf)
            });
            let expect0: f64 = (1..=p).map(|r| r as f64).sum();
            for (rank, buf) in out {
                if rank == 0 {
                    assert_eq!(buf, vec![expect0, 10.0 * p as f64]);
                }
            }
        }
    }

    #[test]
    fn allreduce_matches_serial_various_lengths() {
        for p in 1..=5usize {
            for n in [1usize, 2, 7, 64, 129] {
                let out = run_group(p, move |c| {
                    let mut buf: Vec<f64> =
                        (0..n).map(|i| (i + c.rank() * 100) as f64).collect();
                    allreduce_sum(c, 30, &mut buf);
                    buf
                });
                let want: Vec<f64> = (0..n)
                    .map(|i| {
                        (0..p).map(|r| (i + r * 100) as f64).sum::<f64>()
                    })
                    .collect();
                for v in out {
                    assert_eq!(v, want, "p={p} n={n}");
                }
            }
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        for p in 1..=4usize {
            let out = run_group(p, move |c| {
                let mine = vec![c.rank() as f64; c.rank() + 1];
                let gathered = gather(c, 40, 0, mine);
                // root redistributes what it gathered
                let got = scatter(c, 41, 0, gathered);
                got
            });
            for (r, v) in out.into_iter().enumerate() {
                assert_eq!(v, vec![r as f64; r + 1]);
            }
        }
    }

    #[test]
    fn allgather_concatenates_by_rank() {
        for p in 1..=5usize {
            let out = run_group(p, move |c| {
                allgather(c, 50, vec![c.rank() as f64 * 2.0])
            });
            for parts in out {
                assert_eq!(parts.len(), p);
                for (r, part) in parts.iter().enumerate() {
                    assert_eq!(part, &vec![r as f64 * 2.0]);
                }
            }
        }
    }
}
