//! Primitive binary encoding: little-endian, length-prefixed.
//!
//! `Writer` appends primitives to a `Vec<u8>`; `Reader` consumes them from
//! a slice. Bulk f64 payloads move via memcpy on little-endian targets
//! (the transfer hot path — the paper's whole overhead story is the cost
//! of moving rows between frameworks).

use thiserror::Error;

#[derive(Debug, Error)]
pub enum ProtocolError {
    #[error("unexpected end of message (wanted {wanted} bytes, {left} left)")]
    Truncated { wanted: usize, left: usize },
    #[error("bad tag {tag} for {what}")]
    BadTag { tag: u8, what: &'static str },
    #[error("invalid utf-8 string in message")]
    BadUtf8,
    #[error("trailing {0} bytes after message")]
    Trailing(usize),
    #[error("oversized field: {0} bytes")]
    Oversized(u64),
    #[error("payload of {got} bytes does not match header ({want} bytes)")]
    PayloadMismatch { want: usize, got: usize },
}

/// Copy little-endian f64 wire bytes into `dst` — a single memcpy on
/// little-endian targets, per-element conversion on big-endian ones. This
/// is the one copy the decode hot path performs: straight from the frame
/// receive buffer into the destination matrix block / row vector.
///
/// Panics if `src.len() != dst.len() * 8` (callers size both from the
/// frame header, which the decoder has already validated).
pub fn copy_le_f64s(src: &[u8], dst: &mut [f64]) {
    assert_eq!(src.len(), dst.len() * 8, "payload/destination length mismatch");
    #[cfg(target_endian = "little")]
    {
        // Safety: dst is a valid &mut [f64] of exactly src.len()/8
        // elements; u8 -> f64 byte copy of the full region.
        unsafe {
            std::ptr::copy_nonoverlapping(
                src.as_ptr(),
                dst.as_mut_ptr() as *mut u8,
                src.len(),
            );
        }
    }
    #[cfg(target_endian = "big")]
    for (d, chunk) in dst.iter_mut().zip(src.chunks_exact(8)) {
        *d = f64::from_le_bytes(chunk.try_into().unwrap());
    }
}

/// Decode little-endian f64 wire bytes into a fresh Vec (non-hot-path
/// convenience; the transfer path uses [`copy_le_f64s`] into preallocated
/// destinations instead).
pub fn le_f64s_to_vec(src: &[u8]) -> Vec<f64> {
    let mut out = vec![0f64; src.len() / 8];
    copy_le_f64s(&src[..out.len() * 8], &mut out);
    out
}

/// View an f64 slice as its little-endian wire bytes without copying.
/// Only exists on little-endian targets — big-endian encoders must
/// convert per element (see `Framed::send_data_ref` / `Writer::raw_f64s`).
#[cfg(target_endian = "little")]
pub fn f64s_as_le_bytes(xs: &[f64]) -> &[u8] {
    // Safety: f64 -> u8 reinterpretation is always valid; the length in
    // bytes cannot overflow because xs is in memory.
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 8) }
}

/// Appends primitives to an owned buffer.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        Writer { buf: Vec::with_capacity(n) }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }

    /// Bulk f64 payload: length (count) + raw little-endian bytes.
    pub fn f64s(&mut self, xs: &[f64]) {
        self.u64(xs.len() as u64);
        self.raw_f64s(xs);
    }

    /// Raw bytes without a length prefix (caller's framing implies the
    /// length — mirrors [`Reader::raw_bytes`]).
    pub fn raw_bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    /// Raw f64 bytes without a length prefix (caller encodes the count).
    pub fn raw_f64s(&mut self, xs: &[f64]) {
        #[cfg(target_endian = "little")]
        {
            // Safety: f64 -> u8 reinterpretation is always valid; length in
            // bytes cannot overflow because xs is in memory.
            let bytes = unsafe {
                std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 8)
            };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(target_endian = "big")]
        for x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Consumes primitives from a byte slice.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtocolError> {
        if self.remaining() < n {
            return Err(ProtocolError::Truncated { wanted: n, left: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, ProtocolError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, ProtocolError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, ProtocolError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> Result<i64, ProtocolError> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, ProtocolError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn bool(&mut self) -> Result<bool, ProtocolError> {
        Ok(self.u8()? != 0)
    }

    pub fn str(&mut self) -> Result<String, ProtocolError> {
        let n = self.u32()? as usize;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).map_err(|_| ProtocolError::BadUtf8)
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>, ProtocolError> {
        let n = self.u64()?;
        if n > (1 << 40) {
            return Err(ProtocolError::Oversized(n));
        }
        Ok(self.take(n as usize)?.to_vec())
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>, ProtocolError> {
        let n = self.u64()?;
        if n > (1 << 37) {
            return Err(ProtocolError::Oversized(n));
        }
        self.raw_f64s(n as usize)
    }

    /// Borrow `n` raw bytes out of the underlying buffer without copying
    /// (the zero-copy decode path: payload slices point into the frame
    /// receive buffer).
    pub fn raw_bytes(&mut self, n: usize) -> Result<&'a [u8], ProtocolError> {
        self.take(n)
    }

    /// Read `count` f64s without a length prefix.
    pub fn raw_f64s(&mut self, count: usize) -> Result<Vec<f64>, ProtocolError> {
        let src = self.take(count * 8)?;
        let mut out = vec![0f64; count];
        #[cfg(target_endian = "little")]
        {
            // Safety: writing count*8 bytes into a Vec<f64> of len count.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    src.as_ptr(),
                    out.as_mut_ptr() as *mut u8,
                    count * 8,
                );
            }
        }
        #[cfg(target_endian = "big")]
        for (i, chunk) in src.chunks_exact(8).enumerate() {
            out[i] = f64::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(out)
    }

    /// Error unless the whole buffer was consumed (message framing check).
    pub fn finish(self) -> Result<(), ProtocolError> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(ProtocolError::Trailing(self.remaining()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_roundtrip() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX);
        w.i64(-42);
        w.f64(std::f64::consts::PI);
        w.bool(true);
        w.str("héllo");
        w.bytes(&[1, 2, 3]);
        w.f64s(&[1.5, -2.5]);
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.i64().unwrap(), -42);
        assert_eq!(r.f64().unwrap(), std::f64::consts::PI);
        assert!(r.bool().unwrap());
        assert_eq!(r.str().unwrap(), "héllo");
        assert_eq!(r.bytes().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.f64s().unwrap(), vec![1.5, -2.5]);
        r.finish().unwrap();
    }

    #[test]
    fn truncation_detected() {
        let mut w = Writer::new();
        w.u64(5);
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf[..4]);
        assert!(matches!(r.u64(), Err(ProtocolError::Truncated { .. })));
    }

    #[test]
    fn trailing_detected() {
        let mut w = Writer::new();
        w.u8(1);
        w.u8(2);
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf);
        let _ = r.u8().unwrap();
        assert!(matches!(r.finish(), Err(ProtocolError::Trailing(1))));
    }

    #[test]
    fn le_byte_helpers_roundtrip() {
        let xs = vec![1.5f64, -2.25, 0.0, f64::MAX];
        // canonical little-endian bytes, built by hand
        let mut expect = Vec::new();
        for x in &xs {
            expect.extend_from_slice(&x.to_le_bytes());
        }
        #[cfg(target_endian = "little")]
        assert_eq!(f64s_as_le_bytes(&xs), &expect[..]);
        let mut back = vec![0f64; xs.len()];
        copy_le_f64s(&expect, &mut back);
        assert_eq!(back, xs);
        assert_eq!(le_f64s_to_vec(&expect), xs);
    }

    #[test]
    fn raw_bytes_borrows_without_copy() {
        let buf = [1u8, 2, 3, 4, 5];
        let mut r = Reader::new(&buf);
        let s = r.raw_bytes(3).unwrap();
        assert_eq!(s, &[1, 2, 3]);
        assert_eq!(s.as_ptr(), buf.as_ptr()); // same storage, no copy
        assert_eq!(r.remaining(), 2);
        assert!(r.raw_bytes(3).is_err());
    }

    #[test]
    fn f64_bulk_preserves_bits() {
        let xs: Vec<f64> = vec![0.0, -0.0, f64::MIN, f64::MAX, 1e-300, f64::INFINITY];
        let mut w = Writer::new();
        w.f64s(&xs);
        let buf = w.into_bytes();
        let got = Reader::new(&buf).f64s().unwrap();
        for (a, b) in xs.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
