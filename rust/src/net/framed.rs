//! Length-prefixed frame transport over any `Read + Write` pair.
//!
//! Frame = `u32` little-endian length + payload. The writer is buffered
//! (`Config.transfer.buf_bytes` sized) so row-batch frames coalesce into
//! large socket writes — this buffer is one of the transfer-path knobs the
//! ablation bench sweeps.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpStream;

use anyhow::Context;

use crate::protocol::{ControlMsg, DataMsg};

/// Maximum accepted frame (guards against corrupt length prefixes).
const MAX_FRAME: u32 = 1 << 30;

pub struct Framed<R: Read, W: Write> {
    r: BufReader<R>,
    w: BufWriter<W>,
}

impl Framed<TcpStream, TcpStream> {
    /// Wrap a TCP stream (clones the fd for the read half) with the given
    /// write-buffer size.
    pub fn tcp(stream: TcpStream, buf_bytes: usize) -> crate::Result<Self> {
        stream.set_nodelay(true).context("set_nodelay")?;
        let rd = stream.try_clone().context("clone tcp stream")?;
        Ok(Framed {
            r: BufReader::with_capacity(buf_bytes.max(8 << 10), rd),
            w: BufWriter::with_capacity(buf_bytes.max(8 << 10), stream),
        })
    }

    /// Connect to `addr` and wrap.
    pub fn connect(addr: &str, buf_bytes: usize) -> crate::Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to {addr}"))?;
        Self::tcp(stream, buf_bytes)
    }
}

impl<R: Read, W: Write> Framed<R, W> {
    /// Wrap an arbitrary read/write pair (tests use in-memory pipes).
    pub fn new(r: R, w: W) -> Self {
        Framed {
            r: BufReader::new(r),
            w: BufWriter::new(w),
        }
    }

    /// Queue one frame (stays in the write buffer until [`flush`] or the
    /// buffer fills).
    pub fn send(&mut self, payload: &[u8]) -> crate::Result<()> {
        let len = u32::try_from(payload.len()).context("frame too large")?;
        anyhow::ensure!(len <= MAX_FRAME, "frame of {len} bytes exceeds cap");
        self.w.write_all(&len.to_le_bytes())?;
        self.w.write_all(payload)?;
        Ok(())
    }

    pub fn flush(&mut self) -> crate::Result<()> {
        self.w.flush()?;
        Ok(())
    }

    /// Queue and flush.
    pub fn send_flush(&mut self, payload: &[u8]) -> crate::Result<()> {
        self.send(payload)?;
        self.flush()
    }

    /// Block until one frame arrives.
    pub fn recv(&mut self) -> crate::Result<Vec<u8>> {
        let mut len_buf = [0u8; 4];
        self.r.read_exact(&mut len_buf).context("reading frame length")?;
        let len = u32::from_le_bytes(len_buf);
        anyhow::ensure!(len <= MAX_FRAME, "incoming frame of {len} bytes exceeds cap");
        let mut payload = vec![0u8; len as usize];
        self.r.read_exact(&mut payload).context("reading frame payload")?;
        Ok(payload)
    }

    // -- typed convenience wrappers --

    pub fn send_ctrl(&mut self, msg: &ControlMsg) -> crate::Result<()> {
        self.send_flush(&msg.encode())
    }

    pub fn recv_ctrl(&mut self) -> crate::Result<ControlMsg> {
        Ok(ControlMsg::decode(&self.recv()?)?)
    }

    /// Control request/response in one call; unwraps server-side `Error`
    /// replies into `Err`.
    pub fn call(&mut self, msg: &ControlMsg) -> crate::Result<ControlMsg> {
        self.send_ctrl(msg)?;
        match self.recv_ctrl()? {
            ControlMsg::Error { message } => anyhow::bail!("server error: {message}"),
            reply => Ok(reply),
        }
    }

    /// Queue a data message WITHOUT flushing (row streams batch many).
    pub fn send_data(&mut self, msg: &DataMsg) -> crate::Result<()> {
        self.send(&msg.encode())
    }

    pub fn send_data_flush(&mut self, msg: &DataMsg) -> crate::Result<()> {
        self.send_data(msg)?;
        self.flush()
    }

    pub fn recv_data(&mut self) -> crate::Result<DataMsg> {
        Ok(DataMsg::decode(&self.recv()?)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn frames_roundtrip_over_tcp() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut f = Framed::tcp(s, 1 << 16).unwrap();
            loop {
                match f.recv_ctrl().unwrap() {
                    ControlMsg::Shutdown => {
                        f.send_ctrl(&ControlMsg::Bye).unwrap();
                        break;
                    }
                    ControlMsg::Handshake { client_name, version, .. } => {
                        assert_eq!(client_name, "t");
                        f.send_ctrl(&ControlMsg::HandshakeAck {
                            session_id: 1,
                            version,
                            granted_workers: 0,
                            worker_addrs: vec![],
                        })
                        .unwrap();
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
        });

        let mut c = Framed::connect(&addr.to_string(), 1 << 16).unwrap();
        let reply = c
            .call(&ControlMsg::Handshake {
                client_name: "t".into(),
                version: 1,
                request_workers: 0,
            })
            .unwrap();
        assert!(matches!(reply, ControlMsg::HandshakeAck { session_id: 1, .. }));
        let bye = c.call(&ControlMsg::Shutdown).unwrap();
        assert_eq!(bye, ControlMsg::Bye);
        server.join().unwrap();
    }

    #[test]
    fn error_reply_becomes_err() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut f = Framed::tcp(s, 4096).unwrap();
            let _ = f.recv_ctrl().unwrap();
            f.send_ctrl(&ControlMsg::Error { message: "nope".into() }).unwrap();
        });
        let mut c = Framed::connect(&addr.to_string(), 4096).unwrap();
        let err = c.call(&ControlMsg::ListMatrices).unwrap_err();
        assert!(err.to_string().contains("nope"));
        server.join().unwrap();
    }

    #[test]
    fn large_data_frame() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let n = 100_000;
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut f = Framed::tcp(s, 1 << 20).unwrap();
            match f.recv_data().unwrap() {
                DataMsg::PushRows { nrows, ncols, data, .. } => {
                    assert_eq!(nrows as usize * ncols as usize, data.len());
                    assert_eq!(data.len(), n);
                    assert_eq!(data[n - 1], (n - 1) as f64);
                }
                other => panic!("unexpected {other:?}"),
            }
        });
        let mut c = Framed::connect(&addr.to_string(), 1 << 20).unwrap();
        let data: Vec<f64> = (0..n).map(|i| i as f64).collect();
        c.send_data_flush(&DataMsg::PushRows {
            matrix_id: 1,
            start_row: 0,
            nrows: (n / 10) as u32,
            ncols: 10,
            data,
        })
        .unwrap();
        server.join().unwrap();
    }
}
