//! Library registry + the SPMD library interface — the Alchemist-Library
//! Interface of paper §3.1.3.
//!
//! The paper loads ALIs as shared objects with `dlopen`; here the same
//! registration API (`registerLibrary(name, path)`) resolves `builtin:`
//! paths to compiled-in libraries (DESIGN.md §2 records the substitution —
//! the *interface* is what the system contribution is, not the linker
//! mechanics).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::collectives::Communicator;
use crate::compute::Engine;
use crate::config::Config;
use crate::distmat::{LocalMatrix, RowBlockLayout};
use crate::protocol::Params;
use crate::tasks::TaskScope;

use super::store::MatrixStore;

/// Everything a routine sees on one worker rank.
pub struct WorkerCtx<'a> {
    pub rank: usize,
    pub comm: &'a dyn Communicator,
    pub engine: &'a mut dyn Engine,
    /// The store locks internally (short read lock per lookup; see
    /// `coordinator::store` for the concurrency model).
    pub store: &'a MatrixStore,
    pub config: &'a Config,
    /// This rank's view of the running task: cooperative cancel token +
    /// progress slot (see `docs/tasks.md` for the cancellation contract —
    /// SPMD routines must decide cancellation collectively).
    pub scope: &'a TaskScope,
}

impl WorkerCtx<'_> {
    /// Fetch this rank's sealed block of matrix `id` (cloned out of the
    /// store so routines never hold any lock during compute).
    pub fn local_block(&self, id: u64) -> crate::Result<(RowBlockLayout, LocalMatrix)> {
        self.store.get(id)?.snapshot()
    }

    /// This rank's block handle for matrix `id` — the streaming
    /// alternative to [`local_block`](Self::local_block): out-of-core
    /// routines read row panels through `Block::read_span` without ever
    /// materializing the whole payload on the heap (mapped blocks serve
    /// straight from the page cache, spilled ones stream off disk).
    pub fn block(&self, id: u64) -> crate::Result<Arc<super::store::Block>> {
        self.store.get(id)
    }
}

/// One output matrix of a routine: this rank's block plus the layout
/// every rank agrees on.
pub struct OutputMatrix {
    pub name: String,
    pub layout: RowBlockLayout,
    pub local: LocalMatrix,
}

/// What a routine returns on each rank. Output order must be identical on
/// every rank (ids are assigned as `out_base + position`).
#[derive(Default)]
pub struct TaskOutput {
    pub matrices: Vec<OutputMatrix>,
    /// Scalar results; rank 0's values are reported to the client.
    pub scalars: Params,
    /// Named timing laps (rank-local; the driver aggregates).
    pub timings: Vec<(String, f64)>,
}

/// An MPI-style library: `run` executes SPMD on every worker rank.
pub trait Library: Send + Sync {
    fn name(&self) -> &'static str;
    /// Routine names this library exposes (for error messages / listing).
    fn routines(&self) -> Vec<&'static str>;
    fn run(
        &self,
        routine: &str,
        params: &Params,
        ctx: &mut WorkerCtx,
    ) -> crate::Result<TaskOutput>;
}

/// Resolve a compiled-in library by its canonical name. Worker
/// *processes* (protocol v8) use this: the coordinator's [`Registry`]
/// maps client-chosen names to libraries, but only the library's own
/// [`Library::name`] crosses the wire — a worker process rebuilds the
/// instance from that canonical name, never from the client alias.
pub fn builtin(name: &str) -> crate::Result<Arc<dyn Library>> {
    Ok(match name {
        "skylark" => Arc::new(super::libs::skylark::Skylark),
        "elemental" => Arc::new(super::libs::elemental::Elemental),
        other => anyhow::bail!("unknown builtin library {other:?}"),
    })
}

/// name → library map shared by driver and workers.
#[derive(Default)]
pub struct Registry {
    libs: Mutex<HashMap<String, Arc<dyn Library>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resolve `path` and register under `name`. Supported paths:
    /// `builtin:skylark`, `builtin:elemental`.
    pub fn register(&self, name: &str, path: &str) -> crate::Result<()> {
        let lib: Arc<dyn Library> = match path {
            "builtin:skylark" => Arc::new(super::libs::skylark::Skylark),
            "builtin:elemental" => Arc::new(super::libs::elemental::Elemental),
            other => anyhow::bail!(
                "cannot load library {name:?} from {other:?}: this build \
                 resolves `builtin:` libraries only (see DESIGN.md §2, \
                 dynamic-.so substitution)"
            ),
        };
        self.libs.lock().unwrap().insert(name.to_string(), lib);
        Ok(())
    }

    pub fn get(&self, name: &str) -> crate::Result<Arc<dyn Library>> {
        self.libs
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| {
                anyhow::anyhow!("library {name:?} is not registered (call registerLibrary first)")
            })
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> =
            self.libs.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registration_and_lookup() {
        let r = Registry::new();
        assert!(r.get("skylark").is_err());
        r.register("skylark", "builtin:skylark").unwrap();
        r.register("elemental", "builtin:elemental").unwrap();
        let lib = r.get("skylark").unwrap();
        assert_eq!(lib.name(), "skylark");
        assert!(lib.routines().contains(&"cg_solve"));
        assert_eq!(r.names(), vec!["elemental", "skylark"]);
    }

    #[test]
    fn builtin_resolves_canonical_names_only() {
        assert_eq!(builtin("skylark").unwrap().name(), "skylark");
        assert_eq!(builtin("elemental").unwrap().name(), "elemental");
        assert!(builtin("my-alias").is_err());
    }

    #[test]
    fn non_builtin_path_rejected() {
        let r = Registry::new();
        let err = r.register("x", "/usr/lib/libfoo.so").unwrap_err();
        assert!(err.to_string().contains("builtin"), "{err}");
    }
}
