//! 1-D row-block layout: who owns which global rows.

use crate::util::even_ranges;

/// Row-block distribution of an `rows × cols` matrix over `ranges.len()`
/// workers; `ranges[r] = [start, end)` in global row indices, contiguous
/// and covering `0..rows` exactly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RowBlockLayout {
    pub rows: usize,
    pub cols: usize,
    pub ranges: Vec<(usize, usize)>,
}

impl RowBlockLayout {
    /// Even split (first `rows % workers` ranges get one extra row).
    pub fn even(rows: usize, cols: usize, workers: usize) -> Self {
        RowBlockLayout { rows, cols, ranges: even_ranges(rows, workers) }
    }

    pub fn workers(&self) -> usize {
        self.ranges.len()
    }

    /// Which worker owns global row `i`.
    pub fn owner_of(&self, i: usize) -> usize {
        debug_assert!(i < self.rows);
        // ranges are sorted and contiguous: binary search on start
        match self.ranges.binary_search_by(|&(a, b)| {
            if i < a {
                std::cmp::Ordering::Greater
            } else if i >= b {
                std::cmp::Ordering::Less
            } else {
                std::cmp::Ordering::Equal
            }
        }) {
            Ok(r) => r,
            Err(_) => unreachable!("row {i} not covered by layout"),
        }
    }

    /// Number of local rows at `rank`.
    pub fn local_rows(&self, rank: usize) -> usize {
        let (a, b) = self.ranges[rank];
        b - a
    }

    /// Validate invariants (contiguous cover of `0..rows`); used by
    /// property tests and on deserialized layouts from the wire.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(!self.ranges.is_empty(), "empty layout");
        anyhow::ensure!(self.ranges[0].0 == 0, "layout must start at row 0");
        for w in self.ranges.windows(2) {
            anyhow::ensure!(
                w[0].1 == w[1].0,
                "layout ranges must be contiguous: {:?} then {:?}",
                w[0],
                w[1]
            );
        }
        anyhow::ensure!(
            self.ranges.last().unwrap().1 == self.rows,
            "layout must end at row count"
        );
        Ok(())
    }

    /// Wire form used in `MatrixCreated`/`FetchReady` messages.
    pub fn to_wire(&self) -> Vec<(u64, u64)> {
        self.ranges.iter().map(|&(a, b)| (a as u64, b as u64)).collect()
    }

    pub fn from_wire(rows: u64, cols: u64, ranges: &[(u64, u64)]) -> crate::Result<Self> {
        let layout = RowBlockLayout {
            rows: rows as usize,
            cols: cols as usize,
            ranges: ranges.iter().map(|&(a, b)| (a as usize, b as usize)).collect(),
        };
        layout.validate()?;
        Ok(layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_layout_validates_and_owns() {
        for rows in [1usize, 7, 100] {
            for w in [1usize, 2, 3, 8] {
                let l = RowBlockLayout::even(rows, 4, w);
                l.validate().unwrap();
                for i in 0..rows {
                    let r = l.owner_of(i);
                    let (a, b) = l.ranges[r];
                    assert!(a <= i && i < b);
                }
            }
        }
    }

    #[test]
    fn wire_roundtrip() {
        let l = RowBlockLayout::even(17, 3, 4);
        let back =
            RowBlockLayout::from_wire(17, 3, &l.to_wire()).unwrap();
        assert_eq!(l, back);
    }

    #[test]
    fn invalid_layouts_rejected() {
        let l = RowBlockLayout { rows: 4, cols: 1, ranges: vec![(0, 2), (3, 4)] };
        assert!(l.validate().is_err());
        let l2 = RowBlockLayout { rows: 4, cols: 1, ranges: vec![(1, 4)] };
        assert!(l2.validate().is_err());
        let l3 = RowBlockLayout { rows: 4, cols: 1, ranges: vec![(0, 3)] };
        assert!(l3.validate().is_err());
    }
}
