//! Out-of-core ocean SVD: the paper's "datasets larger than memory"
//! claim, scaled to this box. The ocean field is written to disk, each
//! worker maps its shard directly (`LoadMatrix`, zero client bytes),
//! and the rank-k SVD streams row panels while the per-rank heap budget
//! is pinned far below the dataset — the left factor cycles through the
//! spill file and back.
//!
//! ```sh
//! cargo run --release --example ocean_svd_outofcore -- \
//!     [--cells 65536] [--times 1024] [--rank 20] [--workers 3] \
//!     [--budget-mb 2] [--panel-rows 2048]
//! ```

use alchemist::cli::Args;
use alchemist::linalg::SvdOptions;
use alchemist::util::fmt;
use alchemist::workloads::{ocean_svd_outofcore, OceanSpec};

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let cells = args.get_usize("cells", 65_536)?;
    let times = args.get_usize("times", 1_024)?;
    let rank = args.get_usize("rank", 20)?;
    let steps = args.get_usize("steps", 48)?;
    let workers = args.get_usize("workers", 3)?;
    let budget_mb = args.get_usize("budget-mb", 2)?;
    let panel_rows = args.get_usize("panel-rows", 2_048)?;

    let spec = OceanSpec { cells, times, ..OceanSpec::default() };
    let budget = (budget_mb as u64) << 20;
    anyhow::ensure!(
        spec.bytes() >= 4 * budget,
        "dataset ({}) must be at least 4x the budget ({}) for an \
         out-of-core run; lower --budget-mb or raise --cells",
        fmt::bytes(spec.bytes()),
        fmt::bytes(budget)
    );
    // the mapped dataset is budget-exempt; what cycles through the spill
    // file is the N×k left factor, so the budget must sit below its
    // per-rank share or the run has nothing to prove
    let u_per_rank = ((cells / workers) * rank * 8) as u64;
    anyhow::ensure!(
        budget < u_per_rank,
        "budget ({}) must be below U's per-rank share ({}) so the left \
         factor spills; lower --budget-mb or raise --cells/--rank",
        fmt::bytes(budget),
        fmt::bytes(u_per_rank)
    );

    let dir = std::env::temp_dir().join("alchemist-ocean");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("ocean_{cells}x{times}.bin"));
    if !path.exists() {
        println!("generating synthetic ocean field {cells} x {times} ...");
        let bytes = spec.write_file(&path)?;
        println!("wrote {} to {path:?}", fmt::bytes(bytes));
    }

    let opts = SvdOptions { rank, steps, seed: 0x53D5 };
    println!(
        "\n== out-of-core rank-{rank} SVD: {} dataset, {} per-rank budget, \
         {workers} workers, {panel_rows}-row panels ==",
        fmt::bytes(spec.bytes()),
        fmt::bytes(budget)
    );
    let rep = ocean_svd_outofcore(&spec, &path, budget, workers, &opts, panel_rows)?;

    anyhow::ensure!(
        rep.client_bytes_loaded == 0,
        "direct ingest leaked {} payload bytes over the client link",
        rep.client_bytes_loaded
    );
    anyhow::ensure!(
        rep.storage.cycled(),
        "expected blocks to cycle through the spill file: {:?}",
        rep.storage
    );

    println!("load (direct, mapped): {:.2}s, 0 client payload bytes", rep.load_secs);
    println!("svd  ({} x {} panels): {:.2}s", panel_rows, times, rep.svd_secs);
    println!(
        "spill: {} out, {} paged in, {} streamed from disk ({} spill writes)",
        fmt::bytes(rep.storage.bytes_spilled),
        fmt::bytes(rep.storage.bytes_paged_in),
        fmt::bytes(rep.storage.bytes_read_spilled),
        rep.storage.blocks_spilled
    );
    println!("U pulled back: {} rows x {rank}", rep.u_rows);
    let show = rep.sigma.iter().take(6).map(|s| format!("{s:.2}")).collect::<Vec<_>>();
    println!("sigma[0..6] = [{}]", show.join(", "));
    println!(
        "(dataset is {:.1}x the per-rank budget; the SVD never held it in heap)",
        rep.dataset_bytes as f64 / rep.budget_bytes as f64
    );
    Ok(())
}
