//! Ocean-temperature truncated SVD (paper §4.2, Table 5): the three use
//! cases, scaled to this box.
//!
//! 1. Spark loads the file and computes the rank-k SVD (sparklite).
//! 2. Spark loads the file, ships it to Alchemist, Alchemist computes.
//! 3. Alchemist loads the file directly and computes; results ship back.
//!
//! ```sh
//! cargo run --release --example ocean_svd -- \
//!     [--cells 8192] [--times 1024] [--rank 20] [--workers 3] [--engine xla]
//! ```

use alchemist::cli::Args;
use alchemist::client::AlchemistContext;
use alchemist::config::Config;
use alchemist::coordinator::AlchemistServer;
use alchemist::linalg::SvdOptions;
use alchemist::metrics::Table;
use alchemist::protocol::{Params, Value};
use alchemist::sparklite::{mllib, IndexedRowMatrix, SparkEngine};
use alchemist::util::fmt;
use alchemist::workloads::OceanSpec;

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let mut cfg = Config::default();
    if let Some(engine) = args.get("engine") {
        cfg.apply("engine", engine)?;
    }
    let cells = args.get_usize("cells", 8_192)?;
    let times = args.get_usize("times", 1_024)?;
    let rank = args.get_usize("rank", 20)?;
    let steps = args.get_usize("steps", 48)?;
    let workers = args.get_usize("workers", 3)?;

    let spec = OceanSpec { cells, times, ..OceanSpec::default() };
    let dir = std::env::temp_dir().join("alchemist-ocean");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("ocean_{cells}x{times}.bin"));
    if !path.exists() {
        println!("generating synthetic ocean field {cells} x {times} ...");
        let bytes = spec.write_file(&path)?;
        println!("wrote {} to {path:?}", fmt::bytes(bytes));
    }
    let opts = SvdOptions { rank, steps, seed: 0x53D5 };

    let mut table = Table::new(
        "ocean_svd: Table 5 use cases (rank-{k} truncated SVD)",
        &[
            "case", "S nodes", "A nodes", "load (s)", "S=>A (s)", "svd (s)",
            "S<=A (s)", "total (s)", "sim svd (s)", "sigma[0]",
        ],
    );

    // ---------- use case 1: Spark load + Spark SVD ----------
    {
        println!("\n== case 1: sparklite load + sparklite SVD ==");
        let mut engine = SparkEngine::new(workers, &cfg);
        let t0 = std::time::Instant::now();
        // Spark reads the file through one stage over row-range partitions
        let ranges = alchemist::util::even_ranges(cells, workers * 2);
        let parts = engine.run_stage("load", &ranges, |_, &(a, b)| {
            let m = alchemist::hdf5sim::read_rows(&path, a, b).unwrap();
            (a, m)
        });
        let load_secs = t0.elapsed().as_secs_f64();
        let mut rows = Vec::new();
        for (start, m) in parts {
            for i in 0..m.rows() {
                rows.push(alchemist::sparklite::IndexedRow {
                    index: (start + i) as u64,
                    vector: m.row(i).to_vec(),
                });
            }
        }
        let irm = IndexedRowMatrix {
            rdd: alchemist::sparklite::Rdd::parallelize(rows, workers * 2),
            rows: cells,
            cols: times,
        };
        let sim0 = engine.sim_elapsed_secs();
        let t1 = std::time::Instant::now();
        let res = mllib::truncated_svd(&mut engine, &irm, &opts)?;
        let svd_secs = t1.elapsed().as_secs_f64();
        let sim_svd = engine.sim_elapsed_secs() - sim0;
        table.row(&[
            "1: S load, S svd".into(),
            workers.to_string(),
            "0".into(),
            format!("{load_secs:.2}"),
            "n/a".into(),
            format!("{svd_secs:.2}"),
            "n/a".into(),
            format!("{svd_secs:.2}"),
            format!("{sim_svd:.2}"),
            format!("{:.2}", res.sigma[0]),
        ]);
    }

    // ---------- use cases 2 and 3 need a server ----------
    let server = AlchemistServer::start(cfg.clone(), workers)?;

    // ---------- use case 2: Spark load + transfer + Alchemist SVD ----------
    {
        println!("\n== case 2: sparklite load, transfer, alchemist SVD ==");
        let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, workers)?;
        ac.register_library("elemental", "builtin:elemental")?;
        let t0 = std::time::Instant::now();
        let a = alchemist::hdf5sim::read_matrix(&path)?;
        let irm = IndexedRowMatrix::from_local(&a, workers * 2);
        let load_secs = t0.elapsed().as_secs_f64();

        let (al_a, push) = ac.send_matrix("A", &irm)?;
        let res = ac.run_task(
            "elemental",
            "truncated_svd",
            Params::new()
                .with_matrix("A", al_a.id)
                .with_i64("rank", rank as i64)
                .with_i64("steps", steps as i64),
        )?;
        let svd_secs = res.timing("compute");
        let sim_svd = res.timing("sim_secs");
        let (pull_u, su) = ac.to_indexed_row_matrix(res.output("U")?, workers)?;
        let (_, sv) = ac.to_indexed_row_matrix(res.output("V")?, 1)?;
        let back_secs = su.secs + sv.secs;
        let sigma0 = first_sigma(&res.scalars);
        let total = push.secs + svd_secs + back_secs;
        let _ = pull_u;
        table.row(&[
            "2: S load, A svd".into(),
            workers.to_string(),
            workers.to_string(),
            format!("{load_secs:.2}"),
            format!("{:.2}", push.secs),
            format!("{svd_secs:.2}"),
            format!("{back_secs:.2}"),
            format!("{total:.2}"),
            format!("{sim_svd:.2}"),
            format!("{sigma0:.2}"),
        ]);
        ac.stop();
    }

    // ---------- use case 3: Alchemist load + SVD, results to client ----------
    {
        println!("\n== case 3: alchemist load + SVD, results back to client ==");
        let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 2)?;
        ac.register_library("elemental", "builtin:elemental")?;
        let load = ac.run_task(
            "elemental",
            "load_hdf5",
            Params::new().with_str("path", path.to_str().unwrap()),
        )?;
        let load_secs = load.timing("load");
        let al_a = load.output("A")?.clone();
        let res = ac.run_task(
            "elemental",
            "truncated_svd",
            Params::new()
                .with_matrix("A", al_a.id)
                .with_i64("rank", rank as i64)
                .with_i64("steps", steps as i64),
        )?;
        let svd_secs = res.timing("compute");
        let sim_svd = res.timing("sim_secs");
        let (_, su) = ac.to_indexed_row_matrix(res.output("U")?, 2)?;
        let (_, sv) = ac.to_indexed_row_matrix(res.output("V")?, 1)?;
        let back_secs = su.secs + sv.secs;
        let sigma0 = first_sigma(&res.scalars);
        let total = svd_secs + back_secs;
        table.row(&[
            "3: A load, A svd".into(),
            "2".into(),
            workers.to_string(),
            format!("{load_secs:.2}"),
            "n/a".into(),
            format!("{svd_secs:.2}"),
            format!("{back_secs:.2}"),
            format!("{total:.2}"),
            format!("{sim_svd:.2}"),
            format!("{sigma0:.2}"),
        ]);
        ac.shutdown_server()?;
    }
    server.shutdown_on_request();

    println!();
    table.print();
    println!(
        "(paper Table 5 shape: case 3 < case 2 < case 1 total; σ₀ identical across \
         cases because both sides run the same Gram-Lanczos mathematics)"
    );
    Ok(())
}

fn first_sigma(scalars: &Params) -> f64 {
    match scalars.get("sigma") {
        Some(Value::F64s(v)) if !v.is_empty() => v[0],
        _ => f64::NAN,
    }
}
