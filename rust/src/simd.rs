//! Runtime ISA selection for the native compute kernels.
//!
//! The portable build compiles the packed GEMM micro-kernel (and the
//! `blas1` hot loops) at whatever baseline `-C target-cpu` the toolchain
//! was given — SSE2 on a stock x86-64 build. This module detects the
//! host's actual vector extensions once at startup
//! (`is_x86_feature_detected!`) and the kernels keep per-ISA variants
//! behind function pointers, so one portable binary hits AVX2-width code
//! on capable hosts without a fixed `-C target-cpu` flag (see
//! `docs/compute.md`, "Dispatch").
//!
//! Contract: every ISA variant of a kernel performs **the same arithmetic
//! in the same order** as the portable fallback — wider registers, not
//! reassociated (and never contracted into FMA: fusing would change
//! rounding). Results are therefore bit-identical across ISA paths, which
//! keeps the SPMD determinism story independent of which host a rank
//! landed on; `it_compute.rs` pins this.
//!
//! Overrides:
//!
//! * `ALCHEMIST_ISA=fallback|avx2|avx512` — process-wide cap, read once
//!   (CI uses it to emit per-path bench cells; operators can force the
//!   portable path when chasing a suspected codegen issue). Requests the
//!   host cannot satisfy degrade to the best available path with a
//!   warning.
//! * [`with_isa`] — a scoped, per-thread override for tests and benches.
//!   The compute kernels resolve [`current`] on the *calling* thread and
//!   carry the choice into their pool jobs, so the override applies to
//!   pooled work too.
//!
//! Non-x86 targets compile the fallback only; no `unsafe` is reachable.

use std::cell::Cell;
use std::sync::OnceLock;

/// An instruction-set path the compute kernels can take.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// The portable kernel (auto-vectorized at the build's baseline ISA).
    Fallback,
    /// 256-bit AVX2 variants (runtime-detected `avx2` + `fma`).
    Avx2,
    /// 512-bit AVX-512F variants. Compiled only with the off-by-default
    /// `avx512` cargo feature; without it the name still parses for
    /// reporting but the path is never selected.
    Avx512,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Fallback => "fallback",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
        }
    }

    fn parse(s: &str) -> Option<Isa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "fallback" | "scalar" | "portable" => Some(Isa::Fallback),
            "avx2" => Some(Isa::Avx2),
            "avx512" | "avx512f" => Some(Isa::Avx512),
            _ => None,
        }
    }

    /// Ranking used to clamp requests to host capability.
    fn level(self) -> u8 {
        match self {
            Isa::Fallback => 0,
            Isa::Avx2 => 1,
            Isa::Avx512 => 2,
        }
    }
}

/// The widest path this host (and build) can actually execute.
pub fn detected() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        #[cfg(feature = "avx512")]
        if std::arch::is_x86_feature_detected!("avx512f") {
            return Isa::Avx512;
        }
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return Isa::Avx2;
        }
    }
    Isa::Fallback
}

/// Every path runnable on this host, fallback first (tests and benches
/// iterate this to compare paths).
pub fn available() -> Vec<Isa> {
    let mut out = vec![Isa::Fallback];
    let best = detected();
    if best.level() >= Isa::Avx2.level() {
        out.push(Isa::Avx2);
    }
    if best.level() >= Isa::Avx512.level() {
        out.push(Isa::Avx512);
    }
    out
}

/// The process-wide selection: hardware detection capped by
/// `ALCHEMIST_ISA`, resolved once and cached.
pub fn selected() -> Isa {
    static SELECTED: OnceLock<Isa> = OnceLock::new();
    *SELECTED.get_or_init(|| {
        let hw = detected();
        let pick = match std::env::var("ALCHEMIST_ISA") {
            Ok(req) => match Isa::parse(&req) {
                Some(want) if want.level() <= hw.level() => want,
                Some(want) => {
                    log::warn!(
                        "ALCHEMIST_ISA={} not runnable here; using {}",
                        want.name(),
                        hw.name()
                    );
                    hw
                }
                None => {
                    log::warn!("unrecognized ALCHEMIST_ISA={req:?}; using {}", hw.name());
                    hw
                }
            },
            Err(_) => hw,
        };
        log::info!("compute ISA path: {} (host supports {})", pick.name(), hw.name());
        pick
    })
}

thread_local! {
    static FORCED: Cell<Option<Isa>> = const { Cell::new(None) };
}

/// The ISA the *calling thread* should use right now: the innermost
/// [`with_isa`] override, else the process-wide [`selected`] path.
pub fn current() -> Isa {
    FORCED.with(|c| c.get()).unwrap_or_else(selected)
}

/// Run `f` with this thread's kernel ISA forced to `isa` (clamped to what
/// the host can run). Restores the previous override on exit, panics
/// included. Kernels resolve the path on the calling thread and carry it
/// into their pool jobs, so `f`'s compute is covered end to end.
pub fn with_isa<T>(isa: Isa, f: impl FnOnce() -> T) -> T {
    let clamped = if isa.level() <= detected().level() { isa } else { detected() };
    struct Restore(Option<Isa>);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0;
            FORCED.with(|c| c.set(prev));
        }
    }
    let _restore = Restore(FORCED.with(|c| c.replace(Some(clamped))));
    f()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fallback_is_always_available() {
        let avail = available();
        assert_eq!(avail[0], Isa::Fallback);
        // and the cached selection is one of the runnable paths
        assert!(avail.contains(&selected()));
        assert!(avail.contains(&current()));
    }

    #[test]
    fn with_isa_scopes_and_restores() {
        let outer = current();
        with_isa(Isa::Fallback, || {
            assert_eq!(current(), Isa::Fallback);
            with_isa(detected(), || assert_eq!(current(), detected()));
            assert_eq!(current(), Isa::Fallback);
        });
        assert_eq!(current(), outer);
    }

    #[test]
    fn requests_clamp_to_host_capability() {
        // forcing a wider path than the host supports must degrade, not
        // select an unrunnable kernel
        with_isa(Isa::Avx512, || {
            assert!(current().level() <= detected().level());
        });
    }

    #[test]
    fn names_roundtrip() {
        for isa in [Isa::Fallback, Isa::Avx2, Isa::Avx512] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("gpu"), None);
    }
}
