//! Integration: the v3 zero-copy, pipelined data plane — streaming ranged
//! pulls (`RowsData`* + `PullDone`), per-session transfer negotiation,
//! concurrent multi-executor ingest into one worker, pull/push overlap on
//! a single worker (per-block locking), and the steady-state
//! no-per-frame-allocation invariant.

use std::sync::{Arc, Barrier};

use alchemist::client::AlchemistContext;
use alchemist::config::{Config, EngineKind};
use alchemist::coordinator::AlchemistServer;
use alchemist::distmat::LocalMatrix;
use alchemist::net::Framed;
use alchemist::protocol::{ControlMsg, DataMsg, Writer, PROTOCOL_VERSION};
use alchemist::sparklite::IndexedRowMatrix;
use alchemist::util::prng::Rng;

fn native_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.engine = EngineKind::Native;
    cfg
}

fn random_matrix(seed: u64, rows: usize, cols: usize) -> LocalMatrix {
    let mut rng = Rng::new(seed);
    LocalMatrix::from_fn(rows, cols, |_, _| rng.normal())
}

/// Raw control handshake; returns (control link, session id, worker addrs).
fn raw_session(
    control_addr: &str,
    request_workers: u32,
) -> (Framed<std::net::TcpStream, std::net::TcpStream>, u64, Vec<String>) {
    let mut control = Framed::connect(control_addr, 1 << 16).unwrap();
    let ack = control
        .call(&ControlMsg::Handshake {
            client_name: "it-transfer".into(),
            version: PROTOCOL_VERSION,
            request_workers,
            rows_per_frame: 0,
            buf_bytes: 0,
            priority: alchemist::protocol::DEFAULT_PRIORITY,
        })
        .unwrap();
    match ack {
        ControlMsg::HandshakeAck { session_id, worker_addrs, .. } => {
            (control, session_id, worker_addrs)
        }
        other => panic!("bad handshake reply: {other:?}"),
    }
}

fn create_matrix(
    control: &mut Framed<std::net::TcpStream, std::net::TcpStream>,
    name: &str,
    rows: u64,
    cols: u64,
) -> u64 {
    match control
        .call(&ControlMsg::CreateMatrix { name: name.into(), rows, cols })
        .unwrap()
    {
        ControlMsg::MatrixCreated { id, .. } => id,
        other => panic!("bad create reply: {other:?}"),
    }
}

fn data_conn(
    addr: &str,
    session_id: u64,
    executor_id: u32,
    rows_per_frame: u32,
) -> Framed<std::net::TcpStream, std::net::TcpStream> {
    let mut data = Framed::connect(addr, 1 << 16).unwrap();
    data.send_data_flush(&DataMsg::DataHandshake {
        session_id,
        executor_id,
        rows_per_frame,
    })
    .unwrap();
    match data.recv_data().unwrap() {
        DataMsg::DataHandshakeAck { .. } => data,
        other => panic!("bad data handshake reply: {other:?}"),
    }
}

/// Drain one ranged pull stream; returns (frames, rows) and checks values
/// (`value == global row index` convention) and frame metadata.
fn drain_pull_stream(
    data: &mut Framed<std::net::TcpStream, std::net::TcpStream>,
    matrix_id: u64,
    start: u64,
    nrows: u64,
    ncols: usize,
    check_values: bool,
) -> (usize, u64) {
    data.send_data_flush(&DataMsg::PullRows {
        matrix_id,
        start_row: start,
        nrows: nrows as u32,
        start_col: 0,
        sel_cols: 0,
    })
    .unwrap();
    let mut frames = 0usize;
    let mut got = 0u64;
    loop {
        match data.recv_data().unwrap() {
            DataMsg::RowsData { matrix_id: mid, start_row, nrows: n, ncols: nc, data: d } => {
                assert_eq!(mid, matrix_id);
                assert_eq!(nc as usize, ncols, "ncols must come from the layout");
                assert_eq!(start_row, start + got, "stream must be in order");
                assert_eq!(d.len(), n as usize * ncols);
                if check_values {
                    for (k, row) in d.chunks_exact(ncols).enumerate() {
                        let want = (start_row + k as u64) as f64;
                        assert!(row.iter().all(|&v| v == want), "row {} corrupted", start_row + k as u64);
                    }
                }
                frames += 1;
                got += n as u64;
            }
            DataMsg::PullDone { matrix_id: mid } => {
                assert_eq!(mid, matrix_id);
                break;
            }
            other => panic!("bad pull reply: {other:?}"),
        }
    }
    assert_eq!(got, nrows, "stream must cover the requested range");
    (frames, got)
}

#[test]
fn streaming_pull_roundtrip_small_frames() {
    // tiny frames + stripes force the full streaming machinery: several
    // stripes per worker, several frames per stripe, windowed requests
    let mut cfg = native_cfg();
    cfg.apply("transfer.rows_per_frame", "8").unwrap();
    cfg.apply("transfer.pull_stripe_rows", "32").unwrap();
    cfg.apply("transfer.pull_window", "2").unwrap();
    let server = AlchemistServer::start(cfg.clone(), 3).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 4).unwrap();

    let x = random_matrix(1, 203, 5); // awkward split across 3 workers
    let (al, s) = ac.send_matrix("X", &IndexedRowMatrix::from_local(&x, 6)).unwrap();
    assert_eq!(s.bytes, 203 * 5 * 8);

    let (back, p) = ac.to_indexed_row_matrix(&al, 4).unwrap();
    assert_eq!(back.to_local().unwrap(), x);
    assert_eq!(p.bytes, 203 * 5 * 8);
    assert!(
        p.frames >= 203 / 8,
        "streaming pull should arrive in rows_per_frame chunks, got {} frames",
        p.frames
    );

    ac.stop();
    server.shutdown();
}

#[test]
fn handshake_negotiates_and_clamps_transfer_knobs() {
    let server = AlchemistServer::start(native_cfg(), 1).unwrap();

    // a client asking beyond the server limits is clamped
    let mut big = native_cfg();
    big.transfer.rows_per_frame = 1_000_000;
    big.transfer.buf_bytes = 1 << 26;
    let ac = AlchemistContext::connect(&server.control_addr, &big, 1).unwrap();
    let server_limits = native_cfg().transfer;
    assert_eq!(ac.transfer_config().rows_per_frame, server_limits.max_rows_per_frame);
    assert_eq!(ac.transfer_config().buf_bytes, server_limits.max_buf_bytes);
    ac.stop();

    // an in-range request is honored verbatim
    let mut small = native_cfg();
    small.transfer.rows_per_frame = 16;
    small.transfer.buf_bytes = 64 << 10;
    let ac = AlchemistContext::connect(&server.control_addr, &small, 1).unwrap();
    assert_eq!(ac.transfer_config().rows_per_frame, 16);
    assert_eq!(ac.transfer_config().buf_bytes, 64 << 10);
    ac.stop();

    server.shutdown();
}

#[test]
fn concurrent_executors_ingest_interleaved_out_of_order_runs() {
    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg.clone(), 1).unwrap();
    let (mut control, session_id, worker_addrs) = raw_session(&server.control_addr, 0);
    const ROWS: u64 = 256;
    const COLS: usize = 3;
    let id = create_matrix(&mut control, "X", ROWS, COLS as u64);

    // 4 executors own interleaved 2-row runs (run r belongs to executor
    // r % 4) and push them in REVERSE order — ingest must cope with
    // interleaved, out-of-order, concurrent streams
    let mut handles = Vec::new();
    for t in 0..4u32 {
        let addr = worker_addrs[0].clone();
        handles.push(std::thread::spawn(move || {
            let mut data = data_conn(&addr, session_id, t, 8);
            let runs: Vec<u64> =
                (0..ROWS / 2).filter(|r| (r % 4) as u32 == t).collect();
            for &r in runs.iter().rev() {
                let start = r * 2;
                let mut payload = Vec::with_capacity(2 * COLS);
                for row in start..start + 2 {
                    payload.extend(std::iter::repeat(row as f64).take(COLS));
                }
                data.send_data(&DataMsg::PushRows {
                    matrix_id: id,
                    start_row: start,
                    nrows: 2,
                    ncols: COLS as u32,
                    data: payload,
                })
                .unwrap();
            }
            data.send_data_flush(&DataMsg::PushDone { matrix_id: id }).unwrap();
            match data.recv_data().unwrap() {
                DataMsg::PushDoneAck { .. } => {}
                other => panic!("bad push ack: {other:?}"),
            }
            let _ = data.send_data_flush(&DataMsg::DataBye);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // every row must have landed exactly once
    match control.call(&ControlMsg::SealMatrix { id }).unwrap() {
        ControlMsg::MatrixSealed { rows_received, .. } => assert_eq!(rows_received, ROWS),
        other => panic!("bad seal reply: {other:?}"),
    }

    // pull the whole block back as one ranged stream and verify contents
    let mut data = data_conn(&worker_addrs[0], session_id, 9, 16);
    let (frames, _) = drain_pull_stream(&mut data, id, 0, ROWS, COLS, true);
    assert_eq!(frames, ROWS as usize / 16, "worker must honor the negotiated frame size");
    // steady state: the receive buffer stopped growing after the first
    // data frame (ack + first frame = at most 2 growths)
    assert!(
        data.recv_buf_grows() <= 2,
        "per-frame allocations on the pull stream: {} growths",
        data.recv_buf_grows()
    );

    // hardening: zero-row pulls are rejected with a proper diagnostic
    data.send_data_flush(&DataMsg::PullRows {
        matrix_id: id,
        start_row: 0,
        nrows: 0,
        start_col: 0,
        sel_cols: 0,
    })
        .unwrap();
    match data.recv_data().unwrap() {
        DataMsg::DataError { message } => {
            assert!(message.contains("zero-row"), "{message}")
        }
        other => panic!("bad reply to zero-row pull: {other:?}"),
    }

    server.shutdown();
}

#[test]
fn pull_stream_overlaps_concurrent_ingest_on_one_worker() {
    // one worker, one session, two matrices: a long pull stream of M1
    // must proceed while another connection ingests M2 (per-block locks;
    // a store-wide mutex would serialize or deadlock this)
    let mut cfg = native_cfg();
    cfg.apply("transfer.rows_per_frame", "8").unwrap();
    let server = AlchemistServer::start(cfg.clone(), 1).unwrap();
    let (mut control, session_id, worker_addrs) = raw_session(&server.control_addr, 0);
    const ROWS: u64 = 512;
    const COLS: usize = 4;

    // M1: pushed and sealed up front
    let m1 = create_matrix(&mut control, "M1", ROWS, COLS as u64);
    {
        let mut data = data_conn(&worker_addrs[0], session_id, 0, 8);
        for start in (0..ROWS).step_by(8) {
            let mut payload = Vec::with_capacity(8 * COLS);
            for row in start..start + 8 {
                payload.extend(std::iter::repeat(row as f64).take(COLS));
            }
            data.send_data(&DataMsg::PushRows {
                matrix_id: m1,
                start_row: start,
                nrows: 8,
                ncols: COLS as u32,
                data: payload,
            })
            .unwrap();
        }
        data.send_data_flush(&DataMsg::PushDone { matrix_id: m1 }).unwrap();
        assert!(matches!(data.recv_data().unwrap(), DataMsg::PushDoneAck { .. }));
        let _ = data.send_data_flush(&DataMsg::DataBye);
    }
    match control.call(&ControlMsg::SealMatrix { id: m1 }).unwrap() {
        ControlMsg::MatrixSealed { rows_received, .. } => assert_eq!(rows_received, ROWS),
        other => panic!("bad seal reply: {other:?}"),
    }

    let m2 = create_matrix(&mut control, "M2", ROWS, COLS as u64);
    let barrier = Arc::new(Barrier::new(2));

    let puller = {
        let addr = worker_addrs[0].clone();
        let barrier = barrier.clone();
        std::thread::spawn(move || {
            let mut data = data_conn(&addr, session_id, 1, 8);
            barrier.wait();
            for _ in 0..3 {
                let (frames, _) = drain_pull_stream(&mut data, m1, 0, ROWS, COLS, true);
                assert_eq!(frames, ROWS as usize / 8);
            }
            let _ = data.send_data_flush(&DataMsg::DataBye);
        })
    };
    let pusher = {
        let addr = worker_addrs[0].clone();
        let barrier = barrier.clone();
        std::thread::spawn(move || {
            let mut data = data_conn(&addr, session_id, 2, 8);
            barrier.wait();
            for start in (0..ROWS).step_by(4) {
                let mut payload = Vec::with_capacity(4 * COLS);
                for row in start..start + 4 {
                    payload.extend(std::iter::repeat(row as f64 + 0.5).take(COLS));
                }
                data.send_data(&DataMsg::PushRows {
                    matrix_id: m2,
                    start_row: start,
                    nrows: 4,
                    ncols: COLS as u32,
                    data: payload,
                })
                .unwrap();
            }
            data.send_data_flush(&DataMsg::PushDone { matrix_id: m2 }).unwrap();
            assert!(matches!(data.recv_data().unwrap(), DataMsg::PushDoneAck { .. }));
            let _ = data.send_data_flush(&DataMsg::DataBye);
        })
    };
    puller.join().unwrap();
    pusher.join().unwrap();

    match control.call(&ControlMsg::SealMatrix { id: m2 }).unwrap() {
        ControlMsg::MatrixSealed { rows_received, .. } => assert_eq!(rows_received, ROWS),
        other => panic!("bad seal reply: {other:?}"),
    }
    server.shutdown();
}

#[test]
fn cross_tenant_transfers_proceed_concurrently() {
    // regression: one tenant's long pull stream and another tenant's push
    // run at the same time on disjoint worker groups
    let mut cfg = native_cfg();
    cfg.apply("transfer.rows_per_frame", "16").unwrap();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let addr = server.control_addr.clone();

    let mut a = AlchemistContext::connect_with_workers(&addr, &cfg, 2, 1).unwrap();
    let xa = random_matrix(7, 600, 6);
    let (al_a, _) = a.send_matrix("Xa", &IndexedRowMatrix::from_local(&xa, 4)).unwrap();

    let barrier = Arc::new(Barrier::new(2));
    let t_pull = {
        let barrier = barrier.clone();
        std::thread::spawn(move || {
            barrier.wait();
            for _ in 0..3 {
                let (back, _) = a.to_indexed_row_matrix(&al_a, 2).unwrap();
                assert_eq!(back.to_local().unwrap(), xa);
            }
            a.stop();
        })
    };
    let t_push = {
        let addr = addr.clone();
        let cfg = cfg.clone();
        let barrier = barrier.clone();
        std::thread::spawn(move || {
            let mut b = AlchemistContext::connect_with_workers(&addr, &cfg, 2, 1).unwrap();
            let xb = random_matrix(8, 600, 6);
            barrier.wait();
            for i in 0..3 {
                let (al_b, _) = b
                    .send_matrix(&format!("Xb{i}"), &IndexedRowMatrix::from_local(&xb, 4))
                    .unwrap();
                let (back, _) = b.to_indexed_row_matrix(&al_b, 2).unwrap();
                assert_eq!(back.to_local().unwrap(), xb);
                b.free(&al_b).unwrap();
            }
            b.stop();
        })
    };
    t_pull.join().unwrap();
    t_push.join().unwrap();
    server.shutdown();
}

#[test]
fn v2_client_receives_version_mismatch_diagnostic() {
    let server = AlchemistServer::start(native_cfg(), 1).unwrap();
    let mut control = Framed::connect(&server.control_addr, 1 << 16).unwrap();

    // a genuine v2 frame: tag, name, version, request_workers — and
    // nothing else (the v3 transfer-negotiation fields are absent)
    let mut w = Writer::new();
    w.u8(0);
    w.str("old-v2-client");
    w.u32(2);
    w.u32(1);
    control.send_flush(&w.into_bytes()).unwrap();
    match control.recv_ctrl().unwrap() {
        ControlMsg::Error { message } => {
            assert!(
                message.contains("protocol version mismatch: client 2, server 5"),
                "{message}"
            );
        }
        other => panic!("expected a version diagnostic, got {other:?}"),
    }
    // the connection survives to retry with the right version
    let reply = control
        .call(&ControlMsg::Handshake {
            client_name: "retry".into(),
            version: PROTOCOL_VERSION,
            request_workers: 0,
            rows_per_frame: 0,
            buf_bytes: 0,
            priority: alchemist::protocol::DEFAULT_PRIORITY,
        })
        .unwrap();
    assert!(matches!(reply, ControlMsg::HandshakeAck { .. }));
    server.shutdown();
}

/// A stand-in for a STRICT pre-v3 server: decodes the v2 handshake shape
/// exactly — tag, name, version, request_workers — rejects any trailing
/// bytes by dropping the connection without a reply (what a strict
/// decoder's `finish()` does), and answers well-formed v2-shaped frames
/// with its version-mismatch diagnostic.
fn spawn_strict_v2_server() -> (String, std::thread::JoinHandle<()>) {
    use std::io::{Read, Write};
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        // serve exactly two connections: the long-form attempt (dropped)
        // and the short-form diagnostic probe (answered)
        for _ in 0..2 {
            let (mut stream, _) = match listener.accept() {
                Ok(conn) => conn,
                Err(_) => return,
            };
            let mut len = [0u8; 4];
            if stream.read_exact(&mut len).is_err() {
                continue;
            }
            let mut payload = vec![0u8; u32::from_le_bytes(len) as usize];
            if stream.read_exact(&mut payload).is_err() {
                continue;
            }
            let mut r = alchemist::protocol::Reader::new(&payload);
            let parsed = (|| -> Result<u32, alchemist::protocol::ProtocolError> {
                assert_eq!(r.u8()?, 0, "expected a handshake frame");
                let _name = r.str()?;
                let version = r.u32()?;
                let _request_workers = r.u32()?;
                Ok(version)
            })();
            let version = match parsed {
                Ok(v) => v,
                Err(_) => continue,
            };
            if r.remaining() > 0 {
                // strict decoder: trailing bytes → protocol error → the
                // connection is dropped with no diagnostic
                continue;
            }
            let reply = ControlMsg::Error {
                message: format!(
                    "protocol version mismatch: client {version}, server 2"
                ),
            }
            .encode();
            let _ = stream.write_all(&(reply.len() as u32).to_le_bytes());
            let _ = stream.write_all(&reply);
            let _ = stream.flush();
        }
    });
    (addr, handle)
}

#[test]
fn explicit_transfer_request_against_strict_old_server_gets_version_diagnostic() {
    let (addr, server) = spawn_strict_v2_server();

    // explicit (non-default) transfer settings force the long handshake
    // form the strict old server cannot decode; the client must probe
    // with the short form and surface the version diagnostic instead of
    // an opaque disconnect error
    let mut cfg = native_cfg();
    cfg.transfer.rows_per_frame = 128; // != compiled default → explicit request
    let err = AlchemistContext::connect(&addr, &cfg, 1).unwrap_err();
    let text = format!("{err:#}");
    assert!(text.contains("version mismatch"), "{text}");
    assert!(text.contains("v3+"), "{text}");

    server.join().unwrap();
}
