//! Synthetic workload generators — the data-availability substitutions of
//! DESIGN.md §2 (TIMIT is licensed, CFSR is 400 GB; the experiments need
//! their *shapes*, not their bytes).

pub mod ocean;
pub mod timit;

pub use ocean::{ocean_svd_outofcore, OceanSpec, OutOfCoreReport};
pub use timit::TimitSpec;
