//! Streaming mean/σ (Welford) — the paper reports per-iteration costs as
//! mean ± s.d. (Tables 2 and 4).

#[derive(Debug, Clone, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Stats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation (n-1); 0 for fewer than two samples.
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }

    /// `"12.3 ± 4.5"` with the given precision.
    pub fn mean_pm_std(&self, prec: usize) -> String {
        format!("{:.prec$} ± {:.prec$}", self.mean(), self.std())
    }
}

impl FromIterator<f64> for Stats {
    fn from_iter<I: IntoIterator<Item = f64>>(iter: I) -> Self {
        let mut s = Stats::new();
        for x in iter {
            s.push(x);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_closed_form() {
        let s: Stats = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]
            .into_iter()
            .collect();
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample std of that set is sqrt(32/7)
        assert!((s.std() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert!((s.sum() - 40.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_cases() {
        let mut s = Stats::new();
        assert_eq!(s.std(), 0.0);
        s.push(3.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.mean(), 3.0);
    }
}
