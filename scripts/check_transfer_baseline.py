#!/usr/bin/env python3
"""Diff a fresh BENCH_transfer.json against the committed baseline.

CI's transfer-bench job runs the smoke-size bench and calls this script
with the fresh artifact and the repo's committed baseline. Outcomes:

* committed baseline is still the stub (no cells): emit a GitHub warning
  annotation so the ROADMAP's "regenerate the committed baseline"
  follow-up stops rotting silently, and exit 0 (nothing to diff).
* configs are incomparable (different matrix size / runs / transfer
  knobs — e.g. a smoke run against a full-size baseline): warn, exit 0.
* comparable: report per-cell throughput deltas; exit 1 if any cell's
  push or pull GB/s regressed by more than --tolerance (default 50%,
  deliberately loose — CI runners are noisy; the committed baseline is
  for catching collapses, not 5% drifts).

--update flips the script from checker to pinner: it takes FRESH (a CI
artifact or a local full-size run), stamps its provenance into "status",
and writes it to the BASELINE path as the exact pin-ready
BENCH_transfer.json — commit the result to close the ROADMAP
"regenerate the committed baseline" item. Refuses a FRESH with no cells
(pinning an empty baseline would disable the checker forever).

Usage: check_transfer_baseline.py FRESH BASELINE [--tolerance 0.5] [--update]
"""

import argparse
import datetime
import json
import sys


def warn(msg: str) -> None:
    # GitHub Actions annotation; plain stderr elsewhere
    print(f"::warning::{msg}")
    print(f"WARNING: {msg}", file=sys.stderr)


def cell_key(cell: dict) -> tuple:
    return (cell.get("executors"), cell.get("workers"))


def pin_baseline(fresh_path: str, baseline_path: str) -> int:
    """Write FRESH to BASELINE as the committed, pin-ready baseline."""
    with open(fresh_path) as f:
        fresh = json.load(f)
    if not fresh.get("cells"):
        print("::error::refusing to pin a baseline with no cells "
              f"({fresh_path} has an empty 'cells' array — did the bench run?)")
        return 1
    stamp = datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%d")
    fresh["status"] = (
        f"baseline pinned {stamp} via check_transfer_baseline.py --update "
        f"from {fresh_path}; regressions beyond --tolerance now fail CI"
    )
    with open(baseline_path, "w") as f:
        json.dump(fresh, f, indent=2)
        f.write("\n")
    cells = fresh["cells"]
    print(f"pinned {len(cells)} cell(s) from {fresh_path} -> {baseline_path}; "
          "commit the updated baseline to enable regression checking")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh")
    ap.add_argument("baseline")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="max fractional throughput regression per cell")
    ap.add_argument("--update", action="store_true",
                    help="write FRESH to BASELINE as the pin-ready committed "
                         "baseline instead of diffing")
    args = ap.parse_args()

    if args.update:
        return pin_baseline(args.fresh, args.baseline)

    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    if not base.get("cells"):
        warn(
            "BENCH_transfer.json baseline is still the committed stub "
            "(no cells) — paste a CI artifact or a full-size run into the "
            "repo root to pin real GB/s numbers (see ROADMAP 'regenerate "
            "the committed baseline')."
        )
        return 0

    comparable_keys = ("rows", "cols", "runs", "quick", "rows_per_frame",
                       "buf_bytes", "pull_stripe_rows", "pull_window")
    fc, bc = fresh.get("config", {}), base.get("config", {})
    mismatched = [k for k in comparable_keys if fc.get(k) != bc.get(k)]
    if mismatched:
        warn(
            "transfer bench configs are not comparable "
            f"(differ in {', '.join(mismatched)}); skipping the diff. "
            "Regenerate the baseline at the CI smoke size or run CI at "
            "the baseline size to re-enable regression checking."
        )
        return 0

    if not fresh.get("cells"):
        # the baseline has real numbers but this run produced none — the
        # exact collapse the check exists to catch must not pass silently
        print("::error::fresh BENCH_transfer.json has no cells to compare "
              "against the pinned baseline (bench produced no results?)")
        return 1

    base_cells = {cell_key(c): c for c in base["cells"]}
    failures = []
    for cell in fresh.get("cells", []):
        ref = base_cells.get(cell_key(cell))
        if ref is None:
            continue
        for leg in ("push_gbps", "pull_gbps"):
            got, want = cell.get(leg), ref.get(leg)
            if not isinstance(got, (int, float)) or not isinstance(want, (int, float)):
                continue
            if want <= 0:
                continue
            delta = (got - want) / want
            tag = (f"e{cell.get('executors')}xw{cell.get('workers')} {leg}: "
                   f"{got:.3f} vs baseline {want:.3f} GB/s ({delta:+.1%})")
            print(tag)
            if delta < -args.tolerance:
                failures.append(tag)

    if failures:
        for f_ in failures:
            print(f"::error::transfer throughput regression: {f_}")
        return 1
    print("transfer bench within tolerance of the committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
