//! Pure-rust engine: the blocked kernels from [`crate::distmat::dense`].
//!
//! This is (a) the compute floor for the engine ablation, and (b) what the
//! sparklite baseline uses — the paper's Spark side never sees the HPC
//! library either.

use crate::config::EngineKind;
use crate::distmat::LocalMatrix;

use super::{Engine, GemmVariant};

#[derive(Debug, Default)]
pub struct NativeEngine;

impl NativeEngine {
    pub fn new() -> Self {
        NativeEngine
    }
}

impl Engine for NativeEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Native
    }

    fn gemm(
        &mut self,
        variant: GemmVariant,
        c: &mut LocalMatrix,
        a: &LocalMatrix,
        b: &LocalMatrix,
    ) -> crate::Result<()> {
        match variant {
            GemmVariant::NN => c.gemm_nn(a, b),
            GemmVariant::TN => c.gemm_tn(a, b),
            GemmVariant::NT => c.gemm_nt(a, b),
        }
        Ok(())
    }

    fn gram_matvec(
        &mut self,
        a: &LocalMatrix,
        v: &LocalMatrix,
        reg: f64,
    ) -> crate::Result<LocalMatrix> {
        anyhow::ensure!(a.cols() == v.rows(), "gram_matvec: a {}x{} vs v {}x{}",
            a.rows(), a.cols(), v.rows(), v.cols());
        let mut av = LocalMatrix::zeros(a.rows(), v.cols());
        av.gemm_nn(a, v);
        let mut out = v.clone();
        out.scale(reg);
        out.gemm_tn(a, &av);
        Ok(out)
    }

    fn rff_expand(
        &mut self,
        x: &LocalMatrix,
        omega: &LocalMatrix,
        bias: &[f64],
        scale: f64,
    ) -> crate::Result<LocalMatrix> {
        anyhow::ensure!(x.cols() == omega.rows(), "rff_expand shape mismatch");
        anyhow::ensure!(bias.len() == omega.cols(), "rff bias length mismatch");
        let mut z = LocalMatrix::zeros(x.rows(), omega.cols());
        z.gemm_nn(x, omega);
        for i in 0..z.rows() {
            let row = z.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                *v = scale * (*v + bias[j]).cos();
            }
        }
        Ok(z)
    }

    fn cg_update(
        &mut self,
        x: &mut LocalMatrix,
        r: &mut LocalMatrix,
        p: &LocalMatrix,
        q: &LocalMatrix,
        alpha: &[f64],
    ) -> crate::Result<()> {
        anyhow::ensure!(alpha.len() == x.cols(), "alpha length mismatch");
        for i in 0..x.rows() {
            let xr = x.row_mut(i);
            let pr = p.row(i);
            for j in 0..xr.len() {
                xr[j] += alpha[j] * pr[j];
            }
            let rr = r.row_mut(i);
            let qr = q.row(i);
            for j in 0..rr.len() {
                rr[j] -= alpha[j] * qr[j];
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random(rng: &mut Rng, r: usize, c: usize) -> LocalMatrix {
        LocalMatrix::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn gram_matvec_matches_composition() {
        let mut rng = Rng::new(1);
        let a = random(&mut rng, 20, 8);
        let v = random(&mut rng, 8, 3);
        let mut e = NativeEngine::new();
        let got = e.gram_matvec(&a, &v, 0.7).unwrap();
        // reference: Aᵀ(Av) + reg·v
        let mut av = LocalMatrix::zeros(20, 3);
        av.gemm_nn(&a, &v);
        let mut want = v.clone();
        want.scale(0.7);
        want.gemm_tn(&a, &av);
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn rff_expand_is_bounded_and_correct() {
        let mut rng = Rng::new(2);
        let x = random(&mut rng, 5, 4);
        let omega = random(&mut rng, 4, 6);
        let bias: Vec<f64> = (0..6).map(|_| rng.uniform_in(0.0, 6.28)).collect();
        let scale = (2.0f64 / 6.0).sqrt();
        let mut e = NativeEngine::new();
        let z = e.rff_expand(&x, &omega, &bias, scale).unwrap();
        for i in 0..5 {
            for j in 0..6 {
                let mut acc = 0.0;
                for k in 0..4 {
                    acc += x.get(i, k) * omega.get(k, j);
                }
                let want = scale * (acc + bias[j]).cos();
                assert!((z.get(i, j) - want).abs() < 1e-12);
                assert!(z.get(i, j).abs() <= scale + 1e-12);
            }
        }
    }

    #[test]
    fn cg_update_both_halves() {
        let mut rng = Rng::new(3);
        let mut x = random(&mut rng, 6, 2);
        let mut r = random(&mut rng, 6, 2);
        let p = random(&mut rng, 6, 2);
        let q = random(&mut rng, 6, 2);
        let alpha = vec![0.5, -2.0];
        let (x0, r0) = (x.clone(), r.clone());
        NativeEngine::new().cg_update(&mut x, &mut r, &p, &q, &alpha).unwrap();
        for i in 0..6 {
            for j in 0..2 {
                assert!((x.get(i, j) - (x0.get(i, j) + alpha[j] * p.get(i, j))).abs() < 1e-14);
                assert!((r.get(i, j) - (r0.get(i, j) - alpha[j] * q.get(i, j))).abs() < 1e-14);
            }
        }
    }
}
