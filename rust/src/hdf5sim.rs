//! Minimal binary matrix container — the HDF5 stand-in (DESIGN.md §2).
//!
//! The ocean experiments (Table 5 / Figure 3) compare loading the data in
//! Spark vs. loading it directly in Alchemist from HDF5. What matters is
//! the *path* (file → worker shards without a trip through the client);
//! the format is a 40-byte header + row-major f64 payload, and workers can
//! read their row ranges independently (`read_rows`), which is the
//! parallel-read property the experiment leans on.
//!
//! Layout (all little-endian):
//! `magic "ALCH5SIM" | version u32 | reserved u32 | rows u64 | cols u64 |
//!  payload rows*cols*8 bytes`.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::Context;

use crate::distmat::LocalMatrix;

const MAGIC: &[u8; 8] = b"ALCH5SIM";
const VERSION: u32 = 1;
const HEADER_BYTES: u64 = 8 + 4 + 4 + 8 + 8;

/// Write a matrix to `path`.
pub fn write_matrix(path: &Path, m: &LocalMatrix) -> crate::Result<()> {
    let file = File::create(path).with_context(|| format!("creating {path:?}"))?;
    let mut w = BufWriter::with_capacity(1 << 20, file);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&0u32.to_le_bytes())?;
    w.write_all(&(m.rows() as u64).to_le_bytes())?;
    w.write_all(&(m.cols() as u64).to_le_bytes())?;
    // Safety: f64 -> u8 view for bulk write.
    let bytes = unsafe {
        std::slice::from_raw_parts(m.data().as_ptr() as *const u8, m.data().len() * 8)
    };
    w.write_all(bytes)?;
    w.flush()?;
    Ok(())
}

/// Matrix dimensions from the header.
pub fn read_header(path: &Path) -> crate::Result<(usize, usize)> {
    let file = File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("reading magic")?;
    anyhow::ensure!(&magic == MAGIC, "{path:?} is not an ALCH5SIM file");
    let mut u32buf = [0u8; 4];
    r.read_exact(&mut u32buf)?;
    anyhow::ensure!(
        u32::from_le_bytes(u32buf) == VERSION,
        "unsupported ALCH5SIM version"
    );
    r.read_exact(&mut u32buf)?; // reserved
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let rows = u64::from_le_bytes(u64buf) as usize;
    r.read_exact(&mut u64buf)?;
    let cols = u64::from_le_bytes(u64buf) as usize;
    Ok((rows, cols))
}

/// Read rows `[start, end)` — workers call this concurrently with their
/// own ranges (independent file handles, seek + sequential read).
pub fn read_rows(path: &Path, start: usize, end: usize) -> crate::Result<LocalMatrix> {
    let (rows, cols) = read_header(path)?;
    anyhow::ensure!(start <= end && end <= rows, "row range out of bounds");
    let mut file = File::open(path)?;
    file.seek(SeekFrom::Start(HEADER_BYTES + (start * cols * 8) as u64))?;
    let mut data = vec![0f64; (end - start) * cols];
    // Safety: filling the f64 buffer through its byte view.
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, data.len() * 8)
    };
    let mut r = BufReader::with_capacity(1 << 20, file);
    r.read_exact(bytes).context("reading row payload")?;
    Ok(LocalMatrix::from_data(end - start, cols, data))
}

/// Read the whole matrix.
pub fn read_matrix(path: &Path) -> crate::Result<LocalMatrix> {
    let (rows, _) = read_header(path)?;
    read_rows(path, 0, rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("alchemist-hdf5sim-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_and_ranged_reads() {
        let mut rng = Rng::new(4);
        let m = LocalMatrix::from_fn(37, 5, |_, _| rng.normal());
        let path = tmp("roundtrip.bin");
        write_matrix(&path, &m).unwrap();
        assert_eq!(read_header(&path).unwrap(), (37, 5));
        assert_eq!(read_matrix(&path).unwrap(), m);
        assert_eq!(read_rows(&path, 10, 20).unwrap(), m.slice_rows(10, 20));
        assert_eq!(read_rows(&path, 0, 0).unwrap().rows(), 0);
    }

    #[test]
    fn concurrent_shard_reads_cover_matrix() {
        let mut rng = Rng::new(5);
        let m = LocalMatrix::from_fn(100, 3, |_, _| rng.normal());
        let path = tmp("shards.bin");
        write_matrix(&path, &m).unwrap();
        let ranges = crate::util::even_ranges(100, 4);
        let handles: Vec<_> = ranges
            .iter()
            .map(|&(a, b)| {
                let p = path.clone();
                std::thread::spawn(move || read_rows(&p, a, b).unwrap())
            })
            .collect();
        let mut rebuilt = LocalMatrix::zeros(100, 3);
        for (h, &(a, _)) in handles.into_iter().zip(&ranges) {
            rebuilt.write_rows(a, &h.join().unwrap());
        }
        assert_eq!(rebuilt, m);
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.bin");
        std::fs::write(&path, b"definitely not a matrix").unwrap();
        assert!(read_header(&path).is_err());
        let path2 = tmp("missing-range.bin");
        write_matrix(&path2, &LocalMatrix::zeros(3, 2)).unwrap();
        assert!(read_rows(&path2, 2, 5).is_err());
    }
}
