//! Per-worker matrix storage: each worker rank holds its row-block of
//! every live distributed matrix (the server-side half of the `AlMatrix`
//! proxy scheme — data stays put between routines; only handles travel).
//!
//! Blocks are namespaced by owning session: matrix ids are globally
//! unique (the driver hands them out from one counter), but every block
//! records the session that created it and which slot of the layout this
//! worker fills (the session's *group-local* rank — with session-scoped
//! worker groups a worker's global rank no longer indexes
//! `layout.ranges`). Session teardown frees exactly that session's
//! blocks without touching any other tenant's.
//!
//! ## Residency (the out-of-core storage plane)
//!
//! A sealed block's payload lives in exactly one of three homes
//! ([`Residency`]), and moves between them under the block's residency
//! mutex without readers ever noticing:
//!
//! * **Heap** — an `Arc<LocalMatrix>`, the classic push-ingested or
//!   routine-output case. Counted against the owning session's
//!   `storage.budget_bytes`.
//! * **Mapped** — an `Arc<hdf5sim::MappedMatrix>` registered by the v7
//!   `LoadMatrix` direct-ingest RPC: the payload is the page cache's
//!   view of the file, zero heap bytes, exempt from the budget (the
//!   kernel already pages it under memory pressure).
//! * **Spilled** — payload parked in the rank's ledgered spill file
//!   ([`SpillFile`]). Reads stream spans back transiently through a
//!   bounded buffer, or promote the whole block to Heap when the
//!   session's budget has room again (page-in).
//!
//! Reads hand out [`Span`] guards that hold an `Arc` clone of the
//! payload's current home, so an eviction racing a read can never
//! invalidate the bytes mid-stream — the spilled copy becomes the new
//! truth while in-flight readers finish off the old heap Arc (a
//! transient overshoot of the budget bounded by active reads).
//!
//! ## Budget enforcement
//!
//! `storage.budget_bytes` (per session, per rank; 0 = unlimited) is
//! checked at [`MatrixStore::alloc`] — an ingest allocation that cannot
//! fit even after spilling every sealed block fails with a clean error —
//! and at [`MatrixStore::insert`], which always lands the output block
//! and then spills least-recently-used sealed blocks (possibly the new
//! one) until the session is back under budget. Unsealed ingest blocks
//! never spill (their stripes may be mid-write); mapped blocks never
//! spill (nothing to write — the file IS the payload).
//!
//! ## Locking model (the ingest hot path)
//!
//! The store itself is only a directory: an `RwLock`ed id → `Arc<Block>`
//! map held for microseconds per lookup. Payload writes never touch it —
//! each [`Block`] carries its own ingest state and a small array of
//! *stripe locks* over its local row range, so
//!
//! * executors streaming **different matrices** into one worker share
//!   nothing but the read lock on the map;
//! * executors streaming **disjoint row ranges of one matrix** land on
//!   disjoint stripes and copy concurrently;
//! * overlapping writes (a misbehaving client) serialize on their shared
//!   stripes instead of racing.
//!
//! Writers never materialize a reference over the whole payload buffer —
//! that would alias between concurrent writers even on disjoint stripes.
//! Each write derives a `&mut [f64]` over exactly its locked span from a
//! raw base pointer captured at construction (`Block::base`), so the
//! exclusive references of concurrent writers are disjoint by
//! construction.
//!
//! Sealing is the ingest/compute barrier: `seal` flips `sealed` under
//! the state mutex (new writers abort — they re-check it *after*
//! acquiring their stripes), takes every stripe lock once to wait out
//! in-flight writers (who copy AND account while holding their stripes),
//! moves the quiescent payload out of the ingest cell into its
//! `Arc<LocalMatrix>` heap home, and only then sets `readable` — the
//! flag every reader gates on, so a read can never overlap a straggling
//! pre-seal copy and never observes `Residency::Ingest`. A readable
//! block is immutable, which is what lets pulls stream borrowed spans
//! ([`Block::read_span`]) straight from the block (or the mapped file)
//! into the socket buffer with zero copies on the worker side.
//!
//! Lock order: a block's residency mutex may be held while taking the
//! shared budget ledger or the spill-file mutex, never the reverse; no
//! path holds the residency mutex while taking the store's map lock.

use std::cell::UnsafeCell;
use std::collections::HashMap;
use std::fs::File;
use std::io::{Read as _, Seek, SeekFrom, Write as _};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::config::StorageConfig;
use crate::distmat::{LocalMatrix, RowBlockLayout};
use crate::hdf5sim::MappedMatrix;
use crate::metrics::StorageMetrics;
use crate::protocol::wire::copy_le_f64s;

/// Stripe-lock count per block: enough for the handful of concurrent
/// executor streams a worker realistically sees, cheap enough to sit on
/// every block.
const INGEST_STRIPES: usize = 8;

#[derive(Debug, Default)]
struct IngestState {
    rows_received: u64,
    /// Writers stop here: set at the start of `seal`, checked by every
    /// writer after it acquires its stripes.
    sealed: bool,
    /// Readers start here: set at the END of `seal`, after the stripe
    /// barrier has waited out every in-flight writer — the window where
    /// `sealed` is already true but a pre-seal writer is still copying
    /// must not be readable (that read would race the copy).
    readable: bool,
}

/// Where a block's payload currently lives. See the module docs.
enum Residency {
    /// Pre-seal: payload is the zeroed ingest buffer in `Block::data`,
    /// being filled through the stripe protocol.
    Ingest,
    /// Sealed, heap-resident (budget-counted).
    Heap(Arc<LocalMatrix>),
    /// Sealed, mmap-backed (`LoadMatrix` direct ingest; budget-exempt).
    Mapped(Arc<MappedMatrix>),
    /// Sealed, parked in the rank's spill file (`bytes` = segment size).
    Spilled { bytes: u64 },
}

/// Read guard handed out by [`Block::read_span`]: derefs to the row
/// span's `&[f64]` while keeping the payload's current home alive, so a
/// concurrent spill cannot invalidate an in-flight read.
pub enum Span {
    Heap { data: Arc<LocalMatrix>, start: usize, len: usize },
    Mapped { map: Arc<MappedMatrix>, start: usize, len: usize },
    /// Streamed back transiently from the spill file (bounded copy).
    Owned(Vec<f64>),
}

impl std::ops::Deref for Span {
    type Target = [f64];
    fn deref(&self) -> &[f64] {
        match self {
            Span::Heap { data, start, len } => &data.data()[*start..*start + *len],
            Span::Mapped { map, start, len } => &map.data()[*start..*start + *len],
            Span::Owned(v) => v,
        }
    }
}

impl AsRef<[f64]> for Span {
    fn as_ref(&self) -> &[f64] {
        self
    }
}

/// Per-session storage totals on one rank (the accounting surface the
/// budget check and `ServerHandle::storage_usage` read).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionUsage {
    /// Heap bytes: unsealed ingest buffers + `Residency::Heap` payloads.
    pub bytes_resident: u64,
    /// Bytes parked in the spill file.
    pub bytes_spilled: u64,
    /// mmap-backed payload bytes (page cache, budget-exempt).
    pub bytes_mapped: u64,
}

/// One segment of the spill file.
#[derive(Debug, Clone, Copy)]
struct Segment {
    offset: u64,
    bytes: u64,
    session: u64,
}

#[derive(Debug, Default)]
struct SpillInner {
    /// Created lazily on first spill; `None` until then.
    file: Option<File>,
    /// block id → live segment.
    segs: HashMap<u64, Segment>,
    /// Reusable holes `(offset, bytes)` left by freed segments
    /// (first-fit, split on partial reuse; tail frees shrink `end`).
    free: Vec<(u64, u64)>,
    /// High-water mark: next append offset.
    end: u64,
}

/// Spill-file magic: first 8 bytes of every well-formed spill file. The
/// trailing digit doubles as a coarse format generation.
const SPILL_MAGIC: [u8; 8] = *b"ALSPILL1";
/// Spill on-disk format version (header field, little-endian).
const SPILL_FORMAT: u32 = 1;
/// Header layout: 8-byte magic + u32 version + u32 reserved. Segment
/// offsets start past it, so offset 0 is never a valid segment and a
/// zero-filled torn file can never masquerade as one.
const SPILL_HEADER_BYTES: u64 = 16;

/// Per-rank ledgered spill file: whole-block segments tracked by a
/// `block id → (offset, bytes, session)` ledger with a free-list for
/// hole reuse. Payload is stored native-endian — segments are strictly
/// same-host round-trips. The file is deleted when the store drops.
#[derive(Debug)]
struct SpillFile {
    path: PathBuf,
    inner: Mutex<SpillInner>,
}

impl SpillFile {
    fn new(path: PathBuf) -> Self {
        SpillFile { path, inner: Mutex::new(SpillInner::default()) }
    }

    /// Validate (or lay down) the spill header on a freshly opened file.
    /// The ledger lives only in memory, so any payload found on disk is
    /// stale by definition — a valid header is truncated back to
    /// header-only; a torn or foreign file is rebuilt from scratch with
    /// a warning (crash-safety satellite: never trust leftover bytes).
    fn validate_or_init_header(file: &mut File, path: &PathBuf) -> crate::Result<()> {
        let len = file.metadata()?.len();
        if len >= SPILL_HEADER_BYTES {
            let mut hdr = [0u8; SPILL_HEADER_BYTES as usize];
            file.seek(SeekFrom::Start(0))?;
            file.read_exact(&mut hdr)?;
            let version = u32::from_le_bytes(hdr[8..12].try_into().unwrap());
            if hdr[..8] == SPILL_MAGIC && version == SPILL_FORMAT {
                // well-formed, but its segments belong to a dead ledger
                file.set_len(SPILL_HEADER_BYTES)?;
                return Ok(());
            }
            eprintln!(
                "[alchemist] rebuilding torn spill file {:?} (bad magic/version)",
                path
            );
        } else if len > 0 {
            eprintln!(
                "[alchemist] rebuilding torn spill file {:?} (truncated header: {len} bytes)",
                path
            );
        }
        file.set_len(0)?;
        file.seek(SeekFrom::Start(0))?;
        let mut hdr = [0u8; SPILL_HEADER_BYTES as usize];
        hdr[..8].copy_from_slice(&SPILL_MAGIC);
        hdr[8..12].copy_from_slice(&SPILL_FORMAT.to_le_bytes());
        file.write_all(&hdr)
            .map_err(|e| anyhow::anyhow!("writing spill header to {path:?}: {e}"))?;
        Ok(())
    }

    /// Write one block's payload into a segment (first-fit hole or
    /// append); returns the segment size in bytes.
    fn write_block(&self, id: u64, session: u64, data: &[f64]) -> crate::Result<u64> {
        let bytes = (data.len() * 8) as u64;
        let mut inner = self.inner.lock().unwrap();
        anyhow::ensure!(
            !inner.segs.contains_key(&id),
            "block {id} already has a spill segment"
        );
        if inner.file.is_none() {
            let mut f = std::fs::OpenOptions::new()
                .read(true)
                .write(true)
                .create(true)
                .open(&self.path)
                .map_err(|e| anyhow::anyhow!("creating spill file {:?}: {e}", self.path))?;
            Self::validate_or_init_header(&mut f, &self.path)?;
            inner.file = Some(f);
            inner.end = inner.end.max(SPILL_HEADER_BYTES);
        }
        let offset = match inner.free.iter().position(|&(_, cap)| cap >= bytes) {
            Some(i) => {
                let (off, cap) = inner.free[i];
                if cap == bytes {
                    inner.free.remove(i);
                } else {
                    inner.free[i] = (off + bytes, cap - bytes);
                }
                off
            }
            None => {
                let off = inner.end;
                inner.end = off + bytes;
                off
            }
        };
        let write = |file: &mut File| -> std::io::Result<()> {
            file.seek(SeekFrom::Start(offset))?;
            // Safety: plain f64 buffer viewed as its raw bytes
            // (native-endian on purpose: segments never leave this host).
            let raw = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 8)
            };
            file.write_all(raw)
        };
        if let Err(e) = write(inner.file.as_mut().unwrap()) {
            // hand the hole back so a failed spill doesn't leak space
            inner.free.push((offset, bytes));
            anyhow::bail!("spill write to {:?} failed: {e}", self.path);
        }
        inner.segs.insert(id, Segment { offset, bytes, session });
        Ok(bytes)
    }

    /// Read `n_elems` f64s starting `start_elem` into block `id`'s
    /// segment.
    fn read_block_span(&self, id: u64, start_elem: usize, n_elems: usize) -> crate::Result<Vec<f64>> {
        let mut inner = self.inner.lock().unwrap();
        let seg = *inner
            .segs
            .get(&id)
            .ok_or_else(|| anyhow::anyhow!("block {id} has no spill segment"))?;
        anyhow::ensure!(
            ((start_elem + n_elems) * 8) as u64 <= seg.bytes,
            "span beyond spilled segment of block {id}"
        );
        let file = inner
            .file
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("spill file not open"))?;
        // length check against the live file: a segment extending past
        // EOF means something truncated the file behind the ledger —
        // fail with a diagnosis instead of a bare short-read error
        let file_len = file.metadata()?.len();
        anyhow::ensure!(
            seg.offset + seg.bytes <= file_len,
            "torn spill file {:?}: block {id}'s segment ends at {} but the \
             file is {file_len} bytes",
            self.path,
            seg.offset + seg.bytes,
        );
        file.seek(SeekFrom::Start(seg.offset + (start_elem * 8) as u64))?;
        let mut out = vec![0.0f64; n_elems];
        // Safety: reading raw bytes into a plain f64 buffer of exactly
        // that size; written native-endian by `write_block` on this host.
        let raw = unsafe {
            std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, n_elems * 8)
        };
        file.read_exact(raw)
            .map_err(|e| anyhow::anyhow!("spill read from {:?} failed: {e}", self.path))?;
        Ok(out)
    }

    /// Release block `id`'s segment; returns its size (0 if absent).
    fn free_seg(&self, id: u64) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        let Some(seg) = inner.segs.remove(&id) else { return 0 };
        if seg.offset + seg.bytes == inner.end {
            inner.end = seg.offset;
        } else {
            inner.free.push((seg.offset, seg.bytes));
        }
        seg.bytes
    }

    /// Release every segment owned by `session`; returns (count, bytes).
    fn free_session_segs(&self, session: u64) -> (usize, u64) {
        let ids: Vec<u64> = {
            let inner = self.inner.lock().unwrap();
            inner
                .segs
                .iter()
                .filter(|(_, s)| s.session == session)
                .map(|(id, _)| *id)
                .collect()
        };
        let mut bytes = 0;
        for id in &ids {
            bytes += self.free_seg(*id);
        }
        (ids.len(), bytes)
    }

    fn segment_count(&self) -> usize {
        self.inner.lock().unwrap().segs.len()
    }
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        if self.inner.lock().unwrap().file.is_some() {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// State shared between the store and its blocks: budget config, the
/// per-session accounting ledger, the spill file, the LRU clock, and
/// the storage-plane counters.
struct StoreShared {
    rank: usize,
    /// Per-session per-rank heap cap; 0 = unlimited.
    budget_bytes: u64,
    /// Task-boundary snapshot directory (`storage.checkpoint_dir`);
    /// empty = checkpointing off. See `docs/recovery.md`.
    checkpoint_dir: String,
    metrics: Arc<StorageMetrics>,
    /// Monotonic LRU clock; every read stamps its block.
    clock: AtomicU64,
    ledger: Mutex<HashMap<u64, SessionUsage>>,
    spill: SpillFile,
}

impl StoreShared {
    fn next_stamp(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Unconditionally add heap-resident bytes to a session's tally.
    fn charge_resident(&self, session: u64, bytes: u64) {
        self.ledger.lock().unwrap().entry(session).or_default().bytes_resident += bytes;
    }

    /// Add heap-resident bytes only if the session stays within budget.
    fn try_charge_resident(&self, session: u64, bytes: u64) -> bool {
        let mut ledger = self.ledger.lock().unwrap();
        let u = ledger.entry(session).or_default();
        if self.budget_bytes > 0 && u.bytes_resident + bytes > self.budget_bytes {
            return false;
        }
        u.bytes_resident += bytes;
        true
    }

    fn uncharge_resident(&self, session: u64, bytes: u64) {
        let mut ledger = self.ledger.lock().unwrap();
        let u = ledger.entry(session).or_default();
        u.bytes_resident = u.bytes_resident.saturating_sub(bytes);
    }

    fn charge_mapped(&self, session: u64, bytes: u64) {
        self.ledger.lock().unwrap().entry(session).or_default().bytes_mapped += bytes;
    }

    fn uncharge_mapped(&self, session: u64, bytes: u64) {
        let mut ledger = self.ledger.lock().unwrap();
        let u = ledger.entry(session).or_default();
        u.bytes_mapped = u.bytes_mapped.saturating_sub(bytes);
    }

    /// Move bytes resident → spilled in the ledger.
    fn note_spill(&self, session: u64, bytes: u64) {
        let mut ledger = self.ledger.lock().unwrap();
        let u = ledger.entry(session).or_default();
        u.bytes_resident = u.bytes_resident.saturating_sub(bytes);
        u.bytes_spilled += bytes;
    }

    /// Finish a page-in: the resident side was already reserved via
    /// [`try_charge_resident`](Self::try_charge_resident); drop the
    /// spilled side.
    fn note_page_in(&self, session: u64, bytes: u64) {
        let mut ledger = self.ledger.lock().unwrap();
        let u = ledger.entry(session).or_default();
        u.bytes_spilled = u.bytes_spilled.saturating_sub(bytes);
    }

    fn uncharge_spilled(&self, session: u64, bytes: u64) {
        let mut ledger = self.ledger.lock().unwrap();
        let u = ledger.entry(session).or_default();
        u.bytes_spilled = u.bytes_spilled.saturating_sub(bytes);
    }

    fn usage_of(&self, session: u64) -> SessionUsage {
        self.ledger.lock().unwrap().get(&session).copied().unwrap_or_default()
    }

    fn drop_session_entry(&self, session: u64) {
        let mut ledger = self.ledger.lock().unwrap();
        if let Some(u) = ledger.get(&session) {
            if *u == SessionUsage::default() {
                ledger.remove(&session);
            }
        }
    }
}

/// One worker's block of a distributed matrix. Immutable metadata plus
/// interior-mutable payload storage guarded by the stripe/seal protocol
/// and the residency mutex described in the module docs.
pub struct Block {
    pub id: u64,
    pub layout: RowBlockLayout,
    /// Index of this worker's range in `layout.ranges`: the owning
    /// session's group-local rank for this worker.
    pub slot: usize,
    /// Session that owns this matrix.
    pub session: u64,
    pub name: String,
    /// Global rank of the worker holding this block (error messages).
    rank: usize,
    state: Mutex<IngestState>,
    stripes: [Mutex<()>; INGEST_STRIPES],
    /// Pre-seal ingest buffer (`layout.ranges[slot]`'s rows, row-major).
    /// Mutated only through [`Block::write_span`] before sealing; `seal`
    /// moves the payload out into `res` and leaves this empty.
    data: UnsafeCell<LocalMatrix>,
    /// Raw pointer to `data`'s element buffer, captured at construction
    /// (the buffer is fixed-size and never reallocated before seal, so
    /// it stays valid for the ingest phase). Writers derive their span's
    /// `&mut [f64]` from this instead of creating `&mut LocalMatrix`
    /// through the cell — a whole-buffer exclusive reference would alias
    /// between concurrent writers on disjoint stripes.
    base: *mut f64,
    /// Element count behind `base` (span bounds sanity checks).
    len: usize,
    /// Where the sealed payload lives (see [`Residency`]).
    res: Mutex<Residency>,
    /// LRU clock stamp of the last read (spill victim selection).
    last_use: AtomicU64,
    shared: Arc<StoreShared>,
}

// Safety: the raw `base` pointer (which suppresses the auto impls)
// points into the heap buffer owned by `data`, so it moves with the
// block. Payload bytes are only written through per-span `&mut [f64]`
// slices derived from `base` while holding the stripe locks covering
// exactly those rows and only while not `sealed` (checked under the
// state mutex after stripe acquisition), so concurrent writers' spans —
// and therefore their exclusive references — are disjoint. Readers
// require `readable`, which `seal` sets only after a full stripe
// barrier has waited out every in-flight writer AND the payload has
// moved out of the cell into `res` — so reads never touch the cell at
// all, and the state mutex publishes the writes. See the module docs.
unsafe impl Send for Block {}
unsafe impl Sync for Block {}

impl std::fmt::Debug for Block {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Block")
            .field("id", &self.id)
            .field("name", &self.name)
            .field("slot", &self.slot)
            .field("session", &self.session)
            .field("sealed", &self.sealed())
            .field("rows_received", &self.rows_received())
            .finish()
    }
}

impl Block {
    fn new(
        id: u64,
        name: &str,
        layout: RowBlockLayout,
        slot: usize,
        session: u64,
        shared: Arc<StoreShared>,
        local: Option<LocalMatrix>,
    ) -> crate::Result<Self> {
        let rank = shared.rank;
        anyhow::ensure!(
            slot < layout.ranges.len(),
            "slot {slot} outside layout of {} ranges",
            layout.ranges.len()
        );
        let (a, b) = layout.ranges[slot];
        let (mut ingest, res, sealed, rows_received) = match local {
            Some(m) => {
                anyhow::ensure!(
                    m.rows() == b - a && m.cols() == layout.cols,
                    "block shape {}x{} does not match layout slot {}x{} on rank {rank}",
                    m.rows(),
                    m.cols(),
                    b - a,
                    layout.cols,
                );
                let rows = m.rows() as u64;
                // born sealed: payload goes straight to its heap home,
                // the ingest cell stays empty
                (
                    LocalMatrix::zeros(0, 0),
                    Residency::Heap(Arc::new(m)),
                    true,
                    rows,
                )
            }
            None => (
                LocalMatrix::zeros(b - a, layout.cols),
                Residency::Ingest,
                false,
                0,
            ),
        };
        // capture the element buffer's base pointer while we still own
        // the matrix uniquely; moving the LocalMatrix into the cell moves
        // only its header, not the heap buffer the pointer targets
        let buf = ingest.data_mut();
        let len = buf.len();
        let base = buf.as_mut_ptr();
        let stamp = shared.next_stamp();
        Ok(Block {
            id,
            layout,
            slot,
            session,
            name: name.to_string(),
            rank,
            state: Mutex::new(IngestState {
                rows_received,
                sealed,
                readable: sealed,
            }),
            stripes: Default::default(),
            data: UnsafeCell::new(ingest),
            base,
            len,
            res: Mutex::new(res),
            last_use: AtomicU64::new(stamp),
            shared,
        })
    }

    /// A block whose payload is an mmap-backed file view (`LoadMatrix`
    /// direct ingest). Born sealed; the map must cover the layout's full
    /// global shape — the block serves rows `layout.ranges[slot]` of it.
    fn new_mapped(
        id: u64,
        name: &str,
        layout: RowBlockLayout,
        slot: usize,
        session: u64,
        shared: Arc<StoreShared>,
        map: Arc<MappedMatrix>,
    ) -> crate::Result<Self> {
        let rank = shared.rank;
        anyhow::ensure!(
            slot < layout.ranges.len(),
            "slot {slot} outside layout of {} ranges",
            layout.ranges.len()
        );
        anyhow::ensure!(
            map.rows() == layout.rows && map.cols() == layout.cols,
            "mapped file shape {}x{} does not match layout {}x{} on rank {rank}",
            map.rows(),
            map.cols(),
            layout.rows,
            layout.cols,
        );
        let (a, b) = layout.ranges[slot];
        let rows_received = (b - a) as u64;
        let mut empty = LocalMatrix::zeros(0, 0);
        let buf = empty.data_mut();
        let len = buf.len();
        let base = buf.as_mut_ptr();
        let stamp = shared.next_stamp();
        Ok(Block {
            id,
            layout,
            slot,
            session,
            name: name.to_string(),
            rank,
            state: Mutex::new(IngestState {
                rows_received,
                sealed: true,
                readable: true,
            }),
            stripes: Default::default(),
            data: UnsafeCell::new(empty),
            base,
            len,
            res: Mutex::new(Residency::Mapped(map)),
            last_use: AtomicU64::new(stamp),
            shared,
        })
    }

    pub fn sealed(&self) -> bool {
        self.state.lock().unwrap().sealed
    }

    /// True once `seal` has fully completed (flag flipped AND the stripe
    /// barrier passed) — the gate every reader checks. Distinct from
    /// [`sealed`](Self::sealed), which flips first to stop writers.
    fn readable(&self) -> bool {
        self.state.lock().unwrap().readable
    }

    pub fn rows_received(&self) -> u64 {
        self.state.lock().unwrap().rows_received
    }

    /// This block's local row count (`layout.ranges[slot]`).
    pub fn local_rows(&self) -> usize {
        let (a, b) = self.layout.ranges[self.slot];
        b - a
    }

    /// Full payload size in bytes (independent of residency).
    pub fn payload_bytes(&self) -> u64 {
        (self.local_rows() as u64) * (self.layout.cols as u64) * 8
    }

    /// True when the payload is an mmap-backed file view.
    pub fn is_mapped(&self) -> bool {
        matches!(*self.res.lock().unwrap(), Residency::Mapped(_))
    }

    /// True when the payload is currently parked in the spill file.
    pub fn is_spilled(&self) -> bool {
        matches!(*self.res.lock().unwrap(), Residency::Spilled { .. })
    }

    /// Bounds-check a global row span against this block's range; returns
    /// the local start row.
    fn span_local_start(&self, start_row: u64, nrows: usize) -> crate::Result<usize> {
        let (lo, hi) = self.layout.ranges[self.slot];
        let start = usize::try_from(start_row)
            .map_err(|_| anyhow::anyhow!("row index {start_row} out of range"))?;
        let end = start
            .checked_add(nrows)
            .ok_or_else(|| anyhow::anyhow!("row span end overflows"))?;
        anyhow::ensure!(
            start >= lo && end <= hi,
            "rows [{start}, {end}) outside rank {} range [{lo}, {hi})",
            self.rank
        );
        Ok(start - lo)
    }

    /// Stripe index owning local row `row` (rows divide evenly-ish across
    /// [`INGEST_STRIPES`] fixed bands).
    fn stripe_of(&self, row: usize, local_rows: usize) -> usize {
        debug_assert!(local_rows > 0);
        (row * INGEST_STRIPES / local_rows).min(INGEST_STRIPES - 1)
    }

    /// Copy `nrows` rows into the block at `start_row` (global), with the
    /// writer-side locking protocol: acquire covering stripes in order,
    /// re-check `sealed`, copy, then account under the state mutex.
    fn write_span(
        &self,
        start_row: u64,
        ncols: usize,
        nrows: usize,
        fill: impl FnOnce(&mut [f64]),
    ) -> crate::Result<()> {
        anyhow::ensure!(
            ncols == self.layout.cols,
            "row width {ncols} != matrix cols {}",
            self.layout.cols
        );
        let local_start = self.span_local_start(start_row, nrows)?;
        if nrows == 0 {
            return Ok(());
        }
        let (lo, hi) = self.layout.ranges[self.slot];
        let local_rows = hi - lo;
        let first = self.stripe_of(local_start, local_rows);
        let last = self.stripe_of(local_start + nrows - 1, local_rows);
        let guards: Vec<_> =
            (first..=last).map(|i| self.stripes[i].lock().unwrap()).collect();
        {
            let st = self.state.lock().unwrap();
            anyhow::ensure!(!st.sealed, "matrix {} is sealed", self.id);
        }
        debug_assert!((local_start + nrows) * ncols <= self.len);
        // Safety: the stripes covering [local_start, local_start+nrows)
        // are held, so this element range is ours alone; every concurrent
        // writer builds its slice the same way over its own (disjoint)
        // span from the raw `base` pointer, so no exclusive reference
        // over the whole buffer — which would alias between writers —
        // ever exists. Readers are excluded because the block is not
        // `readable` yet — that flag is set only after `seal`'s stripe
        // barrier has waited us out (and after seal, reads go through
        // `res`, never the cell).
        let dst = unsafe {
            std::slice::from_raw_parts_mut(
                self.base.add(local_start * ncols),
                nrows * ncols,
            )
        };
        fill(dst);
        // account while still holding the stripes: once `seal`'s barrier
        // passes our stripes, our rows are guaranteed to be in the count
        self.state.lock().unwrap().rows_received += nrows as u64;
        drop(guards);
        Ok(())
    }

    /// Write incoming rows (global indices) given as f64s.
    pub fn write_rows(
        &self,
        start_row: u64,
        ncols: usize,
        data: &[f64],
    ) -> crate::Result<()> {
        anyhow::ensure!(ncols > 0 && data.len() % ncols == 0, "ragged row payload");
        self.write_span(start_row, ncols, data.len() / ncols, |dst| {
            dst.copy_from_slice(data)
        })
    }

    /// Write incoming rows straight from little-endian wire bytes — the
    /// single-copy ingest path (frame receive buffer → block storage).
    pub fn write_rows_bytes(
        &self,
        start_row: u64,
        ncols: usize,
        payload: &[u8],
    ) -> crate::Result<()> {
        anyhow::ensure!(
            ncols > 0 && payload.len() % (ncols * 8) == 0,
            "ragged row payload"
        );
        self.write_span(start_row, ncols, payload.len() / (ncols * 8), |dst| {
            copy_le_f64s(payload, dst)
        })
    }

    /// Validate a read span (sealed + bounds) without touching payload
    /// bytes — pull serving pre-validates with this so a spilled block
    /// is not read off disk twice.
    pub fn validate_span(&self, start_row: u64, nrows: usize) -> crate::Result<()> {
        anyhow::ensure!(
            self.readable(),
            "matrix {} is still being ingested (not sealed)",
            self.id
        );
        self.span_local_start(start_row, nrows)?;
        Ok(())
    }

    /// Try to promote a spilled payload back to the heap (caller holds
    /// the residency lock and has confirmed `Spilled`). Returns the new
    /// heap Arc, or `None` when the session's budget has no room.
    fn page_in_locked(
        &self,
        res: &mut Residency,
        bytes: u64,
    ) -> crate::Result<Option<Arc<LocalMatrix>>> {
        if !self.shared.try_charge_resident(self.session, bytes) {
            return Ok(None);
        }
        let total = self.local_rows() * self.layout.cols;
        let buf = match self.shared.spill.read_block_span(self.id, 0, total) {
            Ok(b) => b,
            Err(e) => {
                self.shared.uncharge_resident(self.session, bytes);
                return Err(e);
            }
        };
        let arc = Arc::new(LocalMatrix::from_data(self.local_rows(), self.layout.cols, buf));
        *res = Residency::Heap(arc.clone());
        self.shared.spill.free_seg(self.id);
        self.shared.note_page_in(self.session, bytes);
        self.shared.metrics.paged_in(bytes);
        Ok(Some(arc))
    }

    /// Borrow rows (global indices) out of a sealed block — the zero-copy
    /// worker side of a streaming pull. Heap and mapped payloads are
    /// served in place (the guard pins them); spilled payloads page back
    /// in when the budget allows, else stream transiently from disk.
    /// Fails on unsealed blocks (ingest still running ⇒ the span could
    /// be mid-write).
    pub fn read_span(&self, start_row: u64, nrows: usize) -> crate::Result<Span> {
        anyhow::ensure!(
            self.readable(),
            "matrix {} is still being ingested (not sealed)",
            self.id
        );
        let local_start = self.span_local_start(start_row, nrows)?;
        let ncols = self.layout.cols;
        self.last_use.store(self.shared.next_stamp(), Ordering::Relaxed);
        let mut res = self.res.lock().unwrap();
        match &*res {
            Residency::Heap(m) => Ok(Span::Heap {
                data: m.clone(),
                start: local_start * ncols,
                len: nrows * ncols,
            }),
            Residency::Mapped(map) => {
                let (lo, _) = self.layout.ranges[self.slot];
                Ok(Span::Mapped {
                    map: map.clone(),
                    start: (lo + local_start) * ncols,
                    len: nrows * ncols,
                })
            }
            Residency::Spilled { bytes } => {
                let bytes = *bytes;
                if let Some(arc) = self.page_in_locked(&mut res, bytes)? {
                    return Ok(Span::Heap {
                        data: arc,
                        start: local_start * ncols,
                        len: nrows * ncols,
                    });
                }
                // no budget room: stream just this span off the disk
                let buf = self.shared.spill.read_block_span(
                    self.id,
                    local_start * ncols,
                    nrows * ncols,
                )?;
                self.shared.metrics.read_spilled((nrows * ncols * 8) as u64);
                Ok(Span::Owned(buf))
            }
            Residency::Ingest => anyhow::bail!(
                "matrix {} readable but payload still in ingest state (bug)",
                self.id
            ),
        }
    }

    /// Copy rows (global indices) out of a sealed block.
    pub fn read_rows(&self, start_row: u64, nrows: usize) -> crate::Result<Vec<f64>> {
        Ok(self.read_span(start_row, nrows)?.to_vec())
    }

    /// Clone this rank's sealed block for compute (routines never hold
    /// store or block locks while working).
    pub fn snapshot(&self) -> crate::Result<(RowBlockLayout, LocalMatrix)> {
        anyhow::ensure!(self.readable(), "matrix {} is not sealed yet", self.id);
        self.last_use.store(self.shared.next_stamp(), Ordering::Relaxed);
        let mut res = self.res.lock().unwrap();
        let local = match &*res {
            Residency::Heap(m) => (**m).clone(),
            Residency::Mapped(map) => {
                let (lo, hi) = self.layout.ranges[self.slot];
                LocalMatrix::from_data(
                    hi - lo,
                    self.layout.cols,
                    map.row_span(lo, hi)?.to_vec(),
                )
            }
            Residency::Spilled { bytes } => {
                let bytes = *bytes;
                match self.page_in_locked(&mut res, bytes)? {
                    Some(arc) => (*arc).clone(),
                    None => {
                        // transient whole-block read, residency unchanged
                        let total = self.local_rows() * self.layout.cols;
                        let buf = self.shared.spill.read_block_span(self.id, 0, total)?;
                        self.shared.metrics.read_spilled(bytes);
                        LocalMatrix::from_data(self.local_rows(), self.layout.cols, buf)
                    }
                }
            }
            Residency::Ingest => anyhow::bail!(
                "matrix {} readable but payload still in ingest state (bug)",
                self.id
            ),
        };
        Ok((self.layout.clone(), local))
    }

    /// Park a heap-resident sealed payload in the spill file; returns the
    /// bytes moved (0 when the block is not currently heap-resident —
    /// racing spills/page-ins make that benign).
    fn spill(&self) -> crate::Result<u64> {
        let mut res = self.res.lock().unwrap();
        let arc = match &*res {
            Residency::Heap(m) => m.clone(),
            _ => return Ok(0),
        };
        if !self.readable() {
            // sealed-at-birth blocks are readable immediately; push-ingest
            // blocks only reach Residency::Heap inside seal() — but check
            // anyway so an unreadable block can never lose its payload
            return Ok(0);
        }
        let bytes = self.shared.spill.write_block(self.id, self.session, arc.data())?;
        *res = Residency::Spilled { bytes };
        drop(res);
        self.shared.note_spill(self.session, bytes);
        self.shared.metrics.spilled(bytes);
        Ok(bytes)
    }

    /// True when [`spill`](Self::spill) could move bytes right now.
    fn spillable(&self) -> bool {
        self.readable()
            && self.payload_bytes() > 0
            && matches!(*self.res.lock().unwrap(), Residency::Heap(_))
    }

    /// Freeze the block: no further writes land after this returns, every
    /// row written before it is in the returned count, and only now do
    /// readers get the green light.
    fn seal(&self) -> u64 {
        self.state.lock().unwrap().sealed = true;
        // barrier: wait out writers that passed their seal check before
        // the flag flipped (they hold their stripes while copying AND
        // accounting, so after this loop the payload is quiescent and
        // every landed row is counted)
        for s in &self.stripes {
            drop(s.lock().unwrap());
        }
        // move the quiescent payload out of the ingest cell into its heap
        // home BEFORE admitting readers — readers only ever look at `res`,
        // so they must never find it still in `Ingest`
        {
            let mut res = self.res.lock().unwrap();
            if matches!(*res, Residency::Ingest) {
                // Safety: `sealed` + the stripe barrier exclude writers;
                // `readable` is still false so no reader exists. This is
                // the only &mut through the cell after construction.
                let cell = unsafe { &mut *self.data.get() };
                let payload = std::mem::replace(cell, LocalMatrix::zeros(0, 0));
                *res = Residency::Heap(Arc::new(payload));
            }
        }
        // only now may readers touch the payload; the same lock publishes
        // the in-flight writers' bytes and counts to them
        let mut st = self.state.lock().unwrap();
        st.readable = true;
        st.rows_received
    }
}

/// Blocks stream row panels straight off their residency tier — heap and
/// mapped payloads are gathered from memory, spilled blocks read only the
/// requested rows off disk. This is the seam
/// [`crate::linalg::lanczos::truncated_svd_panels`] computes through: an
/// SVD over a dataset several times the storage budget touches one panel
/// at a time.
impl crate::linalg::lanczos::RowPanels for Block {
    fn rows(&self) -> usize {
        self.local_rows()
    }

    fn cols(&self) -> usize {
        self.layout.cols
    }

    fn panel(
        &self,
        start: usize,
        n: usize,
    ) -> crate::Result<std::borrow::Cow<'_, LocalMatrix>> {
        let span = self.read_span(start as u64, n)?;
        Ok(std::borrow::Cow::Owned(LocalMatrix::from_data(
            n,
            self.layout.cols,
            span.to_vec(),
        )))
    }
}

/// Process-wide counter making spill file names unique across the many
/// stores one test binary creates.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

fn spill_path(cfg_dir: &str, rank: usize) -> PathBuf {
    let dir = if cfg_dir.is_empty() {
        std::env::temp_dir()
    } else {
        PathBuf::from(cfg_dir)
    };
    let seq = SPILL_SEQ.fetch_add(1, Ordering::Relaxed);
    dir.join(format!(
        "alchemist-spill-r{rank}-p{}-{seq}.bin",
        std::process::id()
    ))
}

/// Task-boundary checkpoint file for one block's local shard. The name
/// is a pure function of `(session, matrix id, slot)` so the coordinator
/// can derive the same path when replaying a dead rank's shards onto a
/// spare (`StoreRestore`) without ever asking the dead rank. The file
/// holds ONLY the slot's local rows (an `hdf5sim` matrix of
/// `local_rows × cols`), not the global matrix.
pub fn checkpoint_path(dir: &str, session: u64, id: u64, slot: usize) -> PathBuf {
    PathBuf::from(dir).join(format!("alchemist-ckpt-s{session}-m{id}-slot{slot}.h5sim"))
}

/// Matrix-id → block map for one worker rank. Interior-locked: lookups
/// take a short read lock, payload writes synchronize per block (see the
/// module docs), so the store itself never serializes concurrent
/// executor streams.
pub struct MatrixStore {
    blocks: RwLock<HashMap<u64, Arc<Block>>>,
    shared: Arc<StoreShared>,
}

impl std::fmt::Debug for MatrixStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MatrixStore")
            .field("rank", &self.shared.rank)
            .field("blocks", &self.len())
            .field("budget_bytes", &self.shared.budget_bytes)
            .finish()
    }
}

impl Default for MatrixStore {
    fn default() -> Self {
        Self::new(0)
    }
}

impl MatrixStore {
    /// An unlimited-budget store (no spill unless configured) — the
    /// default for tests and budget-less deployments.
    pub fn new(rank: usize) -> Self {
        Self::with_storage(
            rank,
            &StorageConfig {
                budget_bytes: 0,
                total_bytes: 0,
                spill_dir: String::new(),
                checkpoint_dir: String::new(),
            },
            Arc::new(StorageMetrics::new()),
        )
    }

    /// A store enforcing `cfg.budget_bytes` per session on this rank,
    /// spilling to a fresh ledgered file under `cfg.spill_dir` (empty =
    /// the system temp dir) and reporting into `metrics`.
    pub fn with_storage(
        rank: usize,
        cfg: &StorageConfig,
        metrics: Arc<StorageMetrics>,
    ) -> Self {
        MatrixStore {
            blocks: RwLock::new(HashMap::new()),
            shared: Arc::new(StoreShared {
                rank,
                budget_bytes: cfg.budget_bytes,
                checkpoint_dir: cfg.checkpoint_dir.clone(),
                metrics,
                clock: AtomicU64::new(0),
                ledger: Mutex::new(HashMap::new()),
                spill: SpillFile::new(spill_path(&cfg.spill_dir, rank)),
            }),
        }
    }

    /// The task-boundary checkpoint directory (empty = off).
    pub fn checkpoint_dir(&self) -> &str {
        &self.shared.checkpoint_dir
    }

    pub fn rank(&self) -> usize {
        self.shared.rank
    }

    /// The per-session heap budget this store enforces (0 = unlimited).
    pub fn budget_bytes(&self) -> u64 {
        self.shared.budget_bytes
    }

    /// This rank's storage-plane counters (shared with the server's
    /// aggregation surface).
    pub fn storage_metrics(&self) -> Arc<StorageMetrics> {
        self.shared.metrics.clone()
    }

    /// Storage totals for one session on this rank.
    pub fn session_usage(&self, session: u64) -> SessionUsage {
        self.shared.usage_of(session)
    }

    /// Storage totals for every session with live bytes on this rank,
    /// sorted by session id.
    pub fn usage(&self) -> Vec<(u64, SessionUsage)> {
        let mut v: Vec<(u64, SessionUsage)> = self
            .shared
            .ledger
            .lock()
            .unwrap()
            .iter()
            .map(|(s, u)| (*s, *u))
            .collect();
        v.sort_unstable_by_key(|(s, _)| *s);
        v
    }

    /// Live segments in this rank's spill file (teardown tests).
    pub fn spill_segments(&self) -> usize {
        self.shared.spill.segment_count()
    }

    fn add(&self, id: u64, block: Block) -> crate::Result<()> {
        let mut blocks = self.blocks.write().unwrap();
        anyhow::ensure!(
            !blocks.contains_key(&id),
            "matrix id {id} already exists on rank {}",
            self.shared.rank
        );
        blocks.insert(id, Arc::new(block));
        Ok(())
    }

    /// Spill this session's least-recently-used sealed heap block.
    /// `Ok(false)` = nothing left to spill.
    fn spill_one_lru(&self, session: u64) -> crate::Result<bool> {
        let candidate = {
            let blocks = self.blocks.read().unwrap();
            blocks
                .values()
                .filter(|b| b.session == session && b.spillable())
                .min_by_key(|b| b.last_use.load(Ordering::Relaxed))
                .cloned()
        };
        match candidate {
            None => Ok(false),
            // a racing reader may have spilled/promoted it meanwhile;
            // spill() returns 0 then and the caller's loop re-scans
            Some(b) => Ok(b.spill()? > 0 || {
                // nothing moved — report progress only if some other
                // thread's spill beat us (the re-scan will see it)
                b.is_spilled()
            }),
        }
    }

    /// Reserve `bytes` of heap budget for `session`, spilling LRU sealed
    /// blocks as needed; fails when the reservation cannot fit even with
    /// everything spillable spilled.
    fn reserve_or_spill(&self, session: u64, bytes: u64) -> crate::Result<()> {
        let budget = self.shared.budget_bytes;
        if budget > 0 && bytes > budget {
            anyhow::bail!(
                "allocation of {bytes} bytes exceeds storage.budget_bytes={budget} \
                 on rank {}; use LoadMatrix (mapped ingest is budget-exempt) or \
                 raise the budget",
                self.shared.rank
            );
        }
        loop {
            if self.shared.try_charge_resident(session, bytes) {
                return Ok(());
            }
            if !self.spill_one_lru(session)? {
                let u = self.shared.usage_of(session);
                anyhow::bail!(
                    "session {session} over storage budget on rank {}: need {bytes} \
                     bytes, {} resident of {budget}, and nothing left to spill \
                     (unsealed ingest blocks cannot spill)",
                    self.shared.rank,
                    u.bytes_resident
                );
            }
        }
    }

    /// Spill LRU sealed blocks until `session` is back under budget
    /// (no-op when unlimited). Best-effort: stops when nothing is left
    /// to spill.
    fn rebalance(&self, session: u64) -> crate::Result<()> {
        let budget = self.shared.budget_bytes;
        if budget == 0 {
            return Ok(());
        }
        while self.shared.usage_of(session).bytes_resident > budget {
            if !self.spill_one_lru(session)? {
                break;
            }
        }
        Ok(())
    }

    /// Allocate a zeroed, unsealed block for ingest. `slot` is this
    /// worker's index into `layout.ranges` (the session's group-local
    /// rank); `session` namespaces the block for teardown. Charged
    /// against the session's storage budget up front — ingest buffers
    /// cannot spill, so an allocation that cannot fit is rejected here
    /// with a clean error rather than OOMing the rank later.
    pub fn alloc(
        &self,
        id: u64,
        name: &str,
        layout: RowBlockLayout,
        slot: usize,
        session: u64,
    ) -> crate::Result<()> {
        let block = Block::new(id, name, layout, slot, session, self.shared.clone(), None)?;
        let bytes = block.payload_bytes();
        self.reserve_or_spill(session, bytes)?;
        if let Err(e) = self.add(id, block) {
            self.shared.uncharge_resident(session, bytes);
            return Err(e);
        }
        Ok(())
    }

    /// Insert a fully-formed (already computed) block — routine outputs.
    /// Always lands, then LRU blocks (possibly this one) spill until the
    /// session is back under budget.
    pub fn insert(
        &self,
        id: u64,
        name: &str,
        layout: RowBlockLayout,
        local: LocalMatrix,
        slot: usize,
        session: u64,
    ) -> crate::Result<()> {
        let block =
            Block::new(id, name, layout, slot, session, self.shared.clone(), Some(local))?;
        let bytes = block.payload_bytes();
        self.shared.charge_resident(session, bytes);
        if let Err(e) = self.add(id, block) {
            self.shared.uncharge_resident(session, bytes);
            return Err(e);
        }
        self.rebalance(session)?;
        // born-sealed blocks (routine outputs, restored shards) hit the
        // checkpoint the moment they land — a task boundary by definition
        self.checkpoint_block(&self.get(id)?)
    }

    /// Register an mmap-backed block (`LoadMatrix` direct ingest). Born
    /// sealed; the payload is the page cache's view of the file — zero
    /// heap bytes, exempt from the session budget.
    pub fn insert_mapped(
        &self,
        id: u64,
        name: &str,
        layout: RowBlockLayout,
        map: Arc<MappedMatrix>,
        slot: usize,
        session: u64,
    ) -> crate::Result<()> {
        let block =
            Block::new_mapped(id, name, layout, slot, session, self.shared.clone(), map)?;
        let bytes = block.payload_bytes();
        self.shared.charge_mapped(session, bytes);
        if let Err(e) = self.add(id, block) {
            self.shared.uncharge_mapped(session, bytes);
            return Err(e);
        }
        self.shared.metrics.mapped_block();
        Ok(())
    }

    /// Look a block up under the read lock; the returned handle outlives
    /// the lock (pulls stream from it, ingest writes through it).
    pub fn get(&self, id: u64) -> crate::Result<Arc<Block>> {
        self.blocks
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| {
                anyhow::anyhow!("matrix {id} not found on rank {}", self.shared.rank)
            })
    }

    /// Write incoming rows (global indices) into an unsealed block.
    pub fn write_rows(
        &self,
        id: u64,
        start_row: u64,
        ncols: usize,
        data: &[f64],
    ) -> crate::Result<()> {
        self.get(id)?.write_rows(start_row, ncols, data)
    }

    /// Read rows (global indices) out of a sealed block.
    pub fn read_rows(&self, id: u64, start_row: u64, nrows: usize) -> crate::Result<Vec<f64>> {
        self.get(id)?.read_rows(start_row, nrows)
    }

    pub fn seal(&self, id: u64) -> crate::Result<u64> {
        let b = self.get(id)?;
        let rows = b.seal();
        self.checkpoint_block(&b)?;
        Ok(rows)
    }

    /// Write block `b`'s local shard to its task-boundary checkpoint
    /// file (no-op when checkpointing is off or the payload is mapped —
    /// a mapped block's source file IS its checkpoint). Re-running this
    /// for a restored block overwrites the same path, so replay is
    /// idempotent.
    fn checkpoint_block(&self, b: &Arc<Block>) -> crate::Result<()> {
        let dir = &self.shared.checkpoint_dir;
        if dir.is_empty() || b.is_mapped() {
            return Ok(());
        }
        let (_, local) = b.snapshot()?;
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("creating checkpoint dir {dir:?}: {e}"))?;
        let path = checkpoint_path(dir, b.session, b.id, b.slot);
        crate::hdf5sim::write_matrix(&path, &local)
            .map_err(|e| anyhow::anyhow!("checkpointing matrix {} to {path:?}: {e}", b.id))
    }

    /// Release one block's accounting (and spill segment, if any) as it
    /// leaves the map.
    fn release(&self, b: &Arc<Block>) {
        let res = b.res.lock().unwrap();
        match &*res {
            Residency::Ingest | Residency::Heap(_) => {
                self.shared.uncharge_resident(b.session, b.payload_bytes());
            }
            Residency::Mapped(_) => {
                self.shared.uncharge_mapped(b.session, b.payload_bytes());
            }
            Residency::Spilled { bytes } => {
                self.shared.uncharge_spilled(b.session, *bytes);
                self.shared.spill.free_seg(b.id);
            }
        }
        // the handle is gone everywhere once free/free_session returns —
        // its snapshot must not outlive it (leak check in the chaos soak)
        if !self.shared.checkpoint_dir.is_empty() {
            let _ = std::fs::remove_file(checkpoint_path(
                &self.shared.checkpoint_dir,
                b.session,
                b.id,
                b.slot,
            ));
        }
    }

    pub fn free(&self, id: u64) -> bool {
        let removed = self.blocks.write().unwrap().remove(&id);
        match removed {
            Some(b) => {
                self.release(&b);
                self.shared.drop_session_entry(b.session);
                true
            }
            None => false,
        }
    }

    /// Drop every block owned by `session` (teardown); returns how many
    /// were freed. Budget charges are released and the session's spill
    /// segments deleted; other sessions' blocks are untouched.
    pub fn free_session(&self, session: u64) -> usize {
        let removed: Vec<Arc<Block>> = {
            let mut blocks = self.blocks.write().unwrap();
            let ids: Vec<u64> = blocks
                .iter()
                .filter(|(_, b)| b.session == session)
                .map(|(id, _)| *id)
                .collect();
            ids.iter().filter_map(|id| blocks.remove(id)).collect()
        };
        for b in &removed {
            self.release(b);
        }
        // belt and braces: drop any segment the residency walk missed
        // (there should be none) and the ledger entry once it is zero
        self.shared.spill.free_session_segs(session);
        self.shared.drop_session_entry(session);
        removed.len()
    }

    pub fn ids(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self.blocks.read().unwrap().keys().copied().collect();
        v.sort_unstable();
        v
    }

    pub fn len(&self) -> usize {
        self.blocks.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.read().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SID: u64 = 11;

    fn layout2() -> RowBlockLayout {
        RowBlockLayout::even(10, 3, 2)
    }

    /// A store with a per-session budget (spill file in the temp dir).
    fn budgeted(rank: usize, budget: u64) -> MatrixStore {
        MatrixStore::with_storage(
            rank,
            &StorageConfig {
                budget_bytes: budget,
                total_bytes: 0,
                spill_dir: String::new(),
                checkpoint_dir: String::new(),
            },
            Arc::new(StorageMetrics::new()),
        )
    }

    #[test]
    fn ingest_flow() {
        let s = MatrixStore::new(1); // slot 1 owns rows [5, 10)
        s.alloc(7, "X", layout2(), 1, SID).unwrap();
        s.write_rows(7, 5, 3, &[1.0; 6]).unwrap(); // rows 5,6
        s.write_rows(7, 7, 3, &[2.0; 9]).unwrap(); // rows 7,8,9
        assert_eq!(s.seal(7).unwrap(), 5);
        let b = s.get(7).unwrap();
        let (_, local) = b.snapshot().unwrap();
        assert_eq!(local.get(0, 0), 1.0);
        assert_eq!(local.get(2, 2), 2.0);
        // reads are in global coordinates
        assert_eq!(s.read_rows(7, 9, 1).unwrap(), vec![2.0, 2.0, 2.0]);
        // zero-copy span points at the same rows
        assert_eq!(&b.read_span(9, 1).unwrap()[..], &[2.0, 2.0, 2.0]);
    }

    #[test]
    fn byte_ingest_matches_f64_ingest() {
        let s = MatrixStore::new(0); // slot 0 owns rows [0, 5)
        s.alloc(1, "X", layout2(), 0, SID).unwrap();
        let rows = [1.5f64, -2.5, 3.0, 4.0, 5.0, 6.5];
        let mut bytes = Vec::new();
        for x in &rows {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        s.get(1).unwrap().write_rows_bytes(0, 3, &bytes).unwrap();
        s.seal(1).unwrap();
        assert_eq!(s.read_rows(1, 0, 2).unwrap(), rows);
    }

    #[test]
    fn slot_decouples_from_global_rank() {
        // a worker with global rank 5 fills slot 0 of a 2-range layout
        // (session-scoped groups: group-local rank != global rank)
        let s = MatrixStore::new(5);
        s.alloc(1, "X", layout2(), 0, SID).unwrap();
        s.write_rows(1, 0, 3, &[3.0; 15]).unwrap(); // rows [0, 5)
        assert_eq!(s.seal(1).unwrap(), 5);
        assert_eq!(s.read_rows(1, 4, 1).unwrap(), vec![3.0, 3.0, 3.0]);
        // rows of the other slot are rejected
        assert!(s.write_rows(1, 5, 3, &[0.0; 3]).is_err());
    }

    #[test]
    fn rejects_bad_writes() {
        let s = MatrixStore::new(0); // slot 0 owns rows [0, 5)
        s.alloc(1, "X", layout2(), 0, SID).unwrap();
        assert!(s.alloc(1, "X", layout2(), 0, SID).is_err()); // duplicate id
        assert!(s.alloc(2, "X", layout2(), 9, SID).is_err()); // bad slot
        assert!(s.write_rows(1, 4, 3, &[0.0; 6]).is_err()); // crosses range end
        assert!(s.write_rows(1, 0, 2, &[0.0; 2]).is_err()); // wrong width
        assert!(s.write_rows(2, 0, 3, &[0.0; 3]).is_err()); // unknown id
        s.seal(1).unwrap();
        assert!(s.write_rows(1, 0, 3, &[0.0; 3]).is_err()); // sealed
        assert!(s.read_rows(1, 4, 2).is_err()); // read crosses range
    }

    #[test]
    fn reads_require_seal() {
        let s = MatrixStore::new(0);
        s.alloc(1, "X", layout2(), 0, SID).unwrap();
        let b = s.get(1).unwrap();
        assert!(b.read_span(0, 1).is_err());
        assert!(b.snapshot().is_err());
        s.seal(1).unwrap();
        assert!(b.read_span(0, 1).is_ok());
        assert!(b.snapshot().is_ok());
    }

    #[test]
    fn insert_checks_shape() {
        let s = MatrixStore::new(0);
        let l = layout2();
        assert!(s
            .insert(3, "W", l.clone(), LocalMatrix::zeros(4, 3), 0, SID)
            .is_err());
        s.insert(3, "W", l, LocalMatrix::zeros(5, 3), 0, SID).unwrap();
        assert!(s.get(3).unwrap().sealed());
        assert!(s.free(3));
        assert!(!s.free(3));
    }

    #[test]
    fn free_session_is_scoped() {
        let s = MatrixStore::new(0);
        s.alloc(1, "A", layout2(), 0, 100).unwrap();
        s.alloc(2, "B", layout2(), 0, 100).unwrap();
        s.alloc(3, "C", layout2(), 1, 200).unwrap();
        assert_eq!(s.free_session(100), 2);
        assert_eq!(s.ids(), vec![3]);
        assert_eq!(s.free_session(100), 0);
        assert_eq!(s.free_session(200), 1);
        assert!(s.is_empty());
    }

    #[test]
    fn seal_racing_a_writer_counts_exactly_the_landed_rows() {
        // a seal fired mid-stream must (a) include every write that
        // returned Ok, (b) reject everything after, (c) never tear data
        let layout = RowBlockLayout::even(4096, 1, 1);
        let s = Arc::new(MatrixStore::new(0));
        s.alloc(5, "X", layout, 0, SID).unwrap();
        let writer = {
            let s = s.clone();
            std::thread::spawn(move || {
                let mut landed = 0u64;
                for row in 0..4096u64 {
                    match s.write_rows(5, row, 1, &[row as f64]) {
                        Ok(()) => landed += 1,
                        Err(_) => break, // sealed mid-stream
                    }
                }
                landed
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(2));
        let sealed_count = s.seal(5).unwrap();
        let landed = writer.join().unwrap();
        assert_eq!(sealed_count, landed, "seal lost or invented rows");
        assert_eq!(s.get(5).unwrap().rows_received(), landed);
        // rows that landed read back intact
        for row in 0..landed {
            assert_eq!(s.read_rows(5, row, 1).unwrap(), vec![row as f64]);
        }
    }

    #[test]
    fn concurrent_disjoint_writers_land_every_row() {
        // N threads interleave writes to disjoint row runs of one block;
        // the stripe protocol must lose nothing and count every row
        let layout = RowBlockLayout::even(64, 4, 1);
        let s = Arc::new(MatrixStore::new(0));
        s.alloc(9, "X", layout, 0, SID).unwrap();
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                // thread t owns rows {t, t+4, t+8, ...}, written one at a time
                let mut row = t;
                while row < 64 {
                    let vals = [row as f64; 4];
                    s.write_rows(9, row, 4, &vals).unwrap();
                    row += 4;
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.seal(9).unwrap(), 64);
        for row in 0..64u64 {
            assert_eq!(s.read_rows(9, row, 1).unwrap(), vec![row as f64; 4]);
        }
    }

    // ---- out-of-core storage plane ----

    /// One layout2() block on slot 0 is 5 rows × 3 cols × 8 B = 120 B.
    const BLOCK_BYTES: u64 = 120;

    fn filled(v: f64) -> LocalMatrix {
        LocalMatrix::from_fn(5, 3, |_, _| v)
    }

    #[test]
    fn insert_over_budget_spills_lru_and_reads_cycle_back() {
        // budget fits exactly two blocks; the third insert must park the
        // least-recently-used one on disk
        let s = budgeted(0, 2 * BLOCK_BYTES);
        s.insert(1, "A", layout2(), filled(1.0), 0, SID).unwrap();
        s.insert(2, "B", layout2(), filled(2.0), 0, SID).unwrap();
        // touch B so A is the LRU victim
        let _ = s.read_rows(2, 0, 1).unwrap();
        s.insert(3, "C", layout2(), filled(3.0), 0, SID).unwrap();
        assert!(s.get(1).unwrap().is_spilled(), "LRU block A should spill");
        assert!(!s.get(2).unwrap().is_spilled());
        assert!(!s.get(3).unwrap().is_spilled());
        let u = s.session_usage(SID);
        assert_eq!(u.bytes_resident, 2 * BLOCK_BYTES);
        assert_eq!(u.bytes_spilled, BLOCK_BYTES);
        assert_eq!(s.spill_segments(), 1);

        // spilled bytes read back intact — transiently (no budget room)
        assert_eq!(s.read_rows(1, 4, 1).unwrap(), vec![1.0, 1.0, 1.0]);
        assert!(s.get(1).unwrap().is_spilled(), "no room: stays spilled");

        // free C → room opens → the next read pages A back in
        assert!(s.free(3));
        assert_eq!(s.read_rows(1, 0, 1).unwrap(), vec![1.0, 1.0, 1.0]);
        assert!(!s.get(1).unwrap().is_spilled(), "page-in should promote");
        assert_eq!(s.spill_segments(), 0);
        let u = s.session_usage(SID);
        assert_eq!(u.bytes_resident, 2 * BLOCK_BYTES);
        assert_eq!(u.bytes_spilled, 0);

        let m = s.storage_metrics().snapshot();
        assert_eq!(m.blocks_spilled, 1);
        assert_eq!(m.bytes_spilled, BLOCK_BYTES);
        assert_eq!(m.blocks_paged_in, 1);
        assert!(m.bytes_read_spilled > 0);
        assert!(m.cycled());
    }

    #[test]
    fn alloc_rejects_what_cannot_fit() {
        // a single allocation bigger than the whole budget is refused
        // up front with an actionable error
        let s = budgeted(0, BLOCK_BYTES - 8);
        let err = s.alloc(1, "X", layout2(), 0, SID).unwrap_err();
        assert!(err.to_string().contains("budget"), "got: {err}");
        assert!(s.is_empty());
        assert_eq!(s.session_usage(SID), SessionUsage::default());
    }

    #[test]
    fn alloc_spills_sealed_blocks_to_make_room() {
        // two sealed blocks fill the budget; a new ingest alloc forces
        // both out (ingest buffers cannot spill, sealed ones must)
        let s = budgeted(0, 2 * BLOCK_BYTES);
        s.insert(1, "A", layout2(), filled(1.0), 0, SID).unwrap();
        s.insert(2, "B", layout2(), filled(2.0), 0, SID).unwrap();
        s.alloc(3, "C", layout2(), 0, SID).unwrap();
        let spilled = [1, 2]
            .iter()
            .filter(|id| s.get(**id).unwrap().is_spilled())
            .count();
        assert_eq!(spilled, 1, "exactly one sealed block makes room");
        // but with only unsealed blocks left, the next alloc cannot fit
        s.alloc(4, "D", layout2(), 0, SID).unwrap();
        assert!(s.alloc(5, "E", layout2(), 0, SID).is_err());
        // sealing C frees nothing (still heap) — sealing makes it
        // spillable, so the alloc now succeeds
        s.seal(3).unwrap();
        s.alloc(5, "E", layout2(), 0, SID).unwrap();
    }

    #[test]
    fn budgets_are_per_session() {
        let s = budgeted(0, BLOCK_BYTES);
        s.insert(1, "A", layout2(), filled(1.0), 0, 100).unwrap();
        // a different session has its own budget: nothing spills
        s.insert(2, "B", layout2(), filled(2.0), 0, 200).unwrap();
        assert!(!s.get(1).unwrap().is_spilled());
        assert!(!s.get(2).unwrap().is_spilled());
        assert_eq!(s.session_usage(100).bytes_resident, BLOCK_BYTES);
        assert_eq!(s.session_usage(200).bytes_resident, BLOCK_BYTES);
    }

    #[test]
    fn free_session_releases_budget_and_spill_segments() {
        // the teardown satellite: budget charges AND spill segments are
        // gone after free_session
        let s = budgeted(0, BLOCK_BYTES);
        s.insert(1, "A", layout2(), filled(1.0), 0, SID).unwrap();
        s.insert(2, "B", layout2(), filled(2.0), 0, SID).unwrap();
        assert_eq!(s.spill_segments(), 1);
        assert_ne!(s.session_usage(SID), SessionUsage::default());
        assert_eq!(s.free_session(SID), 2);
        assert_eq!(s.spill_segments(), 0);
        assert_eq!(s.session_usage(SID), SessionUsage::default());
        assert!(s.usage().is_empty());
        // the freed budget is immediately reusable
        s.insert(3, "C", layout2(), filled(3.0), 0, SID).unwrap();
        assert!(!s.get(3).unwrap().is_spilled());
    }

    #[test]
    fn spilled_snapshot_round_trips_exact_bits() {
        let s = budgeted(0, BLOCK_BYTES);
        let a = LocalMatrix::from_fn(5, 3, |i, j| (i * 3 + j) as f64 * 1.25 - 7.0);
        s.insert(1, "A", layout2(), a.clone(), 0, SID).unwrap();
        s.insert(2, "B", layout2(), filled(0.5), 0, SID).unwrap(); // spills A
        assert!(s.get(1).unwrap().is_spilled());
        let (_, got) = s.get(1).unwrap().snapshot().unwrap();
        assert_eq!(got.data(), a.data(), "spill round-trip must be bit-exact");
    }

    #[test]
    fn concurrent_readers_survive_a_racing_spill() {
        // readers holding Span guards keep valid bytes while the block
        // is evicted under them
        let s = Arc::new(budgeted(0, 2 * BLOCK_BYTES));
        let a = LocalMatrix::from_fn(5, 3, |i, j| (i + j) as f64);
        s.insert(1, "A", layout2(), a.clone(), 0, SID).unwrap();
        let span = s.get(1).unwrap().read_span(0, 5).unwrap(); // pin pre-spill bytes
        s.insert(2, "B", layout2(), filled(1.0), 0, SID).unwrap();
        s.insert(3, "C", layout2(), filled(2.0), 0, SID).unwrap(); // forces A out
        assert!(s.get(1).unwrap().is_spilled());
        assert_eq!(&span[..], a.data(), "guard outlives eviction");
        drop(span);
        // and fresh reads see the same bytes off the spill file
        assert_eq!(s.read_rows(1, 0, 5).unwrap(), a.data());
    }

    // ---- spill crash safety (v10) ----

    #[test]
    fn torn_spill_file_is_rebuilt_on_open() {
        // a garbage file at the spill path (torn write from a crashed
        // predecessor, or a foreign file) must be rebuilt, not trusted
        let path = std::env::temp_dir().join(format!(
            "alchemist-spill-torn-test-p{}.bin",
            std::process::id()
        ));
        std::fs::write(&path, b"definitely not a spill header").unwrap();
        let sf = SpillFile::new(path.clone());
        sf.write_block(1, SID, &[1.5, -2.5, 3.0]).unwrap();
        assert_eq!(sf.read_block_span(1, 0, 3).unwrap(), vec![1.5, -2.5, 3.0]);
        // the rebuilt file leads with the magic and the payload sits
        // past the header
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], &SPILL_MAGIC);
        assert_eq!(bytes.len() as u64, SPILL_HEADER_BYTES + 3 * 8);
        drop(sf); // Drop removes the file it owned
        assert!(!path.exists());
    }

    #[test]
    fn stale_segments_from_a_dead_ledger_are_dropped_on_open() {
        // a well-formed file left by a crashed process: header is kept,
        // stale payload truncated (the in-memory ledger that described
        // it died with its process)
        let path = std::env::temp_dir().join(format!(
            "alchemist-spill-stale-test-p{}.bin",
            std::process::id()
        ));
        {
            let old = SpillFile::new(path.clone());
            old.write_block(7, SID, &[9.0; 64]).unwrap();
            // simulate a crash: forget the ledger without deleting the file
            std::mem::forget(old);
        }
        assert!(std::fs::metadata(&path).unwrap().len() > SPILL_HEADER_BYTES);
        let sf = SpillFile::new(path.clone());
        sf.write_block(1, SID, &[4.0, 5.0]).unwrap();
        // the new segment starts right after the header — stale bytes gone
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            SPILL_HEADER_BYTES + 2 * 8
        );
        assert_eq!(sf.read_block_span(1, 0, 2).unwrap(), vec![4.0, 5.0]);
        drop(sf);
        assert!(!path.exists());
    }

    #[test]
    fn truncated_spill_read_fails_with_torn_diagnosis() {
        // something shortens the file behind the ledger's back: the next
        // read must fail cleanly naming the file torn, not short-read
        let path = std::env::temp_dir().join(format!(
            "alchemist-spill-chop-test-p{}.bin",
            std::process::id()
        ));
        let sf = SpillFile::new(path.clone());
        sf.write_block(1, SID, &[2.0; 8]).unwrap();
        std::fs::OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_len(SPILL_HEADER_BYTES + 8)
            .unwrap();
        let err = sf.read_block_span(1, 0, 8).unwrap_err();
        assert!(err.to_string().contains("torn spill file"), "got: {err}");
    }

    // ---- task-boundary checkpoints (v10) ----

    #[test]
    fn checkpoints_follow_block_lifecycle() {
        let dir = std::env::temp_dir().join(format!(
            "alchemist-ckpt-test-p{}",
            std::process::id()
        ));
        let cfg = StorageConfig {
            budget_bytes: 0,
            total_bytes: 0,
            spill_dir: String::new(),
            checkpoint_dir: dir.display().to_string(),
        };
        let s = MatrixStore::with_storage(0, &cfg, Arc::new(StorageMetrics::new()));

        // push-ingested block: checkpoint appears at seal time
        s.alloc(1, "X", layout2(), 0, SID).unwrap();
        let p1 = checkpoint_path(s.checkpoint_dir(), SID, 1, 0);
        assert!(!p1.exists(), "no checkpoint before seal");
        s.write_rows(1, 0, 3, &[1.25; 15]).unwrap();
        s.seal(1).unwrap();
        assert!(p1.exists(), "seal writes the checkpoint");
        // the file holds exactly this slot's local rows, readable back
        let shard = crate::hdf5sim::read_rows(&p1, 0, 5).unwrap();
        assert_eq!((shard.rows(), shard.cols()), (5, 3));
        assert_eq!(shard.data(), &[1.25; 15]);

        // born-sealed block (routine output): checkpoint appears at insert
        s.insert(2, "Y", layout2(), filled(2.0), 1, SID).unwrap();
        let p2 = checkpoint_path(s.checkpoint_dir(), SID, 2, 1);
        assert!(p2.exists(), "insert checkpoints born-sealed blocks");

        // free removes the block's checkpoint; free_session the rest
        assert!(s.free(1));
        assert!(!p1.exists(), "free removes the checkpoint");
        assert!(p2.exists());
        s.free_session(SID);
        assert!(!p2.exists(), "free_session removes the checkpoint");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[cfg(all(unix, target_endian = "little"))]
    #[test]
    fn mapped_blocks_serve_spans_and_stay_budget_exempt() {
        let dir = std::env::temp_dir().join("alchemist-store-mapped-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("m-{}.h5sim", std::process::id()));
        let m = LocalMatrix::from_fn(10, 3, |i, j| (i * 31 + j) as f64);
        crate::hdf5sim::write_matrix(&path, &m).unwrap();

        // budget smaller than one block: a heap insert would spill, but
        // the mapped block is exempt
        let s = budgeted(0, 8);
        let map = Arc::new(MappedMatrix::open(&path).unwrap());
        s.insert_mapped(1, "A", layout2(), map, 1, SID).unwrap(); // slot 1: rows [5,10)
        let b = s.get(1).unwrap();
        assert!(b.is_mapped());
        assert!(b.sealed());
        assert_eq!(b.rows_received(), 5);
        // global row 7 = file row 7
        assert_eq!(&b.read_span(7, 1).unwrap()[..], m.slice_rows(7, 8).data());
        let (_, local) = b.snapshot().unwrap();
        assert_eq!(local.data(), m.slice_rows(5, 10).data());
        let u = s.session_usage(SID);
        assert_eq!(u.bytes_resident, 0);
        assert_eq!(u.bytes_mapped, 5 * 3 * 8);
        assert_eq!(s.storage_metrics().snapshot().blocks_mapped, 1);
        // out-of-range rows (other slot's) still rejected
        assert!(b.read_span(0, 1).is_err());
        drop(b);
        s.free_session(SID);
        assert_eq!(s.session_usage(SID), SessionUsage::default());
        std::fs::remove_file(&path).ok();
    }
}
